"""Elastic resume overhead: what does a mid-epoch resume actually cost?

Resuming a worker from a :class:`repro.elastic.WorkerCursor` pays three
things on top of the tail it still has to train: loading the checkpoint,
fast-forwarding the chunk stream to the cursor (the first ``cut`` chunks
are extracted and discarded through the normal fill path — the price of
bit-exact replay without persisting raw chunks), and re-jitting the
single-worker epoch. This bench cuts one worker at the midpoint of a
one-epoch run, resumes it, and reports:

* ``train_s`` — wall-clock of the resumed run (load + fast-forward +
  tail training); the number the CI bench-gate regression-tracks as the
  ``elastic_resume`` row of ``BENCH_wallclock.json``;
* ``fast_forward_s`` — the stream fast-forward in isolation (build the
  epoch iterator at ``start_chunk=cut`` and pull the first chunk);
* ``full_run_s`` — the same worker trained uninterrupted, for the
  overhead ratio.
"""

from __future__ import annotations

import tempfile

from benchmarks.common import fixture, timer
from benchmarks.bench_sampling import _cfg, WINDOW, BATCH
from repro.core.driver import prepare_training
from repro.elastic import ElasticRunner, WorkerCursor, WorkerStateStore

WORKERS = 4


def elastic_resume_row(quick=False, steps=None) -> dict:
    """One ``BENCH_wallclock.json`` row (keys ``engine``/``train_s`` as
    the regression gate requires) measuring mid-epoch resume."""
    gen, corpus, _ = fixture()
    steps = steps if steps is not None else (6 if quick else 24)
    setup = prepare_training(
        corpus, gen.vocab_size, "shuffle", WORKERS, _cfg(),
        epochs=1, batch_size=BATCH, rate=1.0 / WORKERS, window=WINDOW,
        max_vocab=None, base_min_count=20, max_steps_per_epoch=steps,
        steps_per_chunk=max(1, steps // 4),
        process_index=0, process_count=1)
    sched = setup.sched
    cut = max(1, sched.num_chunks // 2)

    with tempfile.TemporaryDirectory() as d_full, \
            tempfile.TemporaryDirectory() as d_cut:
        # Uninterrupted reference run of worker 0.
        full_runner = ElasticRunner(setup, WorkerStateStore(d_full),
                                    ckpt_every=1)
        with timer() as t_full:
            full_runner.run_worker(0, resume=False)

        # Train `cut` chunks, then "die" (drop the runner mid-epoch).
        r1 = ElasticRunner(setup, WorkerStateStore(d_cut), ckpt_every=1)
        params, cursor = r1.load_worker(0, resume=False)
        it = None
        for _ in range(cut):
            if it is None:
                it = r1.chunk_iter(0, cursor)
            params = r1.train_chunk(params, cursor, next(it))
            cursor = cursor.advanced(sched)
            if cursor.chunk == 0:
                it = None
            r1._maybe_save(params, cursor, done=cursor.done(1))
        del r1, params, it

        # The measured quantity: a cold process resumes and finishes.
        r2 = ElasticRunner(setup, WorkerStateStore(d_cut), ckpt_every=1)
        with timer() as t_resume:
            r2.run_worker(0, resume=True)

        # Fast-forward in isolation: iterator built at the cut, first
        # chunk pulled (extracts+discards the first `cut` chunks).
        cur = WorkerCursor(worker=0, epoch=0, chunk=cut,
                           step0=sched.step0(0, cut))
        with timer() as t_ff:
            next(r2.chunk_iter(0, cur))

    return {
        "engine": "elastic_resume",
        "workers": 1,
        "steps_per_epoch": int(sched.steps_per_epoch),
        "batch": BATCH,
        "cut_chunk": cut,
        "num_chunks": int(sched.num_chunks),
        "train_s": t_resume.s,
        "projected_parallel_s": t_resume.s,
        "total_s": t_full.s + t_resume.s,
        "fast_forward_s": t_ff.s,
        "full_run_s": t_full.s,
        "resume_over_full": t_resume.s / max(t_full.s, 1e-9),
    }


def main(quick=False):
    row = elastic_resume_row(quick=quick)
    print(f"[elastic] resume-at-chunk-{row['cut_chunk']}/"
          f"{row['num_chunks']}: {row['train_s']:.2f}s "
          f"(fast-forward {row['fast_forward_s']:.2f}s, uninterrupted "
          f"run {row['full_run_s']:.2f}s, ratio "
          f"{row['resume_over_full']:.2f})")
    return row


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
