"""SGNS step micro-benchmark: jnp reference path throughput (CPU-real),
plus Pallas-kernel equivalence check (interpret mode; Mosaic on TPU)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core import sgns
from repro.kernels import ops, ref


def _bench(fn, args, iters=20):
    jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def run(B=1024, K=5, D=512, V=50_000):
    cfg = sgns.SGNSConfig(vocab_size=V, dim=D, negatives=K)
    params = sgns.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, V, B, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, V, B, dtype=np.int32))
    n = jnp.asarray(rng.integers(0, V, (B, K), dtype=np.int32))
    lr = jnp.float32(0.025)

    sparse = jax.jit(sgns.train_step_sparse)
    dense = jax.jit(sgns.train_step_dense.__wrapped__)  # no buffer donation
    us_sparse = _bench(lambda: sparse(params, c, x, n, lr), ())
    us_dense = _bench(lambda: dense(params, c, x, n, lr), ())

    # kernel equivalence (interpret): correctness, not speed, on CPU
    w = params["W"][c]
    cp = params["C"][x]
    cn = params["C"][n]
    lk, dwk, _, _ = ops.sgns_row_grads(w, cp, cn, interpret=True)
    lr_, dwr, _, _ = ref.sgns_row_grads_ref(w, cp, cn)
    err = float(jnp.max(jnp.abs(dwk - dwr)))
    return {
        "us_sparse_step": us_sparse,
        "us_dense_step": us_dense,
        "pairs_per_s_sparse": B / (us_sparse / 1e6),
        "kernel_max_err": err,
    }


def main(quick=False):
    with timer() as t:
        r = run()
    print(f"\n[kernel] SGNS step micro-bench ({t.s:.1f}s)")
    print(f"sparse step: {r['us_sparse_step']:9.1f} µs/call "
          f"({r['pairs_per_s_sparse']:.2e} pairs/s on 1 CPU)")
    print(f"dense  step: {r['us_dense_step']:9.1f} µs/call "
          f"(materializes (V,d) grad — the path the sparse step replaces)")
    print(f"pallas kernel vs oracle max|Δ| = {r['kernel_max_err']:.2e} "
          f"(interpret mode)")
    return r


if __name__ == "__main__":
    main()
