"""SGNS step micro-benchmark: jnp reference path throughput (CPU-real),
Pallas-kernel equivalence check (interpret mode; Mosaic on TPU), and an
update-engine smoke sweep — one timed step per registered engine, so the
benchmark artifact shows every step path (dense / sparse / pallas /
pallas_fused / pallas_fused_hbm / pallas_fused_pipe /
pallas_fused_tiered) side by side, including the blocked HBM-streaming
engines' bit-equivalence against the per-block sparse reference (the
pipelined and tiered engines must match it — and therefore the
unpipelined chain — bit for bit).

A **hot-fraction sweep** times ``pallas_fused_tiered`` over a ladder of
``hot_rows`` settings on a Zipfian pair stream — the VMEM-budget vs
DMA-traffic trade-off curve, landed in the CI bench artifact (a compact
ladder rides in every ``run()``; ``--hot-sweep`` prints a finer
standalone one)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core import sgns
from repro.core.engine import ENGINE_NAMES, get_engine
from repro.data.pairs import build_noise_table
from repro.kernels import ops, ref


def _bench(fn, args, iters=20):
    jax.block_until_ready(fn(*args))  # warmup/compile
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def engine_sweep(cfg, params, c, x, counts, iters=10, specs=ENGINE_NAMES):
    """Time one engine step per spec (same data, own table layout).
    Returns {engine_spec: µs_per_step} — specs may carry a sampler
    suffix ("sparse:alias"), which is honored, not stripped."""
    out = {}
    for name in specs:
        eng = get_engine(name)
        table = build_noise_table(counts, kind=eng.table_kind)
        step = jax.jit(eng.make_step(cfg, total_steps=1000))
        key = jax.random.PRNGKey(3)
        p0 = jax.tree.map(jnp.copy, params)
        us = _bench(lambda: step(p0, c, x, table, key, jnp.int32(1)), (),
                    iters=iters)
        out[str(name)] = us
    return out


def zipf_ids(rng, V, shape, a=1.2):
    """Zipfian ids clipped to the vocab — the skewed stream the hot
    tier is built for (ids are frequency-ranked, so low id = hot)."""
    return jnp.asarray(np.minimum(rng.zipf(a, shape) - 1, V - 1)
                       .astype(np.int32))


def hot_sweep(cfg, params, counts, hots, B=1024, iters=3, seed=7):
    """Time ``pallas_fused_tiered`` at a ladder of ``hot_rows`` settings
    on a Zipfian pair stream (uniform ids would starve the hot tier).
    Returns ``[{"hot_rows": k, "us": µs_per_step}, ...]`` — the
    VMEM-budget/speed trade-off curve; ``hot_rows=0`` is the pure
    pipeline baseline of the same kernel family."""
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    c = zipf_ids(rng, V, B)
    x = zipf_ids(rng, V, B)
    table = build_noise_table(counts, kind="alias")
    rows = []
    for k in hots:
        eng = get_engine("pallas_fused_tiered", hot_rows=int(k))
        step = jax.jit(eng.make_step(cfg, total_steps=1000))
        key = jax.random.PRNGKey(3)
        p0 = jax.tree.map(jnp.copy, params)
        us = _bench(lambda: step(p0, c, x, table, key, jnp.int32(1)), (),
                    iters=iters)
        rows.append({"hot_rows": int(k), "us": us})
    return rows


def run(B=1024, K=5, D=512, V=50_000, quick=False, engines=ENGINE_NAMES):
    cfg = sgns.SGNSConfig(vocab_size=V, dim=D, negatives=K)
    params = sgns.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, V, B, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, V, B, dtype=np.int32))
    n = jnp.asarray(rng.integers(0, V, (B, K), dtype=np.int32))
    lr = jnp.float32(0.025)

    sparse = jax.jit(sgns.train_step_sparse)
    dense = jax.jit(sgns.train_step_dense.__wrapped__)  # no buffer donation
    us_sparse = _bench(lambda: sparse(params, c, x, n, lr), ())
    us_dense = _bench(lambda: dense(params, c, x, n, lr), ())

    # kernel equivalence (interpret): correctness, not speed, on CPU
    w = params["W"][c]
    cp = params["C"][x]
    cn = params["C"][n]
    lk, dwk, _, _ = ops.sgns_row_grads(w, cp, cn, interpret=True)
    lr_, dwr, _, _ = ref.sgns_row_grads_ref(w, cp, cn)
    err = float(jnp.max(jnp.abs(dwk - dwr)))

    # fused engine vs sparse reference, identical negatives (replayed
    # from the kernel's counter PRNG) — end-to-end step equivalence
    counts = rng.zipf(1.3, V).astype(np.float64)
    eng_f = get_engine("pallas_fused")
    table = build_noise_table(counts, kind="alias")
    key = jax.random.PRNGKey(9)
    pf, _ = eng_f.make_step(cfg, 1000)(
        jax.tree.map(jnp.copy, params), c, x, table, key, jnp.int32(0))
    ids = eng_f.sample(table, key, (B, K))
    ps, _ = sgns.train_step_sparse(jax.tree.map(jnp.copy, params), c, x, ids,
                                   jnp.float32(cfg.lr))
    fused_err = float(jnp.max(jnp.abs(pf["W"] - ps["W"])))

    # HBM-blocked fused engine vs the per-block sparse reference on the
    # same replayed negatives — the blocked step must be *bit-identical*
    eng_h = get_engine("pallas_fused_hbm")
    blk = eng_h.block_pairs
    ph, _ = eng_h.make_step(cfg, 1000)(
        jax.tree.map(jnp.copy, params), c, x, table, key, jnp.int32(0))
    sparse_jit = jax.jit(sgns.train_step_sparse)
    pr = jax.tree.map(jnp.copy, params)
    lr0 = sgns.linear_lr(jnp.int32(0), 1000, cfg)
    for b0 in range(0, B, blk):
        pr, _ = sparse_jit(pr, c[b0:b0 + blk], x[b0:b0 + blk],
                           ids[b0:b0 + blk], lr0)
    hbm_err = float(max(jnp.max(jnp.abs(ph["W"] - pr["W"])),
                        jnp.max(jnp.abs(ph["C"] - pr["C"]))))

    # pipelined HBM engine vs the same per-block sparse reference — the
    # DMA pipeline (dedup + overlap + hazard ordering) must not move a
    # single bit relative to the serial chain
    eng_p = get_engine("pallas_fused_pipe")
    pp, _ = eng_p.make_step(cfg, 1000)(
        jax.tree.map(jnp.copy, params), c, x, table, key, jnp.int32(0))
    pipe_err = float(max(jnp.max(jnp.abs(pp["W"] - pr["W"])),
                         jnp.max(jnp.abs(pp["C"] - pr["C"]))))

    # frequency-tiered engine vs the same reference — tier routing must
    # be bit-invisible too (the hot prefix is genuinely touched: the
    # noise draw is Zipfian over frequency-ranked ids)
    eng_t = get_engine("pallas_fused_tiered")
    pt, _ = eng_t.make_step(cfg, 1000)(
        jax.tree.map(jnp.copy, params), c, x, table, key, jnp.int32(0))
    tiered_err = float(max(jnp.max(jnp.abs(pt["W"] - pr["W"])),
                           jnp.max(jnp.abs(pt["C"] - pr["C"]))))

    engine_us = engine_sweep(cfg, params, c, x, counts,
                             iters=3 if quick else 10, specs=engines)
    sweep = hot_sweep(cfg, params, counts,
                      hots=(0, 256, 4096) if quick else (0, 64, 256, 1024,
                                                         4096, V),
                      B=B, iters=2 if quick else 5)
    return {
        "us_sparse_step": us_sparse,
        "us_dense_step": us_dense,
        "pairs_per_s_sparse": B / (us_sparse / 1e6),
        "kernel_max_err": err,
        "fused_vs_sparse_err": fused_err,
        "fused_hbm_vs_sparse_err": hbm_err,
        "fused_pipe_vs_sparse_err": pipe_err,
        "fused_tiered_vs_sparse_err": tiered_err,
        "engine_us": engine_us,
        "tiered_hot_sweep": sweep,
        "B": B,
    }


def main(quick=False, engine=None):
    """``engine`` (name or spec string) restricts the sweep to one
    engine — ``python -m benchmarks.bench_kernel --engine pallas_fused``."""
    if engine is not None:
        get_engine(engine)                  # validate the spec up front
    specs = ENGINE_NAMES if engine is None else (engine,)
    with timer() as t:
        r = run(quick=quick, engines=specs)
    print(f"\n[kernel] SGNS step micro-bench ({t.s:.1f}s)")
    print(f"sparse step: {r['us_sparse_step']:9.1f} µs/call "
          f"({r['pairs_per_s_sparse']:.2e} pairs/s on 1 CPU)")
    print(f"dense  step: {r['us_dense_step']:9.1f} µs/call "
          f"(materializes (V,d) grad — the path the sparse step replaces)")
    print(f"pallas kernel vs oracle max|Δ| = {r['kernel_max_err']:.2e} "
          f"(interpret mode)")
    print(f"pallas_fused step vs sparse ref max|Δ| = "
          f"{r['fused_vs_sparse_err']:.2e} (same in-kernel negatives)")
    print(f"pallas_fused_hbm step vs per-block sparse ref max|Δ| = "
          f"{r['fused_hbm_vs_sparse_err']:.2e} "
          f"(HBM tables, DMA-gathered rows; bit-identical by contract)")
    print(f"pallas_fused_pipe step vs per-block sparse ref max|Δ| = "
          f"{r['fused_pipe_vs_sparse_err']:.2e} "
          f"(pipelined DMA, deduped rows; bit-identical by contract)")
    print(f"pallas_fused_tiered step vs per-block sparse ref max|Δ| = "
          f"{r['fused_tiered_vs_sparse_err']:.2e} "
          f"(VMEM hot prefix + cold DMA ring; bit-identical by contract)")
    for name, us in r["engine_us"].items():
        print(f"engine {name:12s}: {us:9.1f} µs/step "
              f"({r['B'] / (us / 1e6):.2e} pairs/s)")
    print("tiered hot-fraction sweep (Zipfian stream; hot_rows → µs/step):")
    for row in r["tiered_hot_sweep"]:
        print(f"  hot_rows {row['hot_rows']:6d}: {row['us']:9.1f} µs/step "
              f"({r['B'] / (row['us'] / 1e6):.2e} pairs/s)")
    return r


def main_hot_sweep(quick=False, B=1024, K=5, D=512, V=50_000):
    """Standalone fine-grained hot-fraction ladder — the VMEM-budget vs
    speed trade-off of ``pallas_fused_tiered`` on a Zipfian stream."""
    cfg = sgns.SGNSConfig(vocab_size=V, dim=D, negatives=K)
    params = sgns.init_params(jax.random.PRNGKey(0), cfg)
    counts = np.random.default_rng(0).zipf(1.3, V).astype(np.float64)
    hots = (0, 256, 4096) if quick else (0, 16, 64, 256, 1024, 4096,
                                         16_384, V)
    with timer() as t:
        rows = hot_sweep(cfg, params, counts, hots, B=B,
                         iters=2 if quick else 5)
    print(f"\n[kernel] pallas_fused_tiered hot-fraction sweep "
          f"(V={V}, d={D}, B={B}, Zipfian ids; {t.s:.1f}s)")
    for row in rows:
        vmem_mb = 2 * row["hot_rows"] * D * 4 / 1e6
        print(f"  hot_rows {row['hot_rows']:6d} "
              f"({vmem_mb:7.2f} MB VMEM): {row['us']:9.1f} µs/step "
              f"({B / (row['us'] / 1e6):.2e} pairs/s)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default=None,
                    help="time only this engine's step (dense | sparse | "
                         "pallas | pallas_fused | pallas_fused_hbm | "
                         "pallas_fused_pipe | pallas_fused_tiered)")
    ap.add_argument("--hot-sweep", action="store_true",
                    help="run only the fine-grained pallas_fused_tiered "
                         "hot-fraction ladder (VMEM budget vs µs/step)")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    if a.hot_sweep:
        main_hot_sweep(quick=a.quick)
    else:
        main(quick=a.quick, engine=a.engine)
