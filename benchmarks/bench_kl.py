"""Paper Figure 1: KL divergence of sub-corpus unigram/bigram
distributions to the full corpus — RANDOM SAMPLING vs EQUAL PARTITIONING
(and SHUFFLE, averaged over epochs)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import fixture, timer
from repro.core.sampling import sample_sentence_indices
from repro.core.distributions import (
    unigram_distribution, bigram_distribution,
    kl_divergence_dense, kl_divergence_sparse)


def run(num_workers: int = 10, workers_to_probe: int = 10):
    gen, corpus, _ = fixture()
    V = gen.vocab_size
    ref_u = unigram_distribution(corpus, V)
    ref_b = bigram_distribution(corpus, V)
    rate = 1.0 / num_workers

    rows = []
    with timer() as t:
        for strategy in ("equal", "random", "shuffle"):
            kls_u, kls_b = [], []
            for w in range(workers_to_probe):
                epoch = w % 3 if strategy == "shuffle" else 0
                idx = sample_sentence_indices(
                    corpus.num_sentences, strategy, rate, w, num_workers,
                    epoch=epoch, seed=5)
                sub = corpus.select(idx)
                kls_u.append(kl_divergence_dense(
                    unigram_distribution(sub, V), ref_u))
                kls_b.append(kl_divergence_sparse(
                    bigram_distribution(sub, V), ref_b))
            rows.append({
                "strategy": strategy,
                "kl_unigram": float(np.mean(kls_u)),
                "kl_bigram": float(np.mean(kls_b)),
            })
    return rows, t.s


def main():
    rows, secs = run()
    print(f"\n[Fig 1] sub-corpus→corpus KL divergence ({secs:.1f}s)")
    print(f"{'strategy':10s} {'KL(unigram)':>12s} {'KL(bigram)':>12s}")
    for r in rows:
        print(f"{r['strategy']:10s} {r['kl_unigram']:12.4f} {r['kl_bigram']:12.4f}")
    eq = next(r for r in rows if r["strategy"] == "equal")
    rnd = next(r for r in rows if r["strategy"] == "random")
    claim = rnd["kl_unigram"] < eq["kl_unigram"] and rnd["kl_bigram"] < eq["kl_bigram"]
    print(f"paper claim (random << equal): {'CONFIRMED' if claim else 'REFUTED'}")
    return rows


if __name__ == "__main__":
    main()
