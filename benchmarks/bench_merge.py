"""Paper Table 3: merging methods (Concat / PCA / ALiR-rand / ALiR-PCA /
log-depth ALiR tree / single sub-model / naive average) at fixed Shuffle
sampling — plus the worker-count sweep comparing the flat batch ALiR
solve against the reduction tree (``tree_sweep``): serial wallclock,
critical-path wallclock (what a cluster pays when a tree level's nodes
run concurrently), and the peak solve working set."""

from __future__ import annotations

import numpy as np

from benchmarks.common import fixture, timer
from benchmarks.bench_sampling import _cfg, WINDOW, EPOCHS, BATCH
from repro.core.driver import run_pipeline
from repro.eval.benchmarks import evaluate_all

METHODS = ("concat", "pca", "alir_rand", "alir_pca", "alir_tree",
           "average", "single")


def run(rate=0.1, quick=False):
    gen, corpus, suite = fixture()
    n = int(round(1 / rate))
    rows = []
    with timer() as t:
        res = run_pipeline(
            corpus, gen.vocab_size, strategy="shuffle", num_workers=n,
            cfg=_cfg(), epochs=EPOCHS, batch_size=BATCH, rate=rate,
            window=WINDOW, max_vocab=None, base_min_count=20,
            merge_methods=METHODS,
            max_steps_per_epoch=120 if quick else 400)
        for m in METHODS:
            emb, valid = res.merged[m]
            scores = evaluate_all(emb, valid, res.union_vocab, suite)
            rows.append({"method": m, "rate": rate, **scores,
                         "merge_s": res.timings.get(f"merge_{m}_s", 0.0),
                         "dim": emb.shape[1]})
    return rows, t.s


def fmt(rows):
    out = [f"{'method':10s} {'dim':>5s} {'sim(oov)':>12s} {'analogy(oov)':>13s}"
           f" {'categ(oov)':>12s} {'merge_s':>8s}"]
    for r in rows:
        out.append(
            f"{r['method']:10s} {r['dim']:5d} "
            f"{r['similarity']:6.3f}({r['similarity_oov']:3d}) "
            f"{r['analogy']:7.3f}({r['analogy_oov']:3d}) "
            f"{r['categorization']:6.3f}({r['categorization_oov']:3d}) "
            f"{r['merge_s']:8.2f}")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# Worker-count sweep: flat batch ALiR vs the log-depth reduction tree.
# ---------------------------------------------------------------------------
def _synthetic_stack(n, V, d, seed=0):
    """n rotated copies of one truth table with ~25% missing rows — the
    exact data model ALiR assumes, at a controllable worker count."""
    from repro.core import merge as mg

    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        mask = np.ones(V, bool) if i == 0 else rng.random(V) >= 0.25
        mask[: d + 2] = True
        M = (Y @ q).astype(np.float32)
        M[~mask] = 0.0
        models.append(M)
        masks.append(mask)
    return mg.stack_models(models, masks)


def tree_sweep(worker_counts=(8, 32, 128), fan_in=2, V=1024, dim=32,
               max_iters=8, quick=False):
    """Sweep sub-model count: flat batch solve vs reduction tree.

    Columns per count: ``flat_s`` (the O(W) batch solve), ``tree_s``
    (tree, all nodes solved serially — the single-host cost),
    ``tree_critical_s`` (sum over levels of the slowest node — the
    cluster cost when each level's nodes run concurrently), ``depth``,
    and the peak **solve working set** in MB: the flat solve stacks all
    W tables (W·V·d·4 bytes); a tree node only ever holds its fan_in
    children (fan_in·V·d·4) — the memory term that lets production-vocab
    merges fit at all."""
    from repro.core import merge as mg
    from repro.core.merge_tree import build_tree, tree_depth

    if quick:
        worker_counts = tuple(w for w in worker_counts if w <= 32)
    rows = []
    for n in worker_counts:
        stacked = _synthetic_stack(n, V, dim)
        flat = mg.get_merger("alir", max_iters=max_iters)
        with timer() as t_flat:
            flat.merge(stacked)
        tree = mg.get_merger("alir_tree", fan_in=fan_in,
                             max_iters=max_iters)
        with timer() as t_tree:
            tree.merge(stacked)
        rows.append({
            "workers": n, "fan_in": fan_in, "V": V, "dim": dim,
            "flat_s": t_flat.s,
            "tree_s": t_tree.s,
            "tree_critical_s": tree.critical_path_s(),
            "depth": tree_depth(build_tree(range(n), fan_in)),
            "nodes_solved": tree.stats["solved"],
            "flat_peak_mb": n * V * dim * 4 / 1e6,
            "tree_peak_mb": fan_in * V * dim * 4 / 1e6,
        })
    return rows


def fmt_sweep(rows):
    out = [f"{'workers':>7s} {'depth':>5s} {'flat_s':>8s} {'tree_s':>8s}"
           f" {'critical_s':>10s} {'flat_MB':>8s} {'tree_MB':>8s}"]
    for r in rows:
        out.append(
            f"{r['workers']:7d} {r['depth']:5d} {r['flat_s']:8.2f} "
            f"{r['tree_s']:8.2f} {r['tree_critical_s']:10.2f} "
            f"{r['flat_peak_mb']:8.1f} {r['tree_peak_mb']:8.1f}")
    return "\n".join(out)


def merge_tree_row(quick=False):
    """The gated BENCH_wallclock.json row: the reduction tree's
    critical-path wallclock at a fixed 32-sub-model shape (vs the flat
    solve's, carried alongside for the trajectory)."""
    n = 16 if quick else 32
    r = tree_sweep(worker_counts=(n,), quick=False)[0]
    return {
        "engine": "merge_tree",
        "workers": r["workers"],
        "fan_in": r["fan_in"],
        "depth": r["depth"],
        "train_s": r["tree_critical_s"],
        "tree_serial_s": r["tree_s"],
        "flat_s": r["flat_s"],
        "tree_peak_mb": r["tree_peak_mb"],
        "flat_peak_mb": r["flat_peak_mb"],
    }


def main(quick=False):
    rows, secs = run(quick=quick)
    print(f"\n[Table 3] merge methods at shuffle/10% ({secs:.1f}s)")
    print(fmt(rows))
    by = {r["method"]: r for r in rows}
    alir = max(by["alir_pca"]["similarity"], by["alir_rand"]["similarity"])
    print(f"ALiR vs naive average (sim): {alir:.3f} vs "
          f"{by['average']['similarity']:.3f} "
          f"(paper: averaging fails without alignment) "
          f"{'CONFIRMED' if alir > by['average']['similarity'] else 'REFUTED'}")
    print(f"merged vs single sub-model (sim): {alir:.3f} vs "
          f"{by['single']['similarity']:.3f} "
          f"{'CONFIRMED' if alir > by['single']['similarity'] else 'REFUTED'}")

    sweep = tree_sweep(quick=quick)
    print("\nflat batch ALiR vs reduction tree (synthetic rotated "
          "sub-models):")
    print(fmt_sweep(sweep))
    last = sweep[-1]
    print(f"tree critical path at {last['workers']} workers: "
          f"{last['tree_critical_s']:.2f}s vs flat {last['flat_s']:.2f}s; "
          f"peak solve working set {last['tree_peak_mb']:.1f} MB vs "
          f"{last['flat_peak_mb']:.1f} MB")
    return rows


if __name__ == "__main__":
    main()
