"""Paper Table 3: merging methods (Concat / PCA / ALiR-rand / ALiR-PCA /
single sub-model / naive average) at fixed Shuffle sampling."""

from __future__ import annotations

import numpy as np

from benchmarks.common import fixture, timer
from benchmarks.bench_sampling import _cfg, WINDOW, EPOCHS, BATCH
from repro.core.driver import run_pipeline
from repro.eval.benchmarks import evaluate_all

METHODS = ("concat", "pca", "alir_rand", "alir_pca", "average", "single")


def run(rate=0.1, quick=False):
    gen, corpus, suite = fixture()
    n = int(round(1 / rate))
    rows = []
    with timer() as t:
        res = run_pipeline(
            corpus, gen.vocab_size, strategy="shuffle", num_workers=n,
            cfg=_cfg(), epochs=EPOCHS, batch_size=BATCH, rate=rate,
            window=WINDOW, max_vocab=None, base_min_count=20,
            merge_methods=METHODS,
            max_steps_per_epoch=120 if quick else 400)
        for m in METHODS:
            emb, valid = res.merged[m]
            scores = evaluate_all(emb, valid, res.union_vocab, suite)
            rows.append({"method": m, "rate": rate, **scores,
                         "merge_s": res.timings.get(f"merge_{m}_s", 0.0),
                         "dim": emb.shape[1]})
    return rows, t.s


def fmt(rows):
    out = [f"{'method':10s} {'dim':>5s} {'sim(oov)':>12s} {'analogy(oov)':>13s}"
           f" {'categ(oov)':>12s} {'merge_s':>8s}"]
    for r in rows:
        out.append(
            f"{r['method']:10s} {r['dim']:5d} "
            f"{r['similarity']:6.3f}({r['similarity_oov']:3d}) "
            f"{r['analogy']:7.3f}({r['analogy_oov']:3d}) "
            f"{r['categorization']:6.3f}({r['categorization_oov']:3d}) "
            f"{r['merge_s']:8.2f}")
    return "\n".join(out)


def main(quick=False):
    rows, secs = run(quick=quick)
    print(f"\n[Table 3] merge methods at shuffle/10% ({secs:.1f}s)")
    print(fmt(rows))
    by = {r["method"]: r for r in rows}
    alir = max(by["alir_pca"]["similarity"], by["alir_rand"]["similarity"])
    print(f"ALiR vs naive average (sim): {alir:.3f} vs "
          f"{by['average']['similarity']:.3f} "
          f"(paper: averaging fails without alignment) "
          f"{'CONFIRMED' if alir > by['average']['similarity'] else 'REFUTED'}")
    print(f"merged vs single sub-model (sim): {alir:.3f} vs "
          f"{by['single']['similarity']:.3f} "
          f"{'CONFIRMED' if alir > by['single']['similarity'] else 'REFUTED'}")
    return rows


if __name__ == "__main__":
    main()
