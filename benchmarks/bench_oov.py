"""Paper Figure 3: robustness to missing vocabulary.

Remove k% of the benchmark's unique words from a random non-empty subset
of sub-models (each removed word survives in ≥1 model, as in the paper),
then merge with ALiR / Concat / PCA and re-evaluate. ALiR reconstructs
the missing rows; Concat/PCA lose them from the intersection."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import fixture, timer
from benchmarks.bench_sampling import _cfg, WINDOW, EPOCHS, BATCH
from repro.core.driver import run_pipeline
from repro.core.merge import StackedModels, merge as merge_models
from repro.data.vocab import UNK
from repro.eval.benchmarks import evaluate_all

METHODS = ("alir_pca", "concat", "pca")


def _benchmark_words(suite):
    return np.unique(np.concatenate([
        suite.sim_a, suite.sim_b, suite.quads.reshape(-1), suite.cat_words]))


def knock_out(stacked: StackedModels, vocab, words_raw, frac: float, seed=0):
    """Mask ``frac`` of benchmark words out of random model subsets."""
    rng = np.random.default_rng(seed)
    mask = np.asarray(stacked.mask).copy()
    n = stacked.n
    ids = vocab.encode(words_raw)
    ids = ids[ids != UNK]
    chosen = rng.choice(ids, size=max(1, int(frac * len(ids))), replace=False)
    for v in chosen:
        # remove from a random non-empty strict subset of models
        k = int(rng.integers(1, n))          # 1..n-1 models lose the word
        lose = rng.choice(n, size=k, replace=False)
        mask[lose, v] = False
        if not mask[:, v].any():             # keep ≥ 1 holder
            mask[rng.integers(0, n), v] = True
    models = np.asarray(stacked.models) * mask[..., None]
    return StackedModels(models=jnp.asarray(models), mask=jnp.asarray(mask))


def run(fracs=(0.0, 0.1, 0.5), rate=0.1, quick=False, seed=3):
    gen, corpus, suite = fixture()
    n = int(round(1 / rate))
    rows = []
    with timer() as t:
        res = run_pipeline(
            corpus, gen.vocab_size, strategy="shuffle", num_workers=n,
            cfg=_cfg(), epochs=EPOCHS, batch_size=BATCH, rate=rate,
            window=WINDOW, max_vocab=None, base_min_count=20,
            merge_methods=(),
            max_steps_per_epoch=120 if quick else 400)
        words = _benchmark_words(suite)
        for frac in fracs:
            stacked = (res.stacked if frac == 0.0 else
                       knock_out(res.stacked, res.union_vocab, words, frac,
                                 seed=seed))
            for m in METHODS:
                emb, valid = merge_models(stacked, m, out_dim=_cfg().dim,
                                          key=None if m != "alir_rand" else None)
                scores = evaluate_all(np.asarray(emb), np.asarray(valid),
                                      res.union_vocab, suite)
                rows.append({"removed_frac": frac, "method": m, **scores})
    return rows, t.s


def fmt(rows):
    out = [f"{'removed':>8s} {'method':10s} {'sim(oov)':>12s} "
           f"{'analogy(oov)':>13s} {'categ(oov)':>12s}"]
    for r in rows:
        out.append(
            f"{r['removed_frac']:8.0%} {r['method']:10s} "
            f"{r['similarity']:6.3f}({r['similarity_oov']:3d}) "
            f"{r['analogy']:7.3f}({r['analogy_oov']:3d}) "
            f"{r['categorization']:6.3f}({r['categorization_oov']:3d})")
    return "\n".join(out)


def main(quick=False):
    rows, secs = run(quick=quick)
    print(f"\n[Fig 3] OOV-reconstruction robustness ({secs:.1f}s)")
    print(fmt(rows))
    at50 = {r["method"]: r for r in rows if r["removed_frac"] == 0.5}
    if at50:
        a, c = at50["alir_pca"], at50["concat"]
        drop_claim = (a["similarity"] >= c["similarity"] and
                      a["similarity_oov"] <= c["similarity_oov"])
        print(f"@50% removal ALiR sim={a['similarity']:.3f}"
              f"(oov {a['similarity_oov']}) vs Concat {c['similarity']:.3f}"
              f"(oov {c['similarity_oov']}) — paper claim "
              f"{'CONFIRMED' if drop_claim else 'REFUTED'}")
    return rows


if __name__ == "__main__":
    main()
