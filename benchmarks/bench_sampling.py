"""Paper Table 2: sampling strategies (equal / random / shuffle) × rates,
merged with ALiR(PCA), vs the synchronized single-model baseline.

Scores are similarity (Spearman ρ), analogy (3CosAdd acc) and
categorization (purity) on the synthetic gold suites, with OOV counts in
parentheses exactly as the paper reports them.

Also hosts the negative-sampler micro-bench: inverse-CDF
(O(log V) searchsorted) vs Vose alias table (O(1), two gathers) per
draw, at word2vec-scale vocabularies."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import fixture, timer
from repro.core.driver import run_pipeline, train_sync_baseline
from repro.core.sgns import SGNSConfig
from repro.data.pairs import AliasSampler, NegativeSampler
from repro.eval.benchmarks import evaluate_all

DIM = 64
WINDOW = 5
EPOCHS = 6
BATCH = 512


def _cfg():
    return SGNSConfig(vocab_size=0, dim=DIM, window=WINDOW, negatives=5)


def eval_merged(res, suite, method="alir_pca"):
    emb, valid = res.merged[method]
    return evaluate_all(emb, valid, res.union_vocab, suite)


def run(rates=(0.1,), num_workers_by_rate=None, quick=False):
    gen, corpus, suite = fixture()
    rows = []
    with timer() as t:
        for rate in rates:
            n = int(round(1 / rate))
            for strategy in ("equal", "random", "shuffle"):
                res = run_pipeline(
                    corpus, gen.vocab_size, strategy=strategy, num_workers=n,
                    cfg=_cfg(), epochs=EPOCHS, batch_size=BATCH, rate=rate,
                    window=WINDOW, max_vocab=None, base_min_count=20,
                    merge_methods=("alir_pca",),
                    max_steps_per_epoch=120 if quick else 400)
                scores = eval_merged(res, suite)
                rows.append({"strategy": strategy, "rate": rate, **scores,
                             "train_s": res.timings["train_s"]})
        # synchronized baseline (Hogwild stand-in)
        params, vocab, info = train_sync_baseline(
            corpus, gen.vocab_size, _cfg(), epochs=EPOCHS, batch_size=BATCH,
            window=WINDOW, max_vocab=None,
            max_steps_per_epoch=400 if quick else 1600)
        import numpy as np
        emb = np.asarray(params["W"])
        valid = np.ones(vocab.size, bool)
        scores = evaluate_all(emb, valid, vocab, suite)
        rows.append({"strategy": "sync-baseline", "rate": 1.0, **scores,
                     "train_s": info["train_s"]})
    return rows, t.s


def fmt(rows):
    out = [f"{'strategy':14s} {'rate':>5s} {'sim(oov)':>12s} {'analogy(oov)':>13s}"
           f" {'categ(oov)':>12s} {'train_s':>8s}"]
    for r in rows:
        out.append(
            f"{r['strategy']:14s} {r['rate']:5.2f} "
            f"{r['similarity']:6.3f}({r['similarity_oov']:3d}) "
            f"{r['analogy']:7.3f}({r['analogy_oov']:3d}) "
            f"{r['categorization']:6.3f}({r['categorization_oov']:3d}) "
            f"{r['train_s']:8.1f}")
    return "\n".join(out)


def negative_sampler_microbench(
    vocab_sizes=(10_000, 100_000), batch=4096, negatives=5, reps=50,
    quick=False):
    """us/draw-batch and speedup of alias over inverse-CDF per vocab size."""
    if quick:
        vocab_sizes, reps = (100_000,), 20
    rng = np.random.default_rng(0)
    rows = []
    for V in vocab_sizes:
        counts = rng.zipf(1.3, V).astype(np.float64)
        samplers = {"cdf": NegativeSampler(counts), "alias": AliasSampler(counts)}
        us = {}
        for name, s in samplers.items():
            fn = jax.jit(lambda k, s=s: s.sample(k, (batch, negatives)))
            key = jax.random.PRNGKey(0)
            fn(key).block_until_ready()
            t0 = time.perf_counter()
            for i in range(reps):
                key = jax.random.fold_in(key, i)
                fn(key).block_until_ready()
            us[name] = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"V": V, "us_cdf": us["cdf"], "us_alias": us["alias"],
                     "speedup": us["cdf"] / us["alias"]})
    return rows


def fmt_microbench(rows):
    out = [f"{'V':>8s} {'cdf_us':>9s} {'alias_us':>9s} {'speedup':>8s}"]
    for r in rows:
        out.append(f"{r['V']:8d} {r['us_cdf']:9.1f} {r['us_alias']:9.1f} "
                   f"{r['speedup']:7.2f}x")
    return "\n".join(out)


def main(quick=False):
    rates = (0.1,) if quick else (0.1, 0.05)
    rows, secs = run(rates=rates, quick=quick)
    print(f"\n[Table 2] sampling strategies ({secs:.1f}s)")
    print(fmt(rows))

    micro = negative_sampler_microbench(quick=quick)
    print("\n[micro] negative draws, batch 4096 × 5 (CDF vs alias)")
    print(fmt_microbench(micro))

    def get(strat, rate):
        return next(r for r in rows if r["strategy"] == strat
                    and abs(r["rate"] - rate) < 1e-9)
    sh, rnd, eq = get("shuffle", 0.1), get("random", 0.1), get("equal", 0.1)
    wins_sh_rnd = sum(sh[k] >= rnd[k] for k in
                      ("similarity", "analogy", "categorization"))
    print(f"shuffle >= random on {wins_sh_rnd}/3 tasks "
          f"(paper: shuffle wins nearly all)")
    return rows


if __name__ == "__main__":
    main()
