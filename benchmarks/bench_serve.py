"""Serving-tier benchmark: lookup latency under a concurrent workload.

Publishes a synthetic artifact (no training — the read path is what is
being measured), then drives the :class:`~repro.serve.EmbeddingServer`
with Zipf-distributed concurrent clients, a quarter of them querying in
sub-model space (the on-the-fly reconstruction path). Reports p50/p99
per-lookup latency (submit→resolve through the coalescer; cache hits
bypass it and are counted in the hit rate instead), the mean coalesced
batch size, and throughput.

The row rides in ``BENCH_wallclock.json`` as ``{"engine": "serve"}``
next to the update-engine rows, so the CI bench-gate
(``benchmarks.check_regression``) covers serving regressions with the
same machine-normalized threshold as training ones.
"""

from __future__ import annotations

import asyncio
import tempfile
import time

import numpy as np

from repro.checkpoint import publish_table
from repro.serve import EmbeddingServer, ServeConfig

N_MODELS = 4
ZIPF_A = 1.3          # benchmark-query popularity skew


def _publish_synthetic(artifact_dir: str, V: int, d: int, n: int,
                       seed: int = 0) -> None:
    """A fully-sidecarred artifact with per-model holes, straight from
    random data — table contents don't affect read-path timing."""
    rng = np.random.default_rng(seed)
    emb = rng.normal(size=(V, d)).astype(np.float32)
    mask = rng.random((n, V)) > 0.3
    mask[0] = True
    qs = [np.linalg.qr(rng.normal(size=(d, d)))[0].astype(np.float32)
          for _ in range(n)]
    transforms = np.stack(qs)
    models = np.stack([(emb @ q) * m[:, None]
                       for q, m in zip(qs, mask.astype(np.float32))])
    publish_table(artifact_dir, emb, np.ones(V, bool),
                  worker_ids=np.arange(n, dtype=np.int32), mask=mask,
                  transforms=transforms, models=models,
                  meta={"synthetic": True})


async def _client(server: EmbeddingServer, seed: int, requests: int,
                  batch: int, V: int, submodel: int | None) -> None:
    rng = np.random.default_rng(seed)
    for _ in range(requests):
        rows = np.minimum(rng.zipf(ZIPF_A, size=batch) - 1, V - 1)
        await server.embed_rows(rows, submodel=submodel)


def serve_row(quick: bool = False) -> dict:
    """One bench-gate row for the serving workload (train_s = wall)."""
    V, d = (2_000, 32) if quick else (4_000, 64)
    clients = 16 if quick else 32
    requests = 4 if quick else 8
    batch = 64
    cfg = ServeConfig(coalesce_ms=0.5, max_batch=1024, cache_rows=V // 4)

    with tempfile.TemporaryDirectory() as td:
        _publish_synthetic(td, V, d, N_MODELS)

        async def go():
            server = EmbeddingServer(td, cfg)
            t0 = time.perf_counter()
            await asyncio.gather(*(
                _client(server, 100 + c, requests, batch, V,
                        submodel=(c % N_MODELS) if c % 4 == 0 else None)
                for c in range(clients)))
            return time.perf_counter() - t0, server.stats()

        wall, stats = asyncio.run(go())

    lookups = clients * requests * batch
    return {
        "engine": "serve",
        "clients": clients,
        "lookups": lookups,
        "rows": V,
        "dim": d,
        "train_s": wall,                     # the gate's compared field
        "lookups_per_s": lookups / max(wall, 1e-9),
        "p50_ms": stats["p50_ms"],
        "p99_ms": stats["p99_ms"],
        "mean_batch": stats["mean_batch"],
        "dispatches": stats["dispatches"],
        "cache_hit_rate": stats["cache_hit_rate"],
    }


def main(quick: bool = False) -> dict:
    row = serve_row(quick=quick)
    print(f"[serve] {row['lookups']} lookups ({row['clients']} clients, "
          f"{row['rows']}×{row['dim']} table) in {row['train_s']:.2f}s "
          f"→ {row['lookups_per_s']:.0f} lookups/s")
    print(f"        p50 {row['p50_ms']:.2f} ms  p99 {row['p99_ms']:.2f} ms  "
          f"mean batch {row['mean_batch']:.1f}  "
          f"cache hit rate {row['cache_hit_rate']:.2f}")
    return row


if __name__ == "__main__":
    main()
