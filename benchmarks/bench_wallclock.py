"""Paper Table 4 + Figure 2: wall-clock scaling.

On one CPU device we measure real compute and report:
  * sync baseline epoch time (the Hogwild/MLLib stand-in);
  * total async time for n sub-models trained back-to-back (vmap) and
    the PROJECTED parallel time = total/n + merge (each sub-model is an
    independent worker in the paper's cluster — measured compute is the
    honest per-worker cost, there is zero inter-worker traffic to model);
  * merge times (PCA / ALiR), the paper's "few minutes" claim;
  * near-linear scaling of training time with corpus fraction (Fig 2);
  * one wall-clock row PER UPDATE ENGINE (dense/sparse/pallas/
    pallas_fused/pallas_fused_hbm/pallas_fused_pipe/pallas_fused_tiered)
    through the full streamed driver, a pair of ``<engine>@zipf50k``
    direct-step rows (V=50k×512, Zipfian ids) carrying the
    planner-derived HBM row-traffic columns the tiered engine
    optimizes (see ``zipf_kernel_rows``), plus one ``serve`` row for
    the read path (``benchmarks.bench_serve``), one ``elastic_resume``
    row and one ``merge_tree`` row (the reduction-tree merge's
    critical-path wallclock, ``benchmarks.bench_merge.merge_tree_row``)
    — written to ``BENCH_wallclock.json``
    (CI uploads
    it as an artifact next to the CSV summary; override the path with
    ``REPRO_BENCH_WALLCLOCK_JSON``). The committed repo-root
    ``BENCH_wallclock.json`` is the regression BASELINE the CI
    bench-gate compares fresh rows against
    (``python -m benchmarks.check_regression``).
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import fixture, timer
from benchmarks.bench_sampling import _cfg, WINDOW, BATCH
from repro.core.driver import run_pipeline, train_submodels, train_sync_baseline
from repro.core.engine import ENGINE_NAMES


def engine_rows(quick=False, steps=None):
    """One end-to-end wall-clock row per registered engine: the streamed
    driver (chunked ingest → async trainer → stacked tables), small
    enough that the interpret-mode Pallas engines stay honest on CPU.
    ``steps`` overrides the per-epoch step count — the CI bench-gate
    raises it so the rows are step- rather than compile-dominated."""
    gen, corpus, _ = fixture()
    workers = 4
    steps = steps if steps is not None else (6 if quick else 60)
    rows = []
    for name in ENGINE_NAMES:
        with timer() as t:
            res = train_submodels(
                corpus, gen.vocab_size, strategy="shuffle",
                num_workers=workers, cfg=_cfg(), epochs=1, batch_size=BATCH,
                rate=1.0 / workers, window=WINDOW, max_vocab=None,
                base_min_count=20, max_steps_per_epoch=steps,
                steps_per_chunk=steps, engine=name)
        rows.append({
            "engine": name,
            "workers": workers,
            "steps_per_epoch": int(res.timings["steps_per_epoch"]),
            "batch": BATCH,
            "train_s": res.timings["train_s"],
            "projected_parallel_s": res.timings["train_s"] / workers,
            "total_s": t.s,
            "final_loss": float(res.losses[-1]),
        })
    return rows


def run(rate=0.1, epochs=3, quick=False):
    gen, corpus, suite = fixture()
    n = int(round(1 / rate))
    rows = {}

    res = run_pipeline(
        corpus, gen.vocab_size, strategy="shuffle", num_workers=n,
        cfg=_cfg(), epochs=epochs, batch_size=BATCH, rate=rate, window=WINDOW,
        max_vocab=None, base_min_count=20,
        merge_methods=("pca", "alir_pca"),
        max_steps_per_epoch=100 if quick else None)
    async_total = res.timings["train_s"]
    merge_pca = res.timings["merge_pca_s"]
    merge_alir = res.timings["merge_alir_pca_s"]
    rows["async"] = {
        "workers": n, "total_s": async_total,
        "projected_parallel_s": async_total / n,
        "merge_pca_s": merge_pca, "merge_alir_s": merge_alir,
    }

    _, _, info = train_sync_baseline(
        corpus, gen.vocab_size, _cfg(), epochs=epochs, batch_size=BATCH,
        window=WINDOW, max_vocab=None,
        max_steps_per_epoch=100 * n if quick else None)
    rows["sync"] = {"total_s": info["train_s"]}
    rows["speedup_projected"] = info["train_s"] / (
        async_total / n + merge_alir)

    # Fig 2: scaling with corpus size (sync baseline on fractions)
    fracs = (0.25, 0.5, 1.0)
    scaling = []
    for f in fracs:
        sub = corpus.select(np.arange(int(f * corpus.num_sentences)))
        _, _, inf = train_sync_baseline(
            sub, gen.vocab_size, _cfg(), epochs=1, batch_size=BATCH,
            window=WINDOW, max_vocab=None,
            max_steps_per_epoch=60 if quick else None)
        scaling.append({"fraction": f, "train_s": inf["train_s"],
                        "steps": inf["steps_per_epoch"]})
    rows["scaling"] = scaling

    # Per-engine wall-clock (the bench trajectory CI tracks as JSON),
    # plus the DMA-bound Zipfian kernel rows, the serving-workload row
    # and the elastic mid-epoch-resume row the same gate covers
    rows["engines"] = (engine_rows(quick=quick) + zipf_kernel_rows(quick=quick)
                       + [_serve_row(quick=quick), _elastic_row(quick=quick),
                          _merge_tree_row(quick=quick)])
    return rows


def _serve_row(quick=False):
    from benchmarks.bench_serve import serve_row
    return serve_row(quick=quick)


def _merge_tree_row(quick=False):
    from benchmarks.bench_merge import merge_tree_row
    return merge_tree_row(quick=quick)


def _elastic_row(quick=False, steps=None):
    from benchmarks.bench_elastic import elastic_resume_row
    return elastic_resume_row(quick=quick, steps=steps)


def zipf_kernel_rows(quick=False):
    """Direct-step rows for the two pipelined HBM engines on a Zipfian
    paper-shape workload (V=50k, d=512, power-law ids): wall-clock plus
    the planner-derived **HBM row traffic** each step actually moves
    (``hbm_rows_per_step`` / ``hbm_mb_per_step``).

    The traffic column is the point. Interpret mode executes DMAs as
    plain memcpys with no latency/bandwidth model, so the quantity the
    tiered engine optimizes — HBM round-trips — costs almost nothing
    there and the two engines' interpret wall-clocks land within
    machine noise of each other. The traffic numbers are exact and
    deterministic (summed from the block plans): the hot tier drops
    every hot-row gather/write-back from every block — per-block dedup
    already collapses within-block repeats, so the tier's win is the
    cross-block recurrence, ~1.5x less HBM row traffic (a 35% cut) at
    this skew and batch — which is the term real DMA latency converts
    into step time on hardware. Rows land in the same gated JSON as
    ``<engine>@zipf50k``.

    The workload itself (seeds, id streams, planner traffic) lives in
    ``repro.analysis.workloads`` — the single definition this bench
    measures and ``repro.analysis.contracts`` certifies the committed
    baseline numbers against."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.workloads import ZIPF50K, zipf50k_ids
    from repro.core import sgns
    from repro.core.engine import get_engine
    from repro.kernels.sgns_fused_pipe import plan_blocks, plan_row_traffic

    V, D, B, K = ZIPF50K["V"], ZIPF50K["D"], ZIPF50K["B"], ZIPF50K["K"]
    BLK, HOT = ZIPF50K["BLK"], ZIPF50K["HOT"]
    steps = 2 if quick else 4
    cfg = sgns.SGNSConfig(vocab_size=V, dim=D, negatives=K)
    params = sgns.init_params(jax.random.PRNGKey(0), cfg)
    c, x, neg, table, key = zipf50k_ids()

    def hbm_rows(hot):
        plan = plan_blocks(c, x, neg, V, BLK, hot_rows=hot)
        return plan_row_traffic(plan, hot_rows=hot)

    rows = []
    for name, kw in (("pallas_fused_pipe", {}),
                     ("pallas_fused_tiered", {"hot_rows": HOT})):
        eng = get_engine(name, block_pairs=BLK, **kw)
        step = jax.jit(eng.make_step(cfg, total_steps=1000))
        pp = jax.tree.map(jnp.copy, params)
        pp, loss = step(pp, c, x, table, key, jnp.int32(0))  # compile+warm
        jax.block_until_ready(loss)
        with timer() as t:
            for i in range(steps):
                pp, loss = step(pp, c, x, table, key, jnp.int32(1 + i))
            jax.block_until_ready(loss)
        n_rows = hbm_rows(kw.get("hot_rows", 0))
        rows.append({
            "engine": f"{name}@zipf50k",
            "workers": 1,
            "steps_per_epoch": steps,
            "batch": B,
            "train_s": t.s,
            "projected_parallel_s": t.s,
            "total_s": t.s,
            "final_loss": float(loss),
            "hbm_rows_per_step": n_rows,
            "hbm_mb_per_step": n_rows * D * 4 / 1e6,
        })
    return rows


def write_engine_json(rows, path=None) -> str:
    path = path or os.environ.get("REPRO_BENCH_WALLCLOCK_JSON",
                                  "BENCH_wallclock.json")
    with open(path, "w") as f:
        json.dump(rows["engines"], f, indent=1)
    return path


def print_engine_rows(rows) -> None:
    for r in rows["engines"]:
        if r["engine"] == "serve":
            print(f"  {r['engine']:18s} {r['train_s']:7.2f}s workload "
                  f"({r['lookups']} lookups, p50 {r['p50_ms']:.2f} ms, "
                  f"p99 {r['p99_ms']:.2f} ms, mean batch "
                  f"{r['mean_batch']:.1f}, cache hit "
                  f"{r['cache_hit_rate']:.2f})")
            continue
        if r["engine"] == "merge_tree":
            print(f"  {r['engine']:18s} {r['train_s']:7.2f}s critical "
                  f"path ({r['workers']} sub-models, fan-in "
                  f"{r['fan_in']}, depth {r['depth']}; serial "
                  f"{r['tree_serial_s']:.2f}s, flat {r['flat_s']:.2f}s, "
                  f"peak {r['tree_peak_mb']:.1f} vs "
                  f"{r['flat_peak_mb']:.1f} MB)")
            continue
        if r["engine"] == "elastic_resume":
            print(f"  {r['engine']:18s} {r['train_s']:7.2f}s resume at "
                  f"chunk {r['cut_chunk']}/{r['num_chunks']} "
                  f"(fast-forward {r['fast_forward_s']:.2f}s, "
                  f"uninterrupted {r['full_run_s']:.2f}s)")
            continue
        extra = ""
        if "hbm_mb_per_step" in r:
            extra = (f", {r['hbm_rows_per_step']} HBM row DMAs "
                     f"= {r['hbm_mb_per_step']:.0f} MB/step")
        print(f"  {r['engine']:18s} {r['train_s']:7.2f}s train "
              f"({r['steps_per_epoch']} steps × {r['workers']} workers, "
              f"loss {r['final_loss']:.3f}{extra})")


def main(quick=False, out=None):
    with timer() as t:
        rows = run(quick=quick)
    a, s = rows["async"], rows["sync"]
    print(f"\n[Table 4 / Fig 2] wall-clock ({t.s:.1f}s)")
    print(f"sync baseline total:        {s['total_s']:8.1f}s")
    print(f"async {a['workers']:2d} workers, serial:   {a['total_s']:8.1f}s")
    print(f"async projected parallel:   {a['projected_parallel_s']:8.1f}s"
          f"  (+merge pca {a['merge_pca_s']:.1f}s / alir {a['merge_alir_s']:.1f}s)")
    print(f"projected speedup:          {rows['speedup_projected']:8.1f}×"
          f"  (paper: ~10× at 10% sampling)")
    print("scaling with corpus fraction (sync, 1 epoch):")
    base = rows["scaling"][0]
    for r in rows["scaling"]:
        print(f"  {r['fraction']:4.0%}: {r['train_s']:7.1f}s "
              f"({r['steps']} steps, "
              f"{r['train_s']/max(base['train_s'],1e-9):.2f}× vs 25%)")
    print("per-engine wall-clock (streamed driver, 1 epoch):")
    print_engine_rows(rows)
    path = write_engine_json(rows, path=out)
    print(f"engine rows → {path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (the CI setting)")
    ap.add_argument("--engines-only", action="store_true",
                    help="run only the per-engine wall-clock sweep and "
                         "write the JSON rows — what the CI bench-gate "
                         "compares against the committed baseline")
    ap.add_argument("--steps", type=int, default=None,
                    help="per-epoch steps for the engine sweep "
                         "(engines-only; the bench-gate uses 24 so rows "
                         "are step- rather than compile-dominated)")
    ap.add_argument("--out", default=None,
                    help="engine-rows JSON path (default "
                         "BENCH_wallclock.json / "
                         "$REPRO_BENCH_WALLCLOCK_JSON)")
    a = ap.parse_args()
    if a.engines_only:
        with timer() as t:
            rows = {"engines": engine_rows(quick=a.quick, steps=a.steps)
                    + zipf_kernel_rows(quick=a.quick)
                    + [_serve_row(quick=a.quick),
                       _elastic_row(quick=a.quick, steps=a.steps),
                       _merge_tree_row(quick=a.quick)]}
        print_engine_rows(rows)
        path = write_engine_json(rows, path=a.out)
        print(f"engine rows ({t.s:.1f}s) → {path}")
    else:
        main(quick=a.quick, out=a.out)
