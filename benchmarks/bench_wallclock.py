"""Paper Table 4 + Figure 2: wall-clock scaling.

On one CPU device we measure real compute and report:
  * sync baseline epoch time (the Hogwild/MLLib stand-in);
  * total async time for n sub-models trained back-to-back (vmap) and
    the PROJECTED parallel time = total/n + merge (each sub-model is an
    independent worker in the paper's cluster — measured compute is the
    honest per-worker cost, there is zero inter-worker traffic to model);
  * merge times (PCA / ALiR), the paper's "few minutes" claim;
  * near-linear scaling of training time with corpus fraction (Fig 2).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fixture, timer
from benchmarks.bench_sampling import _cfg, WINDOW, BATCH
from repro.core.driver import run_pipeline, train_sync_baseline


def run(rate=0.1, epochs=3, quick=False):
    gen, corpus, suite = fixture()
    n = int(round(1 / rate))
    rows = {}

    res = run_pipeline(
        corpus, gen.vocab_size, strategy="shuffle", num_workers=n,
        cfg=_cfg(), epochs=epochs, batch_size=BATCH, rate=rate, window=WINDOW,
        max_vocab=None, base_min_count=20,
        merge_methods=("pca", "alir_pca"),
        max_steps_per_epoch=100 if quick else None)
    async_total = res.timings["train_s"]
    merge_pca = res.timings["merge_pca_s"]
    merge_alir = res.timings["merge_alir_pca_s"]
    rows["async"] = {
        "workers": n, "total_s": async_total,
        "projected_parallel_s": async_total / n,
        "merge_pca_s": merge_pca, "merge_alir_s": merge_alir,
    }

    _, _, info = train_sync_baseline(
        corpus, gen.vocab_size, _cfg(), epochs=epochs, batch_size=BATCH,
        window=WINDOW, max_vocab=None,
        max_steps_per_epoch=100 * n if quick else None)
    rows["sync"] = {"total_s": info["train_s"]}
    rows["speedup_projected"] = info["train_s"] / (
        async_total / n + merge_alir)

    # Fig 2: scaling with corpus size (sync baseline on fractions)
    fracs = (0.25, 0.5, 1.0)
    scaling = []
    for f in fracs:
        sub = corpus.select(np.arange(int(f * corpus.num_sentences)))
        _, _, inf = train_sync_baseline(
            sub, gen.vocab_size, _cfg(), epochs=1, batch_size=BATCH,
            window=WINDOW, max_vocab=None,
            max_steps_per_epoch=60 if quick else None)
        scaling.append({"fraction": f, "train_s": inf["train_s"],
                        "steps": inf["steps_per_epoch"]})
    rows["scaling"] = scaling
    return rows


def main(quick=False):
    with timer() as t:
        rows = run(quick=quick)
    a, s = rows["async"], rows["sync"]
    print(f"\n[Table 4 / Fig 2] wall-clock ({t.s:.1f}s)")
    print(f"sync baseline total:        {s['total_s']:8.1f}s")
    print(f"async {a['workers']:2d} workers, serial:   {a['total_s']:8.1f}s")
    print(f"async projected parallel:   {a['projected_parallel_s']:8.1f}s"
          f"  (+merge pca {a['merge_pca_s']:.1f}s / alir {a['merge_alir_s']:.1f}s)")
    print(f"projected speedup:          {rows['speedup_projected']:8.1f}×"
          f"  (paper: ~10× at 10% sampling)")
    print("scaling with corpus fraction (sync, 1 epoch):")
    base = rows["scaling"][0]
    for r in rows["scaling"]:
        print(f"  {r['fraction']:4.0%}: {r['train_s']:7.1f}s "
              f"({r['steps']} steps, "
              f"{r['train_s']/max(base['train_s'],1e-9):.2f}× vs 25%)")
    return rows


if __name__ == "__main__":
    main()
