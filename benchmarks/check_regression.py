"""Benchmark-regression gate: compare a fresh ``BENCH_wallclock.json``
engine sweep against the committed repo-root baseline.

  python -m benchmarks.check_regression \\
      --baseline BENCH_wallclock.json \\
      --current  bench/BENCH_wallclock.json [--threshold 1.5]

An engine REGRESSES when its wall-clock grows by more than ``threshold``
× relative to the baseline, measured machine-normalized: raw seconds are
not comparable across runners, so each engine's time is first divided by
the run's ``sparse`` engine time (the pure-jnp path, a stable proxy for
the machine's single-core speed), and the gate compares those ratios.
A regression in the ``sparse`` reference itself is caught by comparing
its share of the run's total sweep time instead. Engines present in the
fresh run but absent from the baseline (a new engine landing in the PR
under test) are reported informationally, never failed — they become
gated once their regenerated baseline row is committed.

Exit status 1 on any regression — the CI ``bench-gate`` step fails the
build. Intentional changes (an engine deliberately traded slower, a
baseline refresh) go through the documented override: either apply the
``bench-override`` label to the PR (the workflow skips the gate; the
label re-triggers the run) or commit a regenerated baseline in the same
PR with the gate's own command::

    python -m benchmarks.bench_wallclock --engines-only --steps 24 \\
        --out BENCH_wallclock.json

The sweep deliberately uses enough steps per epoch that each row is
step- rather than jit/interpret-compile-dominated; normalization then
cancels machine speed, while compile-ratio shifts (toolchain bumps)
remain the residual noise the 1.5x threshold absorbs.
"""

from __future__ import annotations

import argparse
import json
import sys


def _by_engine(rows: list[dict]) -> dict[str, dict]:
    """Index rows by engine name, dropping malformed rows (no "engine"
    or no "train_s" key) instead of KeyError-ing the gate — a malformed
    *baseline* row must never wedge CI for unrelated PRs."""
    out = {}
    for r in rows:
        name = r.get("engine")
        if name is None or "train_s" not in r:
            print(f"  (row without engine/train_s keys skipped: "
                  f"{sorted(r)[:6]})")
            continue
        out[name] = r
    return out


def _normalized(rows: dict[str, dict], ref: str = "sparse") -> dict[str, float]:
    """Per-engine train_s divided by the run's reference-engine train_s."""
    if ref not in rows:
        raise SystemExit(f"reference engine {ref!r} missing from rows "
                         f"{sorted(rows)} — cannot machine-normalize")
    denom = max(rows[ref]["train_s"], 1e-9)
    return {name: r["train_s"] / denom for name, r in rows.items()}


def compare(baseline: list[dict], current: list[dict],
            threshold: float = 1.5, ref: str = "sparse") -> list[str]:
    """Returns a list of human-readable regression reports (empty = ok)."""
    base = _by_engine(baseline)
    cur = _by_engine(current)
    base_n = _normalized(base, ref)
    cur_n = _normalized(cur, ref)
    bad = []
    # the reference engine itself: compare its share of the sweep total
    # (self-normalization is identically 1.0 and would hide it)
    base_tot = sum(r["train_s"] for r in base.values())
    cur_tot = sum(r["train_s"] for r in cur.values())
    base_share = base[ref]["train_s"] / max(base_tot, 1e-9)
    cur_share = cur[ref]["train_s"] / max(cur_tot, 1e-9)
    if cur_share > threshold * base_share:
        bad.append(f"{ref}: share of sweep {cur_share:.3f} > "
                   f"{threshold}x baseline share {base_share:.3f}")
    for name in sorted(base):
        if name == ref:
            continue
        if name not in cur:
            bad.append(f"{name}: present in baseline but missing from "
                       f"current run")
            continue
        ratio = cur_n[name] / max(base_n[name], 1e-9)
        marker = "REGRESSED" if ratio > threshold else "ok"
        print(f"  {name:18s} baseline {base_n[name]:7.2f}x{ref} "
              f"current {cur_n[name]:7.2f}x{ref}  ({ratio:4.2f}x, {marker})")
        if ratio > threshold:
            bad.append(f"{name}: {cur_n[name]:.2f}x{ref} vs baseline "
                       f"{base_n[name]:.2f}x{ref} ({ratio:.2f}x > "
                       f"{threshold}x)")
    # rows present in the current run but absent from the baseline are a
    # NEW engine landing in this very PR: informational, never a failure
    # (the regenerated baseline committed alongside the engine gates it
    # from the next PR on)
    for name in sorted(set(cur) - set(base)):
        print(f"  {name:18s} current {cur_n[name]:7.2f}x{ref}  "
              f"(new engine, no baseline row — informational)")
    return bad


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_wallclock.json",
                    help="committed baseline JSON (repo root)")
    ap.add_argument("--current", required=True,
                    help="freshly generated engine-rows JSON")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when an engine's machine-normalized "
                         "wall-clock exceeds threshold x its baseline")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    print(f"bench-gate: {len(current)} engine rows vs baseline "
          f"{args.baseline} (threshold {args.threshold}x, "
          f"machine-normalized by the 'sparse' engine)")
    bad = compare(baseline, current, threshold=args.threshold)
    if bad:
        print("\nBENCHMARK REGRESSION:", file=sys.stderr)
        for line in bad:
            print(f"  {line}", file=sys.stderr)
        print("(intentional? add the 'bench-override' PR label or commit "
              "a regenerated BENCH_wallclock.json baseline)",
              file=sys.stderr)
        sys.exit(1)
    print("bench-gate: no engine regressed")


if __name__ == "__main__":
    main()
