"""Shared fixtures for the paper-experiment benchmarks.

The corpus mirrors the paper's conditions at laptop scale: Zipfian
unigrams, topical bigram structure, and — crucially — *topical drift*
(sentences sorted by topic), which is what makes EQUAL PARTITIONING the
paper's losing baseline (Wikipedia articles are topically clustered, so
contiguous slices have skewed distributions).
"""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.data.corpus import SemanticCorpusModel, Corpus
from repro.eval.benchmarks import BenchmarkSuite

VOCAB = 2000
SENTENCES = 30_000
TOP_WORDS = 1200      # benchmarks drawn from the more frequent strata


@functools.lru_cache(maxsize=1)
def fixture():
    gen = SemanticCorpusModel.create(vocab_size=VOCAB, num_topics=16,
                                     num_features=4, seed=0)
    corpus = gen.generate(num_sentences=SENTENCES, seed=1)
    # topical drift: sort sentences by their topic (leading token's topic)
    keys = [int(gen.topics[corpus.sentence(i)[0]])
            for i in range(corpus.num_sentences)]
    order = np.argsort(np.asarray(keys), kind="stable")
    corpus = corpus.select(order)
    suite = BenchmarkSuite.from_model(gen, seed=7, n_pairs=500, n_quads=300,
                                      n_cat=400, top_words=TOP_WORDS)
    return gen, corpus, suite


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
