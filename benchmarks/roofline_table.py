"""§Roofline table: reads the dry-run sweep JSONs and prints the
per-(arch × shape) roofline terms. Rerun the sweeps with
``benchmarks/run_dryruns.sh`` / ``run_dryruns_multipod.sh``."""

from __future__ import annotations

import json
import os

from repro.launch.roofline import format_table

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(name: str):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return []
    return json.load(open(path))


def main(quick=False):
    for name, title in (("dryrun_baseline.json", "single-pod 16×16 baseline"),
                        ("dryrun_multipod.json", "multi-pod 2×16×16"),
                        ("dryrun_sgns.json",
                         "SGNS (the paper's workload): async vs sync vs local-SGD"),
                        ("dryrun_perf.json", "§Perf variants")):
        rows = load(name)
        ok = [r for r in rows if "compute_s" in r]
        skips = [r for r in rows if "skipped" in r]
        fails = [r for r in rows if r.get("failed")]
        if not rows:
            print(f"\n[roofline] {title}: no results yet ({name})")
            continue
        print(f"\n[roofline] {title} — {len(ok)} compiled, "
              f"{len(skips)} skipped, {len(fails)} failed")
        if ok:
            if "dryrun_perf" in name:
                for r in ok:
                    print(f"  {r['arch']:24s} {r['shape']:12s} "
                          f"variant={r.get('variant'):18s} "
                          f"dom={r['dominant']:10s} bound="
                          f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.3e}s")
            else:
                print(format_table(ok))
        for r in skips:
            print(f"  SKIP {r['arch']} × {r['shape']}: {r['skipped'][:70]}")
        for r in fails:
            print(f"  FAIL {r['arch']} × {r['shape']}")
    return None


if __name__ == "__main__":
    main()
