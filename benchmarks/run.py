"""Benchmark harness — one benchmark per paper table/figure.

  bench_kl        — Fig 1   (KL divergence of sampling strategies)
  bench_sampling  — Table 2 (equal/random/shuffle × rates vs sync baseline)
  bench_merge     — Table 3 (Concat/PCA/ALiR/average/single)
  bench_wallclock — Table 4 + Fig 2 (training/merge wall-clock, scaling)
  bench_oov       — Fig 3   (missing-vocabulary reconstruction)
  bench_kernel    — SGNS step micro-bench + Pallas/oracle check
  roofline_table  — §Roofline terms from the dry-run sweeps

Prints a final ``name,us_per_call,derived`` CSV summary.
Env: REPRO_BENCH_QUICK=1 for reduced step counts.
"""

from __future__ import annotations

import os
import sys
import time


def main() -> None:
    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    csv: list[tuple[str, float, str]] = []

    def run(name, fn, derive):
        t0 = time.perf_counter()
        try:
            out = fn(quick=quick)
            us = (time.perf_counter() - t0) * 1e6
            csv.append((name, us, derive(out)))
        except Exception as e:  # keep the harness running
            csv.append((name, -1.0, f"FAILED:{type(e).__name__}"))
            import traceback
            traceback.print_exc()

    from benchmarks import (bench_kl, bench_sampling, bench_merge,
                            bench_wallclock, bench_oov, bench_kernel,
                            roofline_table)

    run("fig1_kl", lambda quick: bench_kl.main(),
        lambda rows: "kl_random<kl_equal=%s" % (
            next(r for r in rows if r['strategy'] == 'random')['kl_unigram'] <
            next(r for r in rows if r['strategy'] == 'equal')['kl_unigram']))
    run("table2_sampling", bench_sampling.main,
        lambda rows: "best=%s" % max(
            (r for r in rows if r['strategy'] != 'sync-baseline'),
            key=lambda r: r['similarity'])['strategy'])
    run("table3_merge", bench_merge.main,
        lambda rows: "best=%s" % max(rows, key=lambda r: r['similarity'])['method'])
    run("table4_wallclock", bench_wallclock.main,
        lambda rows: "speedup=%.1fx" % rows["speedup_projected"])
    run("fig3_oov", bench_oov.main,
        lambda rows: "alir@50%%sim=%.3f" % next(
            r['similarity'] for r in rows
            if r['method'] == 'alir_pca' and r['removed_frac'] == 0.5))
    run("kernel_sgns", bench_kernel.main,
        lambda r: "pairs_per_s=%.2e" % r["pairs_per_s_sparse"])
    run("roofline", roofline_table.main, lambda r: "see tables above")

    print("\n=== summary (name,us_per_call,derived) ===")
    for name, us, derived in csv:
        print(f"{name},{us:.1f},{derived}")
    if any(us < 0 for _, us, _ in csv):
        sys.exit(1)


if __name__ == "__main__":
    main()
