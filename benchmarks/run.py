"""Benchmark harness — one benchmark per paper table/figure.

  bench_kl        — Fig 1   (KL divergence of sampling strategies)
  bench_sampling  — Table 2 (equal/random/shuffle × rates vs sync baseline)
  bench_merge     — Table 3 (Concat/PCA/ALiR/average/single)
  bench_wallclock — Table 4 + Fig 2 (training/merge wall-clock, scaling)
  bench_oov       — Fig 3   (missing-vocabulary reconstruction)
  bench_kernel    — SGNS step micro-bench + Pallas/oracle check +
                    update-engine sweep (dense/sparse/pallas/pallas_fused/
                    pallas_fused_hbm/_pipe/_tiered, incl. the HBM-blocked
                    bit-equivalences and the tiered hot-fraction ladder)
  bench_serve     — serving tier (p50/p99 lookup latency, coalesced
                    batch size, cache hit rate under concurrent clients)
  roofline_table  — §Roofline terms from the dry-run sweeps

Prints a final ``name,us_per_call,derived`` CSV summary.
Env: REPRO_BENCH_QUICK=1 for reduced step counts;
     REPRO_BENCH_ONLY=a,b to run only the named benchmarks.
Args: --out FILE writes the CSV summary to FILE (CI artifact).
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="also write the CSV summary to this file")
    args = ap.parse_args(argv)

    quick = os.environ.get("REPRO_BENCH_QUICK", "0") == "1"
    only = os.environ.get("REPRO_BENCH_ONLY", "")
    only_names = {n.strip() for n in only.split(",") if n.strip()}
    csv: list[tuple[str, float, str]] = []
    seen_names: set[str] = set()

    def run(name, fn, derive):
        seen_names.add(name)
        if only_names and name not in only_names:
            return
        t0 = time.perf_counter()
        try:
            out = fn(quick=quick)
            us = (time.perf_counter() - t0) * 1e6
            csv.append((name, us, derive(out)))
        except Exception as e:  # keep the harness running
            csv.append((name, -1.0, f"FAILED:{type(e).__name__}"))
            import traceback
            traceback.print_exc()

    from benchmarks import (bench_kl, bench_sampling, bench_merge,
                            bench_wallclock, bench_oov, bench_kernel,
                            bench_serve, roofline_table)

    run("fig1_kl", lambda quick: bench_kl.main(),
        lambda rows: "kl_random<kl_equal=%s" % (
            next(r for r in rows if r['strategy'] == 'random')['kl_unigram'] <
            next(r for r in rows if r['strategy'] == 'equal')['kl_unigram']))
    run("table2_sampling", bench_sampling.main,
        lambda rows: "best=%s" % max(
            (r for r in rows if r['strategy'] != 'sync-baseline'),
            key=lambda r: r['similarity'])['strategy'])
    run("table3_merge", bench_merge.main,
        lambda rows: "best=%s" % max(rows, key=lambda r: r['similarity'])['method'])
    run("table4_wallclock", bench_wallclock.main,
        lambda rows: "speedup=%.1fx;engine_rows=%d" % (
            rows["speedup_projected"], len(rows["engines"])))
    run("fig3_oov", bench_oov.main,
        lambda rows: "alir@50%%sim=%.3f" % next(
            r['similarity'] for r in rows
            if r['method'] == 'alir_pca' and r['removed_frac'] == 0.5))
    run("neg_sampler",
        lambda quick: bench_sampling.negative_sampler_microbench(quick=quick),
        lambda rows: "alias_speedup@V=%d=%.1fx" % (
            rows[-1]["V"], rows[-1]["speedup"]))
    run("kernel_sgns", bench_kernel.main,
        lambda r: "pairs_per_s=%.2e;fused_err=%.1e;fused_hbm_err=%.1e;"
                  "fused_pipe_err=%.1e;fused_tiered_err=%.1e;engines=%s;"
                  "hot_sweep=%s" % (
            r["pairs_per_s_sparse"], r["fused_vs_sparse_err"],
            r["fused_hbm_vs_sparse_err"], r["fused_pipe_vs_sparse_err"],
            r["fused_tiered_vs_sparse_err"],
            "|".join("%s:%.0fus" % (n, us)
                     for n, us in r["engine_us"].items()),
            "|".join("%d:%.0fus" % (h["hot_rows"], h["us"])
                     for h in r["tiered_hot_sweep"])))
    run("serve_tier", bench_serve.main,
        lambda r: "p50_ms=%.2f;p99_ms=%.2f;mean_batch=%.1f;hit_rate=%.2f" % (
            r["p50_ms"], r["p99_ms"], r["mean_batch"], r["cache_hit_rate"]))
    run("roofline", roofline_table.main, lambda r: "see tables above")

    lines = [f"{name},{us:.1f},{derived}" for name, us, derived in csv]
    print("\n=== summary (name,us_per_call,derived) ===")
    print("\n".join(lines))
    if args.out:
        with open(args.out, "w") as f:
            f.write("name,us_per_call,derived\n" + "\n".join(lines) + "\n")
    unknown = only_names - seen_names
    if unknown:
        print(f"REPRO_BENCH_ONLY names not found: {sorted(unknown)}; "
              f"known: {sorted(seen_names)}", file=sys.stderr)
        sys.exit(2)
    if any(us < 0 for _, us, _ in csv):
        sys.exit(1)


if __name__ == "__main__":
    main()
