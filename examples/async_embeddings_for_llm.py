"""The paper's technique as a first-class framework feature: pretrain a
transformer's token-embedding table with asynchronous SGNS sub-models +
ALiR merge, then fine-tune the LM and compare against random init.

    PYTHONPATH=src python examples/async_embeddings_for_llm.py   (~3 min)

The LM never touches trainer internals: the merge is published as a
versioned artifact and the embedding table is fetched through the
batched :class:`~repro.serve.EmbeddingServer` — the same read path a
production consumer would use. ALiR's OOV reconstruction is what makes
this integration work: any vocab entry present in ≥1 sub-model gets a
consensus vector; the rest keep their random init.
"""

import asyncio
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.driver import run_pipeline
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.models import Model
from repro.optim import get_optimizer
from repro.serve import EmbeddingServer, ServeConfig, publish_incremental
from repro.serve.publish import submodel_arrivals


def make_lm_batches(corpus, vocab_size, batch, seq, steps, seed=0):
    toks = corpus.tokens % vocab_size
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        starts = rng.integers(0, len(toks) - seq - 1, size=batch)
        yield jnp.asarray(np.stack([toks[s:s + seq] for s in starts]),
                          dtype=jnp.int32)


def train_lm(cfg, params, corpus, steps=60, batch=8, seq=48, lr=3e-3):
    model = Model(cfg)
    opt = get_optimizer("adamw", lr=lr)
    state = opt.init(params)
    step_fn = jax.jit(model.make_train_step(opt))
    losses = []
    for i, toks in enumerate(make_lm_batches(corpus, cfg.vocab_size, batch,
                                             seq, steps)):
        params, state, loss = step_fn(params, state,
                                      {"tokens": toks, "labels": toks},
                                      jnp.int32(i))
        losses.append(float(loss))
    return losses


async def fetch_table(artifact_dir, raw_ids):
    """Pull pretrained vectors through the serving tier: batched,
    coalesced lookups against the latest published artifact version."""
    server = EmbeddingServer(artifact_dir, ServeConfig(coalesce_ms=1.0))
    out = await server.embed_ids(np.asarray(raw_ids))
    s = server.stats()
    print(f"fetched {len(raw_ids)} vectors from artifact "
          f"v{out['version']} in {s['dispatches']} coalesced dispatches "
          f"(mean batch {s['mean_batch']:.0f})")
    return out["vectors"], out["found"]


def main():
    cfg = get_config("smollm-360m").reduced()
    d = cfg.d_model

    gen = SemanticCorpusModel.create(vocab_size=cfg.vocab_size, seed=0)
    corpus = gen.generate(num_sentences=15_000, seed=1)

    # Phase 1: the paper — async sub-models + ALiR merge, at the LM's
    # dim; publish the incremental merge as a versioned artifact.
    res = run_pipeline(
        corpus, cfg.vocab_size, strategy="shuffle", num_workers=4,
        cfg=SGNSConfig(vocab_size=0, dim=d, window=5, negatives=5),
        epochs=8, batch_size=512, window=5, max_vocab=None,
        merge_methods=())
    print(f"async embedding pretrain: {res.timings['train_s']:.1f}s; "
          f"publishing incremental merge…")

    # Phase 2: initialize the LM embedding table via the serving tier —
    # the LM is just another client of the published artifact.
    with tempfile.TemporaryDirectory() as td:
        publish_incremental(submodel_arrivals(res.stacked), td,
                            word_ids=res.union_vocab.word_ids)
        emb, found = asyncio.run(fetch_table(td, np.arange(cfg.vocab_size)))
    print(f"{int(found.sum())}/{cfg.vocab_size} vocab covered by the "
          f"merged model")

    model = Model(cfg)
    params_rand = model.init(jax.random.PRNGKey(0))
    params_pre = jax.tree.map(jnp.copy, params_rand)
    table = np.array(params_pre["embed"], np.float32)  # writable copy
    scale = np.std(table) / (np.std(emb[found]) + 1e-9)
    table = np.where(found[:, None], emb * scale, table)
    params_pre["embed"] = jnp.asarray(table, params_pre["embed"].dtype)

    # Phase 3: fine-tune both and compare.
    steps = 100
    l_rand = train_lm(cfg, params_rand, corpus, steps=steps)
    l_pre = train_lm(cfg, params_pre, corpus, steps=steps)
    k = 10
    print(f"LM loss, first {k} steps — random init: "
          f"{np.mean(l_rand[:k]):.3f} | ALiR-pretrained: "
          f"{np.mean(l_pre[:k]):.3f}")
    print(f"LM loss, last {k} of {steps} — random init: "
          f"{np.mean(l_rand[-k:]):.4f} | ALiR-pretrained: "
          f"{np.mean(l_pre[-k:]):.4f}")
    print("(pretrained-embedding init should lead on both)")


if __name__ == "__main__":
    main()
