"""The paper's technique as a first-class framework feature: pretrain a
transformer's token-embedding table with asynchronous SGNS sub-models +
ALiR merge, then fine-tune the LM and compare against random init.

    PYTHONPATH=src python examples/async_embeddings_for_llm.py   (~3 min)

ALiR's OOV reconstruction is what makes this integration work: any vocab
entry present in ≥1 sub-model gets a consensus vector; the rest keep
their random init.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.driver import run_pipeline
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.models import Model
from repro.optim import get_optimizer


def make_lm_batches(corpus, vocab_size, batch, seq, steps, seed=0):
    toks = corpus.tokens % vocab_size
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        starts = rng.integers(0, len(toks) - seq - 1, size=batch)
        yield jnp.asarray(np.stack([toks[s:s + seq] for s in starts]),
                          dtype=jnp.int32)


def train_lm(cfg, params, corpus, steps=60, batch=8, seq=48, lr=3e-3):
    model = Model(cfg)
    opt = get_optimizer("adamw", lr=lr)
    state = opt.init(params)
    step_fn = jax.jit(model.make_train_step(opt))
    losses = []
    for i, toks in enumerate(make_lm_batches(corpus, cfg.vocab_size, batch,
                                             seq, steps)):
        params, state, loss = step_fn(params, state,
                                      {"tokens": toks, "labels": toks},
                                      jnp.int32(i))
        losses.append(float(loss))
    return losses


def main():
    cfg = get_config("smollm-360m").reduced()
    d = cfg.d_model

    gen = SemanticCorpusModel.create(vocab_size=cfg.vocab_size, seed=0)
    corpus = gen.generate(num_sentences=15_000, seed=1)

    # Phase 1: the paper — async sub-models + ALiR merge, at the LM's dim.
    res = run_pipeline(
        corpus, cfg.vocab_size, strategy="shuffle", num_workers=4,
        cfg=SGNSConfig(vocab_size=0, dim=d, window=5, negatives=5),
        epochs=8, batch_size=512, window=5, max_vocab=None,
        merge_methods=("alir_pca",))
    emb, valid = res.merged["alir_pca"]
    print(f"async embedding pretrain: {res.timings['train_s']:.1f}s, "
          f"{int(np.asarray(valid).sum())}/{cfg.vocab_size} vocab covered")

    # Phase 2: initialize the LM embedding table from the merged model.
    model = Model(cfg)
    params_rand = model.init(jax.random.PRNGKey(0))
    params_pre = jax.tree.map(jnp.copy, params_rand)
    table = np.array(params_pre["embed"], np.float32)  # writable copy
    word_rows = res.union_vocab.word_ids          # raw id per union row
    scale = np.std(table) / (np.std(emb[np.asarray(valid)]) + 1e-9)
    table[word_rows] = np.where(np.asarray(valid)[:, None],
                                emb * scale, table[word_rows])
    params_pre["embed"] = jnp.asarray(table, params_pre["embed"].dtype)

    # Phase 3: fine-tune both and compare.
    steps = 100
    l_rand = train_lm(cfg, params_rand, corpus, steps=steps)
    l_pre = train_lm(cfg, params_pre, corpus, steps=steps)
    k = 10
    print(f"LM loss, first {k} steps — random init: "
          f"{np.mean(l_rand[:k]):.3f} | ALiR-pretrained: "
          f"{np.mean(l_pre[:k]):.3f}")
    print(f"LM loss, last {k} of {steps} — random init: "
          f"{np.mean(l_rand[-k:]):.4f} | ALiR-pretrained: "
          f"{np.mean(l_pre[-k:]):.4f}")
    print("(pretrained-embedding init should lead on both)")


if __name__ == "__main__":
    main()
