"""Quickstart: the paper's divide → async-train → merge pipeline, tiny.

    PYTHONPATH=src python examples/quickstart.py          (~1 min on CPU)

Trains 4 SGNS sub-models fully asynchronously on Shuffle samples of a
synthetic corpus, merges them with ALiR, and evaluates against the
corpus generator's gold semantics.
"""

from repro.core.driver import run_pipeline
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.eval.benchmarks import BenchmarkSuite, evaluate_all


def main():
    gen = SemanticCorpusModel.create(vocab_size=1200, seed=0)
    corpus = gen.generate(num_sentences=12_000, seed=1)
    suite = BenchmarkSuite.from_model(gen, top_words=800)

    res = run_pipeline(
        corpus,
        raw_vocab_size=1200,
        strategy="shuffle",          # the paper's best divide strategy
        num_workers=4,
        cfg=SGNSConfig(vocab_size=0, dim=48, window=5, negatives=5),
        epochs=4,
        batch_size=512,
        window=5,
        max_vocab=None,
        merge_methods=("alir_pca", "concat", "average"),
    )
    print(f"trained 4 async sub-models in {res.timings['train_s']:.1f}s "
          f"({res.timings['steps_per_epoch']} steps/epoch); "
          f"losses {['%.2f' % l for l in res.losses]}")
    for method, (emb, valid) in res.merged.items():
        s = evaluate_all(emb, valid, res.union_vocab, suite)
        print(f"{method:10s} similarity ρ={s['similarity']:.3f}  "
              f"analogy={s['analogy']:.3f}  purity={s['categorization']:.3f}")
    print("(expect alir_pca ≥ average — alignment before averaging is "
          "the paper's Merge-phase point)")


if __name__ == "__main__":
    main()
