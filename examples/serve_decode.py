"""The full train → publish → serve loop, end to end.

    PYTHONPATH=src python examples/serve_decode.py       (~1 min on CPU)

Trains async SGNS sub-models with per-worker vocabularies (RANDOM
sampling — sub-models genuinely miss words), folds them through the
**incremental** ALiR merger publishing a versioned artifact per fold,
then stands up the batched asyncio :class:`EmbeddingServer` over the
artifact directory and decodes nearest neighbors from served vectors:

* a hot-reload: queries start at artifact v1 (one folded sub-model) and
  pick up the final version as later folds publish;
* coalesced concurrent lookups (one batched gather per window);
* a word absent from a sub-model served in that sub-model's own space —
  reconstructed on the fly (``Y @ W_i.T``), the paper's robustness
  claim as a serving feature.
"""

import asyncio
import tempfile

import numpy as np

from repro.core.driver import run_pipeline
from repro.core.merge import IncrementalAlirMerger
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.serve import EmbeddingServer, ServeConfig, publish_incremental
from repro.serve.publish import submodel_arrivals

VOCAB, WORKERS, DIM = 900, 4, 32


def train(workers=WORKERS):
    gen = SemanticCorpusModel.create(vocab_size=VOCAB, seed=0)
    corpus = gen.generate(num_sentences=8_000, seed=1)
    # RANDOM sampling: each worker builds its own vocabulary, so the
    # presence mask has real holes — the OOV serving path is exercised.
    return run_pipeline(
        corpus, VOCAB, strategy="random", num_workers=workers,
        cfg=SGNSConfig(vocab_size=0, dim=DIM, window=5, negatives=5),
        epochs=2, batch_size=512, window=5, max_vocab=None,
        base_min_count=8, merge_methods=())


async def decode(server: EmbeddingServer, res, query_raw_ids):
    """Nearest-neighbor decode of served vectors against the served
    table itself (all through the same batched query path)."""
    union = res.union_vocab
    all_rows = np.arange(union.size)
    table = (await server.embed_rows(all_rows))["vectors"]
    norm = table / (np.linalg.norm(table, axis=1, keepdims=True) + 1e-9)
    out = (await server.embed_ids(np.asarray(query_raw_ids)))
    for rid, vec, ok in zip(query_raw_ids, out["vectors"], out["found"]):
        if not ok:
            print(f"  raw id {rid}: not covered yet")
            continue
        v = vec / (np.linalg.norm(vec) + 1e-9)
        sims = norm @ v
        sims[union.lookup[rid]] = -np.inf      # not itself
        nn = np.argsort(-sims)[:3]
        print(f"  raw id {rid:>4d} → neighbors "
              f"{[int(union.word_ids[j]) for j in nn]} "
              f"(cos {[round(float(sims[j]), 2) for j in nn]})")
    return out


async def main_async(res, artifact_dir):
    mask = np.asarray(res.stacked.mask)
    word_ids = res.union_vocab.word_ids

    # Publish fold 1 only, stand the server up on it (no wait-for-all)…
    arrivals = list(submodel_arrivals(res.stacked))
    merger = IncrementalAlirMerger()
    publish_incremental(arrivals[:1], artifact_dir, word_ids=word_ids,
                        merger=merger, final_cold_fold=False)
    server = EmbeddingServer(artifact_dir,
                             ServeConfig(coalesce_ms=1.0, cache_rows=2048))
    v0 = server.store.version
    print(f"serving starts at artifact v{v0} "
          f"({int(np.asarray(server.store.table.valid).sum())} rows valid)")

    # …then the remaining workers "finish", fold into the SAME merger
    # (warm folds + a final cold canonical solve) and the server
    # hot-swaps to the latest published version.
    versions, final = publish_incremental(arrivals[1:], artifact_dir,
                                          word_ids=word_ids, merger=merger)
    server.refresh()
    print(f"hot-swapped to artifact v{server.store.version} "
          f"({int(np.asarray(server.store.table.valid).sum())} rows valid)")

    # Batched concurrent decode through the coalescer.
    hot = word_ids[:8].tolist()
    await decode(server, res, hot)

    # The OOV serving feature: a word some sub-model never saw, queried
    # in THAT sub-model's space, reconstructed on the fly.
    table = server.store.table
    w, m = np.nonzero(~np.asarray(table.mask))
    if len(w):
        axis, row = int(w[0]), int(m[0])
        worker = int(np.asarray(table.worker_ids)[axis])
        rec = (await server.embed_rows([row], submodel=worker))["vectors"][0]
        print(f"row {row} is absent from worker {worker}'s sub-model → "
              f"reconstructed ‖v‖={np.linalg.norm(rec):.3f} "
              f"(= Y[{row}] @ W_{worker}ᵀ, served)")

    s = server.stats()
    print(f"serving stats: {s['requests']} lookups in {s['dispatches']} "
          f"coalesced dispatches (mean batch {s['mean_batch']:.1f}), "
          f"p50 {s['p50_ms']:.2f} ms, p99 {s['p99_ms']:.2f} ms, "
          f"cache hit rate {s['cache_hit_rate']:.2f}")
    assert s["mean_batch"] > 1.0, "coalescing should batch concurrent lookups"
    print(f"sub-model coverage: "
          f"{mask.sum(axis=1).tolist()} of {mask.shape[1]} union rows each")


def main():
    res = train()
    print(f"trained {WORKERS} async sub-models in "
          f"{res.timings['train_s']:.1f}s; folding + publishing…")
    with tempfile.TemporaryDirectory() as td:
        asyncio.run(main_async(res, td))


if __name__ == "__main__":
    main()
