"""Batched serving with KV/state caches across architecture families.

    PYTHONPATH=src python examples/serve_decode.py        (~2 min)

Decodes batched requests on three cache mechanics: GQA ring-buffer SWA
(h2o-danube), MLA compressed cache (deepseek-v2-lite) and recurrent
state (xlstm) — all through the same serve loop.
"""

from repro.launch.serve import serve

ARCHS = ("h2o-danube-1.8b", "deepseek-v2-lite-16b", "xlstm-1.3b")


def main():
    for arch in ARCHS:
        gen, stats = serve(arch, reduced=True, batch=4, prompt_len=12,
                           new_tokens=24)
        print(f"{arch:24s} generated {gen.shape}  "
              f"prefill {stats['prefill_s']:.2f}s  "
              f"decode {stats['decode_s']:.2f}s  "
              f"{stats['tok_per_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
