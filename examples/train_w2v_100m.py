"""End-to-end driver at the paper's model scale: a ~100M-parameter SGNS
model (vocab 100k × dim 500 input table, matching the paper's 300k×500
setup proportions) trained for a few hundred steps per async worker,
merged with ALiR, evaluated, checkpointed.

    PYTHONPATH=src python examples/train_w2v_100m.py [--steps 600]

This is the paper's kind of workload (embedding *training*), so the
end-to-end example trains rather than serves. ~10-15 min on CPU
at the defaults; pass smaller --steps/--vocab for a quick pass.

Ingestion is the streaming pipeline: pairs are extracted block-of-
sentences at a time into fixed-shape chunks and prefetched to the device
while it trains — no epoch of pairs is ever materialized in host memory.
The per-step compute is an update engine (``--engine``): the default
``sparse:alias`` draws negatives from the O(1) alias sampler;
``pallas_fused`` moves the draw inside the step kernel;
``pallas_fused_hbm`` additionally keeps the (V, d) tables HBM-resident
and DMA-streams only each pair block's touched rows — the engine family
sized for exactly this example's 100k×500 (and the paper's 300k×500)
tables; ``pallas_fused_pipe`` is its double-buffered successor (each
touched row deduped to one DMA per block, gathers overlapped with
compute behind a hazard-ordering planner); ``pallas_fused_tiered`` adds
frequency-tiered placement on top — the ``--hot-rows`` hottest rows
(the frequency-sorted id prefix) pinned VMEM-resident so the Zipfian
bulk of row traffic never touches DMA, cold rows behind a
``--ring-depth``-slot ring; ``sparse:cdf`` is the binary-search oracle.
"""

import argparse
import time

import numpy as np

from repro.core.driver import train_submodels
from repro.core.merge import merge as merge_models
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.eval.benchmarks import BenchmarkSuite, evaluate_all
from repro.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600,
                    help="steps per worker per epoch")
    ap.add_argument("--vocab", type=int, default=100_000)
    ap.add_argument("--dim", type=int, default=500)
    ap.add_argument("--workers", type=int, default=10)
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--engine", default="sparse:alias",
                    help="update engine (dense | sparse | pallas | "
                         "pallas_fused | pallas_fused_hbm | "
                         "pallas_fused_pipe | pallas_fused_tiered, "
                         "optional ':cdf'/':alias' suffix)")
    ap.add_argument("--hot-rows", type=int, default=None,
                    help="pallas_fused_tiered: VMEM-pinned hot-prefix "
                         "rows per table (default 256)")
    ap.add_argument("--ring-depth", type=int, default=None,
                    help="pallas_fused_pipe/_tiered: cold-row DMA ring "
                         "slots (default 2)")
    ap.add_argument("--steps-per-chunk", type=int, default=128,
                    help="steps per fixed-shape streamed chunk")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="chunk prefetch depth (host/device overlap)")
    ap.add_argument("--processes", type=int, default=None,
                    help="ingestion host count (default: "
                         "jax.process_count()); >1 shards the worker "
                         "streams per host and trains under shard_map")
    ap.add_argument("--process-index", type=int, default=None,
                    help="this host's index (default: jax.process_index())")
    ap.add_argument("--save", default="/tmp/w2v_100m.npz")
    args = ap.parse_args()

    from repro.core.engine import get_engine
    from repro.launch.mesh import multihost_train_kwargs
    overrides = {k: v for k, v in (("hot_rows", args.hot_rows),
                                   ("ring_depth", args.ring_depth))
                 if v is not None}
    args.engine = get_engine(args.engine, **overrides)
    processes, train_kw = multihost_train_kwargs(args.workers, args.processes)

    print(f"model: 2 × {args.vocab} × {args.dim} = "
          f"{2*args.vocab*args.dim/1e6:.0f}M parameters")
    gen = SemanticCorpusModel.create(vocab_size=args.vocab, num_topics=64,
                                     seed=0)
    corpus = gen.generate(num_sentences=120_000, seed=1)
    print(f"corpus: {corpus.num_sentences} sentences, "
          f"{corpus.num_tokens/1e6:.1f}M tokens")
    suite = BenchmarkSuite.from_model(gen, top_words=min(20_000, args.vocab))

    cfg = SGNSConfig(vocab_size=0, dim=args.dim, window=5, negatives=5)
    t0 = time.perf_counter()
    res = train_submodels(
        corpus, args.vocab, strategy="shuffle", num_workers=args.workers,
        cfg=cfg, epochs=args.epochs, batch_size=1024, window=5,
        max_vocab=args.vocab, base_min_count=10,
        max_steps_per_epoch=args.steps, engine=args.engine,
        steps_per_chunk=args.steps_per_chunk, prefetch=args.prefetch,
        process_index=args.process_index, process_count=processes,
        **train_kw)
    print(f"async training: {res.timings['train_s']:.1f}s total "
          f"({res.timings['train_s']/args.workers:.1f}s/worker projected "
          f"parallel), losses {['%.3f' % l for l in res.losses]}")

    t0 = time.perf_counter()
    emb, valid = merge_models(res.stacked, "alir_pca", out_dim=args.dim)
    emb = np.asarray(emb)
    print(f"ALiR merge of {args.workers} × ({res.union_vocab.size}, "
          f"{args.dim}) sub-models: {time.perf_counter()-t0:.1f}s")

    scores = evaluate_all(emb, np.asarray(valid), res.union_vocab, suite)
    print(f"merged model: sim ρ={scores['similarity']:.3f} "
          f"analogy={scores['analogy']:.3f} "
          f"purity={scores['categorization']:.3f}")
    save_checkpoint(args.save, {"embedding": emb,
                                "word_ids": res.union_vocab.word_ids})
    print(f"checkpoint → {args.save}")


if __name__ == "__main__":
    main()
