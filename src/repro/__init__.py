"""Reproduction of "Asynchronous Training of Word Embeddings for Large
Text Corpora" (WSDM 2019): divide → asynchronously train sub-models with
zero collectives → merge (ALiR) → evaluate, as a JAX/Pallas system."""

__version__ = "0.1.0"
