"""Static verification layer for the zero-collective training stack.

Four passes, each runnable standalone and all wired into the CI
``static-analysis`` job (``python -m repro.analysis`` runs every pass
over every registered engine):

``analysis.dma_model``
    Bounded-exhaustive model checker for the DMA schedule both
    pipelined kernels share (``kernel_schedule``/``resolve_schedule``):
    for every ``ring_depth`` in {2, 3, 4} × every hazard vector up to a
    bounded block count × padded-tail shapes, proves every
    ``make_async_copy`` start has exactly one matching wait, no VMEM
    ring slot is rewritten before its in-flight DMA completes, and no
    scatter-before-regather WAR hazard escapes the look-behind window.
``analysis.contracts``
    Structured-op certifier over lowered StableHLO / compiled HLO:
    the zero-collective contract (replacing the text regex, which was
    vacuous on MLIR spellings), ``(V, d)``-table donation aliasing (no
    silent full-table copies), and planner-predicted DMA row traffic
    matching the committed ``@zipf50k`` bench baselines.
``analysis.vmem``
    Static VMEM footprint from ``(block_pairs, ring_depth, hot_rows,
    d, K)``; rejects over-budget configs at plan time (trainer + CLIs)
    instead of at Mosaic compile time on TPU.
``analysis.lint_rules``
    Repo-specific AST lint encoding past bug classes from CHANGES.md:
    arithmetic PRNG seed construction, ``searchsorted`` without
    ``side='right'`` in sampling code, unseeded/wall-clock randomness
    in ``core/``/``kernels/``, and collective primitives in the
    zero-collective train path.

Submodules import lazily where they need jax so the lint pass stays
usable as a lightweight standalone tool.
"""

from __future__ import annotations

__all__ = ["contracts", "dma_model", "lint_rules", "vmem", "workloads"]
