"""Run every static-analysis pass — the CI ``static-analysis`` job.

``python -m repro.analysis`` executes, in order:

1. **dma-model** — exhaustive model check of the pipeline DMA schedule
   (ring_depth ∈ {2,3,4} × all hazard vectors × padded tails) plus the
   planner integration sweep.
2. **contracts** — zero-collective + table-donation-aliasing
   certification for every registered engine, and the ``@zipf50k``
   planner-traffic ↔ bench-baseline cross-check.
3. **vmem** — each engine's reference operating shape fits the default
   16 MiB budget (``pallas_fused`` at its VMEM-resident dev shape; the
   HBM family at the paper shape), and the known-over-budget config is
   rejected.
4. **lint** — the repo-specific AST rules over ``src/repro``.

``--quick`` shrinks the dma-model bounds for fast local iteration;
CI runs the full bounds. Exit status is nonzero if any pass fails.
"""

from __future__ import annotations

import argparse
import sys
import time


def _run_dma_model(quick: bool) -> bool:
    from repro.analysis import dma_model

    report = dma_model.run(
        max_nblocks_schedule=4 if quick else 6,
        max_nblocks_planner=3 if quick else 4)
    print(f"dma_model: {report.summary()}")
    return report.ok


def _run_contracts(baseline: str) -> bool:
    from repro.analysis import contracts

    return contracts.main(["--baseline", baseline]) == 0


# (engine spec, shape kwargs) — the reference operating point each
# engine must fit in DEFAULT_VMEM_BUDGET_BYTES. The paper shape is
# V=300k × d=500; pallas_fused is certified at its dev shape because
# VMEM-resident tables at the paper shape are exactly the cliff the
# HBM family exists to dodge (asserted over-budget below).
_PAPER = dict(vocab_size=300_000, dim=500, negatives=5, batch=1024)
_VMEM_REFERENCE = [
    ("dense", _PAPER),
    ("sparse", _PAPER),
    ("pallas", _PAPER),
    ("pallas_fused", dict(vocab_size=4_000, dim=128, negatives=5,
                          batch=512)),
    ("pallas_fused_hbm", _PAPER),
    ("pallas_fused_pipe", _PAPER),
    ("pallas_fused_tiered:alias", _PAPER),
]


def _run_vmem() -> bool:
    from repro.analysis.vmem import VmemBudgetError, check_vmem_budget

    ok = True
    for spec, shape in _VMEM_REFERENCE:
        try:
            est = check_vmem_budget(spec, **shape)
            print(f"vmem: {est.summary()} ✓")
        except VmemBudgetError as e:
            ok = False
            print(f"vmem: FAILED {e}")
    # The cliff itself must still be caught: VMEM-resident tables at
    # the paper shape have to be rejected, not waved through.
    try:
        check_vmem_budget("pallas_fused", **_PAPER)
        ok = False
        print("vmem: FAILED pallas_fused at the paper shape was NOT "
              "rejected — the estimator lost the VMEM cliff")
    except VmemBudgetError:
        print("vmem: pallas_fused at paper shape correctly rejected ✓")
    return ok


def _run_lint() -> bool:
    from repro.analysis import lint_rules

    return lint_rules.main(["src/repro"]) == 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced dma-model bounds for local iteration")
    ap.add_argument("--skip", action="append", default=[],
                    choices=["dma-model", "contracts", "vmem", "lint"],
                    help="skip a pass (repeatable)")
    ap.add_argument("--baseline", default="BENCH_wallclock.json",
                    help="bench baseline for the traffic cross-check")
    args = ap.parse_args(argv)

    passes = [
        ("dma-model", lambda: _run_dma_model(args.quick)),
        ("contracts", lambda: _run_contracts(args.baseline)),
        ("vmem", _run_vmem),
        ("lint", _run_lint),
    ]
    failed = []
    for name, fn in passes:
        if name in args.skip:
            print(f"== {name}: skipped ==")
            continue
        print(f"== {name} ==")
        t0 = time.perf_counter()
        ok = fn()
        dt = time.perf_counter() - t0
        print(f"== {name}: {'OK' if ok else 'FAILED'} ({dt:.1f}s) ==")
        if not ok:
            failed.append(name)
    if failed:
        print(f"static analysis FAILED: {', '.join(failed)}")
        return 1
    print("static analysis: all passes OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
