"""HLO/StableHLO contract certifier for the registered engines.

The paper's headline property — zero parameter synchronization — was
previously asserted by a regex over ``lowered.as_text()`` that matched
the *post-compile* HLO spellings (``all-reduce``). Lowered text on
current jax is StableHLO MLIR, where collectives print as
``stablehlo.all_reduce`` — the regex was vacuous there (a planted
``psum`` sailed through). This module replaces it with a structured
walk over the program text as *ops*:

* :func:`certify_zero_collective` — parses each line's **op position**
  (MLIR ``dialect.op`` after the optional ``%... =`` results, or HLO
  opcode immediately before its operand list) in both formats, so a
  collective spelled either way is caught and a collective name inside
  a metadata/location string is not a false positive.
* :func:`certify_table_aliasing` — lowers each engine's step with the
  parameter pytree donated and certifies both ``(V, d)`` tables carry
  ``tf.aliasing_output`` input/output aliasing — i.e. the update is
  genuinely in place, no silent full-table copy per step.
* :func:`certify_bench_traffic` — recomputes the ``@zipf50k``
  planner-predicted HBM row traffic from the shared workload
  definition (``repro.analysis.workloads``) and certifies it equals
  the committed ``BENCH_wallclock.json`` baseline the CI bench gate
  compares against.

``repro.core.async_trainer.assert_no_collectives`` and
``count_collective_ops`` delegate here, as do the ``dryrun_sgns``
cases and the engine×sampler test matrix — one checker, no duplicated
regexes.

Standalone: ``python -m repro.analysis.contracts`` certifies every
registered engine.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass

# Cross-device communication primitives in both surface syntaxes.
# MLIR dialects (lowered text): stablehlo / mhlo, underscore spellings.
_MLIR_COLLECTIVES = {
    "all_reduce", "all_gather", "all_to_all", "collective_permute",
    "reduce_scatter", "collective_broadcast",
}
# Post-compile HLO: hyphen spellings, plus async -start/-done forms.
_HLO_COLLECTIVES = {
    "all-reduce", "all-gather", "all-to-all", "collective-permute",
    "reduce-scatter", "collective-broadcast",
}

# MLIR op position: optional `%r = ` / `%r:2 = ` result list, then a
# (possibly quoted, generic-form) `dialect.op`. Attribute continuation
# lines, type annotations and loc("...") strings never match: they do
# not start with an identifier immediately followed by a dot.
_MLIR_OP_RE = re.compile(
    r"^\s*(?:%[\w.#$:]+(?:\s*,\s*%[\w.#$:]+)*\s*=\s*)?"
    r"\"?([a-z_][\w$]*)\.([a-z_][\w]*)\"?(?=[\s(\"])")
# HLO op position: `%name = <shape> opcode(` — the opcode is the
# identifier immediately before the operand '(' (metadata strings sit
# after the operand list and are never the first such identifier).
_HLO_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?[%\w.-]+\s*=\s*.*?\b([a-z][a-z0-9-]*)\(")


class ContractViolation(AssertionError):
    """A certified contract does not hold. Subclasses AssertionError so
    existing callers of the old assertion helpers keep working."""


def _as_text(lowered_or_text) -> str:
    if isinstance(lowered_or_text, str):
        return lowered_or_text
    return lowered_or_text.as_text()


def parse_op_counts(text: str) -> dict[str, int]:
    """Ops by name at op position, both formats merged: MLIR ops as
    ``dialect.op``, HLO opcodes bare."""
    out: dict[str, int] = {}
    for line in text.splitlines():
        m = _MLIR_OP_RE.match(line)
        if m:
            name = f"{m.group(1)}.{m.group(2)}"
            out[name] = out.get(name, 0) + 1
            continue
        m = _HLO_OP_RE.match(line)
        if m:
            out[m.group(1)] = out.get(m.group(1), 0) + 1
    return out


def _is_collective(op: str) -> bool:
    if "." in op:       # MLIR dialect.op
        return op.split(".", 1)[1] in _MLIR_COLLECTIVES
    base = re.sub(r"-(start|done)$", "", op)
    return base in _HLO_COLLECTIVES


def count_collective_ops(text: str) -> dict[str, int]:
    """Collective ops (either format) by name — structured op-position
    parse, immune to the metadata-string false positives and the
    MLIR-spelling false negatives of the old regex."""
    return {op: n for op, n in parse_op_counts(text).items()
            if _is_collective(op)}


def certify_zero_collective(lowered_or_text, label: str = "") -> str:
    """Certify a lowered/compiled program contains zero cross-device
    collectives; returns the text. Accepts a ``Lowered``/``Compiled``
    object (``.as_text()``) or raw program text in either format."""
    txt = _as_text(lowered_or_text)
    hits = count_collective_ops(txt)
    if hits:
        where = f" [{label}]" if label else ""
        raise ContractViolation(
            f"zero-collective contract violated{where}: found "
            f"{dict(sorted(hits.items()))}")
    return txt


# ---------------------------------------------------------------------------
# Donation aliasing of the (V, d) tables.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AliasingReport:
    engine: str
    vocab_size: int
    dim: int
    aliased_table_args: int     # (V, d) f32 args carrying tf.aliasing_output
    expected: int = 2           # W and C


def _step_arg_structs(engine, cfg, batch: int):
    import jax
    import jax.numpy as jnp

    sds = jax.ShapeDtypeStruct
    V, d = cfg.vocab_size, cfg.dim
    params = {"W": sds((V, d), jnp.float32), "C": sds((V, d), jnp.float32)}
    if engine.table_kind == "alias":
        table = {"prob": sds((V,), jnp.float32),
                 "alias": sds((V,), jnp.int32)}
    else:
        table = sds((V,), jnp.float32)
    return (params, sds((batch,), jnp.int32), sds((batch,), jnp.int32),
            table, sds((2,), jnp.uint32), sds((), jnp.int32))


def certify_table_aliasing(engine_spec, *, vocab_size: int = 150,
                           dim: int = 32, negatives: int = 4,
                           batch: int = 64,
                           total_steps: int = 100) -> AliasingReport:
    """Lower one engine step with the parameter pytree donated and
    certify both ``(V, d)`` tables are input/output-aliased
    (``tf.aliasing_output`` on their args) — the update really is in
    place; a step that silently copies the tables fails here."""
    import jax

    from repro.core.engine import get_engine
    from repro.core.sgns import SGNSConfig

    engine = get_engine(engine_spec)
    cfg = SGNSConfig(vocab_size=vocab_size, dim=dim, negatives=negatives)
    step = engine.make_step(cfg, total_steps=total_steps)
    lowered = jax.jit(step, donate_argnums=(0,)).lower(
        *_step_arg_structs(engine, cfg, batch))
    txt = lowered.as_text()
    table_arg = re.compile(
        rf"tensor<{vocab_size}x{dim}xf32>\s*\{{[^}}]*tf\.aliasing_output")
    rep = AliasingReport(engine.describe(), vocab_size, dim,
                         aliased_table_args=len(table_arg.findall(txt)))
    if rep.aliased_table_args < rep.expected:
        raise ContractViolation(
            f"table-aliasing contract violated [{rep.engine}]: only "
            f"{rep.aliased_table_args}/{rep.expected} (V, d) table args "
            f"carry tf.aliasing_output — the donated tables are being "
            f"silently copied each step")
    return rep


# ---------------------------------------------------------------------------
# Whole-engine certification (epoch-level zero-collective + aliasing).
# ---------------------------------------------------------------------------
def lower_engine_epoch(engine_spec, *, vocab_size: int = 150, dim: int = 32,
                       negatives: int = 4, steps: int = 4, batch: int = 64):
    """Lower a 1-worker ``shard_map`` epoch for an engine spec — the
    same path the dryrun and the production mesh use."""
    import jax

    from repro.core.async_trainer import AsyncShardTrainer
    from repro.core.sgns import SGNSConfig

    mesh = jax.make_mesh((1,), ("worker",))
    tr = AsyncShardTrainer(
        cfg=SGNSConfig(vocab_size=vocab_size, dim=dim, negatives=negatives),
        num_workers=1, total_steps=steps, backend="shard_map", mesh=mesh,
        engine=engine_spec)
    return tr.lower_epoch(steps=steps, batch=batch)


@dataclass(frozen=True)
class EngineContractReport:
    engine: str
    zero_collective: bool
    aliasing: AliasingReport


def certify_engine_contracts(engine_spec, *, vocab_size: int = 150,
                             dim: int = 32, negatives: int = 4,
                             steps: int = 4,
                             batch: int = 64) -> EngineContractReport:
    """Zero-collective (epoch under shard_map) + table aliasing (donated
    step) for one engine spec. Raises :class:`ContractViolation`."""
    from repro.core.engine import get_engine

    engine = get_engine(engine_spec)
    certify_zero_collective(
        lower_engine_epoch(engine, vocab_size=vocab_size, dim=dim,
                           negatives=negatives, steps=steps, batch=batch),
        label=f"{engine.describe()} epoch")
    rep = certify_table_aliasing(engine, vocab_size=vocab_size, dim=dim,
                                 negatives=negatives, batch=batch)
    return EngineContractReport(engine.describe(), True, rep)


# ---------------------------------------------------------------------------
# Planner-predicted DMA traffic vs the committed bench baseline.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TrafficReport:
    engine: str
    predicted_rows: int
    baseline_rows: int


def certify_bench_traffic(
        baseline_path: str = "BENCH_wallclock.json") -> list[TrafficReport]:
    """Recompute the ``@zipf50k`` per-step HBM row traffic from the
    shared workload definition and certify it matches the committed
    baseline rows the CI bench gate compares against — the planner and
    the gated numbers cannot drift apart silently."""
    from repro.analysis.workloads import ZIPF50K, zipf50k_row_traffic

    with open(baseline_path) as f:
        rows = {r["engine"]: r for r in json.load(f)
                if "hbm_rows_per_step" in r}
    if not rows:
        raise ContractViolation(
            f"no @zipf50k traffic rows found in {baseline_path}")
    reports = []
    for name, hot in (("pallas_fused_pipe", 0),
                      ("pallas_fused_tiered", ZIPF50K["HOT"])):
        key = f"{name}@zipf50k"
        if key not in rows:
            raise ContractViolation(f"baseline row {key!r} missing from "
                                    f"{baseline_path}")
        predicted = zipf50k_row_traffic(hot_rows=hot)
        baseline = int(rows[key]["hbm_rows_per_step"])
        if predicted != baseline:
            raise ContractViolation(
                f"DMA-traffic contract violated [{key}]: planner predicts "
                f"{predicted} rows/step, committed baseline carries "
                f"{baseline}")
        reports.append(TrafficReport(key, predicted, baseline))
    return reports


def main(argv=None) -> int:
    import argparse

    from repro.core.engine import ENGINE_NAMES, get_engine

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_wallclock.json")
    ap.add_argument("--skip-traffic", action="store_true")
    args = ap.parse_args(argv)
    V = 150
    ok = True
    for name in ENGINE_NAMES:
        # fit the tiered hot prefix inside the certification vocab
        eng = get_engine(name, hot_rows=64) if name == "pallas_fused_tiered" \
            else get_engine(name)
        try:
            certify_engine_contracts(eng, vocab_size=V)
            print(f"contracts: {name:22s} zero-collective ✓  "
                  f"table-aliasing ✓")
        except ContractViolation as e:
            ok = False
            print(f"contracts: {name:22s} FAILED: {e}")
    if not args.skip_traffic:
        try:
            for r in certify_bench_traffic(args.baseline):
                print(f"contracts: {r.engine:22s} planner traffic "
                      f"{r.predicted_rows} rows/step == baseline ✓")
        except (ContractViolation, FileNotFoundError) as e:
            ok = False
            print(f"contracts: traffic FAILED: {e}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
