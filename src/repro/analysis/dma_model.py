"""Bounded-exhaustive model checker for the fused-pipeline DMA schedule.

``kernels/sgns_fused_pipe.kernel_schedule`` is the single source of
truth both pipelined kernels (``pallas_fused_pipe`` and
``pallas_fused_tiered``) execute: an unrolled sequence of
``(op, block, slot, guard)`` events whose guards are resolved against
the planner's hazard flags. Its safety argument — matched start/wait
pairs under every hazard outcome, slot-recycling waits serializing
buffer reuse, a ``ring_depth - 1`` look-behind window sufficing for
chain fidelity — was previously exercised by hand-picked hazard
vectors. This module replaces that with bounded-exhaustive
verification:

* :func:`check_events` — a symbolic state machine over one resolved
  event sequence. It tracks per-slot in-flight DMAs, buffer ownership
  and per-block lifecycle counts, and reports a :class:`Violation` for
  every breach of the three safety properties:

  1. **matched DMAs** — every block's gather and scatter is started
     exactly once and waited exactly once, the wait follows its start
     on the same slot semaphore, and nothing is left in flight at the
     end of the step;
  2. **no slot rewrite under an in-flight DMA** — a gather may not
     overwrite a ring buffer whose previous occupant's write-back has
     not completed (the VMEM slot-reuse race class), and two DMAs of
     the same kind may never be in flight on one slot semaphore;
  3. **no WAR escape** — block *b*'s gathers may not issue while any
     older block that *may* share rows with it still has an undrained
     write-back. ``may_overlap(b0, b)`` is symbolic: inside the
     look-behind window it is exactly the planner's hazard flag;
     outside the window the planner proves nothing, so the checker
     demands the drain unconditionally — which is precisely the
     obligation the slot-recycling waits must discharge.

* :func:`check_schedule_space` — drives :func:`check_events` over
  every ``ring_depth`` × block count × hazard vector in the bound
  (the full space: ``resolve_schedule`` is pure Python, so
  exhausting it is cheap).

* :func:`check_planner` — closes the loop on the *flags themselves*:
  constructs concrete id streams realizing every bounded pattern of
  window overlaps (W-table / C-table / none, per window offset,
  including padded-tail batches and hot-tier routing), recomputes the
  expected hazards from the raw ids with an independent numpy oracle,
  asserts ``plan_blocks`` agrees plus the dedup/position-map
  invariants, then runs the resolved schedule through
  :func:`check_events` with ``may_overlap`` derived from the *actual*
  row sets — end-to-end: real ids → planner flags → schedule → chain
  fidelity.

The mutation tests in ``tests/test_analysis.py`` feed this checker
seeded defects (a dropped wait, a slot collision, an off-by-one hazard
window, a planner that zeroes its flags) and assert each is flagged —
a checker that cannot fail is not a check.

Standalone: ``python -m repro.analysis.dma_model [--max-nblocks N]``.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.kernels.sgns_fused_pipe import (
    DMA_WAIT_FOR_START,
    kernel_schedule,
    plan_blocks,
    resolve_schedule,
)

RING_DEPTHS = (2, 3, 4)


@dataclass(frozen=True)
class Violation:
    """One breach of a schedule safety property."""

    rule: str           # matched-dma | slot-race | sem-overlap | war-hazard | order
    detail: str
    ring_depth: int
    nblocks: int
    hazard: tuple[int, ...]

    def __str__(self) -> str:
        return (f"[{self.rule}] S={self.ring_depth} nblocks={self.nblocks} "
                f"hazard={list(self.hazard)}: {self.detail}")


@dataclass
class ModelCheckReport:
    """Aggregate result of a model-checking sweep."""

    schedules_checked: int = 0
    plans_checked: int = 0
    violations: list[Violation] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def merge(self, other: "ModelCheckReport") -> "ModelCheckReport":
        self.schedules_checked += other.schedules_checked
        self.plans_checked += other.plans_checked
        self.violations.extend(other.violations)
        return self

    def summary(self) -> str:
        head = (f"{self.schedules_checked} schedules, "
                f"{self.plans_checked} planner cases checked: "
                f"{'OK' if self.ok else f'{len(self.violations)} violation(s)'}")
        return "\n".join([head] + [f"  {v}" for v in self.violations[:20]])


def hazard_may_overlap(hazard, ring_depth: int):
    """The symbolic worst-case overlap relation consistent with a hazard
    vector: outside the look-behind window the planner proves nothing
    (every pair may overlap — the slot-recycling waits must cover it);
    inside the window the flag is the only information."""
    def may(b0: int, b: int) -> bool:
        if b - b0 >= ring_depth:
            return True
        return bool(hazard[b])
    return may


def check_events(events, nblocks: int, ring_depth: int, *,
                 may_overlap, hazard=(), expect_slot_policy: bool = True,
                 ) -> list[Violation]:
    """Simulate one resolved ``(op, block, slot)`` event sequence and
    return every safety violation (empty = certified for this vector).

    ``may_overlap(b0, b)`` (b0 < b) answers "may blocks b0 and b touch
    a common parameter row?" — the WAR obligation is only discharged
    for pairs where it returns False. ``expect_slot_policy`` also pins
    the ``slot == block % ring_depth`` assignment the ring implements
    (turn off to check foreign schedules that use another policy).
    """
    S = ring_depth
    hz = tuple(int(h) for h in hazard) or (0,) * nblocks
    out: list[Violation] = []

    def bad(rule: str, detail: str) -> None:
        out.append(Violation(rule, detail, S, nblocks, hz))

    started_g = [0] * nblocks
    waited_g = [0] * nblocks
    computed = [0] * nblocks
    started_s = [0] * nblocks
    waited_s = [0] * nblocks
    gather_inflight: dict[int, int] = {}    # slot -> block
    scatter_inflight: dict[int, int] = {}   # slot -> block
    buffer_owner: dict[int, int] = {}       # slot -> block computed into it
    next_compute = 0

    for op, b, s in events:
        if not (0 <= b < nblocks):
            bad("order", f"{op} references block {b} outside [0, {nblocks})")
            continue
        if expect_slot_policy and s != b % S:
            bad("order", f"{op} of block {b} on slot {s}, ring policy "
                         f"assigns slot {b % S}")
        if op == "gather":
            if started_g[b]:
                bad("matched-dma", f"gather of block {b} started twice")
            started_g[b] += 1
            if s in gather_inflight:
                bad("sem-overlap",
                    f"gather of block {b} starts on slot {s} while block "
                    f"{gather_inflight[s]}'s gather is in flight on the "
                    f"same semaphore")
            gather_inflight[s] = b
            p = buffer_owner.get(s)
            if p is not None:
                if not started_s[p]:
                    bad("slot-race",
                        f"gather of block {b} overwrites buf[{s}] before "
                        f"block {p}'s write-back even started")
                elif not waited_s[p]:
                    bad("slot-race",
                        f"gather of block {b} rewrites buf[{s}] while "
                        f"block {p}'s scatter DMA is in flight from it")
            for b0 in range(b):
                if may_overlap(b0, b) and not waited_s[b0]:
                    bad("war-hazard",
                        f"gather of block {b} issues while block {b0} "
                        f"(may share rows) has an undrained write-back")
        elif op == "wait_gather":
            if gather_inflight.get(s) != b:
                bad("matched-dma",
                    f"wait_gather of block {b} on slot {s} without a "
                    f"matching in-flight start "
                    f"(in flight: {gather_inflight.get(s)})")
            else:
                del gather_inflight[s]
            waited_g[b] += 1
        elif op == "compute":
            if waited_g[b] != 1:
                bad("order", f"compute of block {b} before its gather "
                             f"completed (waits seen: {waited_g[b]})")
            if computed[b]:
                bad("order", f"block {b} computed twice")
            if b != next_compute:
                bad("order", f"compute of block {b} out of chain order "
                             f"(expected block {next_compute})")
            next_compute = b + 1
            computed[b] += 1
            buffer_owner[s] = b
        elif op == "scatter":
            if not computed[b]:
                bad("order", f"scatter of block {b} before its compute")
            if buffer_owner.get(s) != b:
                bad("slot-race",
                    f"scatter of block {b} reads buf[{s}] now owned by "
                    f"block {buffer_owner.get(s)} (stale write-back)")
            if started_s[b]:
                bad("matched-dma", f"scatter of block {b} started twice")
            started_s[b] += 1
            if s in scatter_inflight:
                bad("sem-overlap",
                    f"scatter of block {b} starts on slot {s} while block "
                    f"{scatter_inflight[s]}'s scatter is in flight on the "
                    f"same semaphore")
            scatter_inflight[s] = b
        elif op == "wait_scatter":
            if scatter_inflight.get(s) != b:
                bad("matched-dma",
                    f"wait_scatter of block {b} on slot {s} without a "
                    f"matching in-flight start "
                    f"(in flight: {scatter_inflight.get(s)})")
            else:
                del scatter_inflight[s]
            waited_s[b] += 1
        else:
            bad("order", f"unknown op {op!r}")

    for b in range(nblocks):
        for what, n in (("gather start", started_g[b]),
                        ("gather wait", waited_g[b]),
                        ("compute", computed[b]),
                        ("scatter start", started_s[b]),
                        ("scatter wait", waited_s[b])):
            if n != 1:
                bad("matched-dma", f"block {b}: {what} ran {n}× (want 1)")
    for kind, inflight in (("gather", gather_inflight),
                           ("scatter", scatter_inflight)):
        for s, b in inflight.items():
            bad("matched-dma",
                f"{kind} of block {b} still in flight on slot {s} at "
                f"step end (unwaited DMA)")
    # sanity: the start→wait pairing above must agree with the kernels'
    # declared DMA semantics metadata
    assert set(DMA_WAIT_FOR_START) == {"gather", "scatter"}
    return out


# ---------------------------------------------------------------------------
# Pass 1a: exhaust the schedule space (every hazard vector in the bound).
# ---------------------------------------------------------------------------
def check_schedule_space(ring_depths=RING_DEPTHS, max_nblocks: int = 6,
                         schedule_fn=kernel_schedule) -> ModelCheckReport:
    """Exhaustively check every ``(ring_depth, nblocks, hazard vector)``
    in the bound. ``schedule_fn`` is injectable so the mutation tests
    can hand the checker a deliberately defective schedule generator.

    Also re-verifies the guard *partition* property structurally: for a
    fixed vector, resolving the guards must keep exactly one
    wait_scatter site per block — that is what :func:`check_events`'
    exactly-once counts certify.
    """
    rep = ModelCheckReport()
    for S in ring_depths:
        for nblocks in range(1, max_nblocks + 1):
            ev_guarded = schedule_fn(nblocks, S)
            for bits in itertools.product((0, 1), repeat=nblocks):
                # plan_blocks never flags block 0 (nothing precedes it);
                # sweeping it anyway is free and proves the schedule
                # never reads hazard[0]
                resolved = [(op, b, s) for op, b, s, g in ev_guarded
                            if g is None or all(bool(bits[f]) is w
                                                for f, w in g)]
                rep.violations.extend(check_events(
                    resolved, nblocks, S, hazard=bits,
                    may_overlap=hazard_may_overlap(bits, S)))
                rep.schedules_checked += 1
    return rep


# ---------------------------------------------------------------------------
# Pass 1b: the planner's flags against an independent oracle, then the
# planner→schedule composition end-to-end on concrete id streams.
# ---------------------------------------------------------------------------
_W_BASE, _C_BASE, _N_BASE = 1000, 4000, 7000
_PLAN_V = 10_000


def _stream_ids(nblocks: int, blk: int, choices, S: int):
    """Concrete (centers, contexts, negatives) realizing an overlap
    pattern: ``choices[(b, m)]`` ∈ {0: none, 1: W overlap, 2: C
    overlap} makes block b share a row with block b-m. Overlap targets
    are always the *last* pair slot of the target block (never itself
    rewritten — overlap writes occupy slots < S-1 ≤ blk-2), so the
    intended intersection is guaranteed to exist."""
    cen = np.zeros((nblocks, blk), np.int32)
    ctx = np.zeros((nblocks, blk), np.int32)
    neg = np.zeros((nblocks, blk, 1), np.int32)
    for b in range(nblocks):
        for j in range(blk):
            cen[b, j] = _W_BASE + b * 100 + j
            ctx[b, j] = _C_BASE + b * 100 + j
            neg[b, j, 0] = _N_BASE + b * 100 + j
    for (b, m), choice in choices.items():
        j = m - 1                   # one dedicated pair slot per offset
        if choice == 1:
            cen[b, j] = _W_BASE + (b - m) * 100 + (blk - 1)
        elif choice == 2:
            # alternate the C-table route: context ids vs negative ids
            # (both land in the shared C row set)
            tgt = _C_BASE + (b - m) * 100 + (blk - 1)
            if (b + m) % 2:
                neg[b, j, 0] = tgt
            else:
                ctx[b, j] = tgt
    return cen.reshape(-1), ctx.reshape(-1), neg.reshape(-1, 1)


def _expected_sets(c, x, n, nblocks: int, blk: int, hot_rows: int):
    """Independent numpy reimplementation of the planner's padded,
    tier-filtered per-block row sets (the oracle the jnp planner is
    checked against). Padding replicates element 0, exactly like
    ``_pad_to_blocks``."""
    def blocks(a):
        a = np.asarray(a).reshape(a.shape[0], -1)
        pad = nblocks * blk - a.shape[0]
        if pad:
            a = np.concatenate([a, np.repeat(a[:1], pad, axis=0)])
        return a.reshape(nblocks, blk, -1)

    cb, xb, nb = blocks(c), blocks(x), blocks(n)
    w_sets, c_sets = [], []
    for b in range(nblocks):
        w = set(int(v) for v in cb[b].ravel() if v >= hot_rows)
        cc = set(int(v) for v in np.concatenate(
            [xb[b].ravel(), nb[b].ravel()]) if v >= hot_rows)
        w_sets.append(w)
        c_sets.append(cc)
    return w_sets, c_sets


def _expected_hazards(w_sets, c_sets, S: int):
    n = len(w_sets)
    hz = np.zeros(n, np.int32)
    for b in range(n):
        for m in range(1, min(S, b + 1)):
            if w_sets[b] & w_sets[b - m] or c_sets[b] & c_sets[b - m]:
                hz[b] = 1
    return hz


def _check_one_plan(c, x, n, blk: int, S: int, hot_rows: int,
                    plan_fn, rep: ModelCheckReport) -> None:
    import jax.numpy as jnp

    B = c.shape[0]
    nblocks = -(-B // blk)
    plan = plan_fn(jnp.asarray(c), jnp.asarray(x), jnp.asarray(n),
                   _PLAN_V, blk, hot_rows=hot_rows, ring_depth=S)
    hz = tuple(int(v) for v in np.asarray(plan.hazard))
    w_sets, c_sets = _expected_sets(c, x, n, nblocks, blk, hot_rows)

    def bad(rule, detail):
        rep.violations.append(Violation(rule, detail, S, nblocks, hz))

    exp = _expected_hazards(w_sets, c_sets, S)
    if not np.array_equal(np.asarray(plan.hazard), exp):
        bad("war-hazard",
            f"planner hazards {list(np.asarray(plan.hazard))} != windowed "
            f"look-behind oracle {list(exp)} (hot_rows={hot_rows}, B={B})")
    # dedup + position-map invariants per block/table
    uw, uc = np.asarray(plan.uw), np.asarray(plan.uc)
    n_w, n_c = np.asarray(plan.n_w), np.asarray(plan.n_c)
    pos = [(uw, n_w, np.asarray(plan.w_pos), np.asarray(plan.cen), w_sets),
           (uc, n_c, np.asarray(plan.cp_pos), np.asarray(plan.ctx), c_sets),
           (uc, n_c, np.asarray(plan.cn_pos), np.asarray(plan.neg), c_sets)]
    for b in range(nblocks):
        for u, cnt, p, ids, sets in pos:
            k = int(cnt[b])
            valid = u[b, :k]
            if not (np.all(np.diff(valid) > 0) if k > 1 else True):
                bad("order", f"block {b}: unique rows not strictly sorted")
            if np.any(u[b, k:] != _PLAN_V):
                bad("order", f"block {b}: padding slots not sentinel")
            if set(int(v) for v in valid) - sets[b]:
                bad("order", f"block {b}: unique set exceeds touched rows")
            for j, rid in enumerate(ids[b]):
                if rid >= hot_rows:
                    if u[b, p[b, j]] != rid:
                        bad("order",
                            f"block {b} pair {j}: position map does not "
                            f"recover row {int(rid)}")
                elif p[b, j] < k:
                    bad("order",
                        f"block {b} pair {j}: hot row {int(rid)} mapped "
                        f"into the DMA'd region (slot {int(p[b, j])} < "
                        f"{k})")
        # every deduped (cold) row must come from the block's touched set
    if int(np.asarray(plan.mask).sum()) != B:
        bad("order", f"mask covers {int(np.asarray(plan.mask).sum())} "
                     f"pairs, batch has {B}")

    # end-to-end: the schedule this plan resolves to must preserve chain
    # fidelity for the ACTUAL row sets
    def set_overlap(b0, b):
        return bool(w_sets[b] & w_sets[b0] or c_sets[b] & c_sets[b0])

    rep.violations.extend(check_events(
        resolve_schedule(hz, S), nblocks, S, hazard=hz,
        may_overlap=set_overlap))
    rep.plans_checked += 1


def check_planner(ring_depths=RING_DEPTHS, max_nblocks: int = 4,
                  include_tails: bool = True,
                  plan_fn=plan_blocks) -> ModelCheckReport:
    """Constructively exhaustive planner check over bounded overlap
    patterns: for every ring depth × block count, every assignment of
    {none, W-overlap, C-overlap} to every (block, window-offset) pair,
    with padded-tail variants and a hot-tier routing case per shape.
    ``plan_fn`` is injectable for the mutation tests."""
    rep = ModelCheckReport()
    K = 1
    for S in ring_depths:
        blk = max(S, 3)     # overlap slots 0..S-2 + one stable last slot
        for nblocks in range(1, max_nblocks + 1):
            slots = [(b, m) for b in range(1, nblocks)
                     for m in range(1, min(S, b + 1))]
            tails = (0, 1) if include_tails and nblocks >= 2 else (0,)
            for pattern in itertools.product((0, 1, 2), repeat=len(slots)):
                choices = dict(zip(slots, pattern))
                cen, ctx, neg = _stream_ids(nblocks, blk, choices, S)
                for tail in tails:
                    B = nblocks * blk - tail
                    _check_one_plan(cen[:B], ctx[:B], neg[:B], blk, S,
                                    0, plan_fn, rep)
            # hot-tier routing: one shared hot id in every block's C set
            # — must produce zero hazards with the tier on, and a full
            # hazard chain with it off
            cen, ctx, neg = _stream_ids(nblocks, blk, {}, S)
            hot_id = 5
            ctx = ctx.copy()
            ctx[::blk] = hot_id                     # pair 0 of every block
            _check_one_plan(cen, ctx, neg, blk, S, hot_rows=10,
                            plan_fn=plan_fn, rep=rep)
            if nblocks >= 2:
                import jax.numpy as jnp
                plan = plan_fn(jnp.asarray(cen), jnp.asarray(ctx),
                               jnp.asarray(neg), _PLAN_V, blk,
                               hot_rows=0, ring_depth=S)
                if not np.asarray(plan.hazard)[1:].all():
                    rep.violations.append(Violation(
                        "war-hazard",
                        "shared cold id across all blocks must flag every "
                        "window", S, nblocks, tuple()))
                if not bool(np.asarray(plan.uc == hot_id).any()):
                    rep.violations.append(Violation(
                        "order", "cold shared id missing from dedup sets",
                        S, nblocks, tuple()))
                rep.plans_checked += 1
    return rep


def run(max_nblocks_schedule: int = 6, max_nblocks_planner: int = 4,
        ring_depths=RING_DEPTHS) -> ModelCheckReport:
    """The full pass: schedule-space sweep + planner integration."""
    rep = check_schedule_space(ring_depths, max_nblocks_schedule)
    return rep.merge(check_planner(ring_depths, max_nblocks_planner))


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--max-nblocks", type=int, default=6,
                    help="schedule-space block-count bound (default 6)")
    ap.add_argument("--max-planner-nblocks", type=int, default=4,
                    help="planner overlap-pattern block bound (default 4)")
    ap.add_argument("--ring-depths", default="2,3,4")
    args = ap.parse_args(argv)
    depths = tuple(int(s) for s in args.ring_depths.split(","))
    rep = run(args.max_nblocks, args.max_planner_nblocks, depths)
    print(f"dma_model: {rep.summary()}")
    return 0 if rep.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
