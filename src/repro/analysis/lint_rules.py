"""Repo-specific AST lint for reproducibility hazards.

Generic linters can't know this repo's invariants. Four rules encode
the classes of bug the project has actually hit or designed against:

* **RL001 arithmetic-seed** — a PRNG seed built by arithmetic
  (``PRNGKey(seed + worker)``, ``default_rng(seed * 31 + i)``).
  Arithmetic seed derivation collides across (worker, epoch) lattices;
  the repo's convention is ``jax.random.fold_in`` / tuple-fed
  ``np.random.SeedSequence`` (see ``core/driver._epoch_rng``).
* **RL002 searchsorted-side** — ``searchsorted`` without an explicit
  ``side=``. For CDF inversion the side decides whether a u exactly on
  a boundary lands in the open or closed bucket; the default silently
  changes sampling semantics. Inside ``data/`` the side must be
  ``"right"`` (inverse-CDF convention of ``pairs.cdf_draw``).
* **RL003 unseeded-randomness** — legacy global-state NumPy RNG
  (``np.random.rand`` etc.), stdlib ``random.*``, argless
  ``default_rng()``, or wall-clock time fed to a seed constructor,
  inside ``core/`` or ``kernels/``. Everything in the training core
  must be replayable from explicit seeds.
* **RL004 collective-in-train-path** — ``lax.psum``-family collectives
  in ``kernels/``, ``data/``, ``core/engine.py`` or ``core/sgns.py``.
  The paper's zero-synchronization claim lives or dies here; only
  ``core/async_trainer.py`` (which hosts the *synchronous baseline*
  backends) may name collectives. The **merge phase** is intentionally
  outside this scope: merging happens after training ends, so the one
  sanctioned collective — the fixed-order sharded Gram reduction in
  ``sharding/merge.py`` (``core/merge*.py`` consumes it) — does not
  threaten the claim; its lowering is pinned to exactly one
  ``all_gather`` by ``tests/test_analysis.py`` instead.

Suppression: end the offending line with ``# repro-lint:
ignore[RL002]`` (comma-separate several rules) plus a justification —
the pragma is a reviewed exception, not an off switch.

Standalone: ``python -m repro.analysis.lint_rules [root ...]``.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path

PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*ignore\[([A-Z0-9,\s]+)\]")

# Seed sinks: calls whose argument IS a seed.
_SEED_SINKS = {"PRNGKey", "SeedSequence", "default_rng", "fold_in", "key"}
# Legacy global-state numpy RNG entry points (np.random.<name>(...)).
_NP_LEGACY = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "seed", "choice", "shuffle", "permutation", "uniform",
    "normal", "standard_normal", "binomial", "poisson", "exponential",
}
_WALLCLOCK = {"time", "time_ns", "monotonic", "monotonic_ns",
              "perf_counter", "perf_counter_ns"}
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather",
                "all_to_all", "ppermute", "pshuffle", "psum_scatter"}


@dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _call_name(node: ast.AST) -> str:
    """Rightmost identifier of a call target: ``a.b.c(...)`` → ``c``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name: ``np.random.rand`` → ``np.random.rand``."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _has_name_operand(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Name) for n in ast.walk(node))


def _in_scope(rel: str, scopes: tuple[str, ...]) -> bool:
    return any(rel == s or rel.startswith(s) for s in scopes)


def _check_tree(tree: ast.AST, rel: str) -> list[LintFinding]:
    found: list[LintFinding] = []

    def add(rule: str, node: ast.AST, msg: str) -> None:
        found.append(LintFinding(rule, rel, node.lineno, msg))

    in_core = _in_scope(rel, ("core/", "kernels/"))
    in_train_path = _in_scope(
        rel, ("kernels/", "data/", "core/engine.py", "core/sgns.py"))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fname = _call_name(node.func)
            dotted = _dotted(node.func)

            # RL001: arithmetic seed construction fed to a seed sink.
            if fname in _SEED_SINKS:
                for arg in node.args:
                    if isinstance(arg, ast.BinOp) and _has_name_operand(arg):
                        add("RL001", arg,
                            f"arithmetic seed passed to {fname}() — derive "
                            f"streams with jax.random.fold_in or a "
                            f"tuple-fed np.random.SeedSequence instead")
                # RL003 (seed-sink flavour): wall-clock seeding.
                if in_core:
                    for sub in ast.walk(node):
                        if (isinstance(sub, ast.Call)
                                and sub is not node
                                and _dotted(sub.func).startswith("time.")
                                and _call_name(sub.func) in _WALLCLOCK):
                            add("RL003", sub,
                                f"wall-clock {_dotted(sub.func)}() used as "
                                f"a seed for {fname}() — runs become "
                                f"unreplayable")

            # RL002: searchsorted side.
            if fname == "searchsorted":
                side = next((kw for kw in node.keywords
                             if kw.arg == "side"), None)
                if side is None:
                    add("RL002", node,
                        "searchsorted without explicit side= — boundary "
                        "semantics of CDF inversion must be spelled out")
                elif (rel.startswith("data/")
                      and isinstance(side.value, ast.Constant)
                      and side.value.value != "right"):
                    add("RL002", node,
                        f"searchsorted side={side.value.value!r} in data/ — "
                        f"inverse-CDF sampling requires side='right'")

            if in_core:
                # RL003: legacy global-state numpy RNG.
                if (dotted.startswith(("np.random.", "numpy.random."))
                        and fname in _NP_LEGACY):
                    add("RL003", node,
                        f"legacy global-state RNG {dotted}() — use an "
                        f"explicit np.random.Generator")
                # RL003: stdlib random module.
                if dotted.startswith("random.") and dotted.count(".") == 1:
                    add("RL003", node,
                        f"stdlib {dotted}() draws from hidden global "
                        f"state — use an explicit seeded Generator")
                # RL003: unseeded default_rng().
                if (fname == "default_rng" and not node.args
                        and not node.keywords):
                    add("RL003", node,
                        "default_rng() without a seed — entropy-seeded, "
                        "unreplayable")

            # RL004: collectives in the zero-collective train path.
            if in_train_path and fname in _COLLECTIVES:
                add("RL004", node,
                    f"collective {dotted or fname}() in the "
                    f"zero-collective train path — synchronization "
                    f"belongs to the baseline backends in "
                    f"core/async_trainer.py only")
    return found


def _suppressed(finding: LintFinding, lines: list[str]) -> bool:
    if not (1 <= finding.line <= len(lines)):
        return False
    m = PRAGMA_RE.search(lines[finding.line - 1])
    if not m:
        return False
    rules = {r.strip() for r in m.group(1).split(",")}
    return finding.rule in rules


def lint_file(path: Path, root: Path) -> list[LintFinding]:
    rel = path.relative_to(root).as_posix()
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [LintFinding("RL000", rel, e.lineno or 0,
                            f"syntax error: {e.msg}")]
    lines = src.splitlines()
    return [f for f in _check_tree(tree, rel) if not _suppressed(f, lines)]


def run_lint(root) -> list[LintFinding]:
    """Lint every ``*.py`` under ``root`` (a ``src/repro``-like tree:
    rule path-scoping is relative to it). Returns surviving findings."""
    root = Path(root)
    found: list[LintFinding] = []
    for path in sorted(root.rglob("*.py")):
        found.extend(lint_file(path, root))
    return found


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("roots", nargs="*", default=["src/repro"],
                    help="package roots to lint (default: src/repro)")
    args = ap.parse_args(argv)
    findings: list[LintFinding] = []
    for root in args.roots:
        findings.extend(run_lint(root))
    for f in findings:
        print(f"lint: {f}")
    n = len(findings)
    print(f"lint: {n} finding{'s' if n != 1 else ''} in "
          f"{', '.join(args.roots)}" + (": OK" if not n else ""))
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
