"""Static VMEM footprint estimation and budget enforcement.

Every Pallas engine config implies a VMEM-resident working set the
Mosaic compiler will demand at lowering time: scratch rings, pinned
table prefixes, whole resident tables, plan operands. On CI (interpret
mode) an over-budget config runs fine and only fails weeks later on
real TPU hardware — the ROADMAP's open Mosaic item. This pass computes
the footprint *statically* from the engine dials
``(block_pairs, ring_depth, hot_rows)`` and the model shape
``(V, d, K, B)``, so bad configs are rejected at plan time:

* :class:`repro.core.async_trainer.AsyncShardTrainer` checks dial
  consistency at construction (``engine.validate``);
* ``train_sgns`` / ``dryrun_sgns`` run :func:`check_vmem_budget`
  before training/lowering (``--vmem-budget-mb``);
* ``python -m repro.analysis`` certifies each engine's reference
  operating shape fits the default budget.

The estimate models the terms the kernels actually allocate (scratch
shapes + VMEM-spec operands), not XLA's transient buffers — it is a
lower bound designed to catch the catastrophic misconfigurations
(VMEM-resident tables past the cliff, a deep ring × huge blocks, a hot
prefix larger than the budget), with headroom left to the real
compiler.

Standalone: ``python -m repro.analysis.vmem --engine pallas_fused_pipe
--vocab 300000 --dim 500``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

F32 = 4     # bytes; every table/scratch buffer in the stack is f32/i32

# One core's VMEM order (TPU v4/v5e ≈ 16 MiB). A deliberate, documented
# default — override per call/CLI for other parts.
DEFAULT_VMEM_BUDGET_BYTES = 16 * 2 ** 20


class VmemBudgetError(ValueError):
    """An engine config's static VMEM footprint exceeds the budget."""


@dataclass(frozen=True)
class VmemEstimate:
    """Static VMEM working set of one engine config at one shape."""

    engine: str
    shape: dict = field(default_factory=dict)   # V, d, K, B + dials
    terms: dict = field(default_factory=dict)   # name -> bytes

    @property
    def total_bytes(self) -> int:
        return sum(self.terms.values())

    def summary(self) -> str:
        mb = self.total_bytes / 2 ** 20
        parts = ", ".join(f"{k}={v / 2 ** 20:.2f}MiB"
                          for k, v in sorted(self.terms.items(),
                                             key=lambda kv: -kv[1]))
        return (f"{self.engine}: {mb:.2f} MiB VMEM "
                f"({parts or 'no VMEM-resident requirement'})")


def _nblocks(B: int, blk: int) -> int:
    return -(-B // blk)


def estimate_vmem(engine, *, vocab_size: int, dim: int, negatives: int,
                  batch: int) -> VmemEstimate:
    """Static VMEM footprint of one step of ``engine`` at this shape.

    ``engine`` is an :class:`repro.core.engine.UpdateEngine` instance or
    spec string. Dense/sparse engines have no VMEM-resident requirement
    (XLA manages placement) and estimate to zero.
    """
    from repro.core.engine import get_engine
    from repro.kernels.sgns_fused_hbm import _pick_block_pairs

    eng = get_engine(engine)
    V, d, K, B = vocab_size, dim, negatives, batch
    shape = {"V": V, "d": d, "K": K, "B": B}
    terms: dict[str, int] = {}
    name = eng.name

    if name in ("dense", "sparse"):
        pass
    elif name == "pallas":
        # VMEM-tile row-grad kernel: (blk_b, d) w/cp + (blk_b, K, d) cn
        # tiles in and the same three gradient tiles out; ops.py pads B
        # up to a power of two before picking the tile
        from repro.kernels.sgns_update import _pick_block_b
        Bp = 1 << (max(B, 8) - 1).bit_length()
        bt = eng.block_b or _pick_block_b(Bp, K, d)
        shape["block_b"] = bt
        terms["grad_tiles"] = 2 * bt * (K + 2) * d * F32
    elif name == "pallas_fused":
        # whole tables + noise tables resident, plus the gathered rows
        # and their updates for the full batch
        terms["resident_tables"] = 2 * V * d * F32
        terms["noise_tables"] = 2 * V * F32
        terms["batch_rows"] = 2 * B * (K + 2) * d * F32
    elif name == "pallas_fused_hbm":
        blk = _pick_block_pairs(B, eng.block_pairs)
        shape["block_pairs"] = blk
        terms["row_scratch"] = (blk * (K + 2) + 1) * d * F32
        terms["noise_tables"] = 2 * V * F32
    elif name in ("pallas_fused_pipe", "pallas_fused_tiered"):
        blk = _pick_block_pairs(B, eng.block_pairs)
        S = eng.ring_depth
        nb = _nblocks(B, blk)
        shape.update(block_pairs=blk, ring_depth=S)
        terms["ring_w"] = S * blk * d * F32
        terms["ring_c"] = S * blk * (K + 1) * d * F32
        # VMEM plan operands: uw, uc, w_pos, cp_pos, cn_pos, mask
        terms["plan_operands"] = nb * blk * (2 * K + 5) * F32
        if name == "pallas_fused_tiered":
            hot = max(0, min(int(eng.hot_rows), V))
            shape["hot_rows"] = hot
            terms["hot_prefix"] = 2 * (hot + 1) * d * F32
            terms["block_ids"] = nb * blk * (K + 2) * F32
    else:   # future engines: unknown ⇒ no static claim
        pass
    return VmemEstimate(eng.describe(), shape, terms)


def check_vmem_budget(engine, *, vocab_size: int, dim: int, negatives: int,
                      batch: int,
                      budget_bytes: int = DEFAULT_VMEM_BUDGET_BYTES,
                      ) -> VmemEstimate:
    """Estimate and enforce: raises :class:`VmemBudgetError` with the
    per-term breakdown and dial advice when the footprint exceeds the
    budget; returns the estimate otherwise."""
    est = estimate_vmem(engine, vocab_size=vocab_size, dim=dim,
                        negatives=negatives, batch=batch)
    if est.total_bytes > budget_bytes:
        advice = {
            "pallas_fused": "use the HBM-resident family "
                            "(pallas_fused_hbm/_pipe/_tiered) past the "
                            "VMEM cliff",
            "pallas_fused_hbm": "reduce block_pairs",
            "pallas_fused_pipe": "reduce block_pairs or ring_depth",
            "pallas_fused_tiered": "reduce hot_rows, block_pairs or "
                                   "ring_depth",
        }.get(est.engine.split(":")[0], "reduce the blocking dials")
        raise VmemBudgetError(
            f"VMEM budget exceeded: {est.summary()} > "
            f"{budget_bytes / 2 ** 20:.1f} MiB budget — {advice}")
    return est


def main(argv=None) -> int:
    import argparse

    from repro.core.engine import ENGINE_NAMES, get_engine

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engine", default=None,
                    help="one engine spec (default: every registered "
                         "engine)")
    ap.add_argument("--vocab", type=int, default=300_000)
    ap.add_argument("--dim", type=int, default=500)
    ap.add_argument("--negatives", type=int, default=5)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--hot-rows", type=int, default=None)
    ap.add_argument("--ring-depth", type=int, default=None)
    ap.add_argument("--block-pairs", type=int, default=None)
    ap.add_argument("--budget-mb", type=float, default=16.0,
                    help="0 disables enforcement (report only)")
    args = ap.parse_args(argv)
    overrides = {k: v for k, v in (("hot_rows", args.hot_rows),
                                   ("ring_depth", args.ring_depth),
                                   ("block_pairs", args.block_pairs))
                 if v is not None}
    names = [args.engine] if args.engine else list(ENGINE_NAMES)
    ok = True
    for name in names:
        eng = get_engine(name, **{k: v for k, v in overrides.items()
                                  if hasattr(get_engine(name), k)})
        try:
            if args.budget_mb:
                est = check_vmem_budget(
                    eng, vocab_size=args.vocab, dim=args.dim,
                    negatives=args.negatives, batch=args.batch,
                    budget_bytes=int(args.budget_mb * 2 ** 20))
            else:
                est = estimate_vmem(eng, vocab_size=args.vocab,
                                    dim=args.dim, negatives=args.negatives,
                                    batch=args.batch)
            print(f"vmem: {est.summary()}")
        except VmemBudgetError as e:
            ok = False
            print(f"vmem: REJECTED {e}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
