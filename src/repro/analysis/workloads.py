"""Shared reference workloads for the analysis passes and benchmarks.

``zipf50k`` — the Zipfian paper-shape direct-step workload whose
``<engine>@zipf50k`` rows in ``BENCH_wallclock.json`` carry the
planner-derived HBM row-traffic columns the CI bench gate compares.
Defined ONCE here so ``benchmarks/bench_wallclock.py`` (which measures
it) and ``repro.analysis.contracts`` (which certifies the committed
traffic numbers against the planner) can never drift apart. The id
construction is deterministic and must stay bit-stable: the committed
baseline rows were produced by exactly these seeds.
"""

from __future__ import annotations

import numpy as np

# V=50k×512 at batch 8192: small blocks maximize cross-block hot-row
# recurrence; the large batch amortizes the per-step hot-prefix DMA
# over 64 blocks.
ZIPF50K = {"V": 50_000, "D": 512, "B": 8192, "K": 5, "BLK": 128,
           "HOT": 2048}


def zipf50k_ids():
    """The workload's deterministic id streams: ``(centers, contexts,
    negatives, noise_table, key)``. Power-law ids over the
    frequency-sorted vocab (``choice`` keeps the mid-frequency strata
    populated, unlike a raw Zipf draw whose mass all lands on a handful
    of head ids); negatives are the replayed counter-PRNG draw the
    fused kernels perform in-kernel."""
    import jax
    import jax.numpy as jnp

    from repro.data.pairs import build_noise_table
    from repro.kernels.sgns_fused import _as_seed, fused_negative_ids

    V, B, K = ZIPF50K["V"], ZIPF50K["B"], ZIPF50K["K"]
    rng = np.random.default_rng(11)
    p = 1.0 / np.arange(1, V + 1) ** 1.05
    p /= p.sum()
    c = jnp.asarray(rng.choice(V, size=B, p=p).astype(np.int32))
    x = jnp.asarray(rng.choice(V, size=B, p=p).astype(np.int32))
    table = build_noise_table((p * 1e6).astype(np.float32), kind="alias")
    key = jax.random.PRNGKey(3)
    neg = fused_negative_ids(_as_seed(key), table["prob"], table["alias"],
                             (B, K))
    return c, x, neg, table, key


def zipf50k_row_traffic(hot_rows: int) -> int:
    """Planner-predicted HBM rows DMA'd per step at this hot-tier
    setting — the ``hbm_rows_per_step`` column of the ``@zipf50k``
    bench rows."""
    from repro.kernels.sgns_fused_pipe import plan_blocks, plan_row_traffic

    c, x, neg, _, _ = zipf50k_ids()
    plan = plan_blocks(c, x, neg, ZIPF50K["V"], ZIPF50K["BLK"],
                       hot_rows=hot_rows)
    return plan_row_traffic(plan, hot_rows=hot_rows)
