from repro.checkpoint.io import (
    MANIFEST_NAME,
    ServableTable,
    latest_step_path,
    load_checkpoint,
    load_manifest,
    load_table,
    next_version,
    publish_table,
    save_checkpoint,
)

__all__ = [
    "MANIFEST_NAME",
    "ServableTable",
    "latest_step_path",
    "load_checkpoint",
    "load_manifest",
    "load_table",
    "next_version",
    "publish_table",
    "save_checkpoint",
]
