"""Checkpointing + the versioned merged-table artifact.

Two layers live here:

1. **Pytree checkpoints** (:func:`save_checkpoint` /
   :func:`load_checkpoint`): flat-key .npz save/restore for arbitrary
   dict/list/tuple trees — training state, single-host scope
   (device_get on save; the caller re-shards on restore).

2. **Published embedding artifacts** (:func:`publish_table` /
   :func:`load_table`): the handoff point between the merge phase and
   the serving tier. An artifact directory holds monotonically
   versioned, immutable table files plus a ``MANIFEST.json`` naming the
   latest complete one. Both the table file and the manifest are
   written to a temp name in the same directory and atomically
   ``os.replace``d, so a reader (or a crash at any instant) can only
   ever observe:

   * no manifest — nothing published yet;
   * a manifest pointing at a fully-written table file.

   A partial table write leaves only a ``.tmp-``-prefixed file that
   readers never look at; a crash *between* the table rename and the
   manifest rename leaves an orphan table file that readers ignore
   (manifest is the source of truth) and whose version number is never
   reused (:func:`next_version` scans files as well as the manifest).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}

    def walk(path, node):
        if isinstance(node, dict):
            if not node:
                out[_SEP.join(path) + "@emptydict"] = np.zeros(0)
                return
            for k in sorted(node):
                walk(path + [str(k)], node[k])
        elif isinstance(node, (list, tuple)):
            if not node:
                out[_SEP.join(path) + "@emptylist"] = np.zeros(0)
                return
            for i, v in enumerate(node):
                walk(path + [f"#{i}"], v)
        elif node is None:
            out[_SEP.join(path) + "@none"] = np.zeros(0)
        else:
            out[_SEP.join(path)] = np.asarray(jax.device_get(node))

    walk([], tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]):
    _EMPTY_LIST = object()
    _EMPTY_DICT = object()
    root: dict = {}
    for key, val in flat.items():
        for tag, marker in (("@none", None), ("@emptylist", _EMPTY_LIST),
                            ("@emptydict", _EMPTY_DICT)):
            if key.endswith(tag):
                key = key[: -len(tag)]
                val = marker
                break
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if node is _EMPTY_LIST:
            return []
        if node is _EMPTY_DICT:
            return {}
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.startswith("#") for k in keys):
            return [fix(node[f"#{i}"]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, tree, step: int | None = None,
                    extra: dict | None = None) -> None:
    """Save a dict/list/tuple pytree of arrays to ``path`` (.npz) plus a
    ``<path>.meta.json`` sidecar carrying ``step`` and ``extra``.
    Not atomic — use :func:`publish_table` for tables a live reader may
    race with."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    meta = {"step": step, **(extra or {})}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str):
    """Restore a :func:`save_checkpoint` pytree. Returns ``(tree, meta)``
    where ``meta`` is the sidecar dict (empty if the sidecar is gone)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    tree = _unflatten({k: data[k] for k in data.files})
    meta = {}
    meta_path = path + ".meta.json" if os.path.exists(path + ".meta.json") \
        else path[:-4] + ".npz.meta.json"
    if os.path.exists(path + ".meta.json"):
        meta = json.load(open(path + ".meta.json"))
    elif os.path.exists(meta_path):
        meta = json.load(open(meta_path))
    return tree, meta


def latest_step_path(ckpt_dir: str, prefix: str = "step_") -> str | None:
    """Path of the highest-step ``<prefix>N.npz`` checkpoint in
    ``ckpt_dir``, or ``None`` if there is none."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.startswith(prefix) and f.endswith(".npz"):
            try:
                steps.append((int(f[len(prefix):-4]), f))
            except ValueError:
                pass
    if not steps:
        return None
    return os.path.join(ckpt_dir, max(steps)[1])


# ---------------------------------------------------------------------------
# Versioned merged-table artifacts (the merge → serve handoff).
# ---------------------------------------------------------------------------
MANIFEST_NAME = "MANIFEST.json"
_TABLE_FMT = "table_v{:06d}.npz"
_TMP_PREFIX = ".tmp-"
# Keys of publish_table's array kwargs, in npz order. Optional ones are
# simply absent from the file when not published.
_REQUIRED_KEYS = ("emb", "valid")
_OPTIONAL_KEYS = ("word_ids", "worker_ids", "mask", "transforms", "models")


@dataclass(frozen=True)
class ServableTable:
    """One complete, immutable published table version.

    Required payload:
        ``emb (V, d)``   — the merged embedding table;
        ``valid (V,)``   — rows the table actually covers (union
                           presence of the folded sub-models).

    Optional serving sidecars (``None`` when not published):
        ``word_ids (V,)``      — raw word id per table row (the external
                                 query namespace);
        ``worker_ids (n,)``    — which workers each sub-model axis index
                                 corresponds to, canonical order;
        ``mask (n, V)``        — per-sub-model presence;
        ``transforms (n,d,d)`` — ALiR alignment maps ``W_i``, enough to
                                 reconstruct any sub-model's *missing*
                                 rows on the fly (``Y[w] @ W_i.T``);
        ``models (n, V, d)``   — the aligned-input sub-models themselves
                                 (needed to serve a sub-model's
                                 *present* rows in its own space).
    """

    emb: np.ndarray
    valid: np.ndarray
    version: int
    meta: dict = field(default_factory=dict)
    word_ids: np.ndarray | None = None
    worker_ids: np.ndarray | None = None
    mask: np.ndarray | None = None
    transforms: np.ndarray | None = None
    models: np.ndarray | None = None

    @property
    def dim(self) -> int:
        """Embedding dimensionality of the published table."""
        return int(self.emb.shape[1])


def _table_path(artifact_dir: str, version: int) -> str:
    return os.path.join(artifact_dir, _TABLE_FMT.format(version))


def _atomic_write_bytes(path: str, write_fn) -> None:
    """Write via a same-directory temp file + ``os.replace``. ``write_fn``
    receives the temp path; on any failure the temp file is removed (a
    crash can still leave one behind — readers never match the
    ``.tmp-`` prefix, and publishers overwrite/ignore it)."""
    d, name = os.path.split(path)
    tmp = os.path.join(d, f"{_TMP_PREFIX}{name}.{os.getpid()}")
    try:
        write_fn(tmp)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_manifest(artifact_dir: str) -> dict | None:
    """The artifact directory's manifest, or ``None`` before the first
    completed publish."""
    path = os.path.join(artifact_dir, MANIFEST_NAME)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _scan_table_versions(artifact_dir: str) -> list[int]:
    if not os.path.isdir(artifact_dir):
        return []
    out = []
    for f in os.listdir(artifact_dir):
        if f.startswith("table_v") and f.endswith(".npz"):
            try:
                out.append(int(f[len("table_v"):-4]))
            except ValueError:
                pass
    return sorted(out)


def next_version(artifact_dir: str) -> int:
    """The next free (monotonic) version number: past the manifest's
    latest AND past any orphan table file a crash-between-renames left
    behind — an orphan's number is never reused, so a version string
    uniquely names one byte-content forever. :func:`gc_orphans` removes
    orphan *files* but records their high-water mark in the manifest
    (``gc_floor``), so collection does not reopen their numbers."""
    manifest = load_manifest(artifact_dir)
    latest = manifest["latest"] if manifest else 0
    floor = (manifest or {}).get("gc_floor", 0)
    orphans = _scan_table_versions(artifact_dir)
    return max([latest, floor] + orphans) + 1


def gc_orphans(artifact_dir: str) -> list[str]:
    """Remove crash debris from an artifact directory; returns the
    removed file names.

    Two kinds of debris can exist, both invisible to readers:

    * ``.tmp-``-prefixed partial writes (a crash mid-:func:`_atomic_write_bytes`);
    * complete-but-unmanifested table files — a crash landed the table
      rename but died before the manifest rename ever pointed at it.

    Collection never touches a manifested version, and it records the
    highest collected orphan version as the manifest's ``gc_floor`` so
    :func:`next_version` still never reuses a collected number (a
    version string names one byte-content forever even across a gc).
    Like publishing itself, gc assumes a single writer per directory —
    do not run it concurrently with a publisher.
    """
    if not os.path.isdir(artifact_dir):
        return []
    manifest = load_manifest(artifact_dir)
    manifested = {e["version"] for e in (manifest or {}).get("versions", [])}
    removed: list[str] = []
    orphan_hi = 0
    for f in sorted(os.listdir(artifact_dir)):
        path = os.path.join(artifact_dir, f)
        if f.startswith(_TMP_PREFIX):
            os.remove(path)
            removed.append(f)
        elif f.startswith("table_v") and f.endswith(".npz"):
            try:
                v = int(f[len("table_v"):-4])
            except ValueError:
                continue
            if v not in manifested:
                os.remove(path)
                removed.append(f)
                orphan_hi = max(orphan_hi, v)
    if orphan_hi:
        manifest = manifest or {"latest": 0, "versions": []}
        manifest["gc_floor"] = max(manifest.get("gc_floor", 0), orphan_hi)
        _atomic_write_bytes(
            os.path.join(artifact_dir, MANIFEST_NAME),
            lambda tmp: _write_json(tmp, manifest))
    return removed


def publish_arrays(artifact_dir: str, arrays: dict, *,
                   meta: dict | None = None) -> int:
    """Atomically publish one version of an arbitrary dict of arrays —
    the generic core :func:`publish_table` (and the elastic layer's
    per-worker state checkpoints) build on. Same crash-safety argument:
    the .npz lands under a temp name and is renamed into place *before*
    the manifest rename points at it, so a reader (or a crash at any
    instant) only ever observes the previous complete version. Returns
    the new version number."""
    os.makedirs(artifact_dir, exist_ok=True)
    version = next_version(artifact_dir)
    arrays = {k: np.asarray(v) for k, v in arrays.items()}
    table_path = _table_path(artifact_dir, version)
    _atomic_write_bytes(table_path, lambda tmp: _savez_to(tmp, arrays))

    manifest = load_manifest(artifact_dir) or {"latest": 0, "versions": []}
    entry = {"version": version, "file": os.path.basename(table_path),
             "created_unix": time.time(), **(meta or {})}
    manifest["versions"].append(entry)
    manifest["latest"] = version
    _atomic_write_bytes(
        os.path.join(artifact_dir, MANIFEST_NAME),
        lambda tmp: _write_json(tmp, manifest))
    return version


def load_arrays(artifact_dir: str, version: int | None = None
                ) -> tuple[dict, dict, int]:
    """Load a :func:`publish_arrays` version (``None`` = manifest's
    latest). Returns ``(arrays, entry_meta, version)``; raises
    ``FileNotFoundError`` when nothing is published — orphan files are
    not loadable state."""
    manifest = load_manifest(artifact_dir)
    if manifest is None or not manifest["versions"]:
        raise FileNotFoundError(
            f"no published version in {artifact_dir!r} (no {MANIFEST_NAME})")
    by_version = {e["version"]: e for e in manifest["versions"]}
    version = manifest["latest"] if version is None else version
    if version not in by_version:
        raise FileNotFoundError(
            f"version {version} not in manifest (has {sorted(by_version)})")
    entry = by_version[version]
    with np.load(os.path.join(artifact_dir, entry["file"]),
                 allow_pickle=False) as data:
        arrays = {k: data[k] for k in data.files}
    meta = {k: v for k, v in entry.items() if k not in ("version", "file")}
    return arrays, meta, version


def publish_table(
    artifact_dir: str,
    emb,
    valid,
    *,
    word_ids=None,
    worker_ids=None,
    mask=None,
    transforms=None,
    models=None,
    meta: dict | None = None,
) -> int:
    """Atomically publish one table version; returns its version number.

    Write order is the crash-safety argument: (1) the table .npz goes to
    a temp name and is renamed into place — a reader can never open a
    partial table; (2) only then is the manifest (also temp + rename)
    updated to point at it — a crash between (1) and (2) leaves the
    previous version live and the new file an ignored, never-reused
    orphan. Concurrent publishers to the same directory are not
    supported (single merge process per artifact dir, by design — the
    merge is the system's one synchronization point).
    """
    arrays = {"emb": np.asarray(emb), "valid": np.asarray(valid)}
    for k, v in (("word_ids", word_ids), ("worker_ids", worker_ids),
                 ("mask", mask), ("transforms", transforms),
                 ("models", models)):
        if v is not None:
            arrays[k] = np.asarray(v)
    return publish_arrays(
        artifact_dir, arrays,
        meta={"rows": int(arrays["emb"].shape[0]),
              "dim": int(arrays["emb"].shape[1]),
              "n_models": int(arrays["mask"].shape[0]) if mask is not None
              else None,
              **(meta or {})})


def _savez_to(path: str, arrays: dict) -> None:
    # np.savez appends '.npz' to bare string names; temp names end in
    # '.<pid>', so hand it an open file object, which it never renames.
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def _write_json(path: str, obj) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)


def load_table(artifact_dir: str, version: int | None = None) -> ServableTable:
    """Load a published table — always a complete one.

    ``version=None`` loads the manifest's latest. Raises
    ``FileNotFoundError`` if nothing has been published (or the named
    version was never *manifested* — orphan files are not loadable
    state)."""
    arrays, meta, version = load_arrays(artifact_dir, version)
    return ServableTable(
        emb=arrays["emb"], valid=arrays["valid"].astype(bool),
        version=version, meta=meta,
        **{k: arrays.get(k) for k in _OPTIONAL_KEYS})


# ---------------------------------------------------------------------------
# Per-worker elastic training state (table shards + cursor).
# ---------------------------------------------------------------------------
_WORKER_DIR_FMT = "worker_{:04d}"


def worker_state_dir(state_dir: str, worker: int) -> str:
    """The per-worker artifact directory under an elastic state root —
    each worker gets its own versioned manifest, so workers checkpoint
    concurrently without sharing a writer."""
    return os.path.join(state_dir, _WORKER_DIR_FMT.format(worker))


def publish_worker_state(state_dir: str, worker: int, params: dict,
                         cursor: dict) -> int:
    """Atomically checkpoint one worker's training state: its table
    shards (``params`` — a flat dict of arrays, typically ``{"W", "C"}``)
    plus its :class:`~repro.elastic.cursor.WorkerCursor` as manifest
    metadata. Same publish-then-manifest crash ordering as
    :func:`publish_table`: a kill at any instant leaves the previous
    complete state loadable and never a torn one. Returns the state
    version number."""
    return publish_arrays(
        worker_state_dir(state_dir, worker),
        {k: np.asarray(v) for k, v in params.items()},
        meta={"worker": int(worker),
              "cursor": {k: int(v) for k, v in cursor.items()}})


def load_worker_state(state_dir: str, worker: int,
                      version: int | None = None
                      ) -> tuple[dict, dict, int] | None:
    """Load a worker's last complete checkpoint: ``(params, cursor,
    version)``, or ``None`` when the worker has never checkpointed (a
    fresh start). Readers only ever see manifested versions — a crash
    mid-checkpoint is invisible."""
    wdir = worker_state_dir(state_dir, worker)
    try:
        arrays, meta, version = load_arrays(wdir, version)
    except FileNotFoundError:
        return None
    return arrays, dict(meta["cursor"]), version


# ---------------------------------------------------------------------------
# Reduction-tree merge state (restartable hierarchical merges).
# ---------------------------------------------------------------------------
_TREE_NODE_DIR_FMT = "tree_L{:02d}_N{:05d}"


def tree_node_dir(state_dir: str, level: int, index: int) -> str:
    """The per-node artifact directory for a reduction-tree merge
    (:class:`repro.core.merge_tree.TreeAlirMerger`) under a merge state
    root. Level 0 holds arrived leaves (``index`` = worker id); higher
    levels hold solved interior nodes (``index`` = node index at that
    level). Each node versions independently, like worker state."""
    return os.path.join(state_dir, _TREE_NODE_DIR_FMT.format(level, index))


def publish_tree_node(state_dir: str, level: int, index: int,
                      arrays: dict, *, meta: dict | None = None) -> int:
    """Atomically persist one tree node's arrays (leaf sub-model or
    solved interior consensus) with the same publish-then-manifest
    crash ordering as every other artifact: a restart mid-merge only
    ever reloads complete nodes. Returns the node's version number."""
    return publish_arrays(
        tree_node_dir(state_dir, level, index),
        {k: np.asarray(v) for k, v in arrays.items()},
        meta={"level": int(level), "index": int(index), **(meta or {})})


def load_tree_node(state_dir: str, level: int, index: int,
                   version: int | None = None
                   ) -> tuple[dict, dict, int] | None:
    """Load a persisted tree node: ``(arrays, meta, version)``, or
    ``None`` when the node was never published."""
    try:
        return load_arrays(tree_node_dir(state_dir, level, index), version)
    except FileNotFoundError:
        return None


def list_tree_nodes(state_dir: str) -> list[tuple[int, int]]:
    """All persisted ``(level, index)`` tree nodes under ``state_dir``,
    leaves first (ascending level, then index)."""
    if not os.path.isdir(state_dir):
        return []
    out = []
    for name in os.listdir(state_dir):
        if not name.startswith("tree_L"):
            continue
        try:
            level, index = name[len("tree_L"):].split("_N")
            out.append((int(level), int(index)))
        except ValueError:
            continue
    return sorted(out)
