"""Checkpointing: flat-key .npz save/restore for arbitrary pytrees.

Scope-appropriate for this framework (single-host save of possibly
sharded trees by device_get; restore re-shards via the caller's specs).
Keys encode the tree path; dataclass-free trees (dict/list/tuple) only —
which is all this codebase uses for params/opt state/caches.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}

    def walk(path, node):
        if isinstance(node, dict):
            if not node:
                out[_SEP.join(path) + "@emptydict"] = np.zeros(0)
                return
            for k in sorted(node):
                walk(path + [str(k)], node[k])
        elif isinstance(node, (list, tuple)):
            if not node:
                out[_SEP.join(path) + "@emptylist"] = np.zeros(0)
                return
            for i, v in enumerate(node):
                walk(path + [f"#{i}"], v)
        elif node is None:
            out[_SEP.join(path) + "@none"] = np.zeros(0)
        else:
            out[_SEP.join(path)] = np.asarray(jax.device_get(node))

    walk([], tree)
    return out


def _unflatten(flat: dict[str, np.ndarray]):
    _EMPTY_LIST = object()
    _EMPTY_DICT = object()
    root: dict = {}
    for key, val in flat.items():
        for tag, marker in (("@none", None), ("@emptylist", _EMPTY_LIST),
                            ("@emptydict", _EMPTY_DICT)):
            if key.endswith(tag):
                key = key[: -len(tag)]
                val = marker
                break
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if node is _EMPTY_LIST:
            return []
        if node is _EMPTY_DICT:
            return {}
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(k.startswith("#") for k in keys):
            return [fix(node[f"#{i}"]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, tree, step: int | None = None,
                    extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    meta = {"step": step, **(extra or {})}
    with open(path + ".meta.json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str):
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    tree = _unflatten({k: data[k] for k in data.files})
    meta = {}
    meta_path = path + ".meta.json" if os.path.exists(path + ".meta.json") \
        else path[:-4] + ".npz.meta.json"
    if os.path.exists(path + ".meta.json"):
        meta = json.load(open(path + ".meta.json"))
    elif os.path.exists(meta_path):
        meta = json.load(open(meta_path))
    return tree, meta


def latest_step_path(ckpt_dir: str, prefix: str = "step_") -> str | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.startswith(prefix) and f.endswith(".npz"):
            try:
                steps.append((int(f[len(prefix):-4]), f))
            except ValueError:
                pass
    if not steps:
        return None
    return os.path.join(ckpt_dir, max(steps)[1])
