from repro.configs.base import ModelConfig, MoESettings, MLASettings, SSMSettings
from repro.configs.shapes import SHAPES, InputShape, smoke_shape
from repro.configs.registry import (
    ARCH_IDS, get_config, all_configs, supports_shape, config_for_shape,
    LONG_500K_SKIPS,
)

__all__ = [
    "ModelConfig", "MoESettings", "MLASettings", "SSMSettings",
    "SHAPES", "InputShape", "smoke_shape",
    "ARCH_IDS", "get_config", "all_configs", "supports_shape",
    "config_for_shape", "LONG_500K_SKIPS",
]
