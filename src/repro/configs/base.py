"""Model configuration schema for the architecture zoo.

A model is a stack of layers described by *layer codes*. To keep compiled
HLO small (and multi-pod dry-run compiles tractable) the stack is
declared as ``prefix_codes + cycle_codes × num_cycles``: the prefix is
unrolled, the cycle is ``lax.scan``-ned over stacked params (the MaxText
"scan over layers" idiom).

Layer code grammar:  ``<mixer>[-<ffn>]``
  mixer: A   GQA attention            S   GQA with sliding window
         L   MLA (DeepSeek-V2)        M   Mamba
         m   mLSTM                    s   sLSTM
         C   GQA self-attn + cross-attn (decoder-only layers of enc-dec)
  ffn:   D   dense SwiGLU             E   MoE             (omitted: none)
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoESettings:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int | None = None
    capacity_factor: float = 1.25
    aux_weight: float = 0.01
    groups: int | None = None   # dispatch groups (None → data-axis size)


@dataclass(frozen=True)
class MLASettings:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64


@dataclass(frozen=True)
class SSMSettings:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None
    mlstm_expand: int = 2
    mlstm_chunk: int = 256    # chunkwise-parallel mLSTM chunk length (0 = sequential)
    slstm_segment: int = 64   # sLSTM remat segment (0 = monolithic scan)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|vlm|audio
    source: str                         # citation
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                           # dense-FFN width (0 = no dense FFN)
    vocab_size: int

    prefix_codes: tuple = ()
    cycle_codes: tuple = ("A-D",)
    num_cycles: int = 0                 # 0 → derived from num_layers

    head_dim: int | None = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_kind: str = "rope"             # rope|mrope
    mrope_sections: tuple = (16, 24, 24)
    attention_window: int | None = None # native SWA (h2o-danube)
    long_context_window: int = 8192     # SWA fallback used only for long_500k

    moe: MoESettings | None = None
    mla: MLASettings | None = None
    ssm: SSMSettings = field(default_factory=SSMSettings)

    encoder_layers: int = 0             # >0 → encoder-decoder
    frontend: str | None = None         # None|vision|audio (stubbed)
    frontend_tokens: int = 1024         # patches per image / stub granularity

    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "float32"
    vocab_pad_to: int = 256
    remat: bool = True
    remat_per_layer: bool = False   # nested per-layer remat inside the cycle

    # production training knobs (used by launch/train.py and the dry-run)
    train_optimizer: str = "adamw"      # adamw | adafactor | sgd
    train_microbatches: int = 1         # gradient-accumulation chunks
    fsdp: bool = True                   # also shard weights over 'data'
                                        # (ZeRO-3; off = pure TP × DP)

    # ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return (self.vocab_size + m - 1) // m * m

    @property
    def resolved_num_cycles(self) -> int:
        if self.num_cycles:
            return self.num_cycles
        body = self.num_layers - len(self.prefix_codes)
        assert body % len(self.cycle_codes) == 0, (
            f"{self.name}: {body} layers not divisible by cycle "
            f"{len(self.cycle_codes)}")
        return body // len(self.cycle_codes)

    def layer_codes(self) -> list[str]:
        codes = list(self.prefix_codes)
        codes += list(self.cycle_codes) * self.resolved_num_cycles
        assert len(codes) == self.num_layers, (self.name, len(codes))
        return codes

    def parse_code(self, code: str) -> tuple[str, str | None]:
        parts = code.split("-")
        mixer = parts[0]
        ffn = parts[1] if len(parts) > 1 else None
        assert mixer in ("A", "S", "L", "M", "m", "s", "C"), code
        assert ffn in (None, "D", "E"), code
        return mixer, ffn

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 cycles, small widths, ≤4 experts."""
        moe = self.moe
        if moe is not None:
            moe = replace(moe, num_experts=min(moe.num_experts, 4),
                          top_k=min(moe.top_k, 2),
                          d_ff_expert=min(moe.d_ff_expert, 128),
                          num_shared=min(moe.num_shared, 1))
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        heads = (heads // kv) * kv  # keep divisibility
        d_model = min(self.d_model, 128)
        cycles = 1 if len(self.cycle_codes) > 2 else 2
        num_layers = len(self.prefix_codes) + cycles * len(self.cycle_codes)
        mla = self.mla
        if mla is not None:
            mla = replace(mla, kv_lora_rank=32, rope_head_dim=16)
        new_head_dim = 32 if self.head_dim else None
        sections = self.mrope_sections
        if self.rope_kind == "mrope":
            half = (new_head_dim or d_model // heads) // 2
            total = sum(sections)
            scaled = [max(1, s * half // total) for s in sections]
            scaled[0] += half - sum(scaled)
            sections = tuple(scaled)
        return replace(
            self,
            num_layers=num_layers,
            num_cycles=cycles,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=new_head_dim,
            mrope_sections=sections,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            moe=moe,
            mla=mla,
            encoder_layers=min(self.encoder_layers, 2),
            attention_window=(min(self.attention_window, 32)
                              if self.attention_window else None),
            long_context_window=64,
            frontend_tokens=min(self.frontend_tokens, 16),
            vocab_pad_to=64,
        )
