"""deepseek-v2-lite-16b — MLA (kv_lora 512) + MoE [arXiv:2405.04434].

Layer 0 uses a dense FFN (width 10944, per the HF config); layers 1–26
are MoE with 64 routed experts top-6 plus 2 shared experts of width 1408.
(The assignment note "2 shared+160 routed" mixes in full V2's 160-expert
count; V2-*Lite* has 64 routed — we follow the Lite card, matching the
assigned "MoE 64e top-6".)

MLA decode uses the absorbed-matmul formulation over the *compressed*
cache (c_kv 512 + decoupled rope key 64) — the memory saving that is the
point of MLA."""

from repro.configs.base import ModelConfig, MoESettings, MLASettings

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434 (DeepSeek-V2); hf:deepseek-ai/DeepSeek-V2-Lite",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,            # informational; MLA shares one latent KV
    head_dim=128,
    d_ff=10944,                 # dense FFN of layer 0
    vocab_size=102400,
    prefix_codes=("L-D",),
    cycle_codes=("L-E",),
    mla=MLASettings(kv_lora_rank=512, rope_head_dim=64),
    moe=MoESettings(num_experts=64, top_k=6, d_ff_expert=1408,
                    num_shared=2, d_ff_shared=1408),
    train_microbatches=4,
)
