"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

Native SWA (window 4096): the KV cache never exceeds the window, which
also makes this the one *dense* arch that runs long_500k natively."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    source="arXiv:2401.16818 (H2O-Danube-1.8B)",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    cycle_codes=("A-D",),
    attention_window=4096,
)
