"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 with MoE every other
layer [arXiv:2403.19887 / arXiv:2408.12570].

72 layers = 9 cycles of 8 (attention at cycle position 3, MoE on odd
positions). Optimizer is adafactor: AdamW fp32 state for 398B params is
~4.8 TB and does not fit a single 256×16 GB pod (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, MoESettings, SSMSettings

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887 (Jamba); 1.5-Large sizes from arXiv:2408.12570",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    cycle_codes=("M-D", "M-E", "M-D", "A-E", "M-D", "M-E", "M-D", "M-E"),
    moe=MoESettings(num_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMSettings(d_state=16, d_conv=4, expand=2),
    train_optimizer="adafactor",
    train_microbatches=16,
)
