"""qwen2-vl-7b — VLM with M-RoPE + dynamic resolution [arXiv:2409.12191].

The vision tower (ViT + merger) is stubbed per the assignment carve-out:
``input_specs`` supplies pre-projected patch embeddings
(B, frontend_tokens, d_model); this config is the language decoder that
consumes them, with multimodal rotary position embedding (sections
16/24/24 over the 64 half-dim frequency bands).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    source="arXiv:2409.12191 (Qwen2-VL); hf:Qwen/Qwen2-VL-7B-Instruct",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    cycle_codes=("A-D",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    frontend="vision",
    frontend_tokens=1024,
    train_microbatches=8,
)
