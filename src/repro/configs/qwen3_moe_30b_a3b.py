"""qwen3-moe-30b-a3b — 128 experts, top-8, all-MoE FFN
[hf:Qwen/Qwen3-30B-A3B]."""

from repro.configs.base import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B (config.json)",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                     # every FFN is MoE
    vocab_size=151936,
    cycle_codes=("A-E",),
    rope_theta=1_000_000.0,
    moe=MoESettings(num_experts=128, top_k=8, d_ff_expert=768),
    train_microbatches=8,
)
