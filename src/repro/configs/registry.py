"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

_MODULES = {
    "llama3-8b": "repro.configs.llama3_8b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "smollm-360m": "repro.configs.smollm_360m",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
}

ARCH_IDS = tuple(_MODULES)

# Decode shapes this arch cannot run, with the DESIGN.md reason.
LONG_500K_SKIPS = {
    "seamless-m4t-large-v2":
        "enc-dec: full attention over a 500k-frame encoder is quadratic; "
        "no sub-quadratic variant in scope (DESIGN.md §5)",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def supports_shape(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-not). long_500k policy per DESIGN.md:
    SSM/hybrid run natively; native-SWA dense runs natively; other
    dense/moe/vlm archs run with the sliding-window variant (the config
    is overridden with ``attention_window=long_context_window``);
    enc-dec audio is skipped."""
    if shape_name != "long_500k":
        return True, ""
    if cfg.name in LONG_500K_SKIPS:
        return False, LONG_500K_SKIPS[cfg.name]
    return True, ""


def config_for_shape(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Shape-specific config adjustments (the SWA fallback for long_500k)."""
    if shape_name == "long_500k" and cfg.attention_window is None:
        has_attn = any(cfg.parse_code(c)[0] in ("A", "S", "L", "C")
                       for c in cfg.layer_codes())
        pure_recurrent = not has_attn
        if not pure_recurrent and cfg.arch_type in ("dense", "moe", "vlm"):
            return cfg.with_overrides(attention_window=cfg.long_context_window)
    return cfg
