"""seamless-m4t-large-v2 — encoder-decoder, multimodal audio
[arXiv:2308.11596].

The speech frontend (mel-spectrogram + conformer feature extractor) is
stubbed per the assignment carve-out: ``input_specs`` supplies frame
embeddings (B, S, d_model). This config is the text decoder (24 layers,
self+cross attention) over a 24-layer transformer encoder consuming
those frames. Vocab 256206 is padded to 256256 (vocab_pad_to=256) for
16-way sharding divisibility.

long_500k is SKIPPED for this arch: full cross/self attention over a
500k-frame encoder is quadratic in the encoder and the paper defines no
sub-quadratic variant (DESIGN.md §5)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    source="arXiv:2308.11596 (SeamlessM4T); v2 card hf:facebook/seamless-m4t-v2-large",
    num_layers=24,             # decoder layers; encoder below
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    cycle_codes=("C-D",),      # decoder: self-attn + cross-attn + FFN
    encoder_layers=24,
    frontend="audio",
    train_microbatches=4,
)
