"""The paper's own model: SGNS word2vec, Wikipedia-scale settings.

dim 500, window 10, 300k vocab cap (paper §4.2); negatives default 5.
This is not a transformer config — it parameterizes repro.core."""

from repro.core.sgns import SGNSConfig

CONFIG = SGNSConfig(
    vocab_size=300_000,
    dim=500,
    window=10,
    negatives=5,
    lr=0.025,
)

# Paper experiment grid (Tables 2–4): sampling rates r% → n = 100/r workers.
SAMPLING_RATES = (0.01, 0.05, 0.0667, 0.10, 0.20, 0.25, 0.33, 0.50)
