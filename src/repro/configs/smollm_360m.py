"""smollm-360m — llama-architecture small model
[hf:HuggingFaceTB/SmolLM-360M, family card hf:HuggingFaceTB/SmolLM-135M].

15 query heads / 5 KV heads: head counts not divisible by a 16-way
tensor axis — sharding uses the flattened heads×head_dim (=960) axis
(see sharding/rules.py)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    source="hf:HuggingFaceTB/SmolLM-360M (config.json)",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    cycle_codes=("A-D",),
    tie_embeddings=True,
)
