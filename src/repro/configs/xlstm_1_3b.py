"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

xLSTM[7:1]-style interleave: one sLSTM block per 8 (position 2 of the
cycle, following the paper's placement of sLSTM blocks in the first
third of each group), remainder mLSTM. Blocks are self-contained
(d_ff=0): mLSTM carries its own 2× up/down projection, sLSTM its own
output projection.
"""

from repro.configs.base import ModelConfig, SSMSettings

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    source="arXiv:2405.04517 (xLSTM), 1.3B scale table",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    cycle_codes=("m", "m", "s", "m", "m", "m", "m", "m"),
    ssm=SSMSettings(mlstm_expand=2),
    train_microbatches=4,
)
