"""Core — the paper's contribution: divide / train / merge.

* :mod:`repro.core.sampling`       — EQUAL PARTITIONING / RANDOM SAMPLING / SHUFFLE
* :mod:`repro.core.sgns`           — SGNS objective + dense/sparse steps
* :mod:`repro.core.engine`         — UpdateEngine registry (dense/sparse/pallas/pallas_fused)
* :mod:`repro.core.schedule`       — epoch/chunk/total-steps derivation
* :mod:`repro.core.async_trainer`  — zero-collective async training + sync baseline
* :mod:`repro.core.merge`          — Concat / PCA / ALiR (+ OOV reconstruction)
* :mod:`repro.core.distributions`  — unigram/bigram KL tooling (Fig. 1, Thm 2)
"""

from repro.core.sgns import SGNSConfig, init_params, loss_fn, embedding_matrix
from repro.core.sampling import sample_sentence_indices, STRATEGIES
from repro.core.engine import UpdateEngine, get_engine, ENGINE_NAMES
from repro.core.schedule import EpochSchedule, plan_epoch
from repro.core.async_trainer import (
    AsyncShardTrainer,
    make_sync_epoch,
    assert_no_collectives,
    count_collective_ops,
)
from repro.core.merge import (
    StackedModels,
    stack_models,
    merge as merge_embeddings,  # `repro.core.merge` stays the submodule
    Merger,
    MergeConfig,
    MergeResult,
    get_merger,
    MERGER_NAMES,
    merge_alir,      # deprecated shims — the registry is the surface
    merge_concat,
    merge_pca,
    merge_average,
    orthogonal_procrustes,
    reconstruct_missing,
    MERGE_METHODS,
)

__all__ = [
    "SGNSConfig", "init_params", "loss_fn", "embedding_matrix",
    "sample_sentence_indices", "STRATEGIES",
    "UpdateEngine", "get_engine", "ENGINE_NAMES",
    "EpochSchedule", "plan_epoch",
    "AsyncShardTrainer", "make_sync_epoch", "assert_no_collectives",
    "count_collective_ops",
    "StackedModels", "stack_models", "merge_embeddings",
    "Merger", "MergeConfig", "MergeResult", "get_merger", "MERGER_NAMES",
    "merge_alir", "merge_concat",
    "merge_pca", "merge_average", "orthogonal_procrustes",
    "reconstruct_missing", "MERGE_METHODS",
]
