"""The Train phase — zero-collective asynchronous sub-model training.

The paper's reducers each train one SGNS sub-model with **no parameter
synchronization whatsoever**. On a TPU mesh this maps to a ``worker``
mesh axis: stacked sub-model tables ``(n, V, d)`` are sharded over
``worker`` and the epoch function runs under ``shard_map`` with *no
collective anywhere in the step* — asserted by
:func:`assert_no_collectives`, and visible as a zero collective-bytes
roofline term (EXPERIMENTS §Roofline).

The per-step compute itself (negative draw → row grads → apply) is an
:class:`repro.core.engine.UpdateEngine`; every epoch builder here takes
``engine=`` and stays agnostic to which step path (dense autodiff,
sparse scatter-add, Pallas tile kernel, the fully-fused in-kernel
sampler, its HBM-blocked paper-scale variant, or the double-buffered
DMA-pipelined variant) runs inside the scan.

The synchronized strawman (`sync_train_epoch`) is conventional
data-parallel SGNS: one table, batch sharded, gradient all-reduced every
step — the TPU-native equivalent of the paper's Hogwild/MLLib baselines.

Both run on one CPU device for tests (``vmap`` backend) and lower to the
production mesh for the dry-run (``shard_map`` backend).
"""

from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding

from repro.core import sgns
from repro.core.engine import get_engine
from repro.core.sgns import SGNSConfig

# --- shard_map compat: jax >= 0.6 exposes jax.shard_map(check_vma=...);
# jax 0.4.x has jax.experimental.shard_map.shard_map(check_rep=...).
try:
    _shard_map_impl = jax.shard_map
except AttributeError:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = inspect.signature(_shard_map_impl).parameters


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, on any supported jax."""
    kw = {}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kw["check_vma"] = False
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kw["check_rep"] = False
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


# ---------------------------------------------------------------------------
# Single-worker epoch: scan over a fixed number of steps.
# ---------------------------------------------------------------------------
def make_worker_epoch(cfg: SGNSConfig, total_steps: int, engine="sparse"):
    """Returns epoch_fn(params, centers (S,B), contexts (S,B), neg_table, key, step0).

    ``engine`` (an :class:`repro.core.engine.UpdateEngine` or spec
    string) owns the whole per-step compute: negative draw, row grads,
    parameter apply. ``neg_table`` is the worker's *own* unigram^0.75
    noise table in the layout ``engine.table_kind`` names — a ``(V,)``
    CDF or a ``{'prob', 'alias'}`` Vose table (each sub-model draws from
    its own sample's noise distribution, paper §3.2).
    """
    step = get_engine(engine).make_step(cfg, total_steps)

    def epoch_fn(params, centers, contexts, neg_table, key, step0):
        def body(carry, xs):
            params, key, i = carry
            c_b, x_b = xs
            key, sub = jax.random.split(key)
            params, loss = step(params, c_b, x_b, neg_table, sub, step0 + i)
            return (params, key, i + 1), loss

        (params, _, _), losses = jax.lax.scan(
            body, (params, key, jnp.int32(0)), (centers, contexts))
        return params, losses

    return epoch_fn


# ---------------------------------------------------------------------------
# Async (paper) trainer
# ---------------------------------------------------------------------------
@dataclass
class AsyncShardTrainer:
    """Trains n sub-models fully asynchronously.

    ``backend='vmap'``     — one device, workers vectorized (tests/CPU).
    ``backend='shard_map'`` — workers sharded over the ``worker`` mesh
    axis; the compiled step contains no collectives.
    ``engine`` — an :class:`repro.core.engine.UpdateEngine` or spec
    string (``"dense"`` / ``"sparse"`` / ``"pallas"`` /
    ``"pallas_fused"`` / ``"pallas_fused_hbm"`` /
    ``"pallas_fused_pipe"`` / ``"pallas_fused_tiered"``, optionally
    ``":cdf"`` / ``":alias"``) that owns the per-step compute; resolved
    once at construction.
    ``plan`` — optional :class:`repro.data.pipeline.HostShardPlan` for
    multi-host ingestion: this host feeds :meth:`device_chunk` only its
    own workers' extracted rows and the trainer assembles the global
    ``(n, ...)`` device arrays (zero inter-host parameter traffic — the
    only multi-host exchange is the input assembly itself).
    """

    cfg: SGNSConfig
    num_workers: int
    total_steps: int
    backend: str = "vmap"
    mesh: Mesh | None = None
    engine: object = "sparse"
    plan: object = None
    _jitted: object = field(default=None, init=False, repr=False, compare=False)
    _jitted_single: object = field(default=None, init=False, repr=False,
                                   compare=False)

    def __post_init__(self):
        self.engine = get_engine(self.engine)
        self.engine.validate(vocab_size=self.cfg.vocab_size)
        if self.plan is not None:
            if self.plan.num_workers != self.num_workers:
                raise ValueError(
                    f"plan covers {self.plan.num_workers} workers, "
                    f"trainer has {self.num_workers}")
            if self.plan.process_count > 1 and (
                    self.backend != "shard_map" or self.mesh is None):
                raise ValueError(
                    "multi-host ingestion needs backend='shard_map' "
                    "and a mesh")

    def init(self, key: jax.Array) -> dict:
        keys = jax.random.split(key, self.num_workers)
        fn = jax.vmap(lambda k: sgns.init_params(k, self.cfg))
        if self.backend == "shard_map" and self.mesh is not None:
            # Worker-sharded global tables from the start: on a
            # multi-process runtime the epoch's shard_map inputs must
            # already be global arrays (host-local default placement
            # cannot be resharded across processes implicitly).
            sh = NamedSharding(self.mesh, P("worker"))
            fn = jax.jit(fn, out_shardings={"W": sh, "C": sh})
        return fn(keys)

    def _epoch_fn(self):
        return make_worker_epoch(self.cfg, self.total_steps,
                                 engine=self.engine)

    def _sharded(self, epoch_fn):
        spec = P("worker")
        return shard_map_compat(
            jax.vmap(epoch_fn),  # local worker block (n/devices per device)
            mesh=self.mesh,
            # spec is a pytree prefix, so the alias table's {prob, alias}
            # leaves pick up the worker sharding too.
            in_specs=(spec,) * 6,
            out_specs=(spec, spec),
        )

    def _jit_epoch(self):
        """Build + jit the epoch once; chunked streaming calls it many
        times per epoch, so the jit cache must live on the trainer."""
        if self._jitted is None:
            epoch_fn = self._epoch_fn()
            if self.backend == "vmap":
                fn = jax.vmap(epoch_fn)
            elif self.backend == "shard_map":
                assert self.mesh is not None
                fn = self._sharded(epoch_fn)
            else:
                raise ValueError(self.backend)
            object.__setattr__(self, "_jitted", jax.jit(fn))
        return self._jitted

    def device_chunk(self, centers, contexts):
        """Host-local ``(num_local, S, B)`` chunk blocks → global
        ``(n, S, B)`` device arrays (worker-sharded under a plan+mesh;
        a plain transfer otherwise)."""
        if self.plan is None or self.mesh is None:
            return jnp.asarray(centers), jnp.asarray(contexts)
        from repro.launch.mesh import assemble_worker_array

        return (assemble_worker_array(self.mesh, self.plan, centers),
                assemble_worker_array(self.mesh, self.plan, contexts))

    def device_table(self, neg_table):
        """Global worker-sharded noise table from this host's local
        rows (pytree of ``(num_local, V)`` leaves under a plan+mesh;
        passthrough otherwise)."""
        if self.plan is None or self.mesh is None:
            return neg_table
        from repro.launch.mesh import assemble_worker_array

        return jax.tree.map(
            lambda a: assemble_worker_array(self.mesh, self.plan, a),
            neg_table)

    def epoch(self, params, centers, contexts, neg_table, key, step0=0):
        """params: (n,V,d) pytree; centers/contexts: (n,S,B);
        neg_table: (n,V) CDF or {'prob','alias'} of (n,V) alias tables."""
        keys = jax.random.split(key, self.num_workers)
        step0 = jnp.full((self.num_workers,), step0, dtype=jnp.int32)
        return self._jit_epoch()(params, centers, contexts, neg_table, keys, step0)

    def worker_epoch(self, params, centers, contexts, neg_table, key, step0=0):
        """One worker's chunk, un-vmapped: params (V,d) pytree;
        centers/contexts (S,B); neg_table the worker's own (V,) CDF or
        {'prob','alias'} pair; ``key`` the exact per-(worker, chunk) key
        the stacked epoch would have split out for it
        (:func:`repro.core.driver.worker_chunk_key`).

        This is the elastic-training path: because every worker runs the
        same single-worker jit regardless of which host executes it or
        how many peers are alive, kill/resume/steal schedules are
        bit-identical to the uninterrupted elastic run by construction
        (vmapped and un-vmapped executions of the same program are *not*
        guaranteed bit-identical, so elasticity equivalence is defined
        against this path, not against :meth:`epoch`)."""
        if self._jitted_single is None:
            object.__setattr__(self, "_jitted_single",
                               jax.jit(self._epoch_fn()))
        return self._jitted_single(params, centers, contexts, neg_table,
                                   key, jnp.int32(step0))

    def lower_epoch(self, steps: int, batch: int):
        """Lower the sharded epoch for the dry-run, ShapeDtypeStruct only."""
        assert self.mesh is not None
        n, V, d = self.num_workers, self.cfg.vocab_size, self.cfg.dim
        spec = P("worker")
        sh = lambda s, t: jax.ShapeDtypeStruct(
            s, t, sharding=NamedSharding(self.mesh, spec))
        if self.engine.table_kind == "alias":
            neg = {"prob": sh((n, V), jnp.float32), "alias": sh((n, V), jnp.int32)}
        else:
            neg = sh((n, V), jnp.float32)       # per-worker negative CDFs
        params = {"W": sh((n, V, d), jnp.float32), "C": sh((n, V, d), jnp.float32)}
        args = (
            params,
            sh((n, steps, batch), jnp.int32),   # centers
            sh((n, steps, batch), jnp.int32),   # contexts
            neg,                                # per-worker noise tables
            sh((n, 2), jnp.uint32),             # PRNG keys
            sh((n,), jnp.int32),                # step0
        )
        fn = self._sharded(self._epoch_fn())
        return jax.jit(fn).lower(*args)


# ---------------------------------------------------------------------------
# Synchronized baseline (Hogwild/MLLib stand-in): data-parallel + all-reduce
# ---------------------------------------------------------------------------
def make_sync_epoch(cfg: SGNSConfig, neg_table, total_steps: int,
                    mesh: Mesh | None = None, data_axis: str = "worker",
                    engine="dense"):
    """One shared table; per-step gradient synchronization.

    Under a mesh, the batch is sharded over ``data_axis`` and the dense
    gradient is psum'd — the per-step collective the paper eliminates.
    The gradient must materialize densely for that all-reduce, so only
    the ``engine``'s negative draw and table layout are used here (its
    apply path is irrelevant to the baseline's cost model).
    """
    engine = get_engine(engine)

    def sample_negatives(key, shape):
        return engine.sample(neg_table, key, shape)

    def step(params, c_b, x_b, key, i):
        negs = sample_negatives(key, (c_b.shape[0], cfg.negatives))
        lr = sgns.linear_lr(i, total_steps, cfg)
        sum_loss, grads = jax.value_and_grad(sgns.sum_loss_fn)(params, c_b, x_b, negs)
        loss = sum_loss / c_b.shape[0]
        if mesh is not None:
            # Per-step synchronization: the collective the paper removes.
            grads = jax.tree.map(partial(jax.lax.psum, axis_name=data_axis), grads)
            loss = jax.lax.pmean(loss, axis_name=data_axis)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, loss

    def epoch_fn(params, centers, contexts, key, step0):
        def body(carry, xs):
            params, key, i = carry
            key, sub = jax.random.split(key)
            params, loss = step(params, xs[0], xs[1], sub, step0 + i)
            return (params, key, i + 1), loss
        (params, _, _), losses = jax.lax.scan(
            body, (params, key, jnp.int32(0)), (centers, contexts))
        return params, losses

    if mesh is None:
        return jax.jit(epoch_fn)

    return jax.jit(shard_map_compat(
        epoch_fn, mesh=mesh,
        in_specs=(P(), P(None, data_axis), P(None, data_axis), P(), P()),
        out_specs=(P(), P())))


# ---------------------------------------------------------------------------
# Beyond-paper: periodic-sync (local-SGD) SGNS — interpolates between the
# per-step-synchronized baseline (k=1) and the paper's fully-asynchronous
# training (k→∞, with the final ALiR merge as the one-time "sync").
# Collective bytes scale as 1/k (EXPERIMENTS §Perf SGNS iterations).
# ---------------------------------------------------------------------------
def make_periodic_sync_epoch(cfg: SGNSConfig, neg_table,
                             total_steps: int, sync_every: int,
                             mesh: Mesh, data_axis: str = "worker",
                             engine="dense"):
    """One shared table; parameters are *averaged* across workers every
    ``sync_every`` steps (local SGD) instead of gradients every step.
    Between syncs each worker runs the ``engine``'s step unmodified —
    local SGD composes with any update engine."""
    engine_step = get_engine(engine).make_step(cfg, total_steps)

    def local_step(params, c_b, x_b, key, i):
        return engine_step(params, c_b, x_b, neg_table, key, i)

    def epoch_fn(params, centers, contexts, key, step0):
        # centers/contexts: (outer, sync_every, B_local)
        def outer_body(carry, xs):
            params, key, i = carry
            c_o, x_o = xs

            def inner_body(c2, xs2):
                params2, key2, i2 = c2
                key2, sub = jax.random.split(key2)
                params2, loss = local_step(params2, xs2[0], xs2[1], sub, i2)
                return (params2, key2, i2 + 1), loss

            (params, key, i), losses = jax.lax.scan(
                inner_body, (params, key, i), (c_o, x_o))
            # the periodic synchronization: average parameters
            params = jax.tree.map(
                partial(jax.lax.pmean, axis_name=data_axis), params)
            return (params, key, i), losses

        (params, _, _), losses = jax.lax.scan(
            outer_body, (params, key, step0), (centers, contexts))
        return params, jax.lax.pmean(losses, axis_name=data_axis)

    spec_b = P(None, None, data_axis)
    return jax.jit(shard_map_compat(
        epoch_fn, mesh=mesh,
        in_specs=(P(), spec_b, spec_b, P(), P()),
        out_specs=(P(), P())))


# ---------------------------------------------------------------------------
def assert_no_collectives(lowered) -> str:
    """Raises if the lowered/compiled program contains any cross-device
    collective — the paper's headline property for the train phase.
    Delegates to the structured op-walk in
    :mod:`repro.analysis.contracts`, which understands both StableHLO
    MLIR (``stablehlo.all_reduce`` — what ``.as_text()`` yields on a
    ``Lowered``) and post-compile HLO (``all-reduce``); the old
    hyphen-spelling regex was vacuous on the MLIR form."""
    from repro.analysis.contracts import certify_zero_collective

    return certify_zero_collective(lowered)


def count_collective_ops(hlo_text: str) -> dict[str, int]:
    """Collective ops by name in either program format (structured
    parse via :mod:`repro.analysis.contracts`)."""
    from repro.analysis import contracts

    return contracts.count_collective_ops(hlo_text)
