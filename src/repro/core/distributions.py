"""Unigram/bigram distribution tooling (paper Fig. 1 + Theorems 1–2).

The paper's empirical justification for random sampling is that the
KL-divergence from a sub-corpus's unigram and bigram distributions to the
full corpus's is small (much smaller than for equal partitioning). We
reproduce that measurement, and the Theorem 2 miss-probability threshold.
"""

from __future__ import annotations

import numpy as np

from repro.data.corpus import Corpus


def unigram_distribution(corpus: Corpus, vocab_size: int) -> np.ndarray:
    c = np.bincount(corpus.tokens, minlength=vocab_size).astype(np.float64)
    return c / max(c.sum(), 1.0)


def bigram_distribution(
    corpus: Corpus, vocab_size: int, window: int = 1
) -> dict[int, float]:
    """Sparse word–context pair distribution within ``window`` (keys w*V+c)."""
    counts: dict[int, int] = {}
    toks, offs = corpus.tokens.astype(np.int64), corpus.offsets
    for off in range(1, window + 1):
        a = toks[:-off]
        b = toks[off:]
        # Drop pairs crossing sentence boundaries.
        sent_id = np.repeat(np.arange(len(offs) - 1), np.diff(offs))
        same = sent_id[:-off] == sent_id[off:]
        keys = (a[same] * vocab_size + b[same])
        uniq, cnt = np.unique(keys, return_counts=True)
        for k, c in zip(uniq.tolist(), cnt.tolist()):
            counts[k] = counts.get(k, 0) + c
    total = float(sum(counts.values())) or 1.0
    return {k: v / total for k, v in counts.items()}


def kl_divergence_dense(p: np.ndarray, q: np.ndarray, eps: float = 1e-10) -> float:
    """KL(p || q) with additive smoothing on q (q = full-corpus reference)."""
    q = (q + eps) / (q + eps).sum()
    mask = p > 0
    return float(np.sum(p[mask] * np.log(p[mask] / q[mask])))


def kl_divergence_sparse(p: dict[int, float], q: dict[int, float], eps: float = 1e-10) -> float:
    qs = sum(q.values()) + eps * (len(p) + len(q))
    out = 0.0
    for k, pv in p.items():
        qv = (q.get(k, 0.0) + eps) / qs
        out += pv * np.log(pv / qv)
    return float(out)


def build_alias_table(probs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Vose's alias method: O(V) build, O(1) draw.

    Returns ``(prob, alias)`` with ``prob`` float64 in [0, 1] and
    ``alias`` int32, such that drawing ``i ~ U{0..V-1}``, ``u ~ U[0,1)``
    and returning ``i`` if ``u < prob[i]`` else ``alias[i]`` samples
    exactly from ``probs``. Replaces the per-draw O(log V) binary search
    over a CDF with two table gathers (Ji et al., Parallelizing Word2Vec
    in Shared and Distributed Memory).
    """
    p = np.asarray(probs, dtype=np.float64)
    if p.ndim != 1 or len(p) == 0:
        raise ValueError("probs must be a non-empty 1-D array")
    if (p < 0).any():
        raise ValueError("probs must be non-negative")
    s = p.sum()
    if s <= 0:
        raise ValueError("probs must sum to a positive value")
    V = len(p)
    scaled = p * (V / s)
    prob = np.ones(V, dtype=np.float64)
    alias = np.arange(V, dtype=np.int32)
    # Partition into under-/over-full buckets and pair them off.
    small = [i for i in range(V) if scaled[i] < 1.0]
    large = [i for i in range(V) if scaled[i] >= 1.0]
    while small and large:
        lo = small.pop()
        hi = large.pop()
        prob[lo] = scaled[lo]
        alias[lo] = hi
        scaled[hi] = (scaled[hi] + scaled[lo]) - 1.0
        (small if scaled[hi] < 1.0 else large).append(hi)
    # Leftovers are exactly full up to float rounding.
    for i in small + large:
        prob[i] = 1.0
        alias[i] = i
    return prob, alias


def alias_implied_probs(prob: np.ndarray, alias: np.ndarray) -> np.ndarray:
    """The exact distribution an alias table samples from (test oracle)."""
    V = len(prob)
    out = prob.astype(np.float64).copy()
    np.add.at(out, alias, 1.0 - prob)
    return out / V


def theorem2_threshold(rate: float, sentence_len: float) -> float:
    """P_C(w) above which a word is exp(-O(N))-unlikely to be missed.

    Theorem 2: u = r/100, ℓ = sentence length; threshold is
    ``1 - (1-u) ** ((1-u) / (ℓ u))``. (Paper's example: u=0.1, ℓ=100
    → ≈ 0.0095.)
    """
    u = rate
    if not (0.0 < u < 1.0):
        raise ValueError("rate must be in (0,1)")
    return 1.0 - (1.0 - u) ** ((1.0 - u) / (sentence_len * u))
