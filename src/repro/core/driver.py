"""End-to-end paper pipeline: divide → async train → merge → evaluate.

This is the high-level API used by the examples, benchmarks and tests:

    result = run_pipeline(corpus, gen, strategy="shuffle", num_workers=10, ...)

Vocabulary policy (paper §4.2):

* ``shuffle`` — one global frequency-capped vocabulary, precomputed
  before epoch 0 and shared by all sub-models;
* ``random`` / ``equal`` — each sub-model builds its own vocabulary from
  its sample with ``min_count = base_min_count / num_workers``; merge
  happens over the union (ALiR's case 2).

All sub-models train in the *union* index space so tables stack into
``(n, V_union, d)``; each worker's pair stream only ever emits its own
vocabulary's ids, so absent rows are never touched.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sgns import SGNSConfig
from repro.core.async_trainer import AsyncShardTrainer, make_sync_epoch
from repro.core.engine import get_engine
from repro.core.merge import StackedModels, merge as merge_models
from repro.core.schedule import plan_epoch
from repro.data.corpus import Corpus
from repro.data.pairs import stack_noise_tables
from repro.data.vocab import Vocab, build_vocab, union_vocab, UNK
from repro.data.pipeline import (
    HostShardPlan, make_worker_streams, prefetch_chunks)


# ---------------------------------------------------------------------------
# PRNG streams. Every per-epoch key is a fold_in chain from a single
# root key: fold_in(fold_in(PRNGKey(seed), stream), epoch). The old
# arithmetic seeds (PRNGKey(seed*1000 + epoch), seed*77 + epoch,
# seed*31 + epoch) collide across distinct (seed, epoch) pairs — e.g.
# seed=1/epoch=1000 and seed=2/epoch=0 shared a stream — so two runs
# that should be independent sampled identical negatives/permutations.
# ---------------------------------------------------------------------------
_STREAM_ASYNC_DATA = 0      # per-chunk keys for the async workers' epochs
_STREAM_SYNC_EPOCH = 1      # the sync baseline's in-epoch negative draws
_STREAM_SYNC_PERM = 2       # the sync baseline's numpy pair permutation

# Leading entropy word of every numpy SeedSequence built here. Each
# module that seeds numpy generators owns a distinct domain constant in
# position 0, so its tuples can never alias another module's no matter
# what user seed/stream/epoch values follow (the pipeline's pair-
# extraction tuples, for instance, would otherwise collide with these
# whenever stream == a worker index).
_SEED_DOMAIN = 0xD21  # driver epoch streams


def _epoch_key(seed: int, stream: int, epoch: int) -> jax.Array:
    """Collision-free per-(seed, stream, epoch) PRNG key."""
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), stream), epoch)


def worker_chunk_key(seed: int, epoch: int, chunk: int, num_workers: int,
                     worker: int) -> jax.Array:
    """The exact PRNG key worker ``worker`` consumes for chunk ``chunk``
    of ``epoch`` inside :func:`train_submodels`'s loop (epoch-stream key
    folded with the chunk index, then split over workers). The elastic
    runner replays this derivation so a worker resumed from a
    :class:`repro.elastic.WorkerCursor` — possibly on a different host —
    draws bit-identical negatives and step keys."""
    ep_key = _epoch_key(seed, _STREAM_ASYNC_DATA, epoch)
    return jax.random.split(
        jax.random.fold_in(ep_key, chunk), num_workers)[worker]


def _epoch_rng(seed: int, stream: int, epoch: int) -> np.random.Generator:
    """numpy counterpart of :func:`_epoch_key` (a domain-tagged
    SeedSequence: distinct (seed, stream, epoch) → distinct streams,
    disjoint from every other module's numpy streams)."""
    return np.random.default_rng(
        np.random.SeedSequence((_SEED_DOMAIN, seed, stream, epoch)))


@jax.jit
def _mean_loss(chunk_losses):
    """Scalar epoch loss from the list of per-chunk loss arrays. Jitted
    so it stays an SPMD computation on worker-sharded global arrays
    (multi-host); the result is replicated, hence float()-able on every
    host. Eager reductions would need fully-addressable shards."""
    return jnp.mean(jnp.concatenate(chunk_losses, axis=-1))


def _tiled_permutation(rng: np.random.Generator, n_pairs: int,
                       need: int) -> np.ndarray:
    """``need`` pair indices covering [0, n_pairs) as evenly as possible:
    whole independent permutations back to back. The old path tiled ONE
    permutation verbatim, so a corpus smaller than a batch replayed its
    pairs in identical order every pass within the epoch."""
    if n_pairs <= 0:
        raise ValueError("no training pairs extracted from the corpus")
    reps = -(-need // n_pairs)
    if reps == 1:
        return rng.permutation(n_pairs)[:need]
    return np.concatenate(
        [rng.permutation(n_pairs) for _ in range(reps)])[:need]


# ---------------------------------------------------------------------------
def _project_vocab(worker_vocab: Vocab, union: Vocab, raw_vocab_size: int) -> Vocab:
    """Worker vocabulary re-indexed into union-vocab id space."""
    lookup = np.full(raw_vocab_size, UNK, dtype=np.int32)
    union_ids = union.lookup[worker_vocab.word_ids]
    lookup[worker_vocab.word_ids] = union_ids
    counts = np.zeros(union.size, dtype=np.int64)
    counts[union_ids] = worker_vocab.counts
    return Vocab(word_ids=union.word_ids, counts=counts, lookup=lookup)


def build_worker_vocabs(
    corpus: Corpus,
    raw_vocab_size: int,
    strategy: str,
    num_workers: int,
    rate: float,
    max_vocab: int | None = 300_000,
    base_min_count: int = 100,
    seed: int = 0,
) -> tuple[list[Vocab], Vocab, np.ndarray]:
    """Returns (projected worker vocabs, union vocab, presence mask (n, V))."""
    if strategy == "shuffle":
        g = build_vocab(corpus, raw_vocab_size, min_count=1, max_size=max_vocab)
        union = g
        workers = [g] * num_workers
        mask = np.ones((num_workers, union.size), dtype=bool)
        return list(workers), union, mask

    from repro.core.sampling import sample_sentence_indices

    min_count = max(1, int(round(base_min_count / num_workers)))
    per_worker = []
    for w in range(num_workers):
        idx = sample_sentence_indices(
            corpus.num_sentences, strategy, rate, w, num_workers, epoch=0, seed=seed)
        sub = corpus.select(idx)
        per_worker.append(build_vocab(sub, raw_vocab_size, min_count=min_count,
                                      max_size=max_vocab))
    union = union_vocab(per_worker, raw_vocab_size)
    projected = [_project_vocab(v, union, raw_vocab_size) for v in per_worker]
    mask = np.zeros((num_workers, union.size), dtype=bool)
    for w, v in enumerate(per_worker):
        mask[w, union.lookup[v.word_ids]] = True
    return projected, union, mask


def _neg_tables(worker_vocabs: list[Vocab], kind: str = "cdf",
                power: float = 0.75):
    """Stacked per-worker noise tables in the layout ``kind`` draws
    from (see :func:`repro.data.pairs.stack_noise_tables`)."""
    return stack_noise_tables([v.counts for v in worker_vocabs],
                              kind=kind, power=power)


# ---------------------------------------------------------------------------
@dataclass
class TrainingSetup:
    """Everything the train loop needs, derived once from the corpus.

    A pure function of (corpus, strategy, seed, …) — see
    :func:`prepare_training` — so the stacked trainer
    (:func:`train_submodels`) and the per-worker elastic runner
    (:mod:`repro.elastic`) start from identical vocabularies, noise
    tables, pair streams and step schedules."""

    cfg: SGNSConfig              # vocab_size bound to the union vocab
    plan: HostShardPlan
    engine: object               # resolved UpdateEngine
    streams: list                # per-worker WorkerStream, union id space
    union_vocab: Vocab
    mask: np.ndarray             # (n, V_union) presence mask
    neg_table: object            # stacked per-worker noise tables
    sched: object                # EpochSchedule
    batch_size: int
    sentences_per_block: int
    seed: int
    epochs: int
    vocab_s: float               # wall-clock of the vocab/noise build


def prepare_training(
    corpus: Corpus,
    raw_vocab_size: int,
    strategy: str,
    num_workers: int,
    cfg: SGNSConfig,
    *,
    epochs: int = 3,
    batch_size: int = 512,
    rate: float | None = None,
    window: int | None = None,
    subsample_t: float | None = 1e-4,
    max_vocab: int | None = 300_000,
    base_min_count: int = 100,
    seed: int = 0,
    max_steps_per_epoch: int | None = None,
    engine="sparse",
    steps_per_chunk: int = 128,
    sentences_per_block: int = 1024,
    process_index: int | None = None,
    process_count: int | None = None,
) -> TrainingSetup:
    """Divide-phase setup shared by the stacked and elastic trainers:
    worker vocabularies (projected into the union id space), stacked
    noise tables in the engine's layout, per-worker pair streams, and
    the epoch schedule sized from a streamed epoch-0 pair count."""
    rate = rate if rate is not None else 1.0 / num_workers
    window = window if window is not None else cfg.window
    engine = get_engine(engine)
    plan = HostShardPlan.for_runtime(num_workers, process_index=process_index,
                                     process_count=process_count)

    t0 = time.perf_counter()
    worker_vocabs, union, mask = build_worker_vocabs(
        corpus, raw_vocab_size, strategy, num_workers, rate,
        max_vocab=max_vocab, base_min_count=base_min_count, seed=seed)
    cfg = SGNSConfig(**{**cfg.__dict__, "vocab_size": union.size})
    neg_table = _neg_tables(worker_vocabs, kind=engine.table_kind)
    vocab_s = time.perf_counter() - t0

    # Pair streams per worker (worker vocab projected into union ids).
    streams = []
    for w in range(num_workers):
        s = make_worker_streams(
            corpus, worker_vocabs[w], num_workers=num_workers, strategy=strategy,
            rate=rate, window=window, subsample_t=subsample_t, seed=seed)[w]
        streams.append(s)

    # Size steps/epoch from a streamed epoch-0 count (O(block) memory —
    # no epoch of pairs is ever materialized; kept equal across workers,
    # shorter streams wrap, as word2vec re-iterates its shard). The count
    # stops as soon as the step cap is known to be reached. Counted over
    # ALL workers on every host: the one-time O(epoch) count is
    # replicated so the schedule is a pure function of (corpus, seed) —
    # no inter-host min-reduction, and every host derives the identical
    # step plan independently.
    count_cap = (None if max_steps_per_epoch is None
                 else max_steps_per_epoch * batch_size)
    min_pairs = min(s.count_pairs(0, sentences_per_block, max_pairs=count_cap)
                    for s in streams)
    if min_pairs == 0:
        raise ValueError("a worker drew an empty sample")
    # One consistent steps/chunks/total_steps derivation (core.schedule):
    # the LR horizon and the chunk loop can't drift apart.
    sched = plan_epoch(min_pairs, batch_size, epochs, steps_per_chunk,
                       max_steps_per_epoch=max_steps_per_epoch)

    return TrainingSetup(
        cfg=cfg, plan=plan, engine=engine, streams=streams,
        union_vocab=union, mask=mask, neg_table=neg_table, sched=sched,
        batch_size=batch_size, sentences_per_block=sentences_per_block,
        seed=seed, epochs=epochs, vocab_s=vocab_s)


@dataclass
class PipelineResult:
    strategy: str
    num_workers: int
    union_vocab: Vocab
    stacked: StackedModels
    merged: dict = field(default_factory=dict)       # method -> (emb, valid)
    timings: dict = field(default_factory=dict)
    losses: list = field(default_factory=list)


def train_submodels(
    corpus: Corpus,
    raw_vocab_size: int,
    strategy: str,
    num_workers: int,
    cfg: SGNSConfig,
    epochs: int = 3,
    batch_size: int = 512,
    rate: float | None = None,
    window: int | None = None,
    subsample_t: float | None = 1e-4,
    max_vocab: int | None = 300_000,
    base_min_count: int = 100,
    backend: str = "vmap",
    mesh=None,
    seed: int = 0,
    max_steps_per_epoch: int | None = None,
    engine="sparse",
    steps_per_chunk: int = 128,
    prefetch: int = 2,
    sentences_per_block: int = 1024,
    process_index: int | None = None,
    process_count: int | None = None,
) -> PipelineResult:
    """``process_index`` / ``process_count`` (default: the jax runtime's)
    select multi-host ingestion: this host extracts only its
    :class:`HostShardPlan` block of workers' chunk streams and the
    global device arrays are assembled from the per-process blocks.
    Everything per-host is a pure function of the plan, so any host
    count can be simulated in one process (``tests/test_multihost.py``);
    with ``process_count == 1`` the path is bit-identical to the
    single-host stream."""
    plan = HostShardPlan.for_runtime(num_workers, process_index=process_index,
                                     process_count=process_count)
    multihost = plan.process_count > 1
    if multihost:
        if backend != "shard_map" or mesh is None:
            raise ValueError(
                "multi-host ingestion (process_count > 1) requires "
                "backend='shard_map' and a mesh")
        plan.validate_for_mesh(mesh)

    setup = prepare_training(
        corpus, raw_vocab_size, strategy, num_workers, cfg,
        epochs=epochs, batch_size=batch_size, rate=rate, window=window,
        subsample_t=subsample_t, max_vocab=max_vocab,
        base_min_count=base_min_count, seed=seed,
        max_steps_per_epoch=max_steps_per_epoch, engine=engine,
        steps_per_chunk=steps_per_chunk,
        sentences_per_block=sentences_per_block,
        process_index=process_index, process_count=process_count)
    cfg, engine, sched = setup.cfg, setup.engine, setup.sched
    streams, union, mask = setup.streams, setup.union_vocab, setup.mask
    neg_table, t_vocab = setup.neg_table, setup.vocab_s

    trainer = AsyncShardTrainer(
        cfg=cfg, num_workers=num_workers, total_steps=sched.total_steps,
        backend=backend, mesh=mesh, engine=engine,
        plan=plan if multihost else None)
    params = trainer.init(jax.random.PRNGKey(cfg.seed))
    if multihost:
        # Each host contributes only its own workers' noise-table rows.
        neg_table = trainer.device_table(
            jax.tree.map(lambda a: np.asarray(a)[plan.start:plan.stop],
                         neg_table))

    # This host's ingestion: only its plan block of worker streams is
    # ever extracted (single-host: the block is all workers).
    chunk_stream = plan.chunk_stream(
        streams, batch_size=batch_size, steps_per_chunk=sched.chunk_steps,
        sentences_per_block=sentences_per_block)

    losses = []
    t_train0 = time.perf_counter()
    for epoch in range(epochs):
        ep_key = _epoch_key(seed, _STREAM_ASYNC_DATA, epoch)
        ep_losses = []
        # Host extraction + H2D copy of chunk k+1 overlap the device's
        # work on chunk k (async dispatch; queue depth = `prefetch`).
        # Multi-host, the transfer is the per-chunk global assembly
        # (make_array_from_process_local_data), done on the main thread.
        chunk_it = prefetch_chunks(
            chunk_stream.chunks(epoch, sched.num_chunks), depth=prefetch,
            to_device=not multihost)
        for k, (centers, contexts) in enumerate(chunk_it):
            if multihost:
                centers, contexts = trainer.device_chunk(centers, contexts)
            params, chunk_losses = trainer.epoch(
                params, centers, contexts, neg_table,
                jax.random.fold_in(ep_key, k),
                step0=sched.step0(epoch, k),
            )
            ep_losses.append(chunk_losses)
        losses.append(float(_mean_loss(ep_losses)))
    jax.block_until_ready(params)
    t_train = time.perf_counter() - t_train0

    stacked = StackedModels(models=params["W"], mask=jnp.asarray(mask))
    return PipelineResult(
        strategy=strategy, num_workers=num_workers, union_vocab=union,
        stacked=stacked, timings={"vocab_s": t_vocab, "train_s": t_train,
                                  "steps_per_epoch": sched.steps_per_epoch},
        losses=losses)


def run_pipeline(
    corpus: Corpus,
    raw_vocab_size: int,
    strategy: str = "shuffle",
    num_workers: int = 10,
    cfg: SGNSConfig | None = None,
    merge_methods: tuple[str, ...] = ("concat", "pca", "alir_pca"),
    merge_fan_in: int = 2,
    merge_shard: int = 1,
    **kw,
) -> PipelineResult:
    cfg = cfg or SGNSConfig(vocab_size=0, dim=64)
    res = train_submodels(corpus, raw_vocab_size, strategy, num_workers, cfg, **kw)
    return apply_merges(res, merge_methods, out_dim=cfg.dim,
                        fan_in=merge_fan_in, shard=merge_shard)


def apply_merges(res: PipelineResult, merge_methods, out_dim: int, *,
                 fan_in: int = 2, shard: int = 1) -> PipelineResult:
    """Merge-phase tail shared by :func:`run_pipeline` and the elastic
    launcher: fold the stacked sub-models with each requested method,
    recording wall-clock per method in ``res.timings``. ``fan_in``
    sizes the ``alir_tree`` reduction tree; ``shard`` the ALiR Gram
    accumulation (both static dials, see :mod:`repro.core.merge`)."""
    for method in merge_methods:
        t0 = time.perf_counter()
        emb, valid = merge_models(res.stacked, method, out_dim=out_dim,
                                  key=jax.random.PRNGKey(42),
                                  fan_in=fan_in, shard=shard)
        jax.block_until_ready(emb)
        res.merged[method] = (np.asarray(emb), np.asarray(valid))
        res.timings[f"merge_{method}_s"] = time.perf_counter() - t0
    return res


# ---------------------------------------------------------------------------
# Synchronized baseline (the paper's Hogwild stand-in) end-to-end.
# ---------------------------------------------------------------------------
def train_sync_baseline(
    corpus: Corpus,
    raw_vocab_size: int,
    cfg: SGNSConfig,
    epochs: int = 3,
    batch_size: int = 512,
    window: int | None = None,
    subsample_t: float | None = 1e-4,
    max_vocab: int | None = 300_000,
    seed: int = 0,
    max_steps_per_epoch: int | None = None,
    mesh=None,
    engine="dense",
):
    from repro.data.pairs import extract_pairs

    engine = get_engine(engine)
    vocab = build_vocab(corpus, raw_vocab_size, min_count=1, max_size=max_vocab)
    cfg = SGNSConfig(**{**cfg.__dict__, "vocab_size": vocab.size})
    window = window if window is not None else cfg.window
    neg_table = _neg_tables([vocab], kind=engine.table_kind)
    # single-model: drop the stacked leading worker axis
    neg_table = jax.tree.map(lambda a: a[0], neg_table)

    centers, contexts = extract_pairs(corpus, vocab, window=window,
                                      subsample_t=subsample_t, seed=seed)
    steps = max(1, len(centers) // batch_size)
    if max_steps_per_epoch is not None:
        steps = min(steps, max_steps_per_epoch)
    total_steps = steps * epochs
    epoch_fn = make_sync_epoch(cfg, neg_table, total_steps, mesh=mesh,
                               engine=engine)

    from repro.core import sgns as sgns_mod
    params = sgns_mod.init_params(jax.random.PRNGKey(cfg.seed), cfg)
    need = steps * batch_size
    losses = []
    t0 = time.perf_counter()
    for epoch in range(epochs):
        rng = _epoch_rng(seed, _STREAM_SYNC_PERM, epoch)
        perm = _tiled_permutation(rng, len(centers), need)
        c = jnp.asarray(centers[perm].reshape(steps, batch_size))
        x = jnp.asarray(contexts[perm].reshape(steps, batch_size))
        params, ep_losses = epoch_fn(params, c, x,
                                     _epoch_key(seed, _STREAM_SYNC_EPOCH,
                                                epoch),
                                     jnp.int32(epoch * steps))
        losses.append(float(jnp.mean(ep_losses)))
    jax.block_until_ready(params)
    return params, vocab, {"train_s": time.perf_counter() - t0,
                           "steps_per_epoch": steps, "losses": losses}
