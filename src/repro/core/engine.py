"""Update engines — the per-step SGNS compute as one swappable object.

An :class:`UpdateEngine` owns everything a training step does between
receiving a ``(centers, contexts)`` micro-batch and returning updated
parameters: the negative draw (and therefore the noise-table *layout*
it consumes), the row gradients, and the parameter apply. Every layer
above — ``make_worker_epoch`` / :class:`AsyncShardTrainer` /
``make_sync_epoch`` / ``make_periodic_sync_epoch`` / the driver / the
launch CLIs — selects one by name instead of threading the old
``sparse`` / ``row_grad_fn`` / ``sampler`` flag trio.

Registry (``get_engine``):

``dense``
    Autodiff through the gathers; materializes a dense ``(V, d)``
    gradient. The oracle — simple and slow.
``sparse``
    Manual per-row gradients + accumulating scatter-add (pure jnp).
    O(B·K·d) memory traffic; the CPU production path.
``pallas``
    ``sparse`` with the row gradients computed by the VMEM-tile Pallas
    kernel (``kernels/sgns_update.py``); gather/scatter stay in XLA.
``pallas_fused``
    The whole step in one Pallas kernel
    (``kernels/sgns_fused.py``): negatives drawn *in-kernel* from the
    alias tables via a counter-based PRNG, ``log σ`` forward + all three
    row grads + scatter-add apply in a single VMEM pass. Negative ids
    and the ``(B, K)`` logit/grad intermediates never touch HBM. Both
    ``(V, d)`` tables ride through the kernel whole, so this caps at
    VMEM-adjacent table sizes.
``pallas_fused_hbm``
    The fused step with **HBM-resident** tables
    (``kernels/sgns_fused_hbm.py``): a chain of per-block kernel
    invocations (tables aliased in place through every one) DMA-gathers
    / RMW-scatters only each ``block_pairs``-sized block's touched
    rows; negatives still drawn in-kernel from the (VMEM-resident)
    alias tables with the same replayable counter PRNG. This is the
    variant that reaches the paper's 300k×500 sub-model shape. Fields:
    ``block_pairs`` (a shorter tail block covers any remainder) and
    ``sequential`` (word2vec's true per-pair apply order instead of
    per-block).
``pallas_fused_pipe``
    The pipelined successor of ``pallas_fused_hbm``
    (``kernels/sgns_fused_pipe.py``): one kernel invocation per step, a
    ``ring_depth``-slot ring of VMEM row buffers (default 2) with
    per-slot DMA semaphores, and a pure-JAX block planner that dedups
    each block's touched rows (each row moves over DMA exactly once per
    block, no RMW round-trips) and flags the scatter-before-regather
    hazards the schedule serializes on. Bit-identical to
    ``pallas_fused_hbm`` — same replayed counter PRNG, same per-block
    chain semantics. ``sequential=True`` is served by the unpipelined
    kernel (per-pair order is inherently serial).
``pallas_fused_tiered``
    The pipelined engine with **frequency-tiered parameter placement**
    (``kernels/sgns_fused_tiered.py``): the ``hot_rows`` hottest rows
    by unigram count — the id prefix, since the vocab is
    frequency-sorted — live in a VMEM-resident copy of the table
    prefix (bulk-DMA'd in once per step and written back once), while
    cold rows stay HBM-resident behind the same DMA pipeline (dedup
    and hazards computed over cold rows only). A tunable dial on the
    VMEM-vs-HBM cliff: ``hot_rows=0`` is ``pallas_fused_pipe``,
    ``hot_rows=V`` is pure-resident like ``pallas_fused``. Bit-identical
    to ``pallas_fused_hbm`` at every setting.

Engine specs are engine instances or strings, optionally carrying a
sampler: ``"sparse"``, ``"sparse:alias"``, ``"pallas:cdf"``. The fused
engines always sample in-kernel from alias tables (``"alias"`` is their
only valid sampler, and their default).

Engines are frozen dataclasses, so they hash/compare by value and are
safe as jit static arguments or cache keys.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax

from repro.core import sgns
from repro.core.sgns import SGNSConfig
from repro.data.pairs import negative_sampler_fn


def _auto_interpret() -> bool:
    """Pallas interpret mode everywhere but on a real TPU backend."""
    return jax.default_backend() != "tpu"


@dataclass(frozen=True)
class UpdateEngine:
    """Base engine: negative draw + step construction.

    ``sampler`` names the negative-draw primitive ("cdf" | "alias") and
    fixes :attr:`table_kind`, the noise-table layout the engine's steps
    consume — a ``(V,)`` CDF or a ``{"prob", "alias"}`` Vose table (see
    ``repro.data.pairs.build_noise_table``).
    """

    sampler: str = "cdf"
    name = "base"

    @property
    def table_kind(self) -> str:
        """Noise-table layout this engine's steps consume ("cdf" |
        "alias") — pass to ``build_noise_table(kind=...)``."""
        return self.sampler

    def sample(self, table, key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
        """Draw negative ids outside a kernel (also the sync baselines'
        draw path)."""
        return negative_sampler_fn(self.sampler)(table, key, shape)

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """Returns ``step(params, centers, contexts, neg_table, key,
        step_idx) -> (params, mean_loss)``."""
        raise NotImplementedError

    def validate(self, *, vocab_size: int | None = None) -> None:
        """Check dials that only make sense against a model shape
        (``__post_init__`` covers the shape-free ones). Called by
        :class:`~repro.core.async_trainer.AsyncShardTrainer` at
        construction; raises ``ValueError`` on a bad combination."""

    def describe(self) -> str:
        """Human-readable ``"name:sampler"`` tag (log/bench labels)."""
        return f"{self.name}:{self.sampler}"


@dataclass(frozen=True)
class DenseEngine(UpdateEngine):
    """Autodiff + dense (V, d) gradient — the numerical oracle."""

    name = "dense"

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """Autodiff step: ``value_and_grad`` through the gathers, dense
        ``(V, d)`` gradient, full-table SGD apply."""
        def step(params, centers, contexts, neg_table, key, step_idx):
            negs = self.sample(neg_table, key, (centers.shape[0], cfg.negatives))
            lr = sgns.linear_lr(step_idx, total_steps, cfg)
            sum_loss, grads = jax.value_and_grad(sgns.sum_loss_fn)(
                params, centers, contexts, negs)
            params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return params, sum_loss / centers.shape[0]

        return step


@dataclass(frozen=True)
class SparseEngine(UpdateEngine):
    """Manual row grads + scatter-add; ``row_grad_fn`` is the seam the
    Pallas engine plugs into."""

    name = "sparse"

    def row_grad_fn(self, cfg: SGNSConfig):
        """Per-row gradient callable the step threads into
        ``train_step_sparse`` (subclass hook — see PallasEngine)."""
        return sgns.sparse_row_grads

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """Sparse step: XLA draw + gather, manual row grads, per-row
        accumulating scatter-add apply."""
        row_grads = self.row_grad_fn(cfg)

        def step(params, centers, contexts, neg_table, key, step_idx):
            negs = self.sample(neg_table, key, (centers.shape[0], cfg.negatives))
            lr = sgns.linear_lr(step_idx, total_steps, cfg)
            return sgns.train_step_sparse(params, centers, contexts, negs, lr,
                                          row_grad_fn=row_grads)

        return step


@dataclass(frozen=True)
class PallasEngine(SparseEngine):
    """Sparse step with the fused-VMEM-tile row-grad kernel in the
    middle; the draw and the gather/scatter seams stay in XLA."""

    interpret: bool | None = None
    block_b: int | None = None
    name = "pallas"

    def row_grad_fn(self, cfg: SGNSConfig):
        """Swap the jnp row grads for the VMEM-tile Pallas kernel
        (interpret-mode off-TPU unless overridden)."""
        from repro.kernels import ops

        interpret = self.interpret if self.interpret is not None \
            else _auto_interpret()
        return ops.make_row_grad_fn(interpret=interpret, block_b=self.block_b)


@dataclass(frozen=True)
class FusedPallasEngine(UpdateEngine):
    """One kernel per step: in-kernel alias negative sampling + forward
    + row grads + apply. Alias tables only."""

    sampler: str = "alias"
    interpret: bool | None = None
    name = "pallas_fused"

    def __post_init__(self):
        if self.sampler != "alias":
            raise ValueError(
                f"{self.name} samples in-kernel from alias tables; "
                f"sampler {self.sampler!r} is not supported")

    def sample(self, table, key, shape):
        """Replay the kernel's counter-PRNG draw outside the kernel
        (exactly the ids an in-kernel step with this key draws)."""
        from repro.kernels.sgns_fused import fused_negative_ids, _as_seed

        return fused_negative_ids(_as_seed(key), table["prob"],
                                  table["alias"], shape)

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """Single-kernel step: in-kernel draw + forward + row grads +
        apply, both tables VMEM-resident."""
        from repro.kernels.sgns_fused import sgns_fused_step

        interpret = self.interpret if self.interpret is not None \
            else _auto_interpret()

        def step(params, centers, contexts, neg_table, key, step_idx):
            lr = sgns.linear_lr(step_idx, total_steps, cfg)
            return sgns_fused_step(params, centers, contexts, neg_table, key,
                                   lr, negatives=cfg.negatives,
                                   interpret=interpret)

        return step


@dataclass(frozen=True)
class FusedHBMPallasEngine(FusedPallasEngine):
    """The fused step against HBM-resident ``(V, d)`` tables: a chain
    of per-block kernel invocations, each DMA-gathering/scattering only
    the touched rows, with the in-kernel alias draw (same counter PRNG
    ⇒ same replay). Reaches the paper's 300k×500 sub-model shape the
    VMEM-resident variant cannot.

    ``block_pairs`` — pairs per block invocation (a shorter tail block
    covers any batch remainder).
    ``sequential``  — word2vec's true per-pair sequential apply (each
    pair's grads see every earlier pair's updates) instead of the
    default per-block semantics. Slower; the update-order oracle.
    """

    block_pairs: int = 256
    sequential: bool = False
    name = "pallas_fused_hbm"

    def __post_init__(self):
        super().__post_init__()
        if self.block_pairs < 1:
            raise ValueError(
                f"{self.name} needs block_pairs >= 1 (pairs per kernel "
                f"block), got {self.block_pairs}")

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """Per-block kernel-chain step against HBM-resident tables
        (DMA gather/RMW-scatter of touched rows only)."""
        from repro.kernels.sgns_fused_hbm import sgns_fused_hbm_step

        interpret = self.interpret if self.interpret is not None \
            else _auto_interpret()

        def step(params, centers, contexts, neg_table, key, step_idx):
            lr = sgns.linear_lr(step_idx, total_steps, cfg)
            return sgns_fused_hbm_step(
                params, centers, contexts, neg_table, key, lr,
                negatives=cfg.negatives, block_pairs=self.block_pairs,
                sequential=self.sequential, interpret=interpret)

        return step


@dataclass(frozen=True)
class FusedPipePallasEngine(FusedHBMPallasEngine):
    """The HBM-resident fused step with the **double-buffered DMA
    pipeline** (``kernels/sgns_fused_pipe.py``): a single kernel
    invocation per step in which block *i+1*'s deduped row gathers are
    in flight while block *i* computes and block *i-1*'s write-backs
    drain, hazard-ordered by the pure-JAX block planner. Bit-identical
    to ``pallas_fused_hbm`` (same replayed counter-PRNG negatives, same
    per-block chain semantics) with strictly less HBM traffic — each
    touched row moves exactly once per block in each direction.

    ``block_pairs`` — pairs per pipeline block (the batch is padded to
    whole blocks; padded pairs are masked to exactly-zero updates).
    ``ring_depth`` — VMEM row-buffer ring slots (≥ 2): a deeper ring
    keeps more blocks' write-backs in flight before the slot-recycling
    wait, at ``ring_depth × block_pairs × (K+2) × d`` floats of VMEM.
    ``sequential`` — word2vec's per-pair apply order is inherently
    unpipelineable, so ``sequential=True`` transparently runs the
    unpipelined :func:`~repro.kernels.sgns_fused_hbm.sgns_fused_hbm_step`
    oracle path instead.
    """

    ring_depth: int = 2
    name = "pallas_fused_pipe"

    def __post_init__(self):
        super().__post_init__()
        if self.ring_depth < 2:
            raise ValueError(
                f"{self.name} needs ring_depth >= 2 (gathers of block "
                f"b+1 must overlap scatters of block b), got "
                f"{self.ring_depth}")

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """One pipelined-kernel step (multi-slot DMA ring, deduped row
        traffic); ``sequential=True`` falls back to the HBM oracle."""
        if self.sequential:
            return FusedHBMPallasEngine.make_step(self, cfg, total_steps)
        from repro.kernels.sgns_fused_pipe import sgns_fused_pipe_step

        interpret = self.interpret if self.interpret is not None \
            else _auto_interpret()

        def step(params, centers, contexts, neg_table, key, step_idx):
            lr = sgns.linear_lr(step_idx, total_steps, cfg)
            return sgns_fused_pipe_step(
                params, centers, contexts, neg_table, key, lr,
                negatives=cfg.negatives, block_pairs=self.block_pairs,
                ring_depth=self.ring_depth, interpret=interpret)

        return step


@dataclass(frozen=True)
class FusedTieredPallasEngine(FusedPipePallasEngine):
    """The pipelined HBM engine with **frequency-tiered hot/cold
    parameter placement** (``kernels/sgns_fused_tiered.py``): the
    ``hot_rows`` hottest rows by unigram count — the id prefix, since
    ``build_vocab`` sorts ids by descending frequency — are pinned in a
    VMEM-resident copy of each table's prefix (one bulk DMA in at step
    start, one back at step end), while cold rows stay HBM-resident
    behind the inherited ``ring_depth``-slot DMA pipeline with dedup
    and hazard flags computed over cold rows only. Under Zipfian word
    frequencies the hot prefix absorbs most row traffic, so per-block
    DMA volume collapses while the VMEM footprint stays a chosen
    ``2 × hot_rows × d`` floats — a tunable dial from pure-pipe
    (``hot_rows=0``, delegates to the pipelined kernel) to
    pure-resident (``hot_rows ≥ V``, zero per-block row DMAs).
    Bit-identical to ``pallas_fused_hbm`` at every setting.

    ``hot_rows`` — rows pinned per table. Must be ≥ 0; the trainer
    rejects ``hot_rows > V`` at construction (:meth:`validate`) — a
    hot tier larger than the vocabulary is a misconfiguration, not a
    request for pure-resident placement (use ``hot_rows = V`` for
    that; direct kernel calls still clamp).
    ``block_pairs`` / ``ring_depth`` / ``sequential`` — as inherited
    (``sequential=True`` falls back to the unpipelined oracle, which is
    tier-free but bit-identical anyway).
    """

    hot_rows: int = 256
    name = "pallas_fused_tiered"

    def __post_init__(self):
        super().__post_init__()
        if self.hot_rows < 0:
            raise ValueError(
                f"{self.name} needs hot_rows >= 0, got {self.hot_rows}")

    def validate(self, *, vocab_size: int | None = None) -> None:
        """Reject a hot tier larger than the table it is a prefix of."""
        super().validate(vocab_size=vocab_size)
        if vocab_size and self.hot_rows > vocab_size:
            raise ValueError(
                f"{self.name} hot_rows={self.hot_rows} exceeds "
                f"vocab_size={vocab_size}; the hot tier is a prefix of "
                f"the (V, d) table — use hot_rows <= V (hot_rows=V is "
                f"fully VMEM-resident)")

    def make_step(self, cfg: SGNSConfig, total_steps: int):
        """One tiered-kernel step (VMEM hot prefix + cold DMA ring);
        ``sequential=True`` falls back to the HBM oracle."""
        if self.sequential:
            return FusedHBMPallasEngine.make_step(self, cfg, total_steps)
        from repro.kernels.sgns_fused_tiered import sgns_fused_tiered_step

        interpret = self.interpret if self.interpret is not None \
            else _auto_interpret()

        def step(params, centers, contexts, neg_table, key, step_idx):
            lr = sgns.linear_lr(step_idx, total_steps, cfg)
            return sgns_fused_tiered_step(
                params, centers, contexts, neg_table, key, lr,
                negatives=cfg.negatives, block_pairs=self.block_pairs,
                hot_rows=self.hot_rows, ring_depth=self.ring_depth,
                interpret=interpret)

        return step


ENGINES: dict[str, type[UpdateEngine]] = {
    "dense": DenseEngine,
    "sparse": SparseEngine,
    "pallas": PallasEngine,
    "pallas_fused": FusedPallasEngine,
    "pallas_fused_hbm": FusedHBMPallasEngine,
    "pallas_fused_pipe": FusedPipePallasEngine,
    "pallas_fused_tiered": FusedTieredPallasEngine,
}
ENGINE_NAMES = tuple(ENGINES)


def get_engine(spec: str | UpdateEngine = "sparse", **overrides) -> UpdateEngine:
    """Resolve an engine spec: an instance (returned as-is, or with
    field overrides applied) or a ``"name"`` / ``"name:sampler"``
    string, e.g. ``get_engine("sparse:alias")``."""
    if isinstance(spec, UpdateEngine):
        return replace(spec, **overrides) if overrides else spec
    name, _, sampler = str(spec).partition(":")
    if name not in ENGINES:
        raise ValueError(
            f"unknown update engine {name!r}; expected one of "
            f"{sorted(ENGINES)} (optionally 'name:sampler')")
    if sampler:
        overrides.setdefault("sampler", sampler)
    return ENGINES[name](**overrides)
