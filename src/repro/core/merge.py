"""The Merge phase — Concat, PCA, and ALiR (the paper's contribution).

All merges operate on *stacked* sub-models: ``models (n, V, d)`` over the
**union** vocabulary, plus a presence ``mask (n, V)`` marking which words
each sub-model actually trained. Concat/PCA use the intersection rows
(as in the paper); ALiR uses the union and reconstructs missing rows.

ALiR (Alternating Linear Regression), a Generalized Procrustes Analysis
variant (paper §3.3.2), per iteration:

1. *Estimate translation* — for each sub-model i, solve Orthogonal
   Procrustes on its **present** rows:  W_i = argmin ‖M_i' W − Y'‖_F
   over orthogonal W  (closed form: UVᵀ from SVD of M_i'ᵀ Y').
2. *Estimate missing values* — reconstruct M_i* from Y* via
   Y* = M_i* W_i  ⇒  M_i* = Y* W_iᵀ (W_i orthogonal).
3. *Update joint embedding* — Y ← mean over i of (M_i W_i), using the
   reconstructed rows for the missing parts.

Stops when the change in the average normalized Frobenius displacement
``(1/n) Σ_i ‖Y − M_i W_i‖_F / sqrt(|V|·d)`` drops below ``tol``.

Everything is vmapped over the model axis and jittable (SVDs are d×d —
tiny next to training).

Two merge schedules share this math:

* **batch** (:func:`merge_alir`) — all sub-models at once, the paper's
  "few minutes at the end" synchronization point;
* **incremental** (:class:`IncrementalAlirMerger`) — sub-models fold
  into the running consensus *as workers finish*, so a versioned,
  servable table exists after the first arrival and improves
  monotonically. There is no wait-for-all barrier; the final fold
  restacks in canonical worker order and is therefore **bit-identical**
  to the batch merge no matter the arrival order
  (``tests/test_merge.py`` property-tests the permutation invariance).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Building the stacked representation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StackedModels:
    """``n`` sub-models on the union vocabulary: ``(n, V, d)`` rows plus
    a ``(n, V)`` presence mask (rows are garbage where the mask is
    False). The input type every ``merge_*`` consumes."""

    models: jax.Array   # (n, V, d) union-vocab rows; garbage where absent
    mask: jax.Array     # (n, V) bool presence

    @property
    def n(self) -> int:
        """Number of stacked sub-models."""
        return self.models.shape[0]

    def intersection(self) -> jax.Array:
        """(V,) bool — words present in *every* sub-model."""
        return jnp.all(self.mask, axis=0)

    def union_present(self) -> jax.Array:
        """(V,) bool — words present in *at least one* sub-model."""
        return jnp.any(self.mask, axis=0)


def stack_models(models: list[np.ndarray], masks: list[np.ndarray]) -> StackedModels:
    """Stack per-worker ``(V, d)`` arrays + ``(V,)`` masks into a
    :class:`StackedModels` (list order is the stacking order)."""
    m = jnp.asarray(np.stack(models))
    k = jnp.asarray(np.stack(masks)).astype(bool)
    return StackedModels(models=m, mask=k)


# ---------------------------------------------------------------------------
# Concat / PCA (baselines from the paper)
# ---------------------------------------------------------------------------
def merge_concat(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    """(V, n*d) concatenation over intersection rows; rows outside the
    intersection are zero (OOV for this merge). Returns (emb, valid)."""
    n, V, d = stacked.models.shape
    emb = jnp.transpose(stacked.models, (1, 0, 2)).reshape(V, n * d)
    valid = stacked.intersection()
    return emb * valid[:, None], valid


def merge_pca(stacked: StackedModels, out_dim: int) -> tuple[jax.Array, jax.Array]:
    """PCA of the concatenated matrix down to ``out_dim`` (paper's Pca).

    Economy form: eigendecomposition of the (nd × nd) covariance over
    intersection rows — never materializes a V×V anything.
    """
    emb, valid = merge_concat(stacked)
    cnt = jnp.maximum(valid.sum(), 1)
    mean = jnp.sum(emb * valid[:, None], axis=0) / cnt
    X = (emb - mean) * valid[:, None]
    cov = X.T @ X / cnt
    eigval, eigvec = jnp.linalg.eigh(cov)          # ascending
    comps = eigvec[:, -out_dim:][:, ::-1]          # (nd, out_dim)
    return (X @ comps) * valid[:, None], valid


# ---------------------------------------------------------------------------
# Orthogonal Procrustes
# ---------------------------------------------------------------------------
def orthogonal_procrustes(A: jax.Array, B: jax.Array,
                          weights: jax.Array | None = None) -> jax.Array:
    """W minimizing ‖A W − B‖_F (rows optionally weighted), W orthogonal."""
    if weights is not None:
        A = A * weights[:, None]
        # weight appears once: Aᵀ diag(w) B — weight either side, not both
        M = A.T @ B
    else:
        M = A.T @ B
    U, _, Vt = jnp.linalg.svd(M, full_matrices=False)
    return U @ Vt


# ---------------------------------------------------------------------------
# ALiR
# ---------------------------------------------------------------------------
def _alir_iteration(Y: jax.Array, models: jax.Array, mask: jax.Array):
    """One ALiR round. Returns (Y_new, displacement, W (n,d,d))."""
    maskf = mask.astype(Y.dtype)                       # (n, V)

    def per_model(M_i, m_i):
        # Step 1: Procrustes on present rows.
        A = M_i * m_i[:, None]
        Byy = Y * m_i[:, None]
        U, _, Vt = jnp.linalg.svd(A.T @ Byy, full_matrices=False)
        W = U @ Vt                                     # (d, d)
        aligned_present = M_i @ W                      # valid on present rows
        # Step 2: reconstruct missing rows: M_i* = Y* W_iᵀ ⇒ aligned = Y*.
        aligned_full = jnp.where(m_i[:, None] > 0, aligned_present, Y)
        # Displacement on present rows (normalized Frobenius).
        num_rows = jnp.maximum(m_i.sum(), 1.0)
        disp = jnp.linalg.norm((Y - aligned_present) * m_i[:, None]) / jnp.sqrt(
            num_rows * Y.shape[1])
        return aligned_full, disp, W

    aligned, disps, Ws = jax.vmap(per_model)(models, maskf)
    # Step 3: mean of translations of all n models (reconstructed rows
    # contribute the current Y, exactly as in the paper's formulation).
    Y_new = jnp.mean(aligned, axis=0)
    return Y_new, jnp.mean(disps), Ws


@partial(jax.jit, static_argnames=("max_iters",))
def _alir_loop(Y0, models, mask, max_iters: int, tol: float):
    """Fixed-length scan with an early-converged fast path: once the
    displacement change drops below ``tol``, Y *and* the reported
    displacement freeze (the remaining iterations skip the per-model
    SVDs entirely via ``cond``). The per-iteration trace therefore ends
    in a constant run of the converged error — previously the carried
    displacement kept mutating after ``done``, so the trace misreported
    the converged error and every residual iteration paid full SVDs."""
    def body(carry, _):
        Y, prev_disp, done = carry

        def converged(_):
            return Y, prev_disp

        def iterate(_):
            Y_new, disp, _ = _alir_iteration(Y, models, mask)
            return Y_new, disp

        Y_out, disp = jax.lax.cond(done, converged, iterate, None)
        new_done = done | (jnp.abs(prev_disp - disp) < tol)
        return (Y_out, disp, new_done), disp

    (Y, _, _), disps = jax.lax.scan(
        body, (Y0, jnp.inf, jnp.array(False)), None, length=max_iters)
    return Y, disps


def alir_init(stacked: StackedModels, out_dim: int, init: str, key: jax.Array):
    """Initial ``(V, out_dim)`` consensus for ALiR: "random" (paper init
    i) or "pca" — PCA on intersection rows, random elsewhere (init ii)."""
    n, V, d = stacked.models.shape
    if init == "random":
        return 0.1 * jax.random.normal(key, (V, out_dim), dtype=jnp.float32)
    if init == "pca":
        pca_emb, valid = merge_pca(stacked, out_dim)
        rnd = 0.1 * jax.random.normal(key, (V, out_dim), dtype=jnp.float32)
        # intersection rows from PCA; other union rows random (paper init ii)
        return jnp.where(valid[:, None], pca_emb, rnd)
    raise ValueError(f"unknown init {init!r}")


def merge_alir(
    stacked: StackedModels,
    out_dim: int | None = None,
    init: str = "pca",
    max_iters: int = 10,
    tol: float = 1e-4,
    key: jax.Array | None = None,
    Y0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """ALiR-merge a stack of sub-models into one consensus table.

    Args:
        stacked: ``(n, V, d)`` sub-models over the union vocabulary plus
            their ``(n, V)`` presence mask.
        out_dim: output dimension — must equal ``d`` (ALiR aligns, it
            does not project; use :func:`merge_pca` to change dims).
        init: ``"pca"`` (paper init ii — intersection rows from the PCA
            merge, the rest random) or ``"random"``.
        max_iters / tol: fixed iteration budget and the displacement-
            change convergence threshold; once converged the remaining
            iterations are skipped via ``lax.cond`` and the trace
            repeats the converged displacement.
        key: PRNG key for the random part of the init.
        Y0: optional **warm start** — an explicit initial consensus
            table that overrides ``init``/``key``. Used by
            :class:`IncrementalAlirMerger` to re-fold from the previous
            consensus when one more sub-model arrives (typically 1–2
            iterations to re-converge instead of a cold solve).

    Returns:
        ``(Y (V, d), valid (V,), disps (max_iters,))`` where ``valid``
        marks union-vocabulary rows (present in ≥1 sub-model); every
        valid row has a representation — that is ALiR's point. Invalid
        rows are zeroed.
    """
    n, V, d = stacked.models.shape
    out_dim = out_dim or d
    if out_dim != d:
        raise ValueError("ALiR aligns in the sub-model dimension; out_dim must equal d")
    if Y0 is None:
        key = key if key is not None else jax.random.PRNGKey(0)
        Y0 = alir_init(stacked, out_dim, init, key)
    elif Y0.shape != (V, d):
        raise ValueError(f"warm-start Y0 has shape {Y0.shape}, expected {(V, d)}")
    models = stacked.models * stacked.mask[..., None]
    Y, disps = _alir_loop(Y0, models, stacked.mask, max_iters, tol)
    valid = stacked.union_present()
    return Y * valid[:, None], valid, disps


def alir_transforms(stacked: StackedModels, Y: jax.Array) -> jax.Array:
    """Per-sub-model orthogonal alignment maps ``W_i`` onto consensus ``Y``.

    Solves Orthogonal Procrustes on each sub-model's **present** rows
    (one :func:`_alir_iteration` step without updating ``Y``). The
    returned ``(n, d, d)`` stack is what the serving tier stores in the
    published artifact: a row absent from sub-model *i* is reconstructed
    on the fly as ``Y[w] @ W_i.T`` — exactly the
    :func:`reconstruct_missing` formula, as a per-query operation.
    """
    _, _, Ws = _alir_iteration(Y, stacked.models * stacked.mask[..., None],
                               stacked.mask)
    return Ws


def reconstruct_missing(stacked: StackedModels, Y: jax.Array) -> jax.Array:
    """Per-sub-model reconstruction of its missing rows in its own space:
    M_i* = Y* W_iᵀ (paper §3.3.2 step 2 — the robustness claim).

    Args:
        stacked: the sub-model stack with presence mask.
        Y: the merged consensus table ``(V, d)``.

    Returns:
        Completed models ``(n, V, d)``: present rows pass through
        untouched, missing rows are reconstructed from the consensus.
    """
    Ws = alir_transforms(stacked, Y)

    def back(M_i, m_i, W):
        rec = Y @ W.T
        return jnp.where(m_i[:, None], M_i, rec)
    return jax.vmap(back)(stacked.models, stacked.mask, Ws)


# ---------------------------------------------------------------------------
# Incremental merge — fold sub-models in as workers finish.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FoldResult:
    """One incremental-merge fold: the consensus over sub-models so far.

    ``worker_ids`` is the canonical (ascending) order of the arrived
    workers — also the sub-model axis order of every array here and of
    the published artifact's ``mask``/``transforms``/``models``.
    """

    worker_ids: tuple[int, ...]
    Y: jax.Array            # (V, d) consensus; invalid rows zeroed
    valid: jax.Array        # (V,) union-presence over arrived sub-models
    disps: jax.Array        # per-iteration ALiR displacement trace


class IncrementalAlirMerger:
    """Folds sub-models into the merged table **as they arrive** — the
    paper's only synchronization point, without the wait-for-all barrier.

    Protocol::

        merger = IncrementalAlirMerger()
        for worker_id, (model, mask) in arrivals:      # any order
            fold = merger.add(worker_id, model, mask)  # servable now
            publish(fold)                              # version k
        final = merger.fold(warm=False)                # == batch merge

    Invariants:

    * Sub-models are restacked in **canonical worker-id order** before
      every fold, so the *final* fold (all arrived, ``warm=False``) is
      bit-identical to :func:`merge_alir` on the batch-stacked models
      regardless of arrival order — property-tested under permutation
      in ``tests/test_merge.py``.
    * Intermediate folds warm-start from the previous consensus
      (``warm_start=True``, the default): the early-convergence freeze
      in :func:`_alir_loop` makes a re-fold that barely moves cost 1–2
      SVD rounds instead of ``max_iters``. The documented tolerance of
      a warm-started full fold vs the batch merge: ALiR's consensus is
      only defined up to a global orthogonal map (rotate ``Y``, absorb
      it into every ``W_i``), and the warm path inherits its gauge from
      the arrival history — so warm results match the batch merge up to
      Procrustes alignment (small residual), not element-wise. Call
      ``fold(warm=False)`` for the canonical, gauge-fixed cold solve.
    * ``valid`` only covers words present in some *arrived* sub-model:
      an early fold is a complete, servable table for its coverage, and
      coverage grows monotonically with arrivals.

    **Merge-from-whatever-finished** (elastic training): workers on
    preempted hosts may never arrive at all. ``quorum`` names the
    minimum number of arrived sub-models a :meth:`final` merge requires;
    ``deadline`` (seconds on ``clock``, measured from construction)
    closes the arrival window — an :meth:`add` after the deadline is
    recorded in :attr:`late_workers` and **not folded**, so the final
    table is a pure function of the on-time subset. A quorum merge over
    the survivors is bit-identical to the batch :func:`merge_alir` over
    that subset's stack (``tests/test_elastic.py``), and the presence
    masks already say which words the missing workers would have
    covered — serving falls back to :func:`reconstruct_missing` /
    OOV exactly as for any absent row.
    """

    def __init__(self, *, init: str = "pca", max_iters: int = 10,
                 tol: float = 1e-4, key: jax.Array | None = None,
                 warm_start: bool = True, quorum: int | None = None,
                 deadline: float | None = None, clock=None):
        if quorum is not None and quorum < 1:
            raise ValueError(f"quorum must be >= 1, got {quorum}")
        if deadline is not None and deadline < 0:
            raise ValueError(f"deadline must be >= 0, got {deadline}")
        self.init = init
        self.max_iters = max_iters
        self.tol = tol
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.warm_start = warm_start
        self.quorum = quorum
        self.deadline = deadline
        # injectable clock so deadline behaviour is deterministic in
        # tests (default: monotonic seconds since construction)
        import time as _time
        self._clock = clock if clock is not None else _time.monotonic
        self._t0 = self._clock()
        self.late_workers: list[int] = []
        self._models: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        self._Y: jax.Array | None = None

    @property
    def worker_ids(self) -> tuple[int, ...]:
        """Arrived workers in canonical (ascending) order."""
        return tuple(sorted(self._models))

    @property
    def n_folded(self) -> int:
        """Number of sub-models that have arrived so far."""
        return len(self._models)

    @property
    def quorum_met(self) -> bool:
        """Whether enough sub-models have arrived for a :meth:`final`
        merge (always ``True`` without a quorum)."""
        return self.quorum is None or self.n_folded >= self.quorum

    @property
    def deadline_passed(self) -> bool:
        """Whether the arrival window has closed (``False`` without a
        deadline)."""
        return (self.deadline is not None
                and self._clock() - self._t0 > self.deadline)

    def stacked(self) -> StackedModels:
        """The arrived sub-models restacked in canonical worker order."""
        if not self._models:
            raise ValueError("no sub-models have arrived yet")
        ids = self.worker_ids
        return stack_models([np.asarray(self._models[i][0]) for i in ids],
                            [np.asarray(self._models[i][1]) for i in ids])

    def add(self, worker_id: int, model, mask, *,
            fold: bool = True) -> FoldResult | None:
        """Register a finished worker's sub-model (and, by default,
        immediately re-fold the consensus).

        Args:
            worker_id: the worker's global id — duplicate arrivals are
                rejected (a retried worker must be idempotent upstream).
            model: ``(V, d)`` table over the union vocabulary.
            mask: ``(V,)`` bool presence for this sub-model.
            fold: re-fold now and return the :class:`FoldResult`;
                ``fold=False`` just registers (batch several arrivals
                into one fold with a later :meth:`fold` call).

        Returns ``None`` without folding when the merger's ``deadline``
        has passed — the straggler is recorded in :attr:`late_workers`
        and the consensus stays a function of the on-time subset.
        """
        if self.deadline_passed:
            self.late_workers.append(int(worker_id))
            return None
        if worker_id in self._models:
            raise ValueError(f"worker {worker_id} already folded in")
        model = np.asarray(model)
        mask = np.asarray(mask).astype(bool)
        if model.ndim != 2 or mask.shape != (model.shape[0],):
            raise ValueError(
                f"expected model (V, d) and mask (V,); got {model.shape} "
                f"and {mask.shape}")
        if self._models:
            V, d = next(iter(self._models.values()))[0].shape
            if model.shape != (V, d):
                raise ValueError(
                    f"sub-model shape {model.shape} != established {(V, d)}")
        self._models[worker_id] = (model, mask)
        return self.fold() if fold else None

    def fold(self, warm: bool | None = None) -> FoldResult:
        """Re-solve ALiR over everything that has arrived.

        ``warm`` overrides the constructor's ``warm_start`` for this
        fold; ``fold(warm=False)`` after all arrivals reproduces the
        batch :func:`merge_alir` bit-for-bit.
        """
        warm = self.warm_start if warm is None else warm
        stacked = self.stacked()
        Y0 = self._Y if (warm and self._Y is not None) else None
        Y, valid, disps = merge_alir(
            stacked, init=self.init, max_iters=self.max_iters, tol=self.tol,
            key=self.key, Y0=Y0)
        self._Y = Y
        return FoldResult(worker_ids=self.worker_ids, Y=Y, valid=valid,
                          disps=disps)

    def final(self, *, require_quorum: bool = True) -> FoldResult:
        """The merge-from-whatever-finished endpoint: the canonical cold
        fold over every sub-model that arrived (on time) — bit-identical
        to batch :func:`merge_alir` over that subset's stack, in
        canonical worker order, regardless of arrival order.

        Raises ``RuntimeError`` when a ``quorum`` is configured and
        unmet (pass ``require_quorum=False`` to fold a below-quorum
        subset anyway, e.g. for a best-effort table while paging the
        operator)."""
        if require_quorum and not self.quorum_met:
            raise RuntimeError(
                f"quorum not met: {self.n_folded} sub-model(s) arrived, "
                f"quorum is {self.quorum}")
        return self.fold(warm=False)


# ---------------------------------------------------------------------------
# Naive averaging (the paper's counter-example) — for tests/benchmarks.
# ---------------------------------------------------------------------------
def merge_average(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    """Presence-weighted element-wise mean over union rows — the
    paper's counter-example (sub-models live in incompatible gauges, so
    averaging cancels signal). Returns (emb, valid=union)."""
    maskf = stacked.mask.astype(stacked.models.dtype)
    num = jnp.sum(stacked.models * maskf[..., None], axis=0)
    den = jnp.maximum(jnp.sum(maskf, axis=0), 1.0)
    return num / den[:, None], stacked.union_present()


MERGE_METHODS = ("concat", "pca", "alir_rand", "alir_pca", "average", "single")


def merge(stacked: StackedModels, method: str, out_dim: int,
          key: jax.Array | None = None, **kw):
    """Dispatch a merge by name (one of :data:`MERGE_METHODS`). Returns
    ``(emb, valid)``; ``key`` is required by the alir_* methods, extra
    kwargs are forwarded to :func:`merge_alir`."""
    if method == "concat":
        return merge_concat(stacked)
    if method == "pca":
        return merge_pca(stacked, out_dim)
    if method == "alir_rand":
        Y, v, _ = merge_alir(stacked, out_dim, init="random", key=key, **kw)
        return Y, v
    if method == "alir_pca":
        Y, v, _ = merge_alir(stacked, out_dim, init="pca", key=key, **kw)
        return Y, v
    if method == "average":
        return merge_average(stacked)
    if method == "single":
        return stacked.models[0], stacked.mask[0]
    raise ValueError(f"unknown merge method {method!r}")
