"""The Merge phase — a unified :class:`Merger` API over Concat, PCA,
averaging, and ALiR (the paper's contribution).

All merges operate on *stacked* sub-models: ``models (n, V, d)`` over the
**union** vocabulary, plus a presence ``mask (n, V)`` marking which words
each sub-model actually trained. Concat/PCA use the intersection rows
(as in the paper); ALiR uses the union and reconstructs missing rows.

ALiR (Alternating Linear Regression), a Generalized Procrustes Analysis
variant (paper §3.3.2), per iteration:

1. *Estimate translation* — for each sub-model i, solve Orthogonal
   Procrustes on its **present** rows:  W_i = argmin ‖M_i' W − Y'‖_F
   over orthogonal W  (closed form: UVᵀ from SVD of M_i'ᵀ Y').
2. *Estimate missing values* — reconstruct M_i* from Y* via
   Y* = M_i* W_i  ⇒  M_i* = Y* W_iᵀ (W_i orthogonal).
3. *Update joint embedding* — Y ← mean over i of (M_i W_i), using the
   reconstructed rows for the missing parts.

Stops when the change in the average normalized Frobenius displacement
``(1/n) Σ_i ‖Y − M_i W_i‖_F / sqrt(|V|·d)`` drops below ``tol``.

Everything is vmapped over the model axis and jittable (SVDs are d×d —
tiny next to training).

**The Merger API.** Every merge strategy is one object implementing the
same protocol (mirroring the ``UpdateEngine`` registry in
:mod:`repro.core.engine`)::

    merger = get_merger("alir", quorum=3, deadline=60.0)   # MergeConfig dials
    out = merger.merge(stacked)                  # batch: all at once
    for worker_id, (model, mask) in arrivals:    # incremental: any order
        res = merger.add(worker_id, model, mask) # servable consensus now
    final = merger.final()                       # canonical cold solve

Registered mergers (:data:`MERGER_NAMES`): ``"alir"`` (the batch +
incremental ALiR solver), ``"alir_tree"`` (the log-depth pairwise
reduction tree in :mod:`repro.core.merge_tree` — merge wallclock O(log W)
instead of O(W)), ``"average"``, ``"concat"``, ``"pca"``. One frozen
:class:`MergeConfig` carries every dial (``quorum`` / ``deadline`` /
``fan_in`` / ``shard`` / the ALiR solver knobs).

**Sharded Gram accumulation.** The only O(V) dense products in the ALiR
iteration are the per-model Grams ``(M_i·m_i)ᵀ(Y·m_i)`` — embarrassingly
data-parallel over row-blocks of ``(V, d)``. ``shard > 1`` computes them
as a **fixed-order** reduction over ``shard`` row-block partials
(:func:`sharded_gram`): the per-block partials are bit-identical no
matter which host computes which block, and the ascending-block-order
summation makes the reduced Gram a pure function of the static ``shard``
dial — never of the host/device partition. The worker-mesh execution of
the same reduction (one ``all_gather``, the system's single intentional
collective) lives in :mod:`repro.sharding.merge` and is bit-identical to
the local path. ``shard=1`` (default) is the plain dense matmul.

The legacy free functions ``merge_alir`` / ``merge_concat`` /
``merge_pca`` / ``merge_average`` remain as thin deprecated shims over
the registry and will be removed; :class:`IncrementalAlirMerger` is the
backward-compatible name for ``AlirMerger`` with keyword dials.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Building the stacked representation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StackedModels:
    """``n`` sub-models on the union vocabulary: ``(n, V, d)`` rows plus
    a ``(n, V)`` presence mask (rows are garbage where the mask is
    False). The input type every merger consumes."""

    models: jax.Array   # (n, V, d) union-vocab rows; garbage where absent
    mask: jax.Array     # (n, V) bool presence

    @property
    def n(self) -> int:
        """Number of stacked sub-models."""
        return self.models.shape[0]

    def intersection(self) -> jax.Array:
        """(V,) bool — words present in *every* sub-model."""
        return jnp.all(self.mask, axis=0)

    def union_present(self) -> jax.Array:
        """(V,) bool — words present in *at least one* sub-model."""
        return jnp.any(self.mask, axis=0)


def stack_models(models: list[np.ndarray], masks: list[np.ndarray]) -> StackedModels:
    """Stack per-worker ``(V, d)`` arrays + ``(V,)`` masks into a
    :class:`StackedModels` (list order is the stacking order)."""
    m = jnp.asarray(np.stack(models))
    k = jnp.asarray(np.stack(masks)).astype(bool)
    return StackedModels(models=m, mask=k)


# ---------------------------------------------------------------------------
# Concat / PCA / averaging — internal impls (public surface is the
# Merger registry; the legacy free functions below are deprecated shims).
# ---------------------------------------------------------------------------
def _merge_concat(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    n, V, d = stacked.models.shape
    emb = jnp.transpose(stacked.models, (1, 0, 2)).reshape(V, n * d)
    valid = stacked.intersection()
    return emb * valid[:, None], valid


def _merge_pca(stacked: StackedModels, out_dim: int) -> tuple[jax.Array, jax.Array]:
    # Economy form: eigendecomposition of the (nd × nd) covariance over
    # intersection rows — never materializes a V×V anything.
    emb, valid = _merge_concat(stacked)
    cnt = jnp.maximum(valid.sum(), 1)
    mean = jnp.sum(emb * valid[:, None], axis=0) / cnt
    X = (emb - mean) * valid[:, None]
    cov = X.T @ X / cnt
    eigval, eigvec = jnp.linalg.eigh(cov)          # ascending
    comps = eigvec[:, -out_dim:][:, ::-1]          # (nd, out_dim)
    return (X @ comps) * valid[:, None], valid


def _merge_average(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    maskf = stacked.mask.astype(stacked.models.dtype)
    num = jnp.sum(stacked.models * maskf[..., None], axis=0)
    den = jnp.maximum(jnp.sum(maskf, axis=0), 1.0)
    return num / den[:, None], stacked.union_present()


# ---------------------------------------------------------------------------
# Orthogonal Procrustes
# ---------------------------------------------------------------------------
def orthogonal_procrustes(A: jax.Array, B: jax.Array,
                          weights: jax.Array | None = None) -> jax.Array:
    """W minimizing ‖A W − B‖_F (rows optionally weighted), W orthogonal."""
    if weights is not None:
        A = A * weights[:, None]
        # weight appears once: Aᵀ diag(w) B — weight either side, not both
        M = A.T @ B
    else:
        M = A.T @ B
    U, _, Vt = jnp.linalg.svd(M, full_matrices=False)
    return U @ Vt


# ---------------------------------------------------------------------------
# Sharded Gram accumulation — the distributable core of the ALiR solve.
#
# ``AᵀB`` over ``(V, d)`` tables is the only O(V) dense product in the
# iteration. Split V into ``num_shards`` row blocks: each block's
# partial Gram is computed independently (any host can own any block —
# the partials are bit-identical regardless of placement), then summed
# in ascending block order. Floating-point addition is not associative,
# so the blocked sum differs from the flat matmul in the last ulp —
# therefore the *fixed-order reduction itself* is the canonical
# definition of the Gram at a given ``shard`` setting: bits are a pure
# function of the static shard count, never of the partition.
# ---------------------------------------------------------------------------
def gram_block_partials(A: jax.Array, B: jax.Array, num_shards: int) -> jax.Array:
    """Per-row-block partial Grams: ``(num_shards, d_A, d_B)`` where
    block ``s`` is ``A[s·blk:(s+1)·blk].T @ B[s·blk:(s+1)·blk]`` (rows
    zero-padded at the end to a multiple of ``num_shards``). Each block
    is independent — this is the piece a host computes for the row
    slice it owns."""
    V = A.shape[0]
    S = int(num_shards)
    pad = (-V) % S
    if pad:
        A = jnp.concatenate([A, jnp.zeros((pad, A.shape[1]), A.dtype)])
        B = jnp.concatenate([B, jnp.zeros((pad, B.shape[1]), B.dtype)])
    blk = (V + pad) // S
    Ab = A.reshape(S, blk, A.shape[1])
    Bb = B.reshape(S, blk, B.shape[1])
    return jax.vmap(lambda a, b: a.T @ b)(Ab, Bb)


def reduce_gram_partials(parts: jax.Array) -> jax.Array:
    """Sum ``(S, d, e)`` partials in **ascending block order** (a
    sequential ``lax.scan``, not a tree/psum reduction) — the fixed
    order that makes the result independent of who computed which
    block."""
    def step(acc, p):
        return acc + p, None
    out, _ = jax.lax.scan(step, jnp.zeros_like(parts[0]), parts)
    return out


def sharded_gram(A: jax.Array, B: jax.Array, num_shards: int = 1) -> jax.Array:
    """``AᵀB`` as the canonical fixed-order ``num_shards``-block
    reduction (``num_shards <= 1``: the plain dense matmul)."""
    if num_shards <= 1:
        return A.T @ B
    return reduce_gram_partials(gram_block_partials(A, B, num_shards))


# ---------------------------------------------------------------------------
# ALiR
# ---------------------------------------------------------------------------
def _alir_iteration(Y: jax.Array, models: jax.Array, mask: jax.Array,
                    gram_shards: int = 1):
    """One ALiR round. Returns (Y_new, displacement, W (n,d,d))."""
    maskf = mask.astype(Y.dtype)                       # (n, V)

    def per_model(M_i, m_i):
        # Step 1: Procrustes on present rows. The Gram is the sharded
        # fixed-order reduction — the distributable part of the solve.
        A = M_i * m_i[:, None]
        Byy = Y * m_i[:, None]
        U, _, Vt = jnp.linalg.svd(sharded_gram(A, Byy, gram_shards),
                                  full_matrices=False)
        W = U @ Vt                                     # (d, d)
        aligned_present = M_i @ W                      # valid on present rows
        # Step 2: reconstruct missing rows: M_i* = Y* W_iᵀ ⇒ aligned = Y*.
        aligned_full = jnp.where(m_i[:, None] > 0, aligned_present, Y)
        # Displacement on present rows (normalized Frobenius).
        num_rows = jnp.maximum(m_i.sum(), 1.0)
        disp = jnp.linalg.norm((Y - aligned_present) * m_i[:, None]) / jnp.sqrt(
            num_rows * Y.shape[1])
        return aligned_full, disp, W

    aligned, disps, Ws = jax.vmap(per_model)(models, maskf)
    # Step 3: mean of translations of all n models (reconstructed rows
    # contribute the current Y, exactly as in the paper's formulation).
    Y_new = jnp.mean(aligned, axis=0)
    return Y_new, jnp.mean(disps), Ws


@partial(jax.jit, static_argnames=("max_iters", "gram_shards"))
def _alir_loop(Y0, models, mask, max_iters: int, tol: float,
               gram_shards: int = 1):
    """Fixed-length scan with an early-converged fast path: once the
    displacement change drops below ``tol``, Y *and* the reported
    displacement freeze (the remaining iterations skip the per-model
    SVDs entirely via ``cond``). The per-iteration trace therefore ends
    in a constant run of the converged error — previously the carried
    displacement kept mutating after ``done``, so the trace misreported
    the converged error and every residual iteration paid full SVDs."""
    def body(carry, _):
        Y, prev_disp, done = carry

        def converged(_):
            return Y, prev_disp

        def iterate(_):
            Y_new, disp, _ = _alir_iteration(Y, models, mask, gram_shards)
            return Y_new, disp

        Y_out, disp = jax.lax.cond(done, converged, iterate, None)
        new_done = done | (jnp.abs(prev_disp - disp) < tol)
        return (Y_out, disp, new_done), disp

    (Y, _, _), disps = jax.lax.scan(
        body, (Y0, jnp.inf, jnp.array(False)), None, length=max_iters)
    return Y, disps


def alir_init(stacked: StackedModels, out_dim: int, init: str, key: jax.Array):
    """Initial ``(V, out_dim)`` consensus for ALiR: "random" (paper init
    i) or "pca" — PCA on intersection rows, random elsewhere (init ii)."""
    n, V, d = stacked.models.shape
    if init == "random":
        return 0.1 * jax.random.normal(key, (V, out_dim), dtype=jnp.float32)
    if init == "pca":
        pca_emb, valid = _merge_pca(stacked, out_dim)
        rnd = 0.1 * jax.random.normal(key, (V, out_dim), dtype=jnp.float32)
        # intersection rows from PCA; other union rows random (paper init ii)
        return jnp.where(valid[:, None], pca_emb, rnd)
    raise ValueError(f"unknown init {init!r}")


def _alir_solve(
    stacked: StackedModels,
    out_dim: int | None = None,
    init: str = "pca",
    max_iters: int = 10,
    tol: float = 1e-4,
    key: jax.Array | None = None,
    Y0: jax.Array | None = None,
    shard: int = 1,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """ALiR-merge a stack of sub-models into one consensus table (the
    internal batch solver behind :class:`AlirMerger`).

    Args:
        stacked: ``(n, V, d)`` sub-models over the union vocabulary plus
            their ``(n, V)`` presence mask.
        out_dim: output dimension — must equal ``d`` (ALiR aligns, it
            does not project; use the ``"pca"`` merger to change dims).
        init: ``"pca"`` (paper init ii — intersection rows from the PCA
            merge, the rest random) or ``"random"``.
        max_iters / tol: fixed iteration budget and the displacement-
            change convergence threshold; once converged the remaining
            iterations are skipped via ``lax.cond`` and the trace
            repeats the converged displacement.
        key: PRNG key for the random part of the init.
        Y0: optional **warm start** — an explicit initial consensus
            table that overrides ``init``/``key``. Used by
            :class:`AlirMerger` to re-fold from the previous consensus
            when one more sub-model arrives (typically 1–2 iterations
            to re-converge instead of a cold solve).
        shard: Gram accumulation blocks (see :func:`sharded_gram`) —
            a **static** dial: results at a given ``shard`` are
            bit-identical no matter which host computes which block.

    Returns:
        ``(Y (V, d), valid (V,), disps (max_iters,))`` where ``valid``
        marks union-vocabulary rows (present in ≥1 sub-model); every
        valid row has a representation — that is ALiR's point. Invalid
        rows are zeroed.
    """
    n, V, d = stacked.models.shape
    out_dim = out_dim or d
    if out_dim != d:
        raise ValueError("ALiR aligns in the sub-model dimension; out_dim must equal d")
    if Y0 is None:
        key = key if key is not None else jax.random.PRNGKey(0)
        Y0 = alir_init(stacked, out_dim, init, key)
    elif Y0.shape != (V, d):
        raise ValueError(f"warm-start Y0 has shape {Y0.shape}, expected {(V, d)}")
    models = stacked.models * stacked.mask[..., None]
    Y, disps = _alir_loop(Y0, models, stacked.mask, max_iters, tol, shard)
    valid = stacked.union_present()
    return Y * valid[:, None], valid, disps


def alir_transforms(stacked: StackedModels, Y: jax.Array,
                    shard: int = 1) -> jax.Array:
    """Per-sub-model orthogonal alignment maps ``W_i`` onto consensus ``Y``.

    Solves Orthogonal Procrustes on each sub-model's **present** rows
    (one :func:`_alir_iteration` step without updating ``Y``). The
    returned ``(n, d, d)`` stack is what the serving tier stores in the
    published artifact: a row absent from sub-model *i* is reconstructed
    on the fly as ``Y[w] @ W_i.T`` — exactly the
    :func:`reconstruct_missing` formula, as a per-query operation.
    """
    _, _, Ws = _alir_iteration(Y, stacked.models * stacked.mask[..., None],
                               stacked.mask, shard)
    return Ws


def reconstruct_missing(stacked: StackedModels, Y: jax.Array) -> jax.Array:
    """Per-sub-model reconstruction of its missing rows in its own space:
    M_i* = Y* W_iᵀ (paper §3.3.2 step 2 — the robustness claim).

    Args:
        stacked: the sub-model stack with presence mask.
        Y: the merged consensus table ``(V, d)``.

    Returns:
        Completed models ``(n, V, d)``: present rows pass through
        untouched, missing rows are reconstructed from the consensus.
    """
    Ws = alir_transforms(stacked, Y)

    def back(M_i, m_i, W):
        rec = Y @ W.T
        return jnp.where(m_i[:, None], M_i, rec)
    return jax.vmap(back)(stacked.models, stacked.mask, Ws)


# ---------------------------------------------------------------------------
# The unified Merger API: one config, one result type, one protocol.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MergeConfig:
    """Every merge dial in one frozen config (the merge counterpart of
    the engine dataclasses in :mod:`repro.core.engine`).

    Solver knobs (ALiR mergers): ``init`` / ``max_iters`` / ``tol`` /
    ``seed`` / ``warm_start``; ``out_dim`` is only consumed by the
    ``"pca"`` merger (ALiR aligns in the sub-model dimension).

    Arrival-policy knobs (any merger used incrementally): ``quorum`` is
    the minimum number of arrived sub-models a :meth:`Merger.final`
    requires; ``deadline`` (seconds on the merger's clock, from
    construction) closes the arrival window — late arrivals are recorded,
    not folded.

    Scale knobs: ``fan_in`` is the reduction-tree arity
    (:mod:`repro.core.merge_tree`); ``shard`` is the Gram-accumulation
    block count (:func:`sharded_gram`) — both static dials that define
    the canonical bits, not runtime hints.
    """

    out_dim: int | None = None
    init: str = "pca"
    max_iters: int = 10
    tol: float = 1e-4
    seed: int = 0
    warm_start: bool = True
    quorum: int | None = None
    deadline: float | None = None
    fan_in: int = 2
    shard: int = 1

    def validated(self) -> "MergeConfig":
        """Raise on out-of-range dials; returns self for chaining."""
        if self.quorum is not None and self.quorum < 1:
            raise ValueError(f"quorum must be >= 1, got {self.quorum}")
        if self.deadline is not None and self.deadline < 0:
            raise ValueError(f"deadline must be >= 0, got {self.deadline}")
        if self.fan_in < 2:
            raise ValueError(f"fan_in must be >= 2, got {self.fan_in}")
        if self.shard < 1:
            raise ValueError(f"shard must be >= 1, got {self.shard}")
        return self

    def prng_key(self) -> jax.Array:
        """The config's base PRNG key (mergers fold in per-node data)."""
        return jax.random.PRNGKey(self.seed)


@dataclass(frozen=True)
class MergeResult:
    """One merge outcome: the consensus over the folded sub-models.

    ``worker_ids`` is the canonical (ascending) order of the merged
    workers — also the sub-model axis order of ``mask``/``transforms``
    and of the published artifact. ``transforms`` (ALiR mergers) are the
    per-worker alignment maps ``W_i``: a row absent from sub-model *i*
    is reconstructed as ``Y[w] @ W_i.T``.
    """

    worker_ids: tuple[int, ...]
    emb: jax.Array                       # (V, d) consensus; invalid rows zeroed
    valid: jax.Array                     # (V,) union presence over merged models
    disps: jax.Array | None = None       # ALiR per-iteration displacement trace
    mask: jax.Array | None = None        # (n, V) per-worker presence
    transforms: jax.Array | None = None  # (n, d, d) worker → consensus maps

    @property
    def Y(self) -> jax.Array:
        """Alias for ``emb`` (the pre-registry ``FoldResult`` name)."""
        return self.emb


#: Backward-compatible alias — incremental folds used to return a
#: dedicated ``FoldResult``; every merger now returns :class:`MergeResult`.
FoldResult = MergeResult


class Merger:
    """The unified merge protocol: batch and incremental use are two
    methods on the same object.

    * :meth:`merge` — one-shot batch merge of a :class:`StackedModels`.
    * :meth:`add` / :meth:`fold` / :meth:`final` — incremental: register
      sub-models **as workers finish** (any order), re-fold a servable
      consensus per arrival, finish with the canonical cold solve.

    The base class owns every arrival-policy mechanism shared by all
    mergers — canonical (ascending worker-id) ordering, duplicate/shape
    rejection, the ``deadline`` arrival window (late arrivals land in
    :attr:`late_workers`, not in the consensus) and the ``quorum`` check
    on :meth:`final` — so quorum/deadline semantics are identical
    whether the consensus is a flat ALiR solve or a reduction tree.

    Subclasses implement :meth:`merge`; incremental folding defaults to
    re-merging everything arrived (subclasses override for warm starts
    or tree reuse).
    """

    name: str = "base"

    def __init__(self, config: MergeConfig | None = None, *, clock=None):
        self.config = (config or MergeConfig()).validated()
        # injectable clock so deadline behaviour is deterministic in
        # tests (default: monotonic seconds since construction)
        import time as _time
        self._clock = clock if clock is not None else _time.monotonic
        self._t0 = self._clock()
        self.late_workers: list[int] = []
        self._models: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    # -- arrival bookkeeping (shared) --------------------------------------
    @property
    def quorum(self) -> int | None:
        return self.config.quorum

    @property
    def deadline(self) -> float | None:
        return self.config.deadline

    @property
    def worker_ids(self) -> tuple[int, ...]:
        """Arrived workers in canonical (ascending) order."""
        return tuple(sorted(self._models))

    @property
    def n_folded(self) -> int:
        """Number of sub-models that have arrived so far."""
        return len(self._models)

    @property
    def quorum_met(self) -> bool:
        """Whether enough sub-models have arrived for a :meth:`final`
        merge (always ``True`` without a quorum)."""
        return self.config.quorum is None or self.n_folded >= self.config.quorum

    @property
    def deadline_passed(self) -> bool:
        """Whether the arrival window has closed (``False`` without a
        deadline)."""
        return (self.config.deadline is not None
                and self._clock() - self._t0 > self.config.deadline)

    def stacked(self) -> StackedModels:
        """The arrived sub-models restacked in canonical worker order."""
        if not self._models:
            raise ValueError("no sub-models have arrived yet")
        ids = self.worker_ids
        return stack_models([np.asarray(self._models[i][0]) for i in ids],
                            [np.asarray(self._models[i][1]) for i in ids])

    def add(self, worker_id: int, model, mask, *,
            fold: bool = True) -> MergeResult | None:
        """Register a finished worker's sub-model (and, by default,
        immediately re-fold the consensus).

        Args:
            worker_id: the worker's global id — duplicate arrivals are
                rejected (a retried worker must be idempotent upstream).
            model: ``(V, d)`` table over the union vocabulary.
            mask: ``(V,)`` bool presence for this sub-model.
            fold: re-fold now and return the :class:`MergeResult`;
                ``fold=False`` just registers (batch several arrivals
                into one fold with a later :meth:`fold` call).

        Returns ``None`` without folding when the merger's ``deadline``
        has passed — the straggler is recorded in :attr:`late_workers`
        and the consensus stays a function of the on-time subset.
        """
        if self.deadline_passed:
            self.late_workers.append(int(worker_id))
            return None
        if worker_id in self._models:
            raise ValueError(f"worker {worker_id} already folded in")
        model = np.asarray(model)
        mask = np.asarray(mask).astype(bool)
        if model.ndim != 2 or mask.shape != (model.shape[0],):
            raise ValueError(
                f"expected model (V, d) and mask (V,); got {model.shape} "
                f"and {mask.shape}")
        if self._models:
            V, d = next(iter(self._models.values()))[0].shape
            if model.shape != (V, d):
                raise ValueError(
                    f"sub-model shape {model.shape} != established {(V, d)}")
        self._models[int(worker_id)] = (model, mask)
        self._on_arrival(int(worker_id))
        return self.fold() if fold else None

    def _on_arrival(self, worker_id: int) -> None:
        """Subclass hook after a sub-model registers (tree mergers
        persist the leaf / eagerly solve completed subtrees here)."""

    # -- the merge protocol ------------------------------------------------
    def merge(self, stacked: StackedModels, *,
              worker_ids: tuple[int, ...] | None = None) -> MergeResult:
        """One-shot batch merge of a stack (stateless with respect to
        arrivals; ``worker_ids`` labels the stack's model axis)."""
        raise NotImplementedError

    def fold(self, warm: bool | None = None) -> MergeResult:
        """Re-merge everything that has arrived. ``warm`` is consumed by
        mergers with warm-startable state (:class:`AlirMerger`);
        ``fold(warm=False)`` after all arrivals reproduces the batch
        :meth:`merge` bit-for-bit."""
        del warm
        return self.merge(self.stacked(), worker_ids=self.worker_ids)

    def final(self, *, require_quorum: bool = True) -> MergeResult:
        """The merge-from-whatever-finished endpoint: the canonical cold
        fold over every sub-model that arrived (on time) — bit-identical
        to the batch :meth:`merge` over that subset's stack, in
        canonical worker order, regardless of arrival order.

        Raises ``RuntimeError`` when a ``quorum`` is configured and
        unmet (pass ``require_quorum=False`` to fold a below-quorum
        subset anyway, e.g. for a best-effort table while paging the
        operator)."""
        if require_quorum and not self.quorum_met:
            raise RuntimeError(
                f"quorum not met: {self.n_folded} sub-model(s) arrived, "
                f"quorum is {self.config.quorum}")
        return self.fold(warm=False)

    def describe(self) -> str:
        return f"{self.name}({self.config})"


def _result_ids(stacked: StackedModels,
                worker_ids: tuple[int, ...] | None) -> tuple[int, ...]:
    if worker_ids is None:
        return tuple(range(stacked.n))
    ids = tuple(int(w) for w in worker_ids)
    if len(ids) != stacked.n:
        raise ValueError(f"{len(ids)} worker ids for {stacked.n} sub-models")
    return ids


class AlirMerger(Merger):
    """The paper's merger, batch + incremental, behind the protocol.

    Invariants (all property-tested):

    * Sub-models are restacked in **canonical worker-id order** before
      every fold, so the *final* fold (all arrived, ``warm=False``) is
      bit-identical to :meth:`merge` on the batch-stacked models
      regardless of arrival order.
    * Intermediate folds warm-start from the previous consensus
      (``warm_start=True``, the default): the early-convergence freeze
      in :func:`_alir_loop` makes a re-fold that barely moves cost 1–2
      SVD rounds instead of ``max_iters``. The documented tolerance of
      a warm-started full fold vs the batch merge: ALiR's consensus is
      only defined up to a global orthogonal map (rotate ``Y``, absorb
      it into every ``W_i``), and the warm path inherits its gauge from
      the arrival history — so warm results match the batch merge up to
      Procrustes alignment (small residual), not element-wise. Call
      ``fold(warm=False)`` for the canonical, gauge-fixed cold solve.
    * ``valid`` only covers words present in some *arrived* sub-model:
      an early fold is a complete, servable table for its coverage, and
      coverage grows monotonically with arrivals.

    **Merge-from-whatever-finished** (elastic training): the base
    class's ``quorum``/``deadline`` dials apply unchanged — a quorum
    merge over the survivors is bit-identical to the batch merge over
    that subset's stack, and the presence masks already say which words
    the missing workers would have covered; serving falls back to
    :func:`reconstruct_missing` / OOV exactly as for any absent row.
    """

    name = "alir"

    def __init__(self, config: MergeConfig | None = None, *,
                 key: jax.Array | None = None, clock=None):
        super().__init__(config, clock=clock)
        self._key_override = key
        self._Y: jax.Array | None = None

    # legacy attribute surface (pre-registry IncrementalAlirMerger)
    @property
    def init(self) -> str:
        return self.config.init

    @property
    def max_iters(self) -> int:
        return self.config.max_iters

    @property
    def tol(self) -> float:
        return self.config.tol

    @property
    def warm_start(self) -> bool:
        return self.config.warm_start

    @property
    def key(self) -> jax.Array:
        """Base PRNG key for the cold-solve init."""
        return (self._key_override if self._key_override is not None
                else self.config.prng_key())

    def merge(self, stacked: StackedModels, *,
              worker_ids: tuple[int, ...] | None = None,
              Y0: jax.Array | None = None) -> MergeResult:
        cfg = self.config
        Y, valid, disps = _alir_solve(
            stacked, out_dim=cfg.out_dim, init=cfg.init,
            max_iters=cfg.max_iters, tol=cfg.tol, key=self.key, Y0=Y0,
            shard=cfg.shard)
        Ws = alir_transforms(stacked, Y, shard=cfg.shard)
        return MergeResult(worker_ids=_result_ids(stacked, worker_ids),
                           emb=Y, valid=valid, disps=disps,
                           mask=stacked.mask, transforms=Ws)

    def fold(self, warm: bool | None = None) -> MergeResult:
        """Re-solve ALiR over everything that has arrived. ``warm``
        overrides the config's ``warm_start`` for this fold."""
        warm = self.config.warm_start if warm is None else warm
        Y0 = self._Y if (warm and self._Y is not None) else None
        res = self.merge(self.stacked(), worker_ids=self.worker_ids, Y0=Y0)
        self._Y = res.emb
        return res


class _FunctionMerger(Merger):
    """Adapter for the stateless merges (average/concat/pca): batch and
    incremental are the same computation over the arrived stack."""

    _fn: Callable[..., tuple[jax.Array, jax.Array]]

    def merge(self, stacked: StackedModels, *,
              worker_ids: tuple[int, ...] | None = None) -> MergeResult:
        emb, valid = self._apply(stacked)
        return MergeResult(worker_ids=_result_ids(stacked, worker_ids),
                           emb=emb, valid=valid, mask=stacked.mask)

    def _apply(self, stacked: StackedModels):
        raise NotImplementedError


class AverageMerger(_FunctionMerger):
    """Presence-weighted element-wise mean over union rows — the
    paper's counter-example (sub-models live in incompatible gauges, so
    averaging cancels signal). Kept for tests/benchmarks."""

    name = "average"

    def _apply(self, stacked: StackedModels):
        return _merge_average(stacked)


class ConcatMerger(_FunctionMerger):
    """(V, n*d) concatenation over intersection rows; rows outside the
    intersection are zero (OOV for this merge)."""

    name = "concat"

    def _apply(self, stacked: StackedModels):
        return _merge_concat(stacked)


class PcaMerger(_FunctionMerger):
    """PCA of the concatenated matrix down to ``config.out_dim``
    (default: the sub-model dimension d) — the paper's Pca baseline."""

    name = "pca"

    def _apply(self, stacked: StackedModels):
        out_dim = self.config.out_dim or int(stacked.models.shape[2])
        return _merge_pca(stacked, out_dim)


class IncrementalAlirMerger(AlirMerger):
    """Backward-compatible keyword-dial spelling of :class:`AlirMerger`
    (the pre-registry name). New code: ``get_merger("alir", ...)``."""

    def __init__(self, *, init: str = "pca", max_iters: int = 10,
                 tol: float = 1e-4, key: jax.Array | None = None,
                 warm_start: bool = True, quorum: int | None = None,
                 deadline: float | None = None, clock=None):
        cfg = MergeConfig(init=init, max_iters=max_iters, tol=tol,
                          warm_start=warm_start, quorum=quorum,
                          deadline=deadline)
        super().__init__(cfg, key=key, clock=clock)


# ---------------------------------------------------------------------------
# The registry (mirrors core.engine's ENGINES / get_engine).
# ---------------------------------------------------------------------------
MERGERS: dict[str, type[Merger]] = {
    "alir": AlirMerger,
    "average": AverageMerger,
    "concat": ConcatMerger,
    "pca": PcaMerger,
}

MERGER_NAMES: tuple[str, ...] = ("alir", "alir_tree", "average", "concat", "pca")


def _tree_merger_cls() -> type[Merger]:
    # Imported lazily: merge_tree builds on this module.
    from repro.core.merge_tree import TreeAlirMerger
    return TreeAlirMerger


def get_merger(spec: str | Merger = "alir",
               config: MergeConfig | None = None, *,
               clock=None, **overrides) -> Merger:
    """Resolve a merger: pass an instance through, or build one from a
    registry name + config (``overrides`` are :class:`MergeConfig`
    fields applied via ``dataclasses.replace``)::

        get_merger("alir_tree", fan_in=4, quorum=3)
        get_merger("alir", MergeConfig(max_iters=20), deadline=60.0)
    """
    if isinstance(spec, Merger):
        if config is not None or overrides:
            raise ValueError(
                "pass either a Merger instance or a name+config, not both")
        return spec
    name = str(spec)
    cfg = config or MergeConfig()
    if overrides:
        cfg = replace(cfg, **overrides)
    if name == "alir_tree":
        cls = _tree_merger_cls()
    elif name in MERGERS:
        cls = MERGERS[name]
    else:
        raise ValueError(
            f"unknown merger {name!r}; expected one of {sorted(MERGER_NAMES)}")
    return cls(cfg, clock=clock)


# ---------------------------------------------------------------------------
# Deprecated free-function shims (the pre-registry surface).
# ---------------------------------------------------------------------------
def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (the Merger registry: "
        "repro.core.merge.get_merger)", DeprecationWarning, stacklevel=3)


def merge_alir(stacked: StackedModels, out_dim: int | None = None,
               init: str = "pca", max_iters: int = 10, tol: float = 1e-4,
               key: jax.Array | None = None, Y0: jax.Array | None = None,
               shard: int = 1) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Deprecated shim — use ``get_merger("alir").merge(stacked)``.
    Returns the legacy ``(Y, valid, disps)`` triple."""
    _deprecated("merge_alir", 'get_merger("alir").merge(...)')
    return _alir_solve(stacked, out_dim=out_dim, init=init,
                       max_iters=max_iters, tol=tol, key=key, Y0=Y0,
                       shard=shard)


def merge_concat(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    """Deprecated shim — use ``get_merger("concat").merge(stacked)``."""
    _deprecated("merge_concat", 'get_merger("concat").merge(...)')
    return _merge_concat(stacked)


def merge_pca(stacked: StackedModels, out_dim: int) -> tuple[jax.Array, jax.Array]:
    """Deprecated shim — use ``get_merger("pca", out_dim=...).merge(stacked)``."""
    _deprecated("merge_pca", 'get_merger("pca", out_dim=...).merge(...)')
    return _merge_pca(stacked, out_dim)


def merge_average(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    """Deprecated shim — use ``get_merger("average").merge(stacked)``."""
    _deprecated("merge_average", 'get_merger("average").merge(...)')
    return _merge_average(stacked)


# ---------------------------------------------------------------------------
# Name-dispatched merge for the pipeline driver / CLI.
# ---------------------------------------------------------------------------
MERGE_METHODS = ("concat", "pca", "alir_rand", "alir_pca", "alir_tree",
                 "average", "single")


def merge(stacked: StackedModels, method: str, out_dim: int,
          key: jax.Array | None = None, *, fan_in: int = 2,
          shard: int = 1, **kw):
    """Dispatch a merge by name (one of :data:`MERGE_METHODS`). Returns
    ``(emb, valid)``; ``key`` seeds the alir_* inits, ``fan_in`` sizes
    the ``alir_tree`` reduction tree, ``shard`` the Gram accumulation;
    extra kwargs are forwarded to the ALiR solver."""
    if method == "concat":
        return _merge_concat(stacked)
    if method == "pca":
        return _merge_pca(stacked, out_dim)
    if method == "alir_rand":
        Y, v, _ = _alir_solve(stacked, out_dim, init="random", key=key,
                              shard=shard, **kw)
        return Y, v
    if method == "alir_pca":
        Y, v, _ = _alir_solve(stacked, out_dim, init="pca", key=key,
                              shard=shard, **kw)
        return Y, v
    if method == "alir_tree":
        cfg = MergeConfig(out_dim=None, fan_in=fan_in, shard=shard, **kw)
        res = get_merger("alir_tree", cfg).merge(stacked)
        return res.emb, res.valid
    if method == "average":
        return _merge_average(stacked)
    if method == "single":
        return stacked.models[0], stacked.mask[0]
    raise ValueError(f"unknown merge method {method!r}")
