"""The Merge phase — Concat, PCA, and ALiR (the paper's contribution).

All merges operate on *stacked* sub-models: ``models (n, V, d)`` over the
**union** vocabulary, plus a presence ``mask (n, V)`` marking which words
each sub-model actually trained. Concat/PCA use the intersection rows
(as in the paper); ALiR uses the union and reconstructs missing rows.

ALiR (Alternating Linear Regression), a Generalized Procrustes Analysis
variant (paper §3.3.2), per iteration:

1. *Estimate translation* — for each sub-model i, solve Orthogonal
   Procrustes on its **present** rows:  W_i = argmin ‖M_i' W − Y'‖_F
   over orthogonal W  (closed form: UVᵀ from SVD of M_i'ᵀ Y').
2. *Estimate missing values* — reconstruct M_i* from Y* via
   Y* = M_i* W_i  ⇒  M_i* = Y* W_iᵀ (W_i orthogonal).
3. *Update joint embedding* — Y ← mean over i of (M_i W_i), using the
   reconstructed rows for the missing parts.

Stops when the change in the average normalized Frobenius displacement
``(1/n) Σ_i ‖Y − M_i W_i‖_F / sqrt(|V|·d)`` drops below ``tol``.

Everything is vmapped over the model axis and jittable (SVDs are d×d —
tiny next to training).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Building the stacked representation
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StackedModels:
    models: jax.Array   # (n, V, d) union-vocab rows; garbage where absent
    mask: jax.Array     # (n, V) bool presence

    @property
    def n(self) -> int:
        return self.models.shape[0]

    def intersection(self) -> jax.Array:
        return jnp.all(self.mask, axis=0)

    def union_present(self) -> jax.Array:
        return jnp.any(self.mask, axis=0)


def stack_models(models: list[np.ndarray], masks: list[np.ndarray]) -> StackedModels:
    m = jnp.asarray(np.stack(models))
    k = jnp.asarray(np.stack(masks)).astype(bool)
    return StackedModels(models=m, mask=k)


# ---------------------------------------------------------------------------
# Concat / PCA (baselines from the paper)
# ---------------------------------------------------------------------------
def merge_concat(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    """(V, n*d) concatenation over intersection rows; rows outside the
    intersection are zero (OOV for this merge). Returns (emb, valid)."""
    n, V, d = stacked.models.shape
    emb = jnp.transpose(stacked.models, (1, 0, 2)).reshape(V, n * d)
    valid = stacked.intersection()
    return emb * valid[:, None], valid


def merge_pca(stacked: StackedModels, out_dim: int) -> tuple[jax.Array, jax.Array]:
    """PCA of the concatenated matrix down to ``out_dim`` (paper's Pca).

    Economy form: eigendecomposition of the (nd × nd) covariance over
    intersection rows — never materializes a V×V anything.
    """
    emb, valid = merge_concat(stacked)
    cnt = jnp.maximum(valid.sum(), 1)
    mean = jnp.sum(emb * valid[:, None], axis=0) / cnt
    X = (emb - mean) * valid[:, None]
    cov = X.T @ X / cnt
    eigval, eigvec = jnp.linalg.eigh(cov)          # ascending
    comps = eigvec[:, -out_dim:][:, ::-1]          # (nd, out_dim)
    return (X @ comps) * valid[:, None], valid


# ---------------------------------------------------------------------------
# Orthogonal Procrustes
# ---------------------------------------------------------------------------
def orthogonal_procrustes(A: jax.Array, B: jax.Array,
                          weights: jax.Array | None = None) -> jax.Array:
    """W minimizing ‖A W − B‖_F (rows optionally weighted), W orthogonal."""
    if weights is not None:
        A = A * weights[:, None]
        # weight appears once: Aᵀ diag(w) B — weight either side, not both
        M = A.T @ B
    else:
        M = A.T @ B
    U, _, Vt = jnp.linalg.svd(M, full_matrices=False)
    return U @ Vt


# ---------------------------------------------------------------------------
# ALiR
# ---------------------------------------------------------------------------
def _alir_iteration(Y: jax.Array, models: jax.Array, mask: jax.Array):
    """One ALiR round. Returns (Y_new, displacement, W (n,d,d))."""
    maskf = mask.astype(Y.dtype)                       # (n, V)

    def per_model(M_i, m_i):
        # Step 1: Procrustes on present rows.
        A = M_i * m_i[:, None]
        Byy = Y * m_i[:, None]
        U, _, Vt = jnp.linalg.svd(A.T @ Byy, full_matrices=False)
        W = U @ Vt                                     # (d, d)
        aligned_present = M_i @ W                      # valid on present rows
        # Step 2: reconstruct missing rows: M_i* = Y* W_iᵀ ⇒ aligned = Y*.
        aligned_full = jnp.where(m_i[:, None] > 0, aligned_present, Y)
        # Displacement on present rows (normalized Frobenius).
        num_rows = jnp.maximum(m_i.sum(), 1.0)
        disp = jnp.linalg.norm((Y - aligned_present) * m_i[:, None]) / jnp.sqrt(
            num_rows * Y.shape[1])
        return aligned_full, disp, W

    aligned, disps, Ws = jax.vmap(per_model)(models, maskf)
    # Step 3: mean of translations of all n models (reconstructed rows
    # contribute the current Y, exactly as in the paper's formulation).
    Y_new = jnp.mean(aligned, axis=0)
    return Y_new, jnp.mean(disps), Ws


@partial(jax.jit, static_argnames=("max_iters",))
def _alir_loop(Y0, models, mask, max_iters: int, tol: float):
    """Fixed-length scan with an early-converged fast path: once the
    displacement change drops below ``tol``, Y *and* the reported
    displacement freeze (the remaining iterations skip the per-model
    SVDs entirely via ``cond``). The per-iteration trace therefore ends
    in a constant run of the converged error — previously the carried
    displacement kept mutating after ``done``, so the trace misreported
    the converged error and every residual iteration paid full SVDs."""
    def body(carry, _):
        Y, prev_disp, done = carry

        def converged(_):
            return Y, prev_disp

        def iterate(_):
            Y_new, disp, _ = _alir_iteration(Y, models, mask)
            return Y_new, disp

        Y_out, disp = jax.lax.cond(done, converged, iterate, None)
        new_done = done | (jnp.abs(prev_disp - disp) < tol)
        return (Y_out, disp, new_done), disp

    (Y, _, _), disps = jax.lax.scan(
        body, (Y0, jnp.inf, jnp.array(False)), None, length=max_iters)
    return Y, disps


def alir_init(stacked: StackedModels, out_dim: int, init: str, key: jax.Array):
    n, V, d = stacked.models.shape
    if init == "random":
        return 0.1 * jax.random.normal(key, (V, out_dim), dtype=jnp.float32)
    if init == "pca":
        pca_emb, valid = merge_pca(stacked, out_dim)
        rnd = 0.1 * jax.random.normal(key, (V, out_dim), dtype=jnp.float32)
        # intersection rows from PCA; other union rows random (paper init ii)
        return jnp.where(valid[:, None], pca_emb, rnd)
    raise ValueError(f"unknown init {init!r}")


def merge_alir(
    stacked: StackedModels,
    out_dim: int | None = None,
    init: str = "pca",
    max_iters: int = 10,
    tol: float = 1e-4,
    key: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (Y (V, d), valid (V,), per-iteration displacements).

    ``valid`` marks union-vocabulary rows (present in ≥1 sub-model);
    every valid row has a representation — that is ALiR's point.
    """
    n, V, d = stacked.models.shape
    out_dim = out_dim or d
    if out_dim != d:
        raise ValueError("ALiR aligns in the sub-model dimension; out_dim must equal d")
    key = key if key is not None else jax.random.PRNGKey(0)
    Y0 = alir_init(stacked, out_dim, init, key)
    models = stacked.models * stacked.mask[..., None]
    Y, disps = _alir_loop(Y0, models, stacked.mask, max_iters, tol)
    valid = stacked.union_present()
    return Y * valid[:, None], valid, disps


def reconstruct_missing(stacked: StackedModels, Y: jax.Array) -> jax.Array:
    """Per-sub-model reconstruction of its missing rows in its own space:
    M_i* = Y* W_iᵀ. Returns completed models (n, V, d)."""
    _, _, Ws = _alir_iteration(Y, stacked.models * stacked.mask[..., None],
                               stacked.mask)
    def back(M_i, m_i, W):
        rec = Y @ W.T
        return jnp.where(m_i[:, None], M_i, rec)
    return jax.vmap(back)(stacked.models, stacked.mask, Ws)


# ---------------------------------------------------------------------------
# Naive averaging (the paper's counter-example) — for tests/benchmarks.
# ---------------------------------------------------------------------------
def merge_average(stacked: StackedModels) -> tuple[jax.Array, jax.Array]:
    maskf = stacked.mask.astype(stacked.models.dtype)
    num = jnp.sum(stacked.models * maskf[..., None], axis=0)
    den = jnp.maximum(jnp.sum(maskf, axis=0), 1.0)
    return num / den[:, None], stacked.union_present()


MERGE_METHODS = ("concat", "pca", "alir_rand", "alir_pca", "average", "single")


def merge(stacked: StackedModels, method: str, out_dim: int,
          key: jax.Array | None = None, **kw):
    if method == "concat":
        return merge_concat(stacked)
    if method == "pca":
        return merge_pca(stacked, out_dim)
    if method == "alir_rand":
        Y, v, _ = merge_alir(stacked, out_dim, init="random", key=key, **kw)
        return Y, v
    if method == "alir_pca":
        Y, v, _ = merge_alir(stacked, out_dim, init="pca", key=key, **kw)
        return Y, v
    if method == "average":
        return merge_average(stacked)
    if method == "single":
        return stacked.models[0], stacked.mask[0]
    raise ValueError(f"unknown merge method {method!r}")
