"""Log-depth reduction-tree ALiR merge: O(log W) merge wallclock.

The flat batch solve is O(W) in sub-model count — every worker's table
sits in one stack and every iteration pays W Procrustes solves over the
full ``(V, d)`` tables. :class:`TreeAlirMerger` replaces it with a
**pairwise reduction tree** (``fan_in`` ≥ 2): leaves are the worker
sub-models, each interior node ALiR-merges its children's consensus
tables as pseudo-sub-models (child ``valid`` = the pseudo-model's
presence mask) and passes one ``(V, d)`` consensus upward. Nodes at the
same level are independent — on a cluster they run concurrently, so the
critical path is ``depth = ceil(log_fan_in W)`` node solves, and each
node solve touches at most ``fan_in`` tables instead of W.

Determinism and permutation invariance, by construction:

* **Topology** is a pure function of the *canonical* (ascending, sorted)
  worker ids and ``fan_in`` (:func:`build_tree`): leaves in id order,
  consecutive ``fan_in``-groups per level. Arrival order never enters.
* **Node solves are always cold**, keyed by ``fold_in(base_key, level,
  index)`` — a node solved eagerly the moment its children completed is
  bit-identical to the same node solved at :meth:`~TreeAlirMerger.final`
  time. (Warm starts would thread arrival history into the bits.)
* Nodes are solved individually, never vmapped across a level — the repo
  documents that vmapped and unvmapped solves differ bit-wise.

What flows upward, so the serving tier works from **any** level:

* ``Y`` — the node's consensus ``(V, d)``;
* ``valid`` — union presence over the node's arrived workers;
* ``mask`` — per-worker presence rows, concatenated in canonical order;
* ``transforms`` — **composed** worker→node maps: if worker *w* aligns
  into child *c* by ``W_w`` and child *c* into this node by ``W_c``,
  then ``W_w^node = W_w · W_c`` — so ``Y_node @ (W_w^node)ᵀ``
  reconstructs *w*'s missing rows exactly as
  :func:`repro.core.merge.reconstruct_missing` does from the flat solve.

Elastic semantics are **tree-node policies**: the arrival ``deadline``
closes the whole tree's window (late workers recorded, their leaves
never join); an interior node whose children are partially arrived
solves over the present children only (a single-present-child node
passes its child through untouched — no pointless self-alignment); the
``quorum`` check applies at the root over total arrived workers.

Restartable merges: give the merger a ``state_dir`` and every arrived
leaf + solved interior node is persisted through the atomic versioned
artifact layer (:func:`repro.checkpoint.io.publish_tree_node`); a new
merger pointed at the same directory reloads them and only re-solves
nodes whose arrived-worker set has since changed.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import (
    list_tree_nodes,
    load_tree_node,
    publish_tree_node,
)
from repro.core.merge import (
    MergeConfig,
    MergeResult,
    Merger,
    StackedModels,
    _alir_solve,
    alir_transforms,
)


# ---------------------------------------------------------------------------
# Topology — a pure function of (sorted worker ids, fan_in).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TreeNode:
    """One reduction-tree node: ``level`` 0 = leaves, the root is the
    single node of the top level. ``worker_ids`` is the (ascending)
    span of workers the subtree covers."""

    level: int
    index: int
    worker_ids: tuple[int, ...]
    children: tuple["TreeNode", ...] = ()

    @property
    def is_leaf(self) -> bool:
        return not self.children


def build_tree(worker_ids, fan_in: int = 2) -> TreeNode:
    """The deterministic reduction tree over ``worker_ids``: leaves in
    canonical (ascending) id order, consecutive ``fan_in``-groups per
    level, repeated to a single root. Same ids + same fan_in ⇒ same
    topology, independent of arrival order."""
    ids = sorted({int(w) for w in worker_ids})
    if not ids:
        raise ValueError("cannot build a reduction tree over zero workers")
    if fan_in < 2:
        raise ValueError(f"fan_in must be >= 2, got {fan_in}")
    level = [TreeNode(level=0, index=i, worker_ids=(w,))
             for i, w in enumerate(ids)]
    depth = 0
    while len(level) > 1:
        depth += 1
        nxt = []
        for i in range(0, len(level), fan_in):
            group = tuple(level[i:i + fan_in])
            covered = tuple(w for g in group for w in g.worker_ids)
            nxt.append(TreeNode(level=depth, index=len(nxt),
                                worker_ids=covered, children=group))
        level = nxt
    return level[0]


def tree_levels(root: TreeNode) -> list[list[TreeNode]]:
    """All nodes grouped by level, ``[leaves, ..., [root]]``."""
    by_level: dict[int, list[TreeNode]] = {}

    def walk(node: TreeNode) -> None:
        by_level.setdefault(node.level, []).append(node)
        for c in node.children:
            walk(c)

    walk(root)
    return [sorted(by_level[lvl], key=lambda n: n.index)
            for lvl in sorted(by_level)]


def tree_depth(root: TreeNode) -> int:
    """Number of solve levels above the leaves (= the critical path in
    node solves when a level runs concurrently)."""
    return root.level


# ---------------------------------------------------------------------------
# Node results — what flows upward.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class NodeResult:
    """One solved tree node: consensus + everything needed to serve or
    keep reducing from this level. ``worker_ids`` are the **arrived**
    workers the node actually covers (ascending); ``mask`` and
    ``transforms`` rows follow that order."""

    level: int
    index: int
    worker_ids: tuple[int, ...]
    Y: jax.Array              # (V, d) node consensus; invalid rows zeroed
    valid: jax.Array          # (V,) union presence over covered workers
    mask: jax.Array           # (k, V) per-worker presence
    transforms: jax.Array     # (k, d, d) composed worker → node maps
    disps: jax.Array | None   # ALiR trace of this node's solve (leaves: None)


def reconstruct_worker(result, worker_id: int) -> jax.Array:
    """Worker ``worker_id``'s full table in its **own** space from any
    node's consensus: ``Y @ W_wᵀ`` with the composed transform — the
    tree generalization of :func:`repro.core.merge.reconstruct_missing`.
    Accepts a :class:`NodeResult` or a root :class:`MergeResult`."""
    ids = tuple(result.worker_ids)
    if worker_id not in ids:
        raise KeyError(f"worker {worker_id} not covered by this node "
                       f"(has {ids})")
    W = result.transforms[ids.index(worker_id)]
    return result.Y @ W.T


class TreeAlirMerger(Merger):
    """ALiR through the pairwise reduction tree, behind the unified
    :class:`~repro.core.merge.Merger` protocol.

    Batch use (``merge``) builds the tree over the stack's workers and
    solves bottom-up. Incremental use (``add``/``fold``/``final``)
    reuses solved nodes across folds: a node is re-solved only when the
    set of arrived workers under it changed, so each new arrival costs
    one root-path of node solves — O(log W) — instead of a full re-fold.

    Args:
        config: the shared :class:`MergeConfig` (``fan_in`` and
            ``shard`` are the tree dials).
        workers: the **expected** worker ids. When given, the topology
            is fixed up front: intermediate folds place arrivals at
            their final leaf positions, missing children degrade
            gracefully, and persisted nodes stay valid across restarts.
            When ``None``, each fold derives the topology from the
            workers arrived so far (and :meth:`merge` from the stack).
        key: explicit base PRNG key (default: ``config.prng_key()``);
            per-node keys fold in ``(level, index)``.
        state_dir: persist leaves + solved interior nodes here (atomic
            versioned artifacts) for restartable merges.
        resume: reload persisted state from ``state_dir`` on
            construction.
    """

    name = "alir_tree"

    def __init__(self, config: MergeConfig | None = None, *,
                 workers=None, key: jax.Array | None = None,
                 clock=None, state_dir: str | None = None,
                 resume: bool = True):
        super().__init__(config, clock=clock)
        self._key_override = key
        self._workers = (tuple(sorted({int(w) for w in workers}))
                         if workers is not None else None)
        # node cache: (level, index) -> (arrived-signature, NodeResult)
        self._cache: dict[tuple[int, int], tuple[tuple[int, ...], NodeResult]] = {}
        self.state_dir = state_dir
        self.stats = {"solved": 0, "passthrough": 0, "loaded": 0,
                      "node_s": {}}
        if state_dir and resume:
            self._load_state()

    @property
    def key(self) -> jax.Array:
        return (self._key_override if self._key_override is not None
                else self.config.prng_key())

    def _node_key(self, node: TreeNode) -> jax.Array:
        """Deterministic per-node PRNG key — a pure function of the
        node's position, never of arrival history."""
        return jax.random.fold_in(
            jax.random.fold_in(self.key, node.level), node.index)

    # -- the Merger protocol ----------------------------------------------
    def merge(self, stacked: StackedModels, *,
              worker_ids: tuple[int, ...] | None = None) -> MergeResult:
        """One-shot batch tree merge of a stack (tree over its workers,
        solved bottom-up; no state shared with incremental folds)."""
        ids = (tuple(int(w) for w in worker_ids)
               if worker_ids is not None else tuple(range(stacked.n)))
        if len(ids) != stacked.n:
            raise ValueError(f"{len(ids)} worker ids for {stacked.n} sub-models")
        scratch = TreeAlirMerger(self.config, workers=ids,
                                 key=self._key_override)
        models = np.asarray(stacked.models)
        masks = np.asarray(stacked.mask)
        order = np.argsort(ids)
        for i in order:
            scratch.add(ids[int(i)], models[int(i)], masks[int(i)], fold=False)
        res = scratch.fold()
        # surface the scratch solve costs (bench reads critical path)
        self.stats["solved"] += scratch.stats["solved"]
        self.stats["passthrough"] += scratch.stats["passthrough"]
        self.stats["node_s"].update(scratch.stats["node_s"])
        return res

    def fold(self, warm: bool | None = None) -> MergeResult:
        """Solve (or reuse) the tree over everything arrived. ``warm``
        is ignored — tree nodes always solve cold (see module doc)."""
        del warm
        if not self._models:
            raise ValueError("no sub-models have arrived yet")
        res = self._node_result(self._topology())
        assert res is not None
        return MergeResult(worker_ids=res.worker_ids, emb=res.Y,
                           valid=res.valid, disps=res.disps,
                           mask=res.mask, transforms=res.transforms)

    def node(self, level: int, index: int) -> NodeResult | None:
        """Inspect a solved node (``None`` if not solved yet) — serving
        can read any level, not just the root."""
        hit = self._cache.get((level, index))
        return hit[1] if hit else None

    def critical_path_s(self) -> float:
        """Sum over levels of the slowest node solve at that level — the
        wallclock model when each level's nodes run concurrently."""
        per_level: dict[int, float] = {}
        for (lvl, _), s in self.stats["node_s"].items():
            per_level[lvl] = max(per_level.get(lvl, 0.0), s)
        return sum(per_level.values())

    # -- solving -----------------------------------------------------------
    def _topology(self) -> TreeNode:
        return build_tree(self._workers or self.worker_ids,
                          self.config.fan_in)

    def _on_arrival(self, worker_id: int) -> None:
        if self.state_dir:
            model, mask = self._models[worker_id]
            publish_tree_node(
                self.state_dir, 0, worker_id,
                {"model": model, "mask": mask},
                meta={"worker": worker_id, "fan_in": self.config.fan_in})

    def _node_result(self, node: TreeNode) -> NodeResult | None:
        """Solve the subtree over its arrived workers, reusing cached
        results whose arrived-signature is unchanged. ``None`` when no
        worker under the node has arrived."""
        if node.is_leaf:
            w = node.worker_ids[0]
            if w not in self._models:
                return None
            hit = self._cache.get((0, node.index))
            if hit and hit[0] == (w,):
                return hit[1]
            res = self._leaf_result(node)
            self._cache[(0, node.index)] = ((w,), res)
            return res
        kids = [r for r in (self._node_result(c) for c in node.children)
                if r is not None]
        if not kids:
            return None
        sig = tuple(w for r in kids for w in r.worker_ids)
        hit = self._cache.get((node.level, node.index))
        if hit and hit[0] == sig:
            return hit[1]
        res = self._solve_node(node, kids)
        self._cache[(node.level, node.index)] = (sig, res)
        if self.state_dir and res.level > 0:
            self._persist_node(res, sig)
        return res

    def _leaf_result(self, node: TreeNode) -> NodeResult:
        w = node.worker_ids[0]
        model, mask = self._models[w]
        Yl = jnp.asarray(model) * jnp.asarray(mask)[:, None]
        d = model.shape[1]
        return NodeResult(
            level=0, index=node.index, worker_ids=(w,),
            Y=Yl, valid=jnp.asarray(mask).astype(bool),
            mask=jnp.asarray(mask).astype(bool)[None],
            transforms=jnp.eye(d, dtype=Yl.dtype)[None], disps=None)

    def _solve_node(self, node: TreeNode,
                    kids: list[NodeResult]) -> NodeResult:
        ids = tuple(w for r in kids for w in r.worker_ids)
        if len(kids) == 1:
            # single present child: pass through unchanged (an ALiR
            # "solve" of one model would just rotate it toward the init)
            c = kids[0]
            self.stats["passthrough"] += 1
            return NodeResult(level=node.level, index=node.index,
                              worker_ids=ids, Y=c.Y, valid=c.valid,
                              mask=c.mask, transforms=c.transforms,
                              disps=c.disps)
        cfg = self.config
        child_stack = StackedModels(
            models=jnp.stack([c.Y for c in kids]),
            mask=jnp.stack([c.valid for c in kids]))
        t0 = time.perf_counter()
        Y, valid, disps = _alir_solve(
            child_stack, init=cfg.init, max_iters=cfg.max_iters,
            tol=cfg.tol, key=self._node_key(node), shard=cfg.shard)
        Wc = alir_transforms(child_stack, Y, shard=cfg.shard)
        # compose: worker → child (c.transforms) then child → node (Wc)
        transforms = jnp.concatenate(
            [c.transforms @ Wc[i] for i, c in enumerate(kids)])
        jax.block_until_ready(transforms)
        self.stats["solved"] += 1
        self.stats["node_s"][(node.level, node.index)] = (
            time.perf_counter() - t0)
        mask = jnp.concatenate([c.mask for c in kids])
        return NodeResult(level=node.level, index=node.index,
                          worker_ids=ids, Y=Y, valid=valid, mask=mask,
                          transforms=transforms, disps=disps)

    # -- persistence -------------------------------------------------------
    def _persist_node(self, res: NodeResult, sig: tuple[int, ...]) -> None:
        arrays = {"Y": res.Y, "valid": res.valid, "mask": res.mask,
                  "transforms": res.transforms}
        if res.disps is not None:
            arrays["disps"] = res.disps
        publish_tree_node(
            self.state_dir, res.level, res.index, arrays,
            meta={"arrived": list(sig), "fan_in": self.config.fan_in,
                  "level": res.level, "index": res.index})

    def _load_state(self) -> None:
        """Reload persisted leaves (arrivals) and interior solves; a
        reloaded node is only *used* when its arrived-signature still
        matches, so stale persisted nodes are harmless."""
        for level, index in list_tree_nodes(self.state_dir):
            loaded = load_tree_node(self.state_dir, level, index)
            if loaded is None:
                continue
            arrays, meta, _ = loaded
            if meta.get("fan_in") != self.config.fan_in:
                continue
            if level == 0:
                self._models[int(index)] = (
                    np.asarray(arrays["model"]),
                    np.asarray(arrays["mask"]).astype(bool))
                self.stats["loaded"] += 1
            else:
                sig = tuple(int(w) for w in meta.get("arrived", ()))
                res = NodeResult(
                    level=level, index=index, worker_ids=sig,
                    Y=jnp.asarray(arrays["Y"]),
                    valid=jnp.asarray(arrays["valid"]).astype(bool),
                    mask=jnp.asarray(arrays["mask"]).astype(bool),
                    transforms=jnp.asarray(arrays["transforms"]),
                    disps=(jnp.asarray(arrays["disps"])
                           if "disps" in arrays else None))
                self._cache[(level, index)] = (sig, res)
                self.stats["loaded"] += 1


# Register with the merge registry (merge.get_merger imports lazily; a
# direct import of this module keeps the mapping consistent too).
from repro.core import merge as _merge_mod  # noqa: E402

_merge_mod.MERGERS.setdefault("alir_tree", TreeAlirMerger)
