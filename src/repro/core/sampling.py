"""The Divide phase — the paper's three data-division strategies.

* EQUAL PARTITIONING — sequentially cut the corpus into ``n`` contiguous
  equal slices (the paper's weak baseline: preserves neither unigram nor
  bigram distributions when the corpus has topical/temporal drift).
* RANDOM SAMPLING  — each worker draws ``r·N`` sentences u.a.r. *with
  replacement*, with a fixed per-worker seed: every epoch re-visits the
  same sample (paper §3.1, Theorem 1: expected unigram distribution of a
  sample equals the corpus distribution).
* SHUFFLE          — identical to RANDOM SAMPLING except the draw is
  re-seeded every epoch, so a worker sees a *fresh* sample per epoch
  (paper §3.2: stateless, regularizing, best quality in Table 2).

All three are deterministic functions of (worker, epoch, seed), which is
what makes the TPU realization stateless — no materialized sub-corpora.
"""

from __future__ import annotations

import numpy as np

STRATEGIES = ("equal", "random", "shuffle")


def sample_sentence_indices(
    num_sentences: int,
    strategy: str,
    rate: float,
    worker: int,
    num_workers: int,
    epoch: int = 0,
    seed: int = 0,
) -> np.ndarray:
    """Sentence indices forming ``worker``'s sub-corpus for ``epoch``."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; expected one of {STRATEGIES}")
    if not (0.0 < rate <= 1.0):
        raise ValueError(f"rate must be in (0, 1], got {rate}")
    target = max(1, int(round(rate * num_sentences)))

    if strategy == "equal":
        # Contiguous slice; ignores `rate` in favour of exact n-way split
        # (the paper's equal partitioning is 100/r partitions of rN/100
        # sentences each — identical when rate == 1/num_workers).
        bounds = np.linspace(0, num_sentences, num_workers + 1).astype(np.int64)
        return np.arange(bounds[worker], bounds[worker + 1], dtype=np.int64)

    if strategy == "random":
        rng = np.random.default_rng((seed, 0x5EED, worker))
    else:  # shuffle: fresh sample every epoch
        rng = np.random.default_rng((seed, 0x5EED, worker, epoch))
    return rng.integers(0, num_sentences, size=target, dtype=np.int64)


def coverage_stats(indices_per_worker: list[np.ndarray], num_sentences: int) -> dict:
    """Vocabulary-coverage-style stats at the sentence level (paper §3.1)."""
    seen = np.zeros(num_sentences, dtype=bool)
    per_worker_unique = []
    for idx in indices_per_worker:
        u = np.unique(idx)
        per_worker_unique.append(len(u))
        seen[u] = True
    return {
        "union_coverage": float(seen.mean()),
        "mean_worker_unique": float(np.mean(per_worker_unique)),
    }
