"""Epoch/chunk/step bookkeeping for streamed training.

The streaming pipeline feeds the device fixed-shape chunks of
``chunk_steps`` batches, so an epoch must be a whole number of chunks,
and the linear LR decay is sized from ``total_steps`` — three coupled
quantities that used to be derived inline in ``train_submodels``. This
module is the single source of that derivation, so schedule consumers
(LR decay, chunk loops, wall-clock projections) can never drift apart.

Rounding policy: the epoch is fitted into whole chunks by *shrinking the
chunk*, never by rounding the epoch up past ``max_steps_per_epoch`` —
a step cap is a hard budget (word2vec's LR floor makes extra steps
harmless, but the paper's wall-clock tables assume the cap is exact).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class EpochSchedule:
    """The one consistent answer to "how many steps is this run?".

    Invariants (asserted in tests):
      * ``steps_per_epoch == num_chunks * chunk_steps``
      * ``steps_per_epoch <= max_steps_per_epoch`` (when capped)
      * ``chunk_steps <= requested steps_per_chunk``
      * ``total_steps == steps_per_epoch * epochs``
    """

    steps_per_epoch: int
    chunk_steps: int
    num_chunks: int
    epochs: int

    @property
    def total_steps(self) -> int:
        """LR-decay horizon: every step the whole run will take."""
        return self.steps_per_epoch * self.epochs

    def step0(self, epoch: int, chunk: int) -> int:
        """Global index of the first step of ``chunk`` within ``epoch``
        (what the LR schedule sees)."""
        return epoch * self.steps_per_epoch + chunk * self.chunk_steps


def plan_epoch(
    min_pairs: int,
    batch_size: int,
    epochs: int,
    steps_per_chunk: int,
    max_steps_per_epoch: int | None = None,
) -> EpochSchedule:
    """Derive the epoch schedule from the streamed epoch-0 pair count.

    ``min_pairs`` is the smallest per-worker pair count (shorter streams
    wrap, so every worker runs the same step count). Always yields at
    least one step; an explicit cap is never exceeded.
    """
    if min_pairs <= 0:
        raise ValueError(f"min_pairs must be positive, got {min_pairs}")
    if batch_size <= 0 or epochs <= 0 or steps_per_chunk <= 0:
        raise ValueError("batch_size, epochs and steps_per_chunk must be "
                         "positive")
    steps = max(1, min_pairs // batch_size)
    if max_steps_per_epoch is not None:
        steps = min(steps, max_steps_per_epoch)
    num_chunks = -(-steps // min(steps_per_chunk, steps))
    chunk_steps = steps // num_chunks
    return EpochSchedule(steps_per_epoch=num_chunks * chunk_steps,
                         chunk_steps=chunk_steps, num_chunks=num_chunks,
                         epochs=epochs)
