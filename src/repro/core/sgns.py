"""SGNS (skip-gram with negative sampling) — the paper's base model.

Pure-JAX reference implementation of word2vec's SGNS objective
(Eq. 1 of the paper):

    log σ(w·c) + Σ_{k} E_{c'~P_D^{3/4}} log σ(−w·c')

Two step functions with identical math:

* ``train_step_dense``   — autodiff through the gathers; materializes a
  dense (V, d) gradient. Simple; used as the oracle in tests.
* ``train_step_sparse``  — manual per-row gradients + scatter-add; the
  production path (O(B·K·d) instead of O(V·d) memory traffic). The
  Pallas kernel in ``repro.kernels`` fuses the middle of this path.

These are the primitives behind the update engines in
:mod:`repro.core.engine` (``dense`` / ``sparse`` / ``pallas`` /
``pallas_fused``) — trainers select an engine rather than calling these
directly.

Initialization matches word2vec: W ~ U(−0.5/d, 0.5/d), C = 0.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SGNSConfig:
    vocab_size: int
    dim: int = 500            # paper: 500 dims
    window: int = 10          # paper: 10 each side
    negatives: int = 5        # word2vec default k
    lr: float = 0.025         # word2vec default initial alpha
    lr_min: float = 1e-4
    seed: int = 0


def init_params(key: jax.Array, cfg: SGNSConfig) -> dict:
    kw, _ = jax.random.split(key)
    w = jax.random.uniform(
        kw, (cfg.vocab_size, cfg.dim), minval=-0.5 / cfg.dim, maxval=0.5 / cfg.dim,
        dtype=jnp.float32,
    )
    c = jnp.zeros((cfg.vocab_size, cfg.dim), dtype=jnp.float32)
    return {"W": w, "C": c}


def negative_logits_loss(
    w: jax.Array, c_pos: jax.Array, c_neg: jax.Array
) -> jax.Array:
    """Mean SGNS loss for gathered rows w (B,d), c_pos (B,d), c_neg (B,K,d)."""
    s_pos = jnp.sum(w * c_pos, axis=-1)
    s_neg = jnp.einsum("bd,bkd->bk", w, c_neg)
    loss = -jax.nn.log_sigmoid(s_pos) - jnp.sum(jax.nn.log_sigmoid(-s_neg), axis=-1)
    return jnp.mean(loss)


def loss_fn(
    params: dict, centers: jax.Array, contexts: jax.Array, negatives: jax.Array
) -> jax.Array:
    w = params["W"][centers]
    c_pos = params["C"][contexts]
    c_neg = params["C"][negatives]
    return negative_logits_loss(w, c_pos, c_neg)


def sum_loss_fn(
    params: dict, centers: jax.Array, contexts: jax.Array, negatives: jax.Array
) -> jax.Array:
    """Sum-over-pairs loss — word2vec's update semantics: each (w, c)
    pair applies its own lr·grad independently, so a minibatch applies
    the *sum* of per-pair gradients (not the mean)."""
    return loss_fn(params, centers, contexts, negatives) * centers.shape[0]


@partial(jax.jit, donate_argnums=0)
def train_step_dense(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    negatives: jax.Array,
    lr: jax.Array,
) -> tuple[dict, jax.Array]:
    sum_loss, grads = jax.value_and_grad(sum_loss_fn)(
        params, centers, contexts, negatives)
    params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    return params, sum_loss / centers.shape[0]


def sparse_row_grads_per_pair(
    w: jax.Array, c_pos: jax.Array, c_neg: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-pair losses + per-row gradients of the *sum* SGNS loss — the
    shared core of :func:`sparse_row_grads` and the fused Pallas kernels
    (which need the loss un-reduced, one value per pair). Keeping one
    copy of these expressions is what the kernels' bit-equivalence
    contract stands on.

    Returns (loss (B,), dW_rows (B,d), dC_pos_rows (B,d),
    dC_neg_rows (B,K,d)).
    """
    s_pos = jnp.sum(w * c_pos, axis=-1)
    s_neg = jnp.einsum("bd,bkd->bk", w, c_neg)
    loss = -jax.nn.log_sigmoid(s_pos) - jnp.sum(
        jax.nn.log_sigmoid(-s_neg), axis=-1)
    g_pos = jax.nn.sigmoid(s_pos) - 1.0                # (B,)
    g_neg = jax.nn.sigmoid(s_neg)                      # (B,K)
    d_w = g_pos[:, None] * c_pos + jnp.einsum("bk,bkd->bd", g_neg, c_neg)
    d_cp = g_pos[:, None] * w
    d_cn = g_neg[..., None] * w[:, None, :]
    return loss, d_w, d_cp, d_cn


def sparse_row_grads(
    w: jax.Array, c_pos: jax.Array, c_neg: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-row gradients of the *sum* SGNS loss (word2vec semantics;
    matches autodiff of :func:`sum_loss_fn` exactly).

    Returns (mean_loss, dW_rows (B,d), dC_pos_rows (B,d), dC_neg_rows (B,K,d)).
    This is the function the Pallas kernel implements.
    """
    loss, d_w, d_cp, d_cn = sparse_row_grads_per_pair(w, c_pos, c_neg)
    return jnp.mean(loss), d_w, d_cp, d_cn


def train_step_sparse(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    negatives: jax.Array,
    lr: jax.Array,
    row_grad_fn=sparse_row_grads,
) -> tuple[dict, jax.Array]:
    """Gather → row grads (jnp or Pallas) → scatter-add. Duplicate indices
    accumulate, exactly like the dense-grad scatter that autodiff builds."""
    w = params["W"][centers]
    c_pos = params["C"][contexts]
    c_neg = params["C"][negatives]
    loss, d_w, d_cp, d_cn = row_grad_fn(w, c_pos, c_neg)
    W = params["W"].at[centers].add(-lr * d_w)
    C = params["C"].at[contexts].add(-lr * d_cp)
    C = C.at[negatives.reshape(-1)].add(-lr * d_cn.reshape(-1, d_cn.shape[-1]))
    return {"W": W, "C": C}, loss


def linear_lr(step: jax.Array, total_steps: int, cfg: SGNSConfig) -> jax.Array:
    """word2vec's linearly decaying alpha."""
    frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
    return jnp.maximum(cfg.lr * (1.0 - frac), cfg.lr_min)


def embedding_matrix(params: dict) -> jax.Array:
    """The word representation the paper evaluates (input vectors W)."""
    return params["W"]
