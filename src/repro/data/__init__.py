"""Data substrate: corpus synthesis, vocabulary, skip-gram pairs, streams.

The paper trains on raw text (Wikipedia 14 GB / Web 268 GB). Offline we
substitute a synthetic corpus drawn from a generative model with *known*
semantic structure (`corpus.SemanticCorpusModel`) so that the evaluation
benchmarks (similarity / analogy / categorization) have exact gold data.
Everything downstream (vocab building, subsampling, window extraction,
negative-sampling tables, per-worker sample streams) is implemented in
full, as it would be for real text.
"""

from repro.data.corpus import SemanticCorpusModel, Corpus
from repro.data.vocab import Vocab, build_vocab
from repro.data.pairs import (
    extract_pairs,
    AliasSampler,
    NegativeSampler,
    negative_sampler_fn,
    build_noise_table,
    stack_noise_tables,
    subsample_mask,
)
from repro.data.pipeline import (
    HostShardPlan,
    PairChunkStream,
    WorkerStream,
    make_worker_streams,
    prefetch_chunks,
    stacked_pair_batches,
)

__all__ = [
    "SemanticCorpusModel",
    "Corpus",
    "Vocab",
    "build_vocab",
    "extract_pairs",
    "AliasSampler",
    "NegativeSampler",
    "negative_sampler_fn",
    "build_noise_table",
    "stack_noise_tables",
    "subsample_mask",
    "HostShardPlan",
    "PairChunkStream",
    "WorkerStream",
    "make_worker_streams",
    "prefetch_chunks",
    "stacked_pair_batches",
]
