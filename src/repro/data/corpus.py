"""Synthetic corpus with known semantic structure.

Generative model ("semantic lattice"):

* Every word ``w`` has a latent vector ``z_w = center[topic(w)] +
  Σ_f flag(w,f)·offset[f]`` — a cluster center plus binary feature
  offsets. Topics give categorization gold; feature flips give analogy
  gold (``a:b :: c:d`` where b = a with feature f flipped, d = c with f
  flipped); cosine of latents gives similarity gold.
* Word frequency is Zipfian by rank, independent of topic — the corpus
  has the heavy-tail unigram distribution that word2vec's subsampling,
  negative-sampling table and the paper's Theorem 2 all care about.
* A sentence picks a topic ``t`` and draws words i.i.d. from
  ``p(w|t) ∝ zipf(w) · exp(β · z_w · center[t])``: words co-occur with
  their topical neighbours, giving a non-trivial bigram (word–context)
  distribution. This is the structure SGNS must recover.

Corpora are stored flat (``tokens`` int32 + ``offsets``) so sampling
strategies can slice sentences cheaply.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Corpus:
    """A tokenized corpus: flat token ids plus sentence boundaries."""

    tokens: np.ndarray   # (T,) int32
    offsets: np.ndarray  # (S+1,) int64; sentence i = tokens[offsets[i]:offsets[i+1]]

    @property
    def num_sentences(self) -> int:
        return len(self.offsets) - 1

    @property
    def num_tokens(self) -> int:
        return int(self.offsets[-1])

    def sentence(self, i: int) -> np.ndarray:
        return self.tokens[self.offsets[i] : self.offsets[i + 1]]

    def sentences(self) -> list:
        return [self.sentence(i) for i in range(self.num_sentences)]

    def select(self, idx: np.ndarray) -> "Corpus":
        """Sub-corpus from sentence indices (with repetition allowed)."""
        lengths = (self.offsets[1:] - self.offsets[:-1])[idx]
        new_offsets = np.zeros(len(idx) + 1, dtype=np.int64)
        np.cumsum(lengths, out=new_offsets[1:])
        out = np.empty(int(new_offsets[-1]), dtype=np.int32)
        starts = self.offsets[idx]
        for j, (s, l, o) in enumerate(zip(starts, lengths, new_offsets[:-1])):
            out[o : o + l] = self.tokens[s : s + l]
        return Corpus(tokens=out, offsets=new_offsets)


@dataclass(frozen=True)
class SemanticCorpusModel:
    """The generator + its gold semantic geometry."""

    vocab_size: int
    latents: np.ndarray        # (V, m) gold latent vectors
    topics: np.ndarray         # (V,) int topic id per word
    features: np.ndarray       # (V, F) binary feature flags per word
    zipf_probs: np.ndarray     # (V,) unigram prior
    centers: np.ndarray        # (K, m) topic centers
    offsets_f: np.ndarray      # (F, m) feature offsets
    beta: float

    # ------------------------------------------------------------------
    @staticmethod
    def create(
        vocab_size: int = 2000,
        num_topics: int = 16,
        num_features: int = 4,
        latent_dim: int = 12,
        zipf_a: float = 1.05,
        beta: float = 4.0,
        seed: int = 0,
    ) -> "SemanticCorpusModel":
        rng = np.random.default_rng(seed)
        centers = rng.normal(size=(num_topics, latent_dim))
        centers /= np.linalg.norm(centers, axis=1, keepdims=True)
        offs = 0.35 * rng.normal(size=(num_features, latent_dim))
        topics = rng.integers(0, num_topics, size=vocab_size)
        feats = (rng.random((vocab_size, num_features)) < 0.5).astype(np.int8)
        latents = centers[topics] + feats @ offs
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        zipf = ranks ** (-zipf_a)
        # Random rank assignment so frequency is independent of topic.
        perm = rng.permutation(vocab_size)
        zipf = zipf[perm]
        zipf /= zipf.sum()
        return SemanticCorpusModel(
            vocab_size=vocab_size,
            latents=latents,
            topics=topics,
            features=feats,
            zipf_probs=zipf,
            centers=centers,
            offsets_f=offs,
            beta=beta,
        )

    # ------------------------------------------------------------------
    def topic_word_dists(self) -> np.ndarray:
        """(K, V) word distribution per topic."""
        logits = self.beta * (self.latents @ self.centers.T)  # (V, K)
        logits = logits - logits.max(axis=0, keepdims=True)
        p = self.zipf_probs[:, None] * np.exp(logits)
        p /= p.sum(axis=0, keepdims=True)
        return p.T  # (K, V)

    def generate(
        self,
        num_sentences: int,
        mean_sentence_len: int = 20,
        seed: int = 1,
    ) -> Corpus:
        """Vectorized sentence sampling."""
        rng = np.random.default_rng(seed)
        K = self.centers.shape[0]
        topic_dists = self.topic_word_dists()           # (K, V)
        cdfs = np.cumsum(topic_dists, axis=1)            # (K, V)
        cdfs[:, -1] = 1.0
        lengths = rng.poisson(mean_sentence_len, size=num_sentences)
        lengths = np.clip(lengths, 3, None).astype(np.int64)
        sent_topics = rng.integers(0, K, size=num_sentences)
        offsets = np.zeros(num_sentences + 1, dtype=np.int64)
        np.cumsum(lengths, out=offsets[1:])
        total = int(offsets[-1])
        u = rng.random(total)
        tokens = np.empty(total, dtype=np.int32)
        # Sample per topic in one vectorized searchsorted each. The
        # default side='left' is kept: the Dirichlet topic weights are
        # strictly positive so no CDF step is flat and u ~ U[0,1) never
        # hits a boundary exactly — and the committed gold-benchmark
        # corpora were generated with this exact lookup, so it must not
        # change bit-for-bit.
        tok_topic = np.repeat(sent_topics, lengths)
        for k in range(K):
            m = tok_topic == k
            if m.any():
                tokens[m] = np.searchsorted(  # repro-lint: ignore[RL002]
                    cdfs[k], u[m]).astype(np.int32)
        np.clip(tokens, 0, self.vocab_size - 1, out=tokens)
        return Corpus(tokens=tokens, offsets=offsets)

    # ------------------- gold benchmark constructors -------------------
    def gold_similarity(self, word_a: np.ndarray, word_b: np.ndarray) -> np.ndarray:
        za, zb = self.latents[word_a], self.latents[word_b]
        num = (za * zb).sum(-1)
        den = np.linalg.norm(za, axis=-1) * np.linalg.norm(zb, axis=-1) + 1e-9
        return num / den

    def similarity_benchmark(self, n_pairs: int = 300, seed: int = 7, top_words: int | None = None):
        rng = np.random.default_rng(seed)
        hi = top_words or self.vocab_size
        a = rng.integers(0, hi, size=n_pairs)
        b = rng.integers(0, hi, size=n_pairs)
        keep = a != b
        a, b = a[keep], b[keep]
        return a, b, self.gold_similarity(a, b)

    def analogy_benchmark(self, n_quads: int = 200, seed: int = 11, top_words: int | None = None):
        """Quadruples a:b :: c:d — b=a with feature f flipped, same for c:d.

        Built from the lattice: pick feature f, pick words a, c with the
        same topic-pair structure differing only in f.
        """
        rng = np.random.default_rng(seed)
        hi = top_words or self.vocab_size
        F = self.features.shape[1]
        # Index words by (topic, feature-vector) signature.
        sig = {}
        for w in range(hi):
            key = (int(self.topics[w]), tuple(int(x) for x in self.features[w]))
            sig.setdefault(key, []).append(w)
        quads = []
        tries = 0
        while len(quads) < n_quads and tries < n_quads * 60:
            tries += 1
            f = int(rng.integers(0, F))
            t1 = int(rng.integers(0, self.centers.shape[0]))
            t2 = int(rng.integers(0, self.centers.shape[0]))
            base = tuple(int(x) for x in (rng.random(F) < 0.5))
            flip = tuple(v if i != f else 1 - v for i, v in enumerate(base))
            ka, kb = (t1, base), (t1, flip)
            kc, kd = (t2, base), (t2, flip)
            if all(k in sig for k in (ka, kb, kc, kd)):
                a = int(rng.choice(sig[ka]))
                b = int(rng.choice(sig[kb]))
                c = int(rng.choice(sig[kc]))
                d = int(rng.choice(sig[kd]))
                if len({a, b, c, d}) == 4:
                    quads.append((a, b, c, d))
        return np.array(quads, dtype=np.int64).reshape(-1, 4)

    def categorization_benchmark(self, n_words: int = 400, seed: int = 13, top_words: int | None = None):
        rng = np.random.default_rng(seed)
        hi = top_words or self.vocab_size
        words = rng.choice(hi, size=min(n_words, hi), replace=False)
        return words, self.topics[words]
