"""Skip-gram (center, context) pair extraction + negative sampling.

Faithful to word2vec/the paper:

* dynamic window — the effective window for each center is drawn
  uniformly from [1, win] (word2vec's ``b`` trick);
* frequent-word subsampling with the usual ``(sqrt(f/t)+1)·t/f`` keep
  probability;
* negative samples drawn from the unigram distribution raised to 3/4.

Pair extraction is vectorized numpy (host-side input pipeline); negative
sampling is a jittable inverse-CDF lookup so it can run on-device inside
the training step.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.corpus import Corpus
from repro.data.vocab import Vocab, UNK


def subsample_mask(
    tokens: np.ndarray, vocab: Vocab, t: float = 1e-4, rng: np.random.Generator | None = None
) -> np.ndarray:
    """word2vec frequent-word subsampling. tokens are vocab ids (UNK allowed)."""
    rng = rng or np.random.default_rng(0)
    freqs = vocab.unigram_probs()
    f = np.where(tokens == UNK, 1.0, freqs[np.clip(tokens, 0, None)])
    keep_prob = np.minimum(1.0, (np.sqrt(f / t) + 1.0) * (t / np.maximum(f, 1e-12)))
    keep = rng.random(len(tokens)) < keep_prob
    return keep & (tokens != UNK)


def extract_pairs(
    corpus: Corpus,
    vocab: Vocab,
    window: int = 10,
    subsample_t: float | None = 1e-4,
    seed: int | np.random.SeedSequence = 0,
    max_pairs: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Return (centers, contexts) vocab-id arrays for the whole corpus.

    Implements word2vec semantics: subsampled/UNK tokens are removed from
    the stream *before* windowing (so windows reach across removed
    words), and each center uses a dynamic window size.
    """
    rng = np.random.default_rng(seed)
    toks = vocab.encode(corpus.tokens)
    if subsample_t is not None:
        keep = subsample_mask(toks, vocab, t=subsample_t, rng=rng)
    else:
        keep = toks != UNK

    # Sentence id per token, so windows never cross sentence boundaries.
    sent_id = np.repeat(
        np.arange(corpus.num_sentences, dtype=np.int64),
        np.diff(corpus.offsets),
    )
    toks, sent_id = toks[keep], sent_id[keep]
    n = len(toks)
    if n == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.int32)

    dyn = rng.integers(1, window + 1, size=n)
    centers_parts, contexts_parts = [], []
    for off in range(1, window + 1):
        # pair (i, i+off) valid both directions when off <= dyn of the center
        valid = np.arange(n - off)
        same_sent = sent_id[valid] == sent_id[valid + off]
        fwd = same_sent & (off <= dyn[valid])
        bwd = same_sent & (off <= dyn[valid + off])
        i = valid[fwd]
        centers_parts.append(toks[i])
        contexts_parts.append(toks[i + off])
        j = valid[bwd]
        centers_parts.append(toks[j + off])
        contexts_parts.append(toks[j])
    centers = np.concatenate(centers_parts).astype(np.int32)
    contexts = np.concatenate(contexts_parts).astype(np.int32)
    perm = rng.permutation(len(centers))
    centers, contexts = centers[perm], contexts[perm]
    if max_pairs is not None:
        centers, contexts = centers[:max_pairs], contexts[:max_pairs]
    return centers, contexts


# ---------------------------------------------------------------------------
# Negative sampling: two interchangeable on-device draw primitives.
#
# ``cdf``   — inverse-CDF lookup, O(log V) searchsorted per draw. The
#             original path; kept as the distribution oracle.
# ``alias`` — Vose alias table, O(1) per draw: one randint + one uniform
#             + two gathers. The production path for large vocabularies.
#
# Both take the table as a traced argument so the same jitted epoch
# function serves every worker's own noise distribution.
# ---------------------------------------------------------------------------
def cdf_to_ids(cdf: jax.Array, u: jax.Array) -> jax.Array:
    """Inverse-CDF lookup: the id ``i`` with ``cdf[i-1] <= u < cdf[i]``.

    ``side='right'`` is load-bearing: it maps each ``u`` to the interval
    *above* it, so an id with zero probability (``cdf[i] == cdf[i-1]``,
    e.g. a union-vocab row this worker never saw) is unreachable.
    ``side='left'`` — the old behavior — returned such an id whenever
    ``u == 0.0`` with a leading zero-count row, or ``u`` landed exactly
    on a duplicated CDF boundary; at B·K draws per step those hits occur
    in practice and wrote to rows absent from the worker's vocabulary,
    corrupting the merge presence mask.
    """
    idx = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(idx, 0, cdf.shape[0] - 1).astype(jnp.int32)


def sample_negatives_cdf(
    cdf: jax.Array, key: jax.Array, shape: tuple[int, ...]
) -> jax.Array:
    u = jax.random.uniform(key, shape, dtype=jnp.float32)
    return cdf_to_ids(cdf, u)


def sample_negatives_alias(
    table: dict, key: jax.Array, shape: tuple[int, ...]
) -> jax.Array:
    prob, alias = table["prob"], table["alias"]
    k_idx, k_u = jax.random.split(key)
    idx = jax.random.randint(k_idx, shape, 0, prob.shape[0], dtype=jnp.int32)
    u = jax.random.uniform(k_u, shape, dtype=jnp.float32)
    return jnp.where(u < prob[idx], idx, alias[idx]).astype(jnp.int32)


NEGATIVE_SAMPLERS = {
    "cdf": sample_negatives_cdf,
    "alias": sample_negatives_alias,
}


def negative_sampler_fn(kind: str):
    """``fn(table, key, shape) -> (shape,) int32`` for ``kind``."""
    try:
        return NEGATIVE_SAMPLERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown negative sampler {kind!r}; expected one of "
            f"{sorted(NEGATIVE_SAMPLERS)}") from None


def unigram_noise_probs(vocab_counts: np.ndarray, power: float = 0.75) -> np.ndarray:
    """word2vec noise distribution: unigram counts raised to 3/4."""
    p = np.asarray(vocab_counts, dtype=np.float64) ** power
    s = p.sum()
    return p / s if s > 0 else np.full_like(p, 1.0 / len(p))


# ---------------------------------------------------------------------------
# Noise-table layouts. An UpdateEngine declares which layout its draw
# consumes (`engine.table_kind`); these helpers build it — one table per
# sub-model, host-side, then stacked along a leading worker axis so the
# tables shard over the `worker` mesh axis like the parameter tables.
# ---------------------------------------------------------------------------
def build_noise_table(vocab_counts: np.ndarray, kind: str = "cdf",
                      power: float = 0.75):
    """One vocab's unigram^0.75 noise table in the layout ``kind``
    draws from: a ``(V,)`` float32 CDF, or a ``{'prob', 'alias'}`` Vose
    alias table (float32/int32 — VMEM-resident operands of the fused
    kernel)."""
    p = unigram_noise_probs(vocab_counts, power)
    if kind == "cdf":
        c = np.cumsum(p)
        c[-1] = 1.0
        return jnp.asarray(c, dtype=jnp.float32)
    if kind == "alias":
        from repro.core.distributions import build_alias_table

        prob, alias = build_alias_table(p)
        return {"prob": jnp.asarray(prob, dtype=jnp.float32),
                "alias": jnp.asarray(alias, dtype=jnp.int32)}
    raise ValueError(f"unknown noise-table kind {kind!r}; "
                     f"expected 'cdf' or 'alias'")


def stack_noise_tables(counts_per_worker: list[np.ndarray], kind: str = "cdf",
                       power: float = 0.75):
    """Stacked per-worker noise tables: ``(n, V)`` CDFs, or
    ``{'prob': (n, V), 'alias': (n, V)}`` alias tables. Each sub-model
    draws from its *own* sample's noise distribution, exactly as a
    standalone word2vec run on that sub-corpus would (paper §3.2)."""
    tables = [build_noise_table(c, kind=kind, power=power)
              for c in counts_per_worker]
    if kind == "cdf":
        return jnp.stack(tables)
    return {k: jnp.stack([t[k] for t in tables]) for k in ("prob", "alias")}


class NegativeSampler:
    """Unigram^0.75 sampler: inverse-CDF lookup, jittable and vectorized."""

    def __init__(self, vocab_counts: np.ndarray, power: float = 0.75):
        p = unigram_noise_probs(vocab_counts, power)
        cdf = np.cumsum(p)
        cdf[-1] = 1.0
        self.cdf = jnp.asarray(cdf, dtype=jnp.float32)
        self.probs = jnp.asarray(p, dtype=jnp.float32)

    def sample(self, key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
        return sample_negatives_cdf(self.cdf, key, shape)


class AliasSampler:
    """Unigram^0.75 sampler via Vose's alias method: O(V) build, O(1) draw."""

    def __init__(self, vocab_counts: np.ndarray, power: float = 0.75):
        from repro.core.distributions import build_alias_table

        p = unigram_noise_probs(vocab_counts, power)
        prob, alias = build_alias_table(p)
        self.prob = jnp.asarray(prob, dtype=jnp.float32)
        self.alias = jnp.asarray(alias, dtype=jnp.int32)
        self.probs = jnp.asarray(p, dtype=jnp.float32)

    @property
    def table(self) -> dict:
        return {"prob": self.prob, "alias": self.alias}

    def sample(self, key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
        return sample_negatives_alias(self.table, key, shape)
