"""Skip-gram (center, context) pair extraction + negative sampling.

Faithful to word2vec/the paper:

* dynamic window — the effective window for each center is drawn
  uniformly from [1, win] (word2vec's ``b`` trick);
* frequent-word subsampling with the usual ``(sqrt(f/t)+1)·t/f`` keep
  probability;
* negative samples drawn from the unigram distribution raised to 3/4.

Pair extraction is vectorized numpy (host-side input pipeline); negative
sampling is a jittable inverse-CDF lookup so it can run on-device inside
the training step.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.corpus import Corpus
from repro.data.vocab import Vocab, UNK


def subsample_mask(
    tokens: np.ndarray, vocab: Vocab, t: float = 1e-4, rng: np.random.Generator | None = None
) -> np.ndarray:
    """word2vec frequent-word subsampling. tokens are vocab ids (UNK allowed)."""
    rng = rng or np.random.default_rng(0)
    freqs = vocab.unigram_probs()
    f = np.where(tokens == UNK, 1.0, freqs[np.clip(tokens, 0, None)])
    keep_prob = np.minimum(1.0, (np.sqrt(f / t) + 1.0) * (t / np.maximum(f, 1e-12)))
    keep = rng.random(len(tokens)) < keep_prob
    return keep & (tokens != UNK)


def extract_pairs(
    corpus: Corpus,
    vocab: Vocab,
    window: int = 10,
    subsample_t: float | None = 1e-4,
    seed: int = 0,
    max_pairs: int | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Return (centers, contexts) vocab-id arrays for the whole corpus.

    Implements word2vec semantics: subsampled/UNK tokens are removed from
    the stream *before* windowing (so windows reach across removed
    words), and each center uses a dynamic window size.
    """
    rng = np.random.default_rng(seed)
    toks = vocab.encode(corpus.tokens)
    if subsample_t is not None:
        keep = subsample_mask(toks, vocab, t=subsample_t, rng=rng)
    else:
        keep = toks != UNK

    # Sentence id per token, so windows never cross sentence boundaries.
    sent_id = np.repeat(
        np.arange(corpus.num_sentences, dtype=np.int64),
        np.diff(corpus.offsets),
    )
    toks, sent_id = toks[keep], sent_id[keep]
    n = len(toks)
    if n == 0:
        return np.zeros(0, np.int32), np.zeros(0, np.int32)

    dyn = rng.integers(1, window + 1, size=n)
    centers_parts, contexts_parts = [], []
    for off in range(1, window + 1):
        # pair (i, i+off) valid both directions when off <= dyn of the center
        valid = np.arange(n - off)
        same_sent = sent_id[valid] == sent_id[valid + off]
        fwd = same_sent & (off <= dyn[valid])
        bwd = same_sent & (off <= dyn[valid + off])
        i = valid[fwd]
        centers_parts.append(toks[i])
        contexts_parts.append(toks[i + off])
        j = valid[bwd]
        centers_parts.append(toks[j + off])
        contexts_parts.append(toks[j])
    centers = np.concatenate(centers_parts).astype(np.int32)
    contexts = np.concatenate(contexts_parts).astype(np.int32)
    perm = rng.permutation(len(centers))
    centers, contexts = centers[perm], contexts[perm]
    if max_pairs is not None:
        centers, contexts = centers[:max_pairs], contexts[:max_pairs]
    return centers, contexts


class NegativeSampler:
    """Unigram^0.75 sampler: inverse-CDF lookup, jittable and vectorized."""

    def __init__(self, vocab_counts: np.ndarray, power: float = 0.75):
        p = vocab_counts.astype(np.float64) ** power
        p /= p.sum()
        cdf = np.cumsum(p)
        cdf[-1] = 1.0
        self.cdf = jnp.asarray(cdf, dtype=jnp.float32)
        self.probs = jnp.asarray(p, dtype=jnp.float32)

    def sample(self, key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
        u = jax.random.uniform(key, shape, dtype=jnp.float32)
        idx = jnp.searchsorted(self.cdf, u)
        return jnp.clip(idx, 0, self.cdf.shape[0] - 1).astype(jnp.int32)
