"""Per-worker sample streams — the paper's mapper/reducer, TPU-style.

In the paper a MapReduce mapper assigns each sentence to each of the
``n`` sub-corpora independently with probability ``r/100`` and ships it
to the matching reducer. Sampling with replacement is *stateless*, so on
a TPU pod we invert control: each worker draws its own sample directly
from the (shared, read-only) corpus with a deterministic PRNG stream —
``seed = hash(worker, epoch)`` for Shuffle, ``hash(worker)`` for fixed
RANDOM SAMPLING. No shuffle network phase exists at all.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.data.corpus import Corpus
from repro.data.vocab import Vocab
from repro.data.pairs import extract_pairs
from repro.core.sampling import sample_sentence_indices


@dataclass
class WorkerStream:
    """One sub-model's training stream for one epoch."""

    corpus: Corpus
    vocab: Vocab
    worker: int
    strategy: str           # 'equal' | 'random' | 'shuffle'
    rate: float             # sampling rate r in (0, 1]
    num_workers: int
    window: int = 10
    subsample_t: float | None = 1e-4
    seed: int = 0

    def sentence_indices(self, epoch: int) -> np.ndarray:
        return sample_sentence_indices(
            num_sentences=self.corpus.num_sentences,
            strategy=self.strategy,
            rate=self.rate,
            worker=self.worker,
            num_workers=self.num_workers,
            epoch=epoch,
            seed=self.seed,
        )

    def pairs(self, epoch: int, max_pairs: int | None = None):
        idx = self.sentence_indices(epoch)
        sub = self.corpus.select(idx)
        return extract_pairs(
            sub,
            self.vocab,
            window=self.window,
            subsample_t=self.subsample_t,
            seed=self.seed * 7919 + self.worker * 104729 + epoch,
            max_pairs=max_pairs,
        )

    def batches(
        self, epoch: int, batch_size: int, max_pairs: int | None = None
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        centers, contexts = self.pairs(epoch, max_pairs=max_pairs)
        n = (len(centers) // batch_size) * batch_size
        for i in range(0, n, batch_size):
            yield centers[i : i + batch_size], contexts[i : i + batch_size]


def make_worker_streams(
    corpus: Corpus,
    vocab: Vocab,
    num_workers: int,
    strategy: str,
    rate: float | None = None,
    **kw,
) -> list[WorkerStream]:
    rate = rate if rate is not None else 1.0 / num_workers
    return [
        WorkerStream(
            corpus=corpus,
            vocab=vocab,
            worker=w,
            strategy=strategy,
            rate=rate,
            num_workers=num_workers,
            **kw,
        )
        for w in range(num_workers)
    ]


def stacked_pair_batches(
    streams: list[WorkerStream],
    epoch: int,
    batch_size: int,
    num_batches: int,
) -> tuple[np.ndarray, np.ndarray]:
    """(n_workers, num_batches, batch) arrays for the async shard trainer.

    Streams shorter than requested wrap around — word2vec also iterates
    its stream multiple times; sub-models stay perfectly load-balanced.
    """
    n = len(streams)
    need = batch_size * num_batches
    centers = np.zeros((n, need), dtype=np.int32)
    contexts = np.zeros((n, need), dtype=np.int32)
    for w, s in enumerate(streams):
        c, x = s.pairs(epoch)
        if len(c) == 0:
            raise ValueError(f"worker {w} drew an empty sample")
        reps = int(np.ceil(need / len(c)))
        centers[w] = np.tile(c, reps)[:need]
        contexts[w] = np.tile(x, reps)[:need]
    shape = (n, num_batches, batch_size)
    return centers.reshape(shape), contexts.reshape(shape)
