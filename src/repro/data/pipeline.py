"""Per-worker sample streams — the paper's mapper/reducer, TPU-style.

In the paper a MapReduce mapper assigns each sentence to each of the
``n`` sub-corpora independently with probability ``r/100`` and ships it
to the matching reducer. Sampling with replacement is *stateless*, so on
a TPU pod we invert control: each worker draws its own sample directly
from the (shared, read-only) corpus with a deterministic PRNG stream —
``seed = hash(worker, epoch)`` for Shuffle, ``hash(worker)`` for fixed
RANDOM SAMPLING. No shuffle network phase exists at all.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Sequence

import numpy as np

from repro.data.corpus import Corpus
from repro.data.vocab import Vocab
from repro.data.pairs import extract_pairs
from repro.core.sampling import sample_sentence_indices

# Pair-extraction RNG streams. Domain-tagged SeedSequence tuples: the
# leading constant keeps this module's streams disjoint from every
# other module's numpy seeding (e.g. the driver's epoch streams), and
# the whole-epoch/per-block sub-tag keeps those two paths disjoint from
# each other — SeedSequence absorbs trailing zero words, so the naive
# (seed, worker, epoch) vs (seed, worker, epoch, 0) pair would collide.
# The old arithmetic seeds (seed*7919 + worker*104729 + epoch) aliased
# across distinct (seed, worker, epoch) outright.
_SEED_DOMAIN = 0x91BE       # pipeline pair extraction
_SUB_EPOCH, _SUB_BLOCK = 0, 1


def _extract_seed(seed: int, worker: int, epoch: int,
                  block: int | None = None) -> np.random.SeedSequence:
    if block is None:
        return np.random.SeedSequence(
            (_SEED_DOMAIN, _SUB_EPOCH, seed, worker, epoch))
    return np.random.SeedSequence(
        (_SEED_DOMAIN, _SUB_BLOCK, seed, worker, epoch, block))


@dataclass
class WorkerStream:
    """One sub-model's training stream for one epoch."""

    corpus: Corpus
    vocab: Vocab
    worker: int
    strategy: str           # 'equal' | 'random' | 'shuffle'
    rate: float             # sampling rate r in (0, 1]
    num_workers: int
    window: int = 10
    subsample_t: float | None = 1e-4
    seed: int = 0

    def sentence_indices(self, epoch: int) -> np.ndarray:
        """This worker's sentence sample for ``epoch`` — deterministic in
        ``(seed, worker, epoch)`` per the division strategy (EQUAL keeps
        a fixed contiguous slice, RANDOM a fixed with-replacement draw,
        SHUFFLE a fresh draw per epoch)."""
        return sample_sentence_indices(
            num_sentences=self.corpus.num_sentences,
            strategy=self.strategy,
            rate=self.rate,
            worker=self.worker,
            num_workers=self.num_workers,
            epoch=epoch,
            seed=self.seed,
        )

    def pairs(self, epoch: int, max_pairs: int | None = None):
        """All of this worker's ``(centers, contexts)`` pairs for
        ``epoch``, materialized in one pass (epoch-sized host memory —
        prefer :meth:`pair_blocks` / :class:`PairChunkStream` for large
        corpora). ``max_pairs`` truncates extraction early."""
        idx = self.sentence_indices(epoch)
        sub = self.corpus.select(idx)
        return extract_pairs(
            sub,
            self.vocab,
            window=self.window,
            subsample_t=self.subsample_t,
            seed=_extract_seed(self.seed, self.worker, epoch),
            max_pairs=max_pairs,
        )

    def pair_blocks(
        self, epoch: int, sentences_per_block: int = 1024
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Stream (centers, contexts) per sentence-block instead of
        materializing the whole epoch.

        Windows never cross sentence boundaries, so block-wise extraction
        yields the same pair *set* as :meth:`pairs` — only the RNG stream
        (subsampling, dynamic windows) and the shuffle scope (within a
        block rather than global) differ. Peak host memory is one block's
        pairs, independent of corpus size. Deterministic in
        (seed, worker, epoch, block).
        """
        idx = self.sentence_indices(epoch)
        for b, start in enumerate(range(0, len(idx), sentences_per_block)):
            sub = self.corpus.select(idx[start : start + sentences_per_block])
            c, x = extract_pairs(
                sub,
                self.vocab,
                window=self.window,
                subsample_t=self.subsample_t,
                seed=_extract_seed(self.seed, self.worker, epoch, block=b),
            )
            if len(c):
                yield c, x

    def count_pairs(self, epoch: int, sentences_per_block: int = 1024,
                    max_pairs: int | None = None) -> int:
        """Number of pairs the block stream yields for ``epoch``, counted
        block-by-block in O(block) memory (no epoch materialization).
        Stops early once ``max_pairs`` is reached — callers sizing a
        capped epoch don't pay for counting the tail."""
        total = 0
        for c, _ in self.pair_blocks(epoch, sentences_per_block):
            total += len(c)
            if max_pairs is not None and total >= max_pairs:
                break
        return total

    def batches(
        self, epoch: int, batch_size: int, max_pairs: int | None = None
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Full-batch slices of :meth:`pairs` (the materialized path;
        the trailing partial batch is dropped)."""
        centers, contexts = self.pairs(epoch, max_pairs=max_pairs)
        n = (len(centers) // batch_size) * batch_size
        for i in range(0, n, batch_size):
            yield centers[i : i + batch_size], contexts[i : i + batch_size]


def make_worker_streams(
    corpus: Corpus,
    vocab: Vocab,
    num_workers: int,
    strategy: str,
    rate: float | None = None,
    **kw,
) -> list[WorkerStream]:
    """One :class:`WorkerStream` per worker (ordered by worker id — the
    order :meth:`HostShardPlan.local_streams` validates against), all
    sharing the corpus/vocab and the division ``strategy``. ``rate``
    defaults to the paper's ``1/num_workers``; extra kwargs (``window``,
    ``subsample_t``, ``seed``) pass through to every stream."""
    rate = rate if rate is not None else 1.0 / num_workers
    return [
        WorkerStream(
            corpus=corpus,
            vocab=vocab,
            worker=w,
            strategy=strategy,
            rate=rate,
            num_workers=num_workers,
            **kw,
        )
        for w in range(num_workers)
    ]


@dataclass
class PairChunkStream:
    """Streaming, fixed-shape chunk producer for the async shard trainer.

    Replaces the materialize-everything path: instead of extracting one
    giant per-epoch pair array and ``np.tile``-ing it, each worker's
    epoch is consumed block-of-sentences at a time
    (:meth:`WorkerStream.pair_blocks`) and packed into
    ``(n_workers, steps_per_chunk, batch)`` buffers whose shape never
    changes — so the trainer compiles once and host memory stays
    O(n_workers · chunk + block), independent of corpus size.

    Workers whose epoch runs dry wrap around (the block stream is
    deterministic, so a wrap replays the same pairs — exactly the old
    ``np.tile`` semantics); sub-models stay perfectly load-balanced.
    """

    streams: list[WorkerStream]
    batch_size: int
    steps_per_chunk: int
    sentences_per_block: int = 1024

    @property
    def num_workers(self) -> int:
        """Number of worker streams feeding this chunk stream."""
        return len(self.streams)

    @property
    def chunk_pairs(self) -> int:
        """Pairs each worker contributes per chunk
        (``batch_size * steps_per_chunk``)."""
        return self.batch_size * self.steps_per_chunk

    def chunks(
        self, epoch: int, num_chunks: int | None = None,
        start_chunk: int = 0,
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """Yield (centers, contexts) arrays of shape
        (n_workers, steps_per_chunk, batch) for chunk indices
        ``[start_chunk, num_chunks)`` of this epoch's stream (infinite
        tail when ``num_chunks`` is ``None``).

        ``start_chunk`` is the elastic-resume fast-forward: the first
        ``start_chunk`` chunks are *extracted and discarded* through
        exactly the buffer-fill path a yielded chunk takes (same
        generators, same wrap-arounds, same slicing), so the yielded
        tail is bit-identical to the corresponding suffix of the
        uninterrupted ``chunks(epoch, num_chunks)`` stream — a resumed
        worker replays its stream exactly. The fast-forward costs pair
        *extraction* only (no output assembly, no device transfer);
        ``benchmarks/bench_elastic.py`` tracks that overhead.
        """
        if start_chunk < 0:
            raise ValueError(f"start_chunk must be >= 0, got {start_chunk}")
        if num_chunks is not None and start_chunk > num_chunks:
            raise ValueError(
                f"start_chunk {start_chunk} past the stream's "
                f"num_chunks {num_chunks}")
        n, need = self.num_workers, self.chunk_pairs
        gens = [s.pair_blocks(epoch, self.sentences_per_block)
                for s in self.streams]
        bufs: list[list[np.ndarray]] = [[] for _ in range(n)]
        xufs: list[list[np.ndarray]] = [[] for _ in range(n)]
        have = [0] * n
        pass_pairs = [0] * n   # pairs seen since this worker's last wrap

        def fill_and_cut(w: int, centers=None, contexts=None) -> None:
            # Advance worker w's buffers by exactly one chunk's worth of
            # pairs; write the chunk rows out only when asked. Skipped
            # (fast-forward) and yielded chunks share this path, which
            # is what makes the resume replay bit-exact.
            while have[w] < need:
                try:
                    c, x = next(gens[w])
                except StopIteration:
                    if pass_pairs[w] == 0:
                        raise ValueError(
                            f"worker {w} epoch {epoch}: empty sample")
                    pass_pairs[w] = 0
                    gens[w] = self.streams[w].pair_blocks(
                        epoch, self.sentences_per_block)
                    continue
                bufs[w].append(c)
                xufs[w].append(x)
                have[w] += len(c)
                pass_pairs[w] += len(c)
            flat_c = np.concatenate(bufs[w])
            flat_x = np.concatenate(xufs[w])
            if centers is not None:
                centers[w] = flat_c[:need]
                contexts[w] = flat_x[:need]
            bufs[w] = [flat_c[need:]]
            xufs[w] = [flat_x[need:]]
            have[w] -= need

        done = 0
        while done < start_chunk:
            for w in range(n):
                fill_and_cut(w)
            done += 1
        while num_chunks is None or done < num_chunks:
            centers = np.empty((n, need), dtype=np.int32)
            contexts = np.empty((n, need), dtype=np.int32)
            for w in range(n):
                fill_and_cut(w, centers, contexts)
            shape = (n, self.steps_per_chunk, self.batch_size)
            yield centers.reshape(shape), contexts.reshape(shape)
            done += 1


# ---------------------------------------------------------------------------
# Multi-host ingestion planning.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HostShardPlan:
    """Which workers' chunk streams THIS host extracts.

    The paper scales by partitioning the *input*, not the parameters:
    each worker's sample stream is a pure function of
    ``(seed, worker, epoch)``, so a host needs nothing but its worker
    ids to reproduce exactly the chunks a single-host run would have
    produced for those workers. The plan is therefore a pure value —
    no jax state, no communication — which is what lets tests simulate
    any ``process_count`` inside one process and assert bit-identity
    against the single-host stream.

    Workers are block-partitioned contiguously and as evenly as
    possible: host ``p`` owns ``[p·W//P, (p+1)·W//P)``. Contiguity
    matters — it matches jax's row-major device order for a 1-D
    ``worker`` mesh axis, so each host's extracted block is exactly the
    process-local shard :func:`jax.make_array_from_process_local_data`
    expects (see ``repro.launch.mesh.assemble_worker_array``).
    """

    process_index: int
    process_count: int
    num_workers: int

    def __post_init__(self):
        if self.process_count < 1:
            raise ValueError(f"process_count must be >= 1, got {self.process_count}")
        if not (0 <= self.process_index < self.process_count):
            raise ValueError(
                f"process_index {self.process_index} outside "
                f"[0, {self.process_count})")
        if self.num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {self.num_workers}")

    # -------------------------------------------------- worker ownership
    @property
    def start(self) -> int:
        """First global worker id this host owns (inclusive)."""
        return (self.process_index * self.num_workers) // self.process_count

    @property
    def stop(self) -> int:
        """One past the last global worker id this host owns."""
        return ((self.process_index + 1) * self.num_workers) // self.process_count

    @property
    def workers(self) -> range:
        """Global worker ids this host owns (possibly empty when there
        are more hosts than workers)."""
        return range(self.start, self.stop)

    @property
    def num_local(self) -> int:
        """How many workers this host owns."""
        return self.stop - self.start

    # ------------------------------------------------------ construction
    @classmethod
    def for_runtime(cls, num_workers: int, process_index: int | None = None,
                    process_count: int | None = None) -> "HostShardPlan":
        """Plan for the current jax runtime; either field can be pinned
        explicitly (that is the whole single-host simulation story)."""
        import jax

        if process_count is None:
            process_count = jax.process_count()
        if process_index is None:
            process_index = jax.process_index()
        return cls(process_index=process_index, process_count=process_count,
                   num_workers=num_workers)

    @classmethod
    def all_hosts(cls, process_count: int,
                  num_workers: int) -> list["HostShardPlan"]:
        """One plan per simulated host — the test harness's entry point."""
        return [cls(p, process_count, num_workers)
                for p in range(process_count)]

    # ------------------------------------------------------- local views
    def local_streams(self, streams: Sequence[WorkerStream]
                      ) -> list[WorkerStream]:
        """This host's slice of the global per-worker stream list."""
        if len(streams) != self.num_workers:
            raise ValueError(
                f"plan covers {self.num_workers} workers, got "
                f"{len(streams)} streams")
        for w, s in zip(self.workers, streams[self.start:self.stop]):
            if s.worker != w:
                raise ValueError(
                    f"stream at global position {w} claims worker "
                    f"{s.worker}; streams must be ordered by worker id")
        return list(streams[self.start:self.stop])

    def chunk_stream(self, streams: Sequence[WorkerStream], *,
                     batch_size: int, steps_per_chunk: int,
                     sentences_per_block: int = 1024) -> PairChunkStream:
        """The host-local :class:`PairChunkStream`: chunks of shape
        ``(num_local, steps_per_chunk, batch)`` whose worker-axis
        concatenation over all hosts is bit-identical to the single-host
        stream over the same ``streams``."""
        return PairChunkStream(
            self.local_streams(streams), batch_size=batch_size,
            steps_per_chunk=steps_per_chunk,
            sentences_per_block=sentences_per_block)

    # -------------------------------------------------------- validation
    def validate_for_mesh(self, mesh) -> None:
        """Check the plan can assemble global arrays on ``mesh``: a
        ``worker`` axis spanning exactly ``num_workers`` positions, and
        even per-process blocks (``make_array_from_process_local_data``
        requires equal-shaped process-local shards)."""
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if "worker" not in axis_sizes:
            raise ValueError(
                f"mesh has no 'worker' axis (axes: {mesh.axis_names})")
        if self.num_workers % axis_sizes["worker"] != 0:
            raise ValueError(
                f"num_workers={self.num_workers} not divisible by the "
                f"worker axis size {axis_sizes['worker']}")
        if self.num_workers % self.process_count != 0:
            raise ValueError(
                f"num_workers={self.num_workers} must divide evenly over "
                f"{self.process_count} processes for per-host block "
                f"sharding (got uneven blocks)")

    def describe(self) -> str:
        """One-line plan summary (the dryrun CLI's printout)."""
        return (f"host {self.process_index}/{self.process_count}: "
                f"workers [{self.start}, {self.stop}) "
                f"({self.num_local} of {self.num_workers})")


_SENTINEL = object()


def prefetch_chunks(iterator, depth: int = 2, to_device: bool = True):
    """Double-buffered prefetch: a background thread extracts the next
    chunk(s) and (optionally) dispatches the host→device transfer while
    the caller's device computation runs — jax dispatch is asynchronous,
    so ``jnp.asarray`` here starts the copy without blocking on it.

    ``depth`` bounds the queue, so at most ``depth`` chunks are ever
    resident beyond the one being consumed.

    Producer-thread lifecycle guarantees (regression-tested in
    ``tests/test_streaming.py``):

    * an exception anywhere in the producer (extraction or the device
      transfer) is delivered to the consumer and re-raised — including
      when the queue is full at the time it is raised;
    * abandoning the generator (``close()`` / ``break`` / consumer
      exception) releases and **joins** the producer thread — it never
      outlives the generator blocked on the bounded queue;
    * a producer thread that dies without delivering its sentinel or
      exception surfaces as ``RuntimeError`` instead of hanging the
      consumer's blocking ``get`` forever.
    """
    if depth < 1:
        raise ValueError("prefetch depth must be >= 1")
    # validation above is eager (plain function); the lazy generator
    # below owns the thread lifecycle
    return _prefetch_gen(iterator, depth, to_device)


def _prefetch_gen(iterator, depth: int, to_device: bool):
    import jax.numpy as jnp

    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def put(item) -> bool:
        # Bounded put that gives up when the consumer abandons the
        # generator — otherwise the thread would block forever holding
        # up to `depth` device-resident chunks.
        while not stop.is_set():
            try:
                q.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    def produce():
        try:
            for item in iterator:
                if to_device:
                    item = tuple(jnp.asarray(a) for a in item)
                if not put(item):
                    return
            put(_SENTINEL)
        except BaseException as e:  # surface extraction errors to the consumer
            put(e)

    thread = threading.Thread(target=produce, daemon=True,
                              name="prefetch_chunks")
    thread.start()
    try:
        while True:
            # Bounded get + liveness check: if the producer thread dies
            # without enqueuing its sentinel/exception (interpreter
            # teardown, thread killed), the consumer must error out, not
            # block forever on an empty queue.
            try:
                item = q.get(timeout=0.5)
            except queue.Empty:
                if not thread.is_alive():
                    raise RuntimeError(
                        "prefetch_chunks producer thread died without "
                        "delivering a chunk, sentinel or exception")
                continue
            if item is _SENTINEL:
                return
            if isinstance(item, BaseException):
                raise item
            yield item
    finally:
        stop.set()
        # Unblock a producer waiting on the full queue, then reap the
        # thread: at most one more item can land after the drain (put()
        # re-checks `stop` before each attempt), so join cannot block on
        # queue capacity.
        while True:
            try:
                q.get_nowait()
            except queue.Empty:
                break
        thread.join(timeout=5.0)


def stacked_pair_batches(
    streams: list[WorkerStream],
    epoch: int,
    batch_size: int,
    num_batches: int,
) -> tuple[np.ndarray, np.ndarray]:
    """(n_workers, num_batches, batch) arrays for the async shard trainer.

    Materialized view of :class:`PairChunkStream` — one chunk covering
    the whole request, so streamed and materialized consumers see
    byte-identical batches for the same seed.
    """
    stream = PairChunkStream(streams, batch_size=batch_size,
                             steps_per_chunk=num_batches)
    return next(stream.chunks(epoch, num_chunks=1))
