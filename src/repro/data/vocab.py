"""Vocabulary construction, mirroring word2vec / the paper's setup.

The paper: vocabulary filtered by frequency (300K cap for Hogwild and
Shuffle; min-count ``100/k`` for the k sub-models of the random-sampling
variants). We reproduce both policies.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.corpus import Corpus

UNK = -1  # tokens outside the vocab map to UNK and are dropped from pairs


@dataclass(frozen=True)
class Vocab:
    """Mapping from raw word ids to contiguous vocab ids [0, size)."""

    word_ids: np.ndarray    # (size,) raw word id per vocab slot, freq-sorted desc
    counts: np.ndarray      # (size,) occurrence counts
    lookup: np.ndarray      # (raw_vocab,) raw -> vocab id or UNK

    @property
    def size(self) -> int:
        return len(self.word_ids)

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def unigram_probs(self) -> np.ndarray:
        return self.counts / max(self.total, 1)

    def encode(self, raw_tokens: np.ndarray) -> np.ndarray:
        return self.lookup[raw_tokens]

    def contains_raw(self, raw: np.ndarray) -> np.ndarray:
        return self.lookup[raw] != UNK


def build_vocab(
    corpus: Corpus,
    raw_vocab_size: int,
    min_count: int = 1,
    max_size: int | None = None,
) -> Vocab:
    counts = np.bincount(corpus.tokens, minlength=raw_vocab_size).astype(np.int64)
    order = np.argsort(-counts, kind="stable")
    keep = counts[order] >= max(min_count, 1)
    order = order[keep]
    if max_size is not None:
        order = order[:max_size]
    lookup = np.full(raw_vocab_size, UNK, dtype=np.int32)
    lookup[order] = np.arange(len(order), dtype=np.int32)
    return Vocab(word_ids=order.astype(np.int32), counts=counts[order], lookup=lookup)


def union_vocab(vocabs: list[Vocab], raw_vocab_size: int) -> Vocab:
    """Union of sub-model vocabularies (the ALiR merge operates on this)."""
    counts = np.zeros(raw_vocab_size, dtype=np.int64)
    for v in vocabs:
        counts[v.word_ids] += v.counts
    order = np.argsort(-counts, kind="stable")
    order = order[counts[order] > 0]
    lookup = np.full(raw_vocab_size, UNK, dtype=np.int32)
    lookup[order] = np.arange(len(order), dtype=np.int32)
    return Vocab(word_ids=order.astype(np.int32), counts=counts[order], lookup=lookup)


def intersection_raw_ids(vocabs: list[Vocab]) -> np.ndarray:
    """Raw word ids present in every sub-model (Concat/PCA operate here)."""
    common = set(vocabs[0].word_ids.tolist())
    for v in vocabs[1:]:
        common &= set(v.word_ids.tolist())
    return np.array(sorted(common), dtype=np.int32)
