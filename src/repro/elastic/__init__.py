"""Elastic, preemption-tolerant training (ROADMAP: elasticity).

Deterministic mid-epoch checkpoint/resume (:mod:`~repro.elastic.cursor`,
:mod:`~repro.elastic.store`), seeded fault injection over the in-process
multi-host simulation (:mod:`~repro.elastic.faults`), per-worker elastic
training with work-stealing (:mod:`~repro.elastic.runner`), and — via
:class:`repro.core.merge.IncrementalAlirMerger`'s quorum/deadline mode —
merge-from-whatever-finished.
"""

from repro.elastic.cursor import WorkerCursor
from repro.elastic.faults import FaultEvent, FaultSchedule
from repro.elastic.runner import (
    ElasticRunner, SimulationResult, merge_finished, simulate_elastic,
    train_submodels_elastic)
from repro.elastic.store import WorkerStateStore

__all__ = [
    "WorkerCursor", "WorkerStateStore", "FaultEvent", "FaultSchedule",
    "ElasticRunner", "SimulationResult", "merge_finished",
    "simulate_elastic", "train_submodels_elastic",
]
