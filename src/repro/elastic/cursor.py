"""Worker cursors: the resume coordinate of an elastic worker.

A :class:`WorkerCursor` names the exact point in a worker's deterministic
work stream where training will continue: the epoch, the chunk index
within it, and the counter-PRNG/LR step offset of that chunk's first
step. Everything a worker consumes is a pure function of
``(seed, worker, epoch, chunk)`` — the pair chunks
(:meth:`repro.data.pipeline.PairChunkStream.chunks` with
``start_chunk=``), the per-chunk PRNG key
(:func:`repro.core.driver.worker_chunk_key`) and the LR/negative-draw
step counter (:meth:`repro.core.schedule.EpochSchedule.step0`) — so the
cursor plus the run configuration is *sufficient* state: a worker
resumed from it on any host replays the remainder of its stream
bit-exactly.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.schedule import EpochSchedule

_CURSOR_FIELDS = ("worker", "epoch", "chunk", "step0")


@dataclass(frozen=True)
class WorkerCursor:
    """Position of the NEXT chunk this worker will train.

    ``step0`` is redundant with ``(epoch, chunk)`` under a fixed
    :class:`EpochSchedule` — it is stored anyway and cross-checked on
    resume (:meth:`validate`), so a checkpoint written under a different
    schedule (corpus changed, step cap changed) fails loudly instead of
    silently training with a shifted LR/negative stream.
    """

    worker: int
    epoch: int
    chunk: int
    step0: int

    def __post_init__(self):
        for name in _CURSOR_FIELDS:
            v = getattr(self, name)
            if not isinstance(v, int) or v < 0:
                raise ValueError(f"cursor field {name!r} must be a "
                                 f"non-negative int, got {v!r}")

    # ------------------------------------------------------ construction
    @classmethod
    def start(cls, worker: int) -> "WorkerCursor":
        """Fresh worker: epoch 0, chunk 0, step 0."""
        return cls(worker=worker, epoch=0, chunk=0, step0=0)

    @classmethod
    def from_meta(cls, meta: dict) -> "WorkerCursor":
        """Inverse of :meth:`to_meta` (checkpoint manifest round-trip)."""
        return cls(**{k: int(meta[k]) for k in _CURSOR_FIELDS})

    def to_meta(self) -> dict:
        """JSON-safe dict stored as checkpoint-manifest metadata."""
        return {k: int(getattr(self, k)) for k in _CURSOR_FIELDS}

    # -------------------------------------------------------- progression
    def advanced(self, sched: EpochSchedule) -> "WorkerCursor":
        """Cursor after training the chunk this one points at, wrapping
        into the next epoch at the chunk horizon."""
        epoch, chunk = self.epoch, self.chunk + 1
        if chunk >= sched.num_chunks:
            epoch, chunk = epoch + 1, 0
        return WorkerCursor(worker=self.worker, epoch=epoch, chunk=chunk,
                            step0=epoch * sched.steps_per_epoch
                            + chunk * sched.chunk_steps)

    def done(self, epochs: int) -> bool:
        """True once every chunk of every epoch has been trained."""
        return self.epoch >= epochs

    # -------------------------------------------------------- validation
    def validate(self, sched: EpochSchedule) -> None:
        """Reject a cursor that does not belong to ``sched`` — the
        schedule-drift guard run on every resume."""
        if self.chunk >= sched.num_chunks:
            raise ValueError(
                f"cursor chunk {self.chunk} out of range for a "
                f"{sched.num_chunks}-chunk schedule")
        expect = sched.step0(self.epoch, self.chunk)
        if self.step0 != expect:
            raise ValueError(
                f"cursor step0={self.step0} disagrees with the schedule "
                f"({expect} for epoch={self.epoch}, chunk={self.chunk}); "
                "the checkpoint was written under a different schedule")

    def global_chunk_index(self, sched: EpochSchedule) -> int:
        """Flat chunk index across epochs under ``sched`` — the
        checkpoint-cadence anchor: tied to stream position, not to any
        host's execution history, so a resumed run checkpoints at the
        same boundaries the uninterrupted run would have."""
        return self.epoch * sched.num_chunks + self.chunk
