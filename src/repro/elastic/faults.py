"""Fault-injection schedules for the in-process multi-host simulation.

The elastic runner's simulation (:func:`repro.elastic.runner.simulate_elastic`)
advances in *ticks*: one tick = every live host trains one chunk for each
worker it owns. A :class:`FaultSchedule` is a list of :class:`FaultEvent`
applied at tick boundaries:

* ``kill``    — the host's process dies: all in-memory worker state is
  lost; its workers restart from their last store checkpoint (on the
  same host after a ``restart``, or on a survivor after work-stealing).
* ``restart`` — a previously killed host comes back empty-handed and
  reloads whatever the store has for the workers it still owns.
* ``delay``   — a straggler: the host executes nothing for ``duration``
  ticks (models preemption warnings, VM migration, slow NICs).

Schedules are either hand-written or drawn by :meth:`FaultSchedule.seeded`
from a domain-tagged ``np.random.SeedSequence`` — fully deterministic in
the seed, which is what makes the chaos matrix's bit-identity assertion
meaningful (the same schedule replays exactly). This module lives
outside ``core/``/``kernels/``, so the repo's RL003 lint (no unseeded or
wall-clock randomness in numeric code) does not apply — but the
generator obeys its spirit anyway: no ``default_rng()`` without a
SeedSequence, no wall-clock anywhere.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# Leading SeedSequence entropy word (see repro.core.driver._SEED_DOMAIN's
# convention): fault streams can never alias any other module's numpy
# streams, whatever the user seed.
_FAULT_DOMAIN = 0xFA17

_KINDS = ("kill", "restart", "delay")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` applied to ``host`` at ``tick``.
    ``duration`` (ticks) is meaningful for ``delay`` only."""

    kind: str
    host: int
    tick: int
    duration: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {_KINDS})")
        if self.host < 0 or self.tick < 0:
            raise ValueError("host and tick must be non-negative")
        if self.kind == "delay" and self.duration < 1:
            raise ValueError("delay events need duration >= 1")


@dataclass(frozen=True)
class FaultSchedule:
    """An ordered set of fault events, queried tick by tick."""

    events: tuple = field(default_factory=tuple)

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.tick, e.host))))

    def at(self, tick: int) -> list[FaultEvent]:
        """Events firing exactly at ``tick``."""
        return [e for e in self.events if e.tick == tick]

    @property
    def last_tick(self) -> int:
        """Tick of the final event (0 when empty) — after this, no more
        faults can change which workers are runnable."""
        return max((e.tick for e in self.events), default=0)

    def killed_hosts(self) -> set[int]:
        """Hosts that die at some point (restarted or not)."""
        return {e.host for e in self.events if e.kind == "kill"}

    # ------------------------------------------------------------ seeded
    @classmethod
    def seeded(cls, seed: int, *, hosts: int, horizon: int,
               kills: int = 1, restarts: int = 0, delays: int = 0,
               max_delay: int = 3) -> "FaultSchedule":
        """Draw a random-but-reproducible schedule.

        ``kills`` distinct hosts die at ticks in ``[1, horizon)``;
        ``restarts`` of them come back at a strictly later tick;
        ``delays`` independent straggler events hit random hosts for
        1..``max_delay`` ticks. Never kills host 0's entire fleet:
        at least one host always survives un-killed (a run with no
        possible survivor tests nothing).
        """
        if hosts < 1 or horizon < 2:
            raise ValueError("need hosts >= 1 and horizon >= 2")
        kills = min(kills, hosts - 1)  # leave one survivor
        restarts = min(restarts, kills)
        rng = np.random.default_rng(
            np.random.SeedSequence((_FAULT_DOMAIN, seed, hosts, horizon)))
        events = []
        victims = rng.choice(hosts, size=kills, replace=False) if kills else []
        kill_ticks = {}
        for h in victims:
            t = int(rng.integers(1, horizon))
            kill_ticks[int(h)] = t
            events.append(FaultEvent("kill", int(h), t))
        for h in list(kill_ticks)[:restarts]:
            events.append(FaultEvent(
                "restart", h, kill_ticks[h] + int(rng.integers(1, 3))))
        for _ in range(delays):
            events.append(FaultEvent(
                "delay", int(rng.integers(0, hosts)),
                int(rng.integers(1, horizon)),
                duration=int(rng.integers(1, max_delay + 1))))
        return cls(events=tuple(events))
