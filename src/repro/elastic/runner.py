"""Elastic per-worker training: checkpoint/resume, fault simulation,
work-stealing, and merge-from-whatever-finished.

The paper's training phase has no cross-worker synchronization, so a
preempted worker should cost nothing beyond its own lost progress. This
module exploits that: every worker trains through its own un-vmapped
:meth:`~repro.core.async_trainer.AsyncShardTrainer.worker_epoch` jit,
with its pair chunks, PRNG keys and LR step counter all derived from a
:class:`~repro.elastic.cursor.WorkerCursor` — so a worker killed at any
chunk boundary and resumed anywhere (same host, restarted host, or a
survivor that stole it) replays the identical step sequence and lands on
bit-identical tables. That per-worker determinism is the whole
elasticity story; the fault simulation
(:func:`simulate_elastic`) exists to *prove* it under seeded
kill/restart/delay/steal schedules.

Note the equivalence baseline: vmapped (stacked) and un-vmapped
executions of the same program are not guaranteed bit-identical, so the
chaos matrix compares faulted elastic runs against the *uninterrupted
elastic run* (:meth:`ElasticRunner.run_all`), not against
:func:`repro.core.driver.train_submodels`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import sgns
from repro.core.async_trainer import AsyncShardTrainer
from repro.core.driver import (
    PipelineResult, TrainingSetup, prepare_training, worker_chunk_key)
from repro.core.merge import MergeConfig, MergeResult, Merger, StackedModels, get_merger
from repro.data.pipeline import HostShardPlan, PairChunkStream
from repro.elastic.cursor import WorkerCursor
from repro.elastic.faults import FaultSchedule
from repro.elastic.store import WorkerStateStore


# ---------------------------------------------------------------------------
class ElasticRunner:
    """Trains one worker at a time from a cursor, checkpointing through
    a :class:`WorkerStateStore`.

    ``ckpt_every`` is the checkpoint cadence in chunks, anchored to the
    worker's *global chunk index* (stream position), not to how many
    chunks this particular process happened to train — so interrupted
    and uninterrupted runs write checkpoints at identical boundaries.
    Epoch boundaries and worker completion always checkpoint.
    """

    def __init__(self, setup: TrainingSetup,
                 store: WorkerStateStore | None = None, *,
                 ckpt_every: int = 1):
        if ckpt_every < 1:
            raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
        self.setup = setup
        self.store = store
        self.ckpt_every = ckpt_every
        self.num_workers = len(setup.streams)
        self.trainer = AsyncShardTrainer(
            cfg=setup.cfg, num_workers=self.num_workers,
            total_steps=setup.sched.total_steps, engine=setup.engine)
        self._neg_cache: dict[int, object] = {}
        # per-(worker, epoch) chunk-loss arrays trained by THIS process
        # (a resumed process only sees the tail it trained).
        self.chunk_losses: dict[tuple[int, int], list] = {}

    # ------------------------------------------------------------ pieces
    def init_params(self, worker: int) -> dict:
        """Worker ``worker``'s initial tables. Derived from the same
        split the stacked trainer uses, but applied un-vmapped — a pure
        function of (cfg.seed, worker), independent of which host calls
        it or how many peers exist."""
        keys = jax.random.split(jax.random.PRNGKey(self.setup.cfg.seed),
                                self.num_workers)
        return sgns.init_params(keys[worker], self.setup.cfg)

    def load_worker(self, worker: int, *, resume: bool = True
                    ) -> tuple[dict, WorkerCursor]:
        """(params, cursor) to continue from: the store's last complete
        checkpoint when ``resume`` and one exists, else a fresh start.
        The stored cursor is schedule-validated — a checkpoint from a
        different corpus/step-cap fails loudly here."""
        if resume and self.store is not None:
            state = self.store.load(worker)
            if state is not None:
                params, cursor, _ = state
                cursor.validate(self.setup.sched)
                return ({k: jnp.asarray(v) for k, v in params.items()},
                        cursor)
        return self.init_params(worker), WorkerCursor.start(worker)

    def chunk_iter(self, worker: int, cursor: WorkerCursor):
        """The worker's chunk stream for ``cursor.epoch``, fast-forwarded
        to ``cursor.chunk`` — bit-exact suffix of the uninterrupted
        stream (``PairChunkStream.chunks(start_chunk=)``)."""
        s = self.setup
        stream = PairChunkStream(
            [s.streams[worker]], batch_size=s.batch_size,
            steps_per_chunk=s.sched.chunk_steps,
            sentences_per_block=s.sentences_per_block)
        return stream.chunks(cursor.epoch, s.sched.num_chunks,
                             start_chunk=cursor.chunk)

    def _neg_table(self, worker: int):
        if worker not in self._neg_cache:
            self._neg_cache[worker] = jax.tree.map(
                lambda a: jnp.asarray(np.asarray(a)[worker]),
                self.setup.neg_table)
        return self._neg_cache[worker]

    def train_chunk(self, params: dict, cursor: WorkerCursor, chunk):
        """One chunk of one worker, keyed exactly as the stacked epoch
        would have keyed it (:func:`worker_chunk_key`)."""
        centers, contexts = chunk          # (1, S, B) host buffers
        key = worker_chunk_key(self.setup.seed, cursor.epoch, cursor.chunk,
                               self.num_workers, cursor.worker)
        params, losses = self.trainer.worker_epoch(
            params, jnp.asarray(centers[0]), jnp.asarray(contexts[0]),
            self._neg_table(cursor.worker), key, step0=cursor.step0)
        self.chunk_losses.setdefault(
            (cursor.worker, cursor.epoch), []).append(losses)
        return params

    def _maybe_save(self, params: dict, cursor: WorkerCursor,
                    *, done: bool) -> None:
        if self.store is None:
            return
        sched = self.setup.sched
        at_cadence = cursor.global_chunk_index(sched) % self.ckpt_every == 0
        at_epoch = cursor.chunk == 0            # just wrapped an epoch
        if done or at_cadence or at_epoch:
            self.store.save(cursor, {k: np.asarray(v)
                                     for k, v in params.items()})

    # -------------------------------------------------------- full runs
    def run_worker(self, worker: int, *, resume: bool = True) -> dict:
        """Train ``worker`` from its cursor to the end of the last epoch;
        returns its final params (host numpy)."""
        params, cursor = self.load_worker(worker, resume=resume)
        it = None
        while not cursor.done(self.setup.epochs):
            if it is None:
                it = self.chunk_iter(worker, cursor)
            params = self.train_chunk(params, cursor, next(it))
            cursor = cursor.advanced(self.setup.sched)
            if cursor.chunk == 0:
                it = None                       # next epoch: new stream
            self._maybe_save(params, cursor,
                             done=cursor.done(self.setup.epochs))
        return {k: np.asarray(v) for k, v in params.items()}

    def run_all(self, *, resume: bool = True) -> dict[int, dict]:
        """Every worker, sequentially, no faults — the uninterrupted
        elastic baseline the chaos matrix compares against."""
        return {w: self.run_worker(w, resume=resume)
                for w in range(self.num_workers)}

    def epoch_losses(self) -> list[float]:
        """Mean loss per epoch over every chunk this process trained
        (partial on resumed runs — only the replayed tail is visible)."""
        out = []
        for epoch in range(self.setup.epochs):
            arrs = [np.asarray(v)
                    for (w, e), vs in self.chunk_losses.items()
                    if e == epoch for v in vs]
            out.append(float(np.mean(np.concatenate(
                [a.ravel() for a in arrs]))) if arrs else float("nan"))
        return out


# ---------------------------------------------------------------------------
# In-process multi-host fault simulation.
# ---------------------------------------------------------------------------
@dataclass
class _LiveWorker:
    params: dict
    cursor: WorkerCursor
    it: object = None


@dataclass
class _Host:
    plan: HostShardPlan
    alive: bool = True
    dead_since: int | None = None
    delay_until: int = 0
    live: dict = field(default_factory=dict)    # worker -> _LiveWorker


@dataclass
class SimulationResult:
    """What the cluster produced: final tables per finished worker, when
    each finished (tick), which never did, and how long the run took."""

    params: dict                 # worker -> {"W": ..., "C": ...} (numpy)
    finished_tick: dict          # worker -> tick index
    unfinished: list             # workers with no complete training
    ticks: int
    stolen: dict                 # worker -> (from_host, to_host)

    @property
    def finished(self) -> list:
        return sorted(self.params)


def simulate_elastic(
    runner: ElasticRunner,
    process_count: int,
    faults: FaultSchedule | None = None,
    *,
    steal_after: int | None = None,
    max_ticks: int = 10_000,
) -> SimulationResult:
    """Drive ``process_count`` simulated hosts over
    :meth:`HostShardPlan.all_hosts` under a fault schedule.

    Time advances in ticks: each tick, every live, un-delayed host
    trains one chunk for each unfinished worker it owns, checkpointing
    per the runner's cadence. Faults apply at tick boundaries (see
    :mod:`repro.elastic.faults`). When ``steal_after`` is set, a host
    dead for that many ticks has its unfinished workers re-assigned
    round-robin to the live hosts (the re-planned ownership map — a
    restarted victim does NOT get stolen workers back, so no worker is
    ever trained twice concurrently); the thief resumes each stolen
    worker from its last store checkpoint.

    Requires the runner to have a store — resume is the whole mechanism.
    """
    if runner.store is None:
        raise ValueError("simulate_elastic needs a runner with a store")
    faults = faults or FaultSchedule()
    epochs = runner.setup.epochs
    sched = runner.setup.sched
    num_workers = runner.num_workers
    hosts = [_Host(plan=p) for p in
             HostShardPlan.all_hosts(process_count, num_workers)]
    owners = {w: hi for hi, h in enumerate(hosts)
              for w in range(h.plan.start, h.plan.stop)}
    finished: dict[int, dict] = {}
    finished_tick: dict[int, int] = {}
    stolen: dict[int, tuple] = {}

    def unfinished_owned(hi: int) -> list[int]:
        return [w for w in sorted(owners)
                if owners[w] == hi and w not in finished]

    tick = 0
    while tick < max_ticks and len(finished) < num_workers:
        # -- faults fire at the tick boundary
        for e in faults.at(tick):
            if e.host >= len(hosts):
                continue
            h = hosts[e.host]
            if e.kind == "kill":
                h.alive, h.dead_since = False, tick
                h.live.clear()                 # in-memory state is gone
            elif e.kind == "restart":
                h.alive, h.dead_since = True, None
            elif e.kind == "delay":
                h.delay_until = max(h.delay_until, tick + e.duration)

        # -- straggler detection → work-stealing
        if steal_after is not None:
            live_ids = [i for i, h in enumerate(hosts) if h.alive]
            for hi, h in enumerate(hosts):
                if (h.alive or h.dead_since is None
                        or tick - h.dead_since < steal_after or not live_ids):
                    continue
                for i, w in enumerate(unfinished_owned(hi)):
                    to = live_ids[i % len(live_ids)]
                    owners[w] = to
                    stolen[w] = (hi, to)

        # -- one chunk of work per live host per owned worker
        progressed = False
        for hi, h in enumerate(hosts):
            if not h.alive or tick < h.delay_until:
                continue
            for w in unfinished_owned(hi):
                lw = h.live.get(w)
                if lw is None:
                    params, cursor = runner.load_worker(w, resume=True)
                    if cursor.done(epochs):
                        finished[w] = {k: np.asarray(v)
                                       for k, v in params.items()}
                        finished_tick.setdefault(w, tick)
                        continue
                    lw = h.live[w] = _LiveWorker(params, cursor)
                if lw.it is None:
                    lw.it = runner.chunk_iter(w, lw.cursor)
                lw.params = runner.train_chunk(lw.params, lw.cursor,
                                               next(lw.it))
                lw.cursor = lw.cursor.advanced(sched)
                if lw.cursor.chunk == 0:
                    lw.it = None
                done = lw.cursor.done(epochs)
                runner._maybe_save(lw.params, lw.cursor, done=done)
                if done:
                    finished[w] = {k: np.asarray(v)
                                   for k, v in lw.params.items()}
                    finished_tick[w] = tick
                    del h.live[w]
                progressed = True
        tick += 1

        if progressed or len(finished) == num_workers:
            continue
        # -- nothing ran this tick: stop unless something can still
        #    unblock us (a future fault event, a pending steal window,
        #    or a delayed host waking up).
        if tick <= faults.last_tick:
            continue
        delayed_wake = any(
            h.alive and h.delay_until > tick and unfinished_owned(hi)
            for hi, h in enumerate(hosts))
        steal_pending = (
            steal_after is not None
            and any(h.alive for h in hosts)
            and any(not h.alive and unfinished_owned(hi)
                    for hi, h in enumerate(hosts)))
        if not (delayed_wake or steal_pending):
            break

    return SimulationResult(
        params=finished, finished_tick=finished_tick,
        unfinished=sorted(set(range(num_workers)) - set(finished)),
        ticks=tick, stolen=stolen)


def merge_finished(
    sim: SimulationResult,
    mask,
    *,
    merger: Merger | str = "alir",
    config: MergeConfig | None = None,
    require_quorum: bool = True,
    **overrides,
) -> MergeResult:
    """Merge-from-whatever-finished through the unified Merger registry:
    feed the simulation's finished workers into any registered merger
    (``"alir"``, the ``"alir_tree"`` reduction tree, ...) **in
    finished-tick order** — the realistic arrival stream — and return
    the canonical :meth:`~repro.core.merge.Merger.final` fold.

    Every registry merger restacks in canonical worker order before
    solving, so the result is independent of the arrival (finish)
    order; ``quorum``/``deadline`` dials (via ``config`` or keyword
    ``overrides``) apply exactly as documented on
    :class:`~repro.core.merge.MergeConfig` — a preempted cluster that
    finished fewer than ``quorum`` workers raises instead of silently
    publishing a thin consensus.

    Args:
        sim: a :func:`simulate_elastic` result (or anything with
            ``params``/``finished_tick``).
        mask: ``(num_workers, V)`` per-worker presence
            (``TrainingSetup.mask``).
        merger: registry name or pre-built :class:`Merger`.
        config / overrides: :class:`MergeConfig` dials
            (``get_merger(merger, config, **overrides)``).
        require_quorum: forwarded to :meth:`Merger.final`.
    """
    m = get_merger(merger, config, **overrides) if isinstance(merger, str) \
        else merger
    mask = np.asarray(mask)
    order = sorted(sim.params,
                   key=lambda w: (sim.finished_tick.get(w, 0), w))
    for w in order:
        m.add(int(w), np.asarray(sim.params[w]["W"]), mask[int(w)],
              fold=False)
    return m.final(require_quorum=require_quorum)


# ---------------------------------------------------------------------------
# High-level entry: the elastic counterpart of driver.train_submodels.
# ---------------------------------------------------------------------------
def train_submodels_elastic(
    corpus,
    raw_vocab_size: int,
    strategy: str,
    num_workers: int,
    cfg,
    *,
    state_dir: str,
    resume: bool = True,
    ckpt_every: int = 1,
    epochs: int = 3,
    batch_size: int = 512,
    rate: float | None = None,
    window: int | None = None,
    subsample_t: float | None = 1e-4,
    max_vocab: int | None = 300_000,
    base_min_count: int = 100,
    seed: int = 0,
    max_steps_per_epoch: int | None = None,
    engine="sparse",
    steps_per_chunk: int = 128,
    sentences_per_block: int = 1024,
) -> PipelineResult:
    """Preemption-tolerant :func:`~repro.core.driver.train_submodels`:
    workers train one at a time through the single-worker jit,
    checkpointing ``(params, cursor)`` to ``state_dir`` every
    ``ckpt_every`` chunks. Re-running the same command after a kill
    resumes every worker from its last checkpoint and produces tables
    bit-identical to the uninterrupted elastic run. Single-process by
    design (the launcher's multi-host path is the stacked trainer);
    multi-host elasticity is exercised by :func:`simulate_elastic`.
    """
    setup = prepare_training(
        corpus, raw_vocab_size, strategy, num_workers, cfg,
        epochs=epochs, batch_size=batch_size, rate=rate, window=window,
        subsample_t=subsample_t, max_vocab=max_vocab,
        base_min_count=base_min_count, seed=seed,
        max_steps_per_epoch=max_steps_per_epoch, engine=engine,
        steps_per_chunk=steps_per_chunk,
        sentences_per_block=sentences_per_block,
        process_index=0, process_count=1)
    store = WorkerStateStore(state_dir)
    runner = ElasticRunner(setup, store, ckpt_every=ckpt_every)

    t0 = time.perf_counter()
    by_worker = runner.run_all(resume=resume)
    t_train = time.perf_counter() - t0

    W = np.stack([by_worker[w]["W"] for w in range(num_workers)])
    stacked = StackedModels(models=jnp.asarray(W),
                            mask=jnp.asarray(setup.mask))
    return PipelineResult(
        strategy=strategy, num_workers=num_workers,
        union_vocab=setup.union_vocab, stacked=stacked,
        timings={"vocab_s": setup.vocab_s, "train_s": t_train,
                 "steps_per_epoch": setup.sched.steps_per_epoch},
        losses=runner.epoch_losses())
