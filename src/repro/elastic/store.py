"""Disk-backed per-worker checkpoint store for elastic training.

A thin, typed layer over :mod:`repro.checkpoint.io`'s worker-state
publisher: each worker owns its own versioned artifact directory
(``worker_0000/``, ``worker_0001/``, …) under one state root, so any
number of workers checkpoint concurrently without sharing a manifest
writer, and the atomic publish-then-manifest ordering makes a kill at
any instant leave the previous complete ``(params, cursor)`` pair
loadable — never a torn one. In production the root is a shared
filesystem (NFS/GCS-fuse); in the fault-injection simulation it is a
tmpdir shared by the in-process "hosts", which models the same thing.
"""

from __future__ import annotations

import numpy as np

from repro.checkpoint.io import (
    gc_orphans, load_worker_state, publish_worker_state, worker_state_dir)
from repro.elastic.cursor import WorkerCursor


class WorkerStateStore:
    """Atomic, versioned ``(params, cursor)`` checkpoints per worker."""

    def __init__(self, state_dir: str):
        self.state_dir = str(state_dir)

    # ------------------------------------------------------------ writes
    def save(self, cursor: WorkerCursor, params: dict) -> int:
        """Checkpoint one worker; returns the new state version. The
        cursor names the NEXT chunk to train, so saving after chunk k
        stores ``chunk=k+1`` (or the next epoch's chunk 0)."""
        arrays = {k: np.asarray(v) for k, v in params.items()}
        return publish_worker_state(self.state_dir, cursor.worker,
                                    arrays, cursor.to_meta())

    # ------------------------------------------------------------- reads
    def load(self, worker: int) -> tuple[dict, WorkerCursor, int] | None:
        """Last complete checkpoint of ``worker`` as
        ``(params, cursor, version)``, or ``None`` on a fresh start."""
        state = load_worker_state(self.state_dir, worker)
        if state is None:
            return None
        params, cursor_meta, version = state
        return params, WorkerCursor.from_meta(cursor_meta), version

    def cursor(self, worker: int) -> WorkerCursor | None:
        """Just the cursor (straggler detection / progress probes read
        this without pulling table shards off disk)."""
        state = self.load(worker)
        return None if state is None else state[1]

    def finished_workers(self, num_workers: int, epochs: int) -> list[int]:
        """Workers whose stored cursor says every epoch is trained —
        the merge phase's arrival set."""
        out = []
        for w in range(num_workers):
            cur = self.cursor(w)
            if cur is not None and cur.done(epochs):
                out.append(w)
        return out

    # --------------------------------------------------------------- gc
    def gc(self, num_workers: int) -> list[str]:
        """Sweep crash debris (:func:`repro.checkpoint.io.gc_orphans`)
        from every worker directory; returns removed file names."""
        removed = []
        for w in range(num_workers):
            removed.extend(gc_orphans(worker_state_dir(self.state_dir, w)))
        return removed
