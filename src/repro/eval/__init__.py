from repro.eval.benchmarks import (
    spearman,
    evaluate_similarity,
    evaluate_analogy,
    evaluate_categorization,
    evaluate_all,
    BenchmarkSuite,
)

__all__ = [
    "spearman",
    "evaluate_similarity",
    "evaluate_analogy",
    "evaluate_categorization",
    "evaluate_all",
    "BenchmarkSuite",
]
