"""Embedding evaluation — the paper's three task families.

* similarity    — Spearman's ρ between model cosine and gold similarity
                  (stand-ins for MEN/RG65/RareWords/WS353);
* analogy       — 3CosAdd accuracy on a:b :: c:? quadruples
                  (stand-ins for Google/SemEval);
* categorization— cluster purity of k-means on the embeddings against
                  gold topic labels (stand-ins for AP/Battig).

Gold data comes from the synthetic corpus generator's latent geometry
(see data/corpus.py). OOV handling follows the paper: benchmark items
containing a word missing from the merged model are dropped, and the
count of such words is reported alongside each score.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.corpus import SemanticCorpusModel
from repro.data.vocab import Vocab, UNK


# ---------------------------------------------------------------------------
def spearman(x: np.ndarray, y: np.ndarray) -> float:
    """Spearman's rank correlation (scipy-free, average ranks for ties)."""
    def ranks(a):
        order = np.argsort(a, kind="stable")
        r = np.empty(len(a), dtype=np.float64)
        r[order] = np.arange(len(a), dtype=np.float64)
        # average tied ranks
        vals, inv, cnt = np.unique(a, return_inverse=True, return_counts=True)
        sums = np.zeros(len(vals))
        np.add.at(sums, inv, r)
        return sums[inv] / cnt[inv]

    rx, ry = ranks(x), ranks(y)
    rx -= rx.mean()
    ry -= ry.mean()
    den = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / den) if den > 0 else 0.0


def _normalize(emb: np.ndarray) -> np.ndarray:
    return emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)


# ---------------------------------------------------------------------------
@dataclass
class BenchmarkSuite:
    """Gold data in *raw word id* space, evaluated against any vocab/emb."""

    sim_a: np.ndarray
    sim_b: np.ndarray
    sim_gold: np.ndarray
    quads: np.ndarray           # (Q, 4) raw ids
    cat_words: np.ndarray
    cat_labels: np.ndarray

    @staticmethod
    def from_model(gen: SemanticCorpusModel, seed: int = 7,
                   n_pairs: int = 400, n_quads: int = 200, n_cat: int = 300,
                   top_words: int | None = None) -> "BenchmarkSuite":
        a, b, g = gen.similarity_benchmark(n_pairs, seed=seed, top_words=top_words)
        q = gen.analogy_benchmark(n_quads, seed=seed + 1, top_words=top_words)
        w, l = gen.categorization_benchmark(n_cat, seed=seed + 2, top_words=top_words)
        return BenchmarkSuite(a, b, g, q, w, l)


def evaluate_similarity(emb: np.ndarray, valid: np.ndarray, vocab: Vocab,
                        suite: BenchmarkSuite) -> tuple[float, int]:
    ia, ib = vocab.encode(suite.sim_a), vocab.encode(suite.sim_b)
    ok = (ia != UNK) & (ib != UNK)
    ok &= valid[np.clip(ia, 0, None)] & valid[np.clip(ib, 0, None)]
    oov = int((~ok).sum())
    if ok.sum() < 5:
        return 0.0, oov
    e = _normalize(emb)
    sims = (e[ia[ok]] * e[ib[ok]]).sum(-1)
    return spearman(sims, suite.sim_gold[ok]), oov


def evaluate_analogy(emb: np.ndarray, valid: np.ndarray, vocab: Vocab,
                     suite: BenchmarkSuite, candidates: int | None = 2000
                     ) -> tuple[float, int]:
    """3CosAdd: argmax_d cos(d, b - a + c), excluding a, b, c."""
    q = vocab.encode(suite.quads.reshape(-1)).reshape(-1, 4)
    ok = np.all(q != UNK, axis=1)
    ok &= np.all(valid[np.clip(q, 0, None)], axis=1)
    oov = int((~ok).sum())
    q = q[ok]
    if len(q) == 0:
        return 0.0, oov
    e = _normalize(emb)
    # candidate set: most-frequent slice keeps eval O(Q · C)
    C = min(candidates or len(e), len(e))
    cand = np.arange(C)
    target = _normalize(e[q[:, 1]] - e[q[:, 0]] + e[q[:, 2]])
    scores = target @ e[cand].T                     # (Q, C)
    for col in range(3):
        inside = q[:, col] < C
        scores[np.arange(len(q))[inside], q[inside, col]] = -np.inf
    pred = cand[np.argmax(scores, axis=1)]
    return float((pred == q[:, 3]).mean()), oov


def _kmeans(x: np.ndarray, k: int, iters: int = 50, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = x[rng.choice(len(x), size=k, replace=False)]
    assign = np.zeros(len(x), dtype=np.int64)
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
        new_assign = d.argmin(1)
        if (new_assign == assign).all():
            break
        assign = new_assign
        for j in range(k):
            m = assign == j
            if m.any():
                centers[j] = x[m].mean(0)
    return assign


def evaluate_categorization(emb: np.ndarray, valid: np.ndarray, vocab: Vocab,
                            suite: BenchmarkSuite) -> tuple[float, int]:
    ids = vocab.encode(suite.cat_words)
    ok = (ids != UNK) & valid[np.clip(ids, 0, None)]
    oov = int((~ok).sum())
    if ok.sum() < 10:
        return 0.0, oov
    x = _normalize(emb)[ids[ok]]
    labels = suite.cat_labels[ok]
    k = len(np.unique(labels))
    assign = _kmeans(x, k)
    purity = 0.0
    for j in range(k):
        m = assign == j
        if m.any():
            _, cnt = np.unique(labels[m], return_counts=True)
            purity += cnt.max()
    return float(purity / ok.sum()), oov


def evaluate_all(emb: np.ndarray, valid: np.ndarray, vocab: Vocab,
                 suite: BenchmarkSuite) -> dict:
    emb = np.asarray(emb)
    valid = np.asarray(valid).astype(bool)
    sim, sim_oov = evaluate_similarity(emb, valid, vocab, suite)
    ana, ana_oov = evaluate_analogy(emb, valid, vocab, suite)
    cat, cat_oov = evaluate_categorization(emb, valid, vocab, suite)
    return {
        "similarity": sim, "similarity_oov": sim_oov,
        "analogy": ana, "analogy_oov": ana_oov,
        "categorization": cat, "categorization_oov": cat_oov,
    }
