"""Pallas TPU kernels for the performance-critical compute layers.

* ``sgns_update`` — fused SGNS forward+backward on gathered rows
  (pl.pallas_call + BlockSpec VMEM tiling); ``ops`` holds the jit'd
  wrappers (padding, gather/scatter); ``ref`` the pure-jnp oracles.
  Powers the ``pallas`` update engine.
* ``sgns_fused`` — the whole SGNS step in one kernel: in-kernel alias
  negative sampling (counter-based PRNG), forward, row grads and
  scatter-add apply in a single VMEM pass. Powers the ``pallas_fused``
  update engine.
* ``swa_decode`` — flash-style single-token sliding-window decode
  attention (online softmax, VMEM scratch accumulators) — the hot op of
  the long_500k shape for dense archs.

Kernels are validated in ``interpret=True`` mode on CPU (the kernel body
runs in Python) and target TPU Mosaic unchanged.
"""

from repro.kernels.ops import (
    sgns_row_grads,
    sgns_apply_step,
    make_row_grad_fn,
)
from repro.kernels.sgns_fused import (
    sgns_fused_step,
    sample_negatives_fused,
    fused_negative_ids,
    counter_uniforms,
)
from repro.kernels.ref import sgns_row_grads_ref, swa_decode_ref
from repro.kernels.swa_decode import swa_decode_kernel

__all__ = [
    "sgns_row_grads",
    "sgns_apply_step",
    "make_row_grad_fn",
    "sgns_fused_step",
    "sample_negatives_fused",
    "fused_negative_ids",
    "counter_uniforms",
    "sgns_row_grads_ref",
    "swa_decode_ref",
    "swa_decode_kernel",
]
