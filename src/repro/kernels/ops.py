"""jit'd wrappers around the Pallas kernels — padding, gather/scatter.

``sgns_row_grads`` is a drop-in for
:func:`repro.core.sgns.sparse_row_grads`; the ``pallas`` update engine
(``repro.core.engine``) routes the sparse step's row gradients through
it. On CPU we run the kernel in interpret mode; on TPU the same code
compiles to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sgns_update import sgns_row_grads_kernel, _pick_block_b
from repro.kernels import ref


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def sgns_row_grads(
    w: jax.Array,
    c_pos: jax.Array,
    c_neg: jax.Array,
    *,
    interpret: bool = True,
    block_b: int | None = None,
):
    """Kernel-backed row grads with automatic lane/batch padding.

    Returns (mean_loss, dW (B,D), dC_pos (B,D), dC_neg (B,K,D)) — the
    same contract as ``sgns.sparse_row_grads`` (sum-loss gradients,
    mean loss for reporting).
    """
    B, D = w.shape
    K = c_neg.shape[1]
    Dp = _round_up(D, 128)
    # The wrapper pads B up to a block multiple, so ask the picker for a
    # block sized to the next pow2 ≥ B (divisibility comes from padding,
    # not from shrinking the block).
    bt = block_b or _pick_block_b(1 << (max(B, 8) - 1).bit_length(), K, Dp)
    Bp = _round_up(max(B, bt), bt)

    pad2 = lambda a: jnp.pad(a, ((0, Bp - B), (0, Dp - D)))
    pad3 = lambda a: jnp.pad(a, ((0, Bp - B), (0, 0), (0, Dp - D)))
    loss, dw, dcp, dcn = sgns_row_grads_kernel(
        pad2(w), pad2(c_pos), pad3(c_neg), block_b=bt, interpret=interpret)
    mean_loss = jnp.sum(loss[:B]) / B
    return mean_loss, dw[:B, :D], dcp[:B, :D], dcn[:B, :, :D]


def make_row_grad_fn(interpret: bool = True, block_b: int | None = None):
    """row_grad_fn for AsyncShardTrainer / train_step_sparse."""

    def fn(w, c_pos, c_neg):
        return sgns_row_grads(w, c_pos, c_neg, interpret=interpret,
                              block_b=block_b)

    return fn


@functools.partial(jax.jit, static_argnames=("interpret",))
def sgns_apply_step(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    negatives: jax.Array,
    lr: jax.Array,
    interpret: bool = True,
):
    """Full fused step: gather → kernel → scatter-add (the production path)."""
    w = params["W"][centers]
    c_pos = params["C"][contexts]
    c_neg = params["C"][negatives]
    loss, d_w, d_cp, d_cn = sgns_row_grads(w, c_pos, c_neg, interpret=interpret)
    W = params["W"].at[centers].add(-lr * d_w)
    C = params["C"].at[contexts].add(-lr * d_cp)
    C = C.at[negatives.reshape(-1)].add(-lr * d_cn.reshape(-1, d_cn.shape[-1]))
    return {"W": W, "C": C}, loss


# Re-export oracles so tests can ask one module for both sides.
sgns_row_grads_ref = ref.sgns_row_grads_ref
