"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sgns_row_grads_ref(
    w: jax.Array, c_pos: jax.Array, c_neg: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused SGNS forward+backward on gathered rows (sum-loss semantics).

    w (B, D), c_pos (B, D), c_neg (B, K, D)  →
    (per_pair_loss (B,), dW (B, D), dC_pos (B, D), dC_neg (B, K, D)).

    Computed in f32 regardless of input dtype; outputs cast back.
    """
    dt = w.dtype
    w32 = w.astype(jnp.float32)
    cp32 = c_pos.astype(jnp.float32)
    cn32 = c_neg.astype(jnp.float32)
    s_pos = jnp.sum(w32 * cp32, axis=-1)                 # (B,)
    s_neg = jnp.einsum("bd,bkd->bk", w32, cn32)          # (B, K)
    loss = jax.nn.softplus(-s_pos) + jnp.sum(jax.nn.softplus(s_neg), axis=-1)
    g_pos = jax.nn.sigmoid(s_pos) - 1.0                  # (B,)
    g_neg = jax.nn.sigmoid(s_neg)                        # (B, K)
    d_w = g_pos[:, None] * cp32 + jnp.einsum("bk,bkd->bd", g_neg, cn32)
    d_cp = g_pos[:, None] * w32
    d_cn = g_neg[..., None] * w32[:, None, :]
    return loss, d_w.astype(dt), d_cp.astype(dt), d_cn.astype(dt)


def swa_decode_ref(
    q: jax.Array, k: jax.Array, v: jax.Array
) -> jax.Array:
    """Sliding-window single-token decode attention oracle.

    q (B, H, D), k (B, W, H, D), v (B, W, H, D) — the cache already holds
    exactly the window. Returns (B, H, D).
    """
    s = jnp.einsum("bhd,bwhd->bhw", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhw,bwhd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
