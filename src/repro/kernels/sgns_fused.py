"""Fully-fused Pallas SGNS step: in-kernel alias negative sampling +
forward + row grads + parameter apply, one VMEM pass.

The partially-fused path (``sgns_update.py``) still leaves two HBM seams
around the kernel: the negative-id draw (an XLA op between the sampler
tables and the gather) and the gather→grad→scatter round-trips for the
``(B, K, d)`` negative rows. This kernel closes both: the alias
``prob``/``alias`` tables are kernel operands, the K negatives per pair
are drawn *inside* the kernel from a counter-based PRNG, and the step's
scatter-add apply happens on the VMEM-resident tables — negative ids and
the ``(B, K)`` logit/grad intermediates never exist as HBM arrays. Both
parameter tables are input/output-aliased, so the step is in-place at
the XLA level too.

PRNG: a stateless counter hash (two rounds of the lowbias32 avalanche
mix) keyed by the step's ``(2,)`` uint32 PRNG key. It is plain uint32
arithmetic, so the *same* draw runs under Mosaic and under interpret
mode, and :func:`fused_negative_ids` reproduces it outside the kernel —
that is what lets the equivalence tests feed identical negatives to the
``sparse`` reference. (``pltpu.prng_random_bits`` would be faster on TPU
but is neither available in interpret mode nor replayable off-device.)

Semantics match :func:`repro.core.sgns.train_step_sparse` exactly: all
row gradients are computed from the pre-step tables, then applied with
accumulating scatter-adds (duplicate ids add up).

Capacity: both ``(V, d)`` tables ride through the kernel whole, so this
variant targets per-worker sub-model tables that fit VMEM-adjacent
memory (the paper's 300k×500 tables need the blocked HBM-streaming
variant — see ROADMAP). Interpret mode has no such limit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Counter-based PRNG (stateless, replayable, uint32-only)
# ---------------------------------------------------------------------------
def _mix32(x: jax.Array) -> jax.Array:
    """lowbias32 avalanche hash round (uint32 → uint32, bijective)."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> jnp.uint32(15))
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def counter_uniforms(seed: jax.Array, counters: jax.Array) -> jax.Array:
    """U[0,1) float32 per counter, keyed by a ``(2,)`` uint32 seed.

    Distinct counters give independent-looking streams (each draw is a
    double avalanche hash of its own counter); distinct seeds give
    disjoint streams for the same counters.
    """
    seed = seed.astype(jnp.uint32)
    bits = _mix32(_mix32(counters.astype(jnp.uint32) ^ seed[0]) + seed[1])
    # top 24 bits → exactly representable uniforms in [0, 1)
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1 / (1 << 24))


def alias_draw_from_counters(
    seed: jax.Array, prob: jax.Array, alias: jax.Array, base: jax.Array
) -> jax.Array:
    """One alias-table draw per counter in ``base`` (draws' global
    row-major positions): two sub-counters per draw (index pick +
    alias-acceptance), top-24-bit uniforms, min-clamped index. The ONE
    copy of the draw expressions — both the VMEM-resident and the
    HBM-blocked kernels, and the off-kernel replay, call this, which is
    what keeps their draws bit-identical by construction."""
    u_idx = counter_uniforms(seed, base * jnp.uint32(2))
    u_acc = counter_uniforms(seed, base * jnp.uint32(2) + jnp.uint32(1))
    V = prob.shape[0]
    idx = jnp.minimum((u_idx * V).astype(jnp.int32), V - 1)
    return jnp.where(u_acc < prob[idx], idx, alias[idx]).astype(jnp.int32)


def fused_negative_ids(
    seed: jax.Array, prob: jax.Array, alias: jax.Array, shape: tuple[int, ...]
) -> jax.Array:
    """The in-kernel negative draw, as a pure-jnp function of values.

    The kernel body calls this on its VMEM-resident table values; tests
    call it on the same ``(prob, alias)`` arrays to replay the exact ids
    a fused step drew (same ``seed`` ⇒ same negatives). Counters are
    assigned row-major over ``shape``, two per draw (index pick +
    alias-acceptance).
    """
    n = 1
    for s in shape:
        n *= s
    base = jnp.arange(n, dtype=jnp.uint32).reshape(shape)
    return alias_draw_from_counters(seed, prob, alias, base)


# ---------------------------------------------------------------------------
# The fused kernel
# ---------------------------------------------------------------------------
def _sgns_fused_kernel(K, seed_ref, lr_ref, w_ref, c_ref, cen_ref, ctx_ref,
                       prob_ref, alias_ref, w_out_ref, c_out_ref, loss_ref):
    W = w_ref[...].astype(jnp.float32)            # (V, d)
    C = c_ref[...].astype(jnp.float32)            # (V, d)
    cen = cen_ref[...]                            # (B,)
    ctx = ctx_ref[...]                            # (B,)
    lr = lr_ref[0]

    # 1. draw the K negatives per pair — ids live only in VMEM/registers
    ids = fused_negative_ids(seed_ref[...], prob_ref[...], alias_ref[...],
                             (cen.shape[0], K))

    # 2. gather all rows from the resident tables
    w = W[cen]                                    # (B, d)
    cp = C[ctx]                                   # (B, d)
    cn = C[ids]                                   # (B, K, d)

    # 3. stable log σ forward + all three row grads, one pass
    s_pos = jnp.sum(w * cp, axis=-1)              # (B,)
    s_neg = jnp.sum(w[:, None, :] * cn, axis=-1)  # (B, K)

    def softplus(x):
        return jnp.maximum(x, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(x)))

    loss = softplus(-s_pos) + jnp.sum(softplus(s_neg), axis=-1)
    g_pos = jax.nn.sigmoid(s_pos) - 1.0           # (B,)
    g_neg = jax.nn.sigmoid(s_neg)                 # (B, K)

    dw = g_pos[:, None] * cp + jnp.sum(g_neg[:, :, None] * cn, axis=1)
    dcp = g_pos[:, None] * w
    dcn = g_neg[:, :, None] * w[:, None, :]

    # 4. apply — accumulating scatter-adds on the resident tables
    #    (word2vec sum-loss semantics: grads from pre-step params)
    W = W.at[cen].add(-lr * dw)
    C = C.at[ctx].add(-lr * dcp)
    C = C.at[ids.reshape(-1)].add(-lr * dcn.reshape(-1, dcn.shape[-1]))

    w_out_ref[...] = W.astype(w_out_ref.dtype)
    c_out_ref[...] = C.astype(c_out_ref.dtype)
    loss_ref[...] = loss[:, None]                 # per-pair loss, (B, 1)


def _as_seed(key: jax.Array) -> jax.Array:
    """(2,) uint32 seed from a raw or typed JAX PRNG key."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("negatives", "interpret"))
def sgns_fused_step(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    table: dict,
    key: jax.Array,
    lr: jax.Array,
    *,
    negatives: int = 5,
    interpret: bool = True,
) -> tuple[dict, jax.Array]:
    """One whole SGNS step in a single ``pallas_call``.

    params: ``{"W": (V, d), "C": (V, d)}``; centers/contexts ``(B,)``
    int32; table: ``{"prob": (V,), "alias": (V,)}`` Vose alias table of
    the worker's unigram^0.75 noise distribution; key: ``(2,)`` uint32.
    Returns ``(params', mean_loss)`` — bit-identical to
    ``train_step_sparse`` fed the ids :func:`fused_negative_ids` yields
    for the same key.
    """
    V, d = params["W"].shape
    B = centers.shape[0]
    out = pl.pallas_call(
        functools.partial(_sgns_fused_kernel, negatives),
        out_shape=[
            jax.ShapeDtypeStruct((V, d), params["W"].dtype),
            jax.ShapeDtypeStruct((V, d), params["C"].dtype),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        # W/C are updated in place: operands 2, 3 alias outputs 0, 1.
        input_output_aliases={2: 0, 3: 1},
        interpret=interpret,
    )(_as_seed(key), jnp.reshape(lr, (1,)).astype(jnp.float32),
      params["W"], params["C"], centers, contexts,
      table["prob"], table["alias"])
    return {"W": out[0], "C": out[1]}, jnp.mean(out[2][:, 0])


# ---------------------------------------------------------------------------
# Standalone in-kernel sampler (test/benchmark surface for the draw path)
# ---------------------------------------------------------------------------
def _sampler_kernel(seed_ref, prob_ref, alias_ref, out_ref):
    out_ref[...] = fused_negative_ids(
        seed_ref[...], prob_ref[...], alias_ref[...], out_ref.shape)


@functools.partial(jax.jit, static_argnames=("shape", "interpret"))
def sample_negatives_fused(
    table: dict, key: jax.Array, shape: tuple[int, ...],
    *, interpret: bool = True,
) -> jax.Array:
    """Draw negative ids with the *kernel's* sampler, via pallas_call.

    Same ``fn(table, key, shape)`` contract as the samplers in
    ``repro.data.pairs`` — used by the chi-square goodness-of-fit tests
    to validate the in-kernel draw path itself, and as the fused
    engine's reference draw outside the kernel.
    """
    return pl.pallas_call(
        _sampler_kernel,
        out_shape=jax.ShapeDtypeStruct(shape, jnp.int32),
        interpret=interpret,
    )(_as_seed(key), table["prob"], table["alias"])
