"""HBM-blocked fully-fused Pallas SGNS step: the paper-scale variant.

The VMEM-resident fused kernel (``sgns_fused.py``) rides both ``(V, d)``
parameter tables through the kernel whole, which caps it at
VMEM-adjacent table sizes — far short of the paper's 300k×500
sub-models. This variant keeps the tables in **HBM**
(``memory_space=ANY``) and walks the batch in fixed-size *pair blocks*:
one kernel invocation per block, which DMAs (``pltpu.make_async_copy``)
only the rows that block actually touches into VMEM scratch — the
center row, positive-context row and K negative rows of each pair —
and RMW-scatters the updates back. Per-block HBM traffic is
O(block·(K+2)·d) rows instead of O(V·d) tables: the cache-blocking idea
of Ji et al.'s shared-memory word2vec, applied to the TPU memory
hierarchy. The tables are input/output-aliased through every block
invocation, so the whole step is a chain of in-place kernels over one
pair of HBM buffers.

Why a chain of invocations rather than a ``pallas_call`` grid or an
in-kernel block loop: all data that matters moves by explicit DMA (the
blocked operands would be KB-sized id vectors), so a grid buys no
pipelining here — and under interpret mode both a grid and an outer
in-kernel loop demote the HBM refs to loop-carried values whose
per-DMA updates XLA materializes as full-table copies (~GB per step at
paper scale). Single-level in-kernel loops keep every row DMA a true
in-place row update; the chain keeps block b+1 reading block b's
writes. The single-launch double-buffered successor of this chain is
``sgns_fused_pipe.py`` (engine ``pallas_fused_pipe``), which overlaps
block *i+1*'s gathers with block *i*'s compute and block *i-1*'s
scatter drain behind a hazard-ordering block planner.

The negative draw stays inside the kernel (Ordentlich et al.'s
network-efficient property: negative ids never exist off-chip): the
``{prob, alias}`` Vose tables are VMEM-resident operands — ``(V,)``
each, tiny next to the ``(V, d)`` tables — and each block draws its K
negatives per pair with the same replayable counter PRNG as the
VMEM-resident kernel, at counter offsets equal to the pairs' global
row-major draw positions. :func:`repro.kernels.sgns_fused.fused_negative_ids`
on the full ``(B, K)`` shape therefore replays a whole step's draws
bit-exactly, blocked or not, so the existing equivalence tests extend
directly.

Semantics:

* default (``sequential=False``) — within each block, all row gradients
  are computed from the tables as of block start, then applied with
  sequentially-accumulating read-modify-write scatters (duplicate ids
  add up, in update order). Block b+1 reads block b's updates. This is
  *bit-identical* to running :func:`repro.core.sgns.train_step_sparse`
  once per block on the replayed negatives; with one block it is
  bit-identical to a single sparse step over the whole batch.
* ``sequential=True`` — word2vec's true per-pair semantics: each pair's
  gradients are computed from the tables as updated by every earlier
  pair, and applied immediately. Equivalent to a loop of batch-size-1
  sparse steps (to the last ulp: XLA's FMA-contraction choices can
  differ between the two compilations). Inherently serial —
  O(B·(K+2)) chained DMAs — so it is the small-shape fidelity oracle
  for Hogwild-style update-order studies, not a throughput path.

The row gradients use the exact expressions of
:func:`repro.core.sgns.sparse_row_grads`, so the default mode's
"bit-identical" above holds at the float level in interpret mode, not
just to tolerance.

Hardware notes: this kernel keeps the *unpipelined* start→wait-per-row
DMA discipline — correct everywhere, the shape Mosaic lowers, and the
simplest possible oracle for the pipelined engine's bit-equivalence
tests. The DMA-overlap optimization it deliberately leaves on the table
lives in ``sgns_fused_pipe.py``: a ring of VMEM row buffers with
per-slot semaphores, touched-row dedup (one DMA per unique row per
block instead of per-pair RMW round-trips), and planner-computed
scatter-before-regather hazard ordering. This kernel remains the
``sequential=True`` path (word2vec's per-pair apply order is inherently
serial) and the fallback reference; real-TPU Mosaic validation of both
is tracked in ROADMAP. Interpret mode (the CI gate) executes the same
DMA semantics on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sgns import sparse_row_grads_per_pair
from repro.kernels.sgns_fused import _as_seed, alias_draw_from_counters


def _pick_block_pairs(B: int, block_pairs: int) -> int:
    """The main block size: ``block_pairs`` clamped to the batch. A
    batch that is not a multiple gets one shorter *tail* invocation for
    the remainder — never a degradation to tiny blocks (a prime B with
    a divisor-only rule would chain B single-pair kernels)."""
    return max(1, min(int(block_pairs), B))


def _block_negative_ids(seed, prob, alias, pair0, blk: int, K: int):
    """The in-kernel draw for one pair block.

    Counters are the pairs' *global* row-major draw positions (two per
    draw), so the concatenation over blocks equals
    ``fused_negative_ids(seed, prob, alias, (B, K))`` bit-exactly.
    """
    row = jax.lax.broadcasted_iota(jnp.uint32, (blk, K), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (blk, K), 1)
    base = (pair0.astype(jnp.uint32) + row) * jnp.uint32(K) + col
    return alias_draw_from_counters(seed, prob, alias, base)


# ---------------------------------------------------------------------------
# Kernel bodies. Operand order:
#   seed (2,) u32 SMEM | lr (1,) f32 SMEM | pair0 (1,) i32 SMEM
#   cen (blk,) | ctx (blk,) | prob (V,) | alias (V,)          [VMEM]
#   W, C  (V, d) HBM (ANY), aliased to the first two outputs
# outputs: W', C' (ANY) | per-pair loss (blk,) VMEM
# scratch: w_rows | cp_rows | cn_rows | tmp (d,) | one DMA semaphore
# All in-kernel loops are single-level with the K copies unrolled
# (K is static) — see the module docstring for why that matters.
# ---------------------------------------------------------------------------
def _copy(src, dst, sem):
    dma = pltpu.make_async_copy(src, dst, sem)
    dma.start()
    dma.wait()


def _hbm_block_kernel(K, seed_ref, lr_ref, pair0_ref, cen_ref, ctx_ref,
                      prob_ref, alias_ref, _w_in, _c_in,
                      w_hbm, c_hbm, loss_ref,
                      w_rows, cp_rows, cn_rows, tmp, sem):
    blk = cen_ref.shape[0]
    d = tmp.shape[0]
    lr = lr_ref[0]
    ids = _block_negative_ids(seed_ref[...], prob_ref[...], alias_ref[...],
                              pair0_ref[0], blk, K)

    # Gather: DMA only the touched rows of the HBM-resident tables,
    # through the *output* refs (aliased) so this block sees the
    # previous block's applied updates.
    def gather(j, _):
        _copy(w_hbm.at[cen_ref[j]], w_rows.at[j], sem)
        _copy(c_hbm.at[ctx_ref[j]], cp_rows.at[j], sem)
        for k in range(K):
            _copy(c_hbm.at[ids[j, k]], cn_rows.at[j * K + k], sem)
        return 0

    jax.lax.fori_loop(0, blk, gather, 0)

    # the exact expressions of the sparse reference — what the
    # bit-equivalence contract stands on
    loss, d_w, d_cp, d_cn = sparse_row_grads_per_pair(
        w_rows[...], cp_rows[...], cn_rows[...].reshape(blk, K, d))
    u_w = -lr * d_w
    u_cp = -lr * d_cp
    u_cn = (-lr * d_cn).reshape(blk * K, d)
    loss_ref[...] = loss

    # Scatter: sequential read-modify-write per touched row, in the same
    # update order as the sparse reference's three scatter-adds —
    # duplicates accumulate identically.
    def rmw(dst, upd):
        _copy(dst, tmp, sem)
        tmp[...] = tmp[...] + upd
        _copy(tmp, dst, sem)

    def apply_w(j, _):
        rmw(w_hbm.at[cen_ref[j]], u_w[j])
        return 0

    def apply_cp(j, _):
        rmw(c_hbm.at[ctx_ref[j]], u_cp[j])
        return 0

    def apply_cn(j, _):
        for k in range(K):
            rmw(c_hbm.at[ids[j, k]], u_cn[j * K + k])
        return 0

    jax.lax.fori_loop(0, blk, apply_w, 0)
    jax.lax.fori_loop(0, blk, apply_cp, 0)
    jax.lax.fori_loop(0, blk, apply_cn, 0)


def _hbm_sequential_kernel(K, seed_ref, lr_ref, pair0_ref, cen_ref, ctx_ref,
                           prob_ref, alias_ref, _w_in, _c_in,
                           w_hbm, c_hbm, loss_ref,
                           w_rows, cp_rows, cn_rows, tmp, sem):
    """word2vec's per-pair sequential apply: pair j's grads see every
    earlier pair's updates. One invocation covers its whole pair range;
    the scratch holds a single pair's rows."""
    n = cen_ref.shape[0]
    d = tmp.shape[0]
    lr = lr_ref[0]
    seed = seed_ref[...]
    prob = prob_ref[...]
    alias = alias_ref[...]
    pair0 = pair0_ref[0]

    def pair(j, _):
        ids = _block_negative_ids(seed, prob, alias, pair0 + j, 1, K)
        _copy(w_hbm.at[cen_ref[j]], w_rows.at[0], sem)
        _copy(c_hbm.at[ctx_ref[j]], cp_rows.at[0], sem)
        for k in range(K):
            _copy(c_hbm.at[ids[0, k]], cn_rows.at[k], sem)
        w = w_rows[0:1]
        cp = cp_rows[0:1]
        cn = cn_rows[0:K].reshape(1, K, d)
        loss, d_w, d_cp, d_cn = sparse_row_grads_per_pair(w, cp, cn)
        loss_ref[j] = loss[0]
        # batch-1 sparse step: the W/ctx rows were just read, so add-
        # and-write; the K negative rows re-read (the ctx write, or an
        # earlier duplicate negative, may have touched them).
        w_rows[0:1] = w + (-lr * d_w)
        _copy(w_rows.at[0], w_hbm.at[cen_ref[j]], sem)
        cp_rows[0:1] = cp + (-lr * d_cp)
        _copy(cp_rows.at[0], c_hbm.at[ctx_ref[j]], sem)
        u_cn = (-lr * d_cn).reshape(K, d)
        for k in range(K):
            _copy(c_hbm.at[ids[0, k]], tmp, sem)
            tmp[...] = tmp[...] + u_cn[k]
            _copy(tmp, c_hbm.at[ids[0, k]], sem)
        return 0

    jax.lax.fori_loop(0, n, pair, 0)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=(
    "negatives", "block_pairs", "sequential", "interpret"))
def sgns_fused_hbm_step(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    table: dict,
    key: jax.Array,
    lr: jax.Array,
    *,
    negatives: int = 5,
    block_pairs: int = 256,
    sequential: bool = False,
    interpret: bool = True,
) -> tuple[dict, jax.Array]:
    """One SGNS step with HBM-resident parameter tables.

    Same contract as :func:`repro.kernels.sgns_fused.sgns_fused_step`
    (``params {"W","C"} (V,d)``, ``centers/contexts (B,)``, Vose
    ``table {"prob","alias"}``, ``(2,)`` uint32 key) — but the ``(V, d)``
    tables never enter VMEM whole: the step chains one aliased kernel
    invocation per ``block_pairs``-sized pair block (plus a shorter
    tail invocation when B is not a multiple), each DMA-gathering /
    RMW-scattering only its own block's touched rows.
    ``sequential=True`` applies word2vec's per-pair update order inside
    each block invocation.
    """
    V, d = params["W"].shape
    B = centers.shape[0]
    K = negatives
    blk = _pick_block_pairs(B, block_pairs)
    body = _hbm_sequential_kernel if sequential else _hbm_block_kernel

    def make_call(n: int):
        """A pallas_call processing one ``n``-pair block (the main block
        size, plus one shorter variant when B % blk != 0)."""
        scratch_rows = 1 if sequential else n
        smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
        return pl.pallas_call(
            functools.partial(body, K),
            in_specs=[
                smem(),                                 # seed (2,)
                smem(),                                 # lr (1,)
                smem(),                                 # pair0 (1,)
                pl.BlockSpec(memory_space=pltpu.VMEM),  # centers block
                pl.BlockSpec(memory_space=pltpu.VMEM),  # contexts block
                pl.BlockSpec(memory_space=pltpu.VMEM),  # prob
                pl.BlockSpec(memory_space=pltpu.VMEM),  # alias
                pl.BlockSpec(memory_space=pltpu.ANY),   # W (HBM)
                pl.BlockSpec(memory_space=pltpu.ANY),   # C (HBM)
            ],
            out_specs=[
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.VMEM),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((V, d), params["W"].dtype),
                jax.ShapeDtypeStruct((V, d), params["C"].dtype),
                jax.ShapeDtypeStruct((n,), jnp.float32),
            ],
            # in-place tables: HBM operands 7, 8 alias outputs 0, 1 —
            # the chain threads one pair of buffers through every block
            input_output_aliases={7: 0, 8: 1},
            scratch_shapes=[
                pltpu.VMEM((scratch_rows, d), jnp.float32),      # centers
                pltpu.VMEM((scratch_rows, d), jnp.float32),      # pos-ctx
                pltpu.VMEM((scratch_rows * K, d), jnp.float32),  # negatives
                pltpu.VMEM((d,), jnp.float32),                   # RMW stage
                pltpu.SemaphoreType.DMA,
            ],
            interpret=interpret,
        )

    calls = {blk: make_call(blk)}
    if B % blk:
        calls[B % blk] = make_call(B % blk)
    seed = _as_seed(key)
    lr1 = jnp.reshape(lr, (1,)).astype(jnp.float32)
    W, C = params["W"], params["C"]
    losses = []
    for b0 in range(0, B, blk):
        n = min(blk, B - b0)
        W, C, loss_b = calls[n](
            seed, lr1, jnp.full((1,), b0, jnp.int32),
            centers[b0:b0 + n], contexts[b0:b0 + n],
            table["prob"], table["alias"], W, C)
        losses.append(loss_b)
    return {"W": W, "C": C}, jnp.mean(jnp.concatenate(losses))
