"""Pipelined HBM-blocked fused SGNS step: overlapped DMA, deduped rows.

``sgns_fused_hbm.py`` made the paper's 300k×500 sub-model shape feasible
by keeping the ``(V, d)`` tables HBM-resident and DMA-streaming each
pair block's touched rows — but its memory pipeline is fully serial:
every row gather and every RMW scatter is issued start→wait, one row at
a time, so the compute units idle through all of the step's DMA latency
(the remaining hot-path item on ROADMAP). This module replaces that loop
with a **multi-slot DMA pipeline** in a single ``pallas_call`` per step:

* a ring of ``ring_depth`` VMEM row-buffer pairs (one ``(R_W, d)`` W
  buffer + one ``(R_C, d)`` C buffer per slot) with per-slot DMA
  semaphores, through which block *i+1*'s row gathers are in flight
  while block *i* computes and older blocks' scatters drain; the ring
  defaults to the classic 2 slots (``NUM_SLOTS``) and deepens to any
  ``ring_depth ≥ 2`` — a deeper ring leaves older blocks' write-backs
  in flight longer before their slot-recycling wait;
* **touched-row dedup**: each block gathers every row it touches
  exactly once (the unique centers for W; the unique contexts ∪
  negatives for C), applies all of its updates to the VMEM-resident
  copy, and writes each row back exactly once. This removes the
  per-duplicate gathers *and* the entire read-modify-write round-trip
  of the unpipelined kernel — per-block HBM traffic drops from
  ``3·blk·(K+2)`` row transfers to ``2·R`` where ``R ≤ blk·(K+2)`` is
  the unique-row count;
* a **pure-JAX block planner** (:func:`plan_blocks`) that computes the
  dedup, the pair→buffer-slot index maps, and the scatter-before-
  regather **hazard flags** outside the kernel, and a static
  :func:`kernel_schedule` that both the kernel body and the unit tests
  iterate — the schedule (slot assignment, gather/compute/scatter/wait
  ordering, hazard guards) is testable entirely without Pallas.

Hazard ordering: with the chain semantics, block *b*'s gathers must
observe every earlier block's applied updates. Pipelining reorders block
*b*'s gathers before older blocks' scatters have drained, which is only
sound when the row sets are disjoint — so the planner emits
``hazard[b] = touched(b) ∩ (written(b-1) ∪ … ∪ written(b-(S-1))) ≠ ∅``
(per table, over the ``S = ring_depth`` ring), and the schedule issues
block *b*'s gathers on the fast path (overlapped) when the flag is
clear, or after draining every still-outstanding write-back when it is
set. Blocks older than the window are always drained by then: the
S-slot ring reuses block *b-S*'s buffers for block *b*, so the
slot-recycling wait already serializes against everything older — which
is why a window of S-1 look-behind flags is sufficient for full chain
fidelity. Each block's scatter drain is guarded by a *partition* of the
hazard outcomes over its window ("first hazard that fires drains it,
else the slot-recycling default"), so every DMA is started and waited
exactly once under every hazard vector — the ``ring_depth = 2``
schedule degenerates to the original complementary ``pl.when`` pairs.

**Frequency tiers** (engine ``pallas_fused_tiered``,
``kernels/sgns_fused_tiered.py``): vocab ids are frequency-sorted, so
:func:`plan_blocks` can route the ``hot_rows`` hottest rows (ids
``< hot_rows``) out of the DMA pipeline entirely — hot ids are dropped
from the gather/scatter lists and from the hazard row sets (dedup and
hazards are computed over **cold rows only**), and their buffer
positions point at a masked pad slot. The tiered kernel serves hot rows
from a pinned VMEM-resident copy of the table prefix instead; this
module's planner/schedule stay the single source of truth for the cold
path. ``hot_rows = 0`` (the ``pallas_fused_pipe`` engine) is the pure
pipeline.

Bit-equivalence contract (same as the unpipelined engine): identical
results to running :func:`repro.core.sgns.train_step_sparse` once per
pair block on the replayed counter-PRNG negatives. Dedup preserves it
exactly: the reference's scatter-add applies duplicate-row updates
sequentially in pair order, and the in-VMEM ``.at[pos].add`` applies the
same addends to the same base values in the same order before the row is
written back once. The negative draw uses the same replayable counter
PRNG (:func:`repro.kernels.sgns_fused.fused_negative_ids`); the planner
replays it outside the kernel because the dedup needs the ids — the one
deliberate trade against the in-kernel draw: negative *ids* now exist as
planner metadata (O(B·K) int32, KBs) so that negative *rows* (MBs) move
exactly once.

Hardware notes: every DMA is started on a slot semaphore and waited
exactly once, with matched start/wait structure under every hazard
outcome (the guards partition the hazard-outcome space), so the kernel
lowers the same way under Mosaic and interpret mode. Interpret mode (the
CI gate) executes the schedule's DMA semantics serially on CPU — the
overlap itself is a hardware property; real-TPU Mosaic validation stays
open on ROADMAP. ``sequential=True`` (word2vec's per-pair apply order)
is inherently unpipelineable and is served by the unpipelined kernel —
see :class:`repro.core.engine.FusedPipePallasEngine`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sgns import sparse_row_grads_per_pair
from repro.kernels.sgns_fused import _as_seed, fused_negative_ids
from repro.kernels.sgns_fused_hbm import _pick_block_pairs

NUM_SLOTS = 2   # default ring depth: gathers of b+1 overlap scatters of b

# DMA semantics of the schedule ops: each start op and the wait op that
# retires it, both on the same per-slot semaphore ring. The static
# analysis layer (repro.analysis.dma_model) checks matched start/wait
# structure against exactly this mapping.
DMA_WAIT_FOR_START = {"gather": "wait_gather", "scatter": "wait_scatter"}


# ---------------------------------------------------------------------------
# Block planner — pure JAX, unit-testable without Pallas.
# ---------------------------------------------------------------------------
class PipelinePlan(NamedTuple):
    """Per-block DMA/compute metadata for one step's pair blocks.

    Shapes: ``nblocks`` blocks of ``blk`` pairs (the batch is padded to
    a whole number of blocks; padded pairs carry ``mask == 0`` and
    contribute exactly-zero updates). ``R_W = blk`` and
    ``R_C = blk·(K+1)`` are the row-buffer capacities.

    With a hot tier (``hot_rows > 0``), the unique sets / counts /
    hazards cover **cold rows only** (ids ``≥ hot_rows``); a hot pair
    element's ``*_pos`` entry points at the first pad slot of its
    buffer (its update is tier-masked to zero there — the kernel
    applies it to the VMEM-resident hot prefix instead, indexed
    directly by the id carried in ``cen``/``ctx``/``neg``).
    """

    uw: jax.Array       # (nblocks, R_W) int32 — sorted unique cold center rows, padded with V
    uc: jax.Array       # (nblocks, R_C) int32 — sorted unique cold context∪negative rows, padded with V
    n_w: jax.Array      # (nblocks,) int32 — valid cold rows in uw (gathered AND scattered)
    n_c: jax.Array      # (nblocks,) int32 — valid cold rows in uc
    w_pos: jax.Array    # (nblocks, blk) int32 — pair j's center row → uw slot
    cp_pos: jax.Array   # (nblocks, blk) int32 — pair j's context row → uc slot
    cn_pos: jax.Array   # (nblocks, blk·K) int32 — pair j's k-th negative row → uc slot
    mask: jax.Array     # (nblocks, blk) float32 — 1 for real pairs, 0 for padding
    hazard: jax.Array   # (nblocks,) int32 — 1 iff touched(b) ∩ written(b-1..b-(S-1)) ≠ ∅
    cen: jax.Array      # (nblocks, blk) int32 — blocked center ids (hot-tier direct index)
    ctx: jax.Array      # (nblocks, blk) int32 — blocked context ids
    neg: jax.Array      # (nblocks, blk·K) int32 — blocked negative ids

    @property
    def nblocks(self) -> int:
        return self.uw.shape[0]

    @property
    def block_pairs(self) -> int:
        return self.w_pos.shape[1]


def _pad_to_blocks(x: jax.Array, nblocks: int, blk: int) -> jax.Array:
    """(B, ...) → (nblocks, blk, ...), padding with the first element
    (any valid id — padded pairs are masked to zero-update anyway)."""
    pad = nblocks * blk - x.shape[0]
    if pad:
        x = jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])
    return x.reshape((nblocks, blk) + x.shape[1:])


def _unique_rows(ids: jax.Array, vocab_size: int):
    """Per-block sorted unique ids, padded with ``vocab_size``.

    ids: (nblocks, R) int32 in [0, V) ∪ {V} (V marks entries already
    routed elsewhere — the hot tier). Returns (u (nblocks, R), n
    (nblocks,)): ``u[b, :n[b]]`` is block b's sorted unique set of
    ids < V and ``u[b, n[b]:] == V`` (past every real id, so
    searchsorted lookups of valid ids never land on padding).
    """
    s = jnp.sort(ids, axis=1)
    first = jnp.concatenate(
        [jnp.ones(s.shape[:1] + (1,), bool), s[:, 1:] != s[:, :-1]], axis=1)
    # sentinel entries (== V) are not counted as unique rows
    n = (first & (s < jnp.int32(vocab_size))).sum(axis=1).astype(jnp.int32)
    # stable argsort floats the first-occurrences to the front, still in
    # ascending id order; the duplicate/sentinel tail is overwritten with V
    order = jnp.argsort(~first, axis=1, stable=True)
    u = jnp.take_along_axis(s, order, axis=1)
    col = jnp.arange(s.shape[1], dtype=jnp.int32)[None, :]
    return jnp.where(col < n[:, None], u, jnp.int32(vocab_size)), n


_searchsorted_rows = jax.vmap(
    functools.partial(jnp.searchsorted, side="left"))


def plan_blocks(
    centers: jax.Array,
    contexts: jax.Array,
    negatives: jax.Array,
    vocab_size: int,
    block_pairs: int,
    *,
    hot_rows: int = 0,
    ring_depth: int = NUM_SLOTS,
) -> PipelinePlan:
    """Plan one step's pair blocks for the pipelined kernel.

    Pure JAX (jit/vmap-safe, static shapes): splits the batch into
    ``blk``-pair blocks, routes each touched row to its tier (ids
    ``< hot_rows`` are hot — dropped from the gather/scatter lists and
    the hazard row sets; the rest are cold), dedups each block's
    touched cold rows per table, maps every pair's (center, context,
    negatives) to positions in the deduped row buffers, and flags the
    blocks whose cold touched set intersects any of the previous
    ``ring_depth - 1`` blocks' written sets (the scatter-before-
    regather hazards the schedule must serialize on; a deeper ring
    leaves more write-backs in flight, so the look-behind window grows
    with it).
    """
    B = centers.shape[0]
    K = negatives.shape[1]
    blk = _pick_block_pairs(B, block_pairs)
    nblocks = -(-B // blk)
    V = vocab_size

    cen = _pad_to_blocks(centers.astype(jnp.int32), nblocks, blk)
    ctx = _pad_to_blocks(contexts.astype(jnp.int32), nblocks, blk)
    neg = _pad_to_blocks(negatives.astype(jnp.int32), nblocks, blk)
    negf = neg.reshape(nblocks, blk * K)

    # tier routing: hot ids leave the DMA path entirely — mapped to the
    # V sentinel so they sort past every cold id and out of the counts
    def cold(ids):
        if hot_rows <= 0:
            return ids
        return jnp.where(ids < jnp.int32(hot_rows), jnp.int32(V), ids)

    uw, n_w = _unique_rows(cold(cen), V)
    c_rows = jnp.concatenate([cold(ctx), cold(negf)], axis=1)
    uc, n_c = _unique_rows(c_rows, V)

    # hot elements look up the V sentinel → the first pad slot (clamped
    # to the buffer when a block is entirely cold, in which case no hot
    # lookups exist and the clamp is a no-op)
    w_pos = jnp.minimum(_searchsorted_rows(uw, cold(cen)),
                        uw.shape[1] - 1).astype(jnp.int32)
    c_pos = jnp.minimum(_searchsorted_rows(uc, c_rows),
                        uc.shape[1] - 1).astype(jnp.int32)
    cp_pos, cn_pos = c_pos[:, :blk], c_pos[:, blk:]

    # With dedup, written(b) == touched(b) per table (every gathered row
    # receives at least one update), so the look-behind intersections are
    # over the same padded unique sets. W rows only conflict with W
    # writes, C rows with C writes — the tables are separate buffers.
    # The window covers the S-1 blocks whose write-backs a ring of S
    # slots can still have in flight when block b's gathers issue.
    def hit(u, m):
        idx = _searchsorted_rows(u[:-m], u[m:])
        found = jnp.take_along_axis(
            u[:-m], jnp.minimum(idx, u.shape[1] - 1), axis=1) == u[m:]
        return (found & (u[m:] < jnp.int32(V))).any(axis=1)

    hz = jnp.zeros((nblocks,), bool)
    for m in range(1, min(ring_depth, nblocks)):
        hz = hz.at[m:].set(hz[m:] | hit(uw, m) | hit(uc, m))

    mask = (jnp.arange(nblocks * blk, dtype=jnp.int32) < B).astype(
        jnp.float32).reshape(nblocks, blk)
    return PipelinePlan(uw=uw, uc=uc, n_w=n_w, n_c=n_c, w_pos=w_pos,
                        cp_pos=cp_pos, cn_pos=cn_pos, mask=mask,
                        hazard=hz.astype(jnp.int32),
                        cen=cen, ctx=ctx, neg=negf)


# ---------------------------------------------------------------------------
# The static pipeline schedule — the single source of truth iterated by
# the kernel body (hazard guards become pl.when) and by the tests
# (hazard guards resolved against a concrete hazard vector).
# ---------------------------------------------------------------------------
def kernel_schedule(nblocks: int, num_slots: int = NUM_SLOTS):
    """The unrolled pipeline as ``(op, block, slot, guard)`` events.

    ``op`` ∈ {gather, wait_gather, compute, scatter, wait_scatter};
    ``guard`` is ``None`` (unconditional) or a tuple of ``(b, want)``
    conditions meaning "only when bool(hazard[b]) == want for every
    condition". For each block, the guards over its wait_scatter sites
    PARTITION the hazard-outcome space of its look-behind window, so
    every DMA is started and waited exactly once for every hazard
    vector (``num_slots = 2`` degenerates to the original
    complementary single-flag pairs):

    * block b+1's gathers are issued *before* outstanding scatters when
      ``hazard[b+1]`` is clear (the overlap fast path), else after
      every still-in-flight write-back has drained;
    * block j's scatters drain at the FIRST hazard in its window
      ``hazard[j+1 .. j+S-1]`` that fires, or — when none fires — at
      the slot-recycling default (top of position ``j+S-1``, always
      before block ``j+S``'s gathers reuse block j's buffer slot).
    """
    S = num_slots
    if S < 2:
        raise ValueError(f"ring needs at least 2 slots, got {S}")

    def clear(lo, hi):
        """'hazard[lo..hi] all clear' conditions (empty → unconditional)."""
        g = tuple((f, False) for f in range(lo, hi + 1))
        return g or None

    ev = [("gather", 0, 0, None)]
    for b in range(nblocks):
        s = b % S
        g = b + 1
        j = g - S
        if j >= 0:
            # slot-recycling default drain of the block whose buffers
            # block g is about to gather into — fires iff no hazard in
            # j's window drained it earlier
            ev.append(("wait_scatter", j, j % S, clear(j + 1, j + S - 1)))
        if g < nblocks:
            ev.append(("gather", g, g % S, ((g, False),)))
        ev.append(("wait_gather", b, s, None))
        ev.append(("compute", b, s, None))
        ev.append(("scatter", b, s, None))
        if g < nblocks:
            # hazard path: drain every still-outstanding write-back
            # (oldest first) before issuing block g's gathers — block
            # j2 is outstanding here iff no flag in hazard[j2+1 .. b]
            # fired (which would have drained it already)
            for j2 in range(max(0, g - S + 1), b + 1):
                pre = tuple((f, False) for f in range(j2 + 1, b + 1))
                ev.append(("wait_scatter", j2, j2 % S, pre + ((g, True),)))
            ev.append(("gather", g, g % S, ((g, True),)))
    # tail: blocks whose slot-recycling default lies past the last
    # position drain on "no later hazard fired" (partition remainder)
    for j in range(max(0, nblocks - S + 1), nblocks):
        ev.append(("wait_scatter", j, j % S, clear(j + 1, nblocks - 1)))
    return ev


def resolve_schedule(hazard, num_slots: int = NUM_SLOTS):
    """The concrete ``(op, block, slot)`` event order the kernel executes
    for a given hazard vector — what the planner property tests check."""
    return [(op, b, s)
            for op, b, s, g in kernel_schedule(len(hazard), num_slots)
            if g is None or all(bool(hazard[f]) is w for f, w in g)]


def plan_row_traffic(plan: PipelinePlan, hot_rows: int = 0) -> int:
    """HBM row transfers one step under this plan actually moves: each
    valid cold row is exactly one gather plus one write-back, and a hot
    prefix of ``hot_rows`` rows moves in and out once per step for both
    tables (the tiered kernel's ``HOT_PREFIX_DMA_OPS`` bulk copies).
    This is the ``hbm_rows_per_step`` quantity the ``@zipf50k`` BENCH
    rows gate on and ``repro.analysis.contracts`` certifies against the
    committed baseline."""
    return 2 * int(plan.n_w.sum() + plan.n_c.sum()) + 4 * int(hot_rows)


# ---------------------------------------------------------------------------
# Kernel plumbing shared with the tiered sibling
# (kernels/sgns_fused_tiered.py): the per-block row-DMA runner and the
# guarded schedule executor.
# ---------------------------------------------------------------------------
def make_row_dma_runner(uw_ref, uc_ref, n_w_ref, n_c_ref,
                        w_hbm, c_hbm, buf_w, buf_c, gsem, ssem):
    """Returns ``run_rows(b, s, gather, wait)``: matched start/wait
    loops over block b's valid (cold) rows — each valid uw/uc slot is
    one row DMA (HBM→slot buffer for gathers, buffer→HBM for the
    write-back scatters)."""
    def run_rows(b, s, gather, wait):
        def w_dma(j):
            pair = (w_hbm.at[uw_ref[b, j]], buf_w.at[s, j])
            src, dst = pair if gather else pair[::-1]
            return pltpu.make_async_copy(src, dst, (gsem if gather
                                                    else ssem).at[s])

        def c_dma(j):
            pair = (c_hbm.at[uc_ref[b, j]], buf_c.at[s, j])
            src, dst = pair if gather else pair[::-1]
            return pltpu.make_async_copy(src, dst, (gsem if gather
                                                    else ssem).at[s])

        def go(dma):
            def body(j, _):
                d_ = dma(j)
                d_.wait() if wait else d_.start()
                return 0
            return body

        jax.lax.fori_loop(0, n_w_ref[b], go(w_dma), 0)
        jax.lax.fori_loop(0, n_c_ref[b], go(c_dma), 0)

    return run_rows


def execute_schedule(nblocks, num_slots, hz_ref, run_rows, compute):
    """Walk :func:`kernel_schedule`, resolving guards against the SMEM
    hazard flags with ``pl.when`` (conjunction of the guard conditions).
    ``run_rows`` is a :func:`make_row_dma_runner` closure; ``compute``
    is the per-block compute callback ``compute(b, s)``."""
    ops = {
        "gather": lambda b, s: run_rows(b, s, gather=True, wait=False),
        "wait_gather": lambda b, s: run_rows(b, s, gather=True, wait=True),
        "compute": compute,
        "scatter": lambda b, s: run_rows(b, s, gather=False, wait=False),
        "wait_scatter": lambda b, s: run_rows(b, s, gather=False, wait=True),
    }
    for op, b, s, guard in kernel_schedule(nblocks, num_slots):
        if guard is None:
            ops[op](b, s)
        else:
            pred = None
            for f, want in guard:
                c = (hz_ref[f] != 0) if want else (hz_ref[f] == 0)
                pred = c if pred is None else jnp.logical_and(pred, c)
            pl.when(pred)(functools.partial(ops[op], b, s))


# ---------------------------------------------------------------------------
# Kernel body. Operand order:
#   lr (1,) f32 SMEM | n_w, n_c, hazard (nblocks,) i32 SMEM
#   uw | uc | w_pos | cp_pos | cn_pos | mask                 [VMEM]
#   W, C (V, d) HBM (ANY), aliased to the first two outputs
# outputs: W', C' (ANY) | per-pair masked loss (nblocks, blk) VMEM
# scratch: bufW (S, R_W, d) | bufC (S, R_C, d) | gather + scatter DMA
#          semaphore rings (S,)
# ---------------------------------------------------------------------------
def _pipe_kernel(nblocks, K, num_slots, lr_ref, n_w_ref, n_c_ref, hz_ref,
                 uw_ref, uc_ref, wpos_ref, cppos_ref, cnpos_ref, mask_ref,
                 _w_in, _c_in, w_hbm, c_hbm, loss_ref,
                 buf_w, buf_c, gsem, ssem):
    blk = wpos_ref.shape[1]
    d = buf_w.shape[2]
    lr = lr_ref[0]
    run_rows = make_row_dma_runner(uw_ref, uc_ref, n_w_ref, n_c_ref,
                                   w_hbm, c_hbm, buf_w, buf_c, gsem, ssem)

    def compute(b, s):
        W_blk = buf_w[s]                                    # (R_W, d)
        C_blk = buf_c[s]                                    # (R_C, d)
        w_pos = wpos_ref[b]
        cp_pos = cppos_ref[b]
        cn_pos = cnpos_ref[b]
        w = W_blk[w_pos]                                    # (blk, d)
        cp = C_blk[cp_pos]                                  # (blk, d)
        cn = C_blk[cn_pos].reshape(blk, K, d)               # (blk, K, d)
        # the exact expressions of the sparse reference — what the
        # bit-equivalence contract stands on
        loss, d_w, d_cp, d_cn = sparse_row_grads_per_pair(w, cp, cn)
        m = mask_ref[b]                                     # (blk,)
        u_w = -lr * (d_w * m[:, None])
        u_cp = -lr * (d_cp * m[:, None])
        u_cn = (-lr * (d_cn * m[:, None, None])).reshape(blk * K, d)
        # same scatter-add order as the reference (W, then C-context,
        # then C-negatives): duplicate rows accumulate identically, so
        # the single write-back per row is bit-identical to its RMW chain
        buf_w[s] = W_blk.at[w_pos].add(u_w)
        buf_c[s] = C_blk.at[cp_pos].add(u_cp).at[cn_pos].add(u_cn)
        loss_ref[b] = loss * m

    execute_schedule(nblocks, num_slots, hz_ref, run_rows, compute)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=(
    "negatives", "block_pairs", "ring_depth", "interpret"))
def sgns_fused_pipe_step(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    table: dict,
    key: jax.Array,
    lr: jax.Array,
    *,
    negatives: int = 5,
    block_pairs: int = 256,
    ring_depth: int = NUM_SLOTS,
    interpret: bool = True,
) -> tuple[dict, jax.Array]:
    """One SGNS step through the pipelined HBM engine.

    Same contract as :func:`repro.kernels.sgns_fused_hbm.sgns_fused_hbm_step`
    with ``sequential=False`` — and bit-identical to it (and therefore
    to the per-block ``train_step_sparse`` reference on the replayed
    negatives) at every ``ring_depth``: the planner replays the same
    counter-PRNG draw, and the schedule's hazard guards preserve the
    chain's read-after-write semantics exactly. One ``pallas_call``
    covers the whole batch.
    """
    V, d = params["W"].shape
    B = centers.shape[0]
    K = negatives
    seed = _as_seed(key)
    neg_ids = fused_negative_ids(seed, table["prob"], table["alias"], (B, K))
    plan = plan_blocks(centers, contexts, neg_ids, V, block_pairs,
                       ring_depth=ring_depth)
    nblocks, blk = plan.nblocks, plan.block_pairs
    S = ring_depth

    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    vmem = functools.partial(pl.BlockSpec, memory_space=pltpu.VMEM)
    out = pl.pallas_call(
        functools.partial(_pipe_kernel, nblocks, K, S),
        in_specs=[
            smem(),                                 # lr (1,)
            smem(), smem(), smem(),                 # n_w, n_c, hazard
            vmem(), vmem(),                         # uw, uc
            vmem(), vmem(), vmem(),                 # w_pos, cp_pos, cn_pos
            vmem(),                                 # mask
            pl.BlockSpec(memory_space=pltpu.ANY),   # W (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),   # C (HBM)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            vmem(),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, d), params["W"].dtype),
            jax.ShapeDtypeStruct((V, d), params["C"].dtype),
            jax.ShapeDtypeStruct((nblocks, blk), jnp.float32),
        ],
        # in-place tables: HBM operands 10, 11 alias outputs 0, 1
        input_output_aliases={10: 0, 11: 1},
        scratch_shapes=[
            pltpu.VMEM((S, blk, d), jnp.float32),            # W rows
            pltpu.VMEM((S, blk * (K + 1), d), jnp.float32),  # C rows
            pltpu.SemaphoreType.DMA((S,)),                   # gathers
            pltpu.SemaphoreType.DMA((S,)),                   # scatters
        ],
        interpret=interpret,
    )(jnp.reshape(lr, (1,)).astype(jnp.float32),
      plan.n_w, plan.n_c, plan.hazard,
      plan.uw, plan.uc, plan.w_pos, plan.cp_pos, plan.cn_pos, plan.mask,
      params["W"], params["C"])
    # padded pairs were masked to exactly-zero loss, so the batch mean
    # divides by the true pair count
    return {"W": out[0], "C": out[1]}, jnp.sum(out[2]) / B
