"""Frequency-tiered hot/cold fused SGNS step: VMEM-pinned hot rows over
the pipelined HBM engine.

Word frequencies are Zipfian, so a small *hot set* of rows absorbs the
large majority of the per-block DMA traffic the all-HBM pipeline
(``sgns_fused_pipe.py``) pays: at word2vec's unigram^0.75 noise
distribution plus the Zipfian center/context stream, the few hundred
most frequent ids appear in nearly every pair block, yet the pipeline
re-gathers and re-scatters them for every block that touches them.
Ordentlich et al. (1606.08495) built their network-efficient
distributed word2vec on exactly this skew; the paper's input-space-
partitioned async design keeps per-worker tables private, so a
per-worker hot tier needs no cross-worker synchronization of any kind.

This engine (``pallas_fused_tiered``) splits each ``(V, d)`` table at a
build-time-known row index ``hot_rows``:

* **hot tier** — ids ``< hot_rows``. Vocab ids are frequency-sorted
  descending (``data/vocab.build_vocab``), so the hottest rows by
  unigram count are exactly the id prefix, and a row's id doubles as
  its direct index into a VMEM-resident copy of the table prefix. The
  kernel bulk-DMAs the prefix into VMEM scratch once at step start,
  applies every hot update in place through all blocks (chain semantics
  are automatic: computes execute in block order), and writes the
  prefix back once at step end — hot rows move over DMA **once per
  step** instead of once per touching block.
* **cold tier** — ids ``≥ hot_rows``. Exactly the existing pipelined
  path: the :func:`repro.kernels.sgns_fused_pipe.plan_blocks` planner
  (this module's single source of truth for the cold side) dedups,
  position-maps and hazard-flags over cold rows only, and the same
  :func:`~repro.kernels.sgns_fused_pipe.kernel_schedule` drives the
  ``ring_depth``-slot DMA ring.

The result is a tunable dial on the VMEM-vs-HBM cliff:
``hot_rows = 0`` is the pure pipeline (the ``pallas_fused_pipe``
engine), ``hot_rows = V`` is pure-resident (every row served from VMEM
like ``pallas_fused``, zero per-block row DMAs), and intermediate
settings trade VMEM budget (``2·hot_rows·d`` floats) for DMA traffic
under the corpus's actual skew — ``benchmarks/bench_kernel.py
--hot-sweep`` measures the curve.

Bit-equivalence contract: identical (interpret mode) to
``sgns_fused_hbm`` / ``sgns_fused_pipe`` — and therefore to the
per-block sparse reference on the replayed counter-PRNG negatives — at
**every** hot fraction. Tier routing preserves it exactly: each row id
belongs to exactly one tier, so each physical row receives exactly the
reference's update sequence through exactly one path; the other path's
scatter lands in write-off memory that is never DMA'd back — a hot
id's cold-side position is a pad slot ``≥ n`` (the sentinel sorts past
every cold id, and the write-back loop covers only slots ``< n``), and
a cold id's hot-side index is the spill row at ``kH`` (the prefix
write-back copies only rows ``[0, kH)``). Gathers select per element
between the hot VMEM copy and the cold row buffer (``jnp.where`` on
the tier predicate), so the compute sees bit-identical inputs either
way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sgns import sparse_row_grads_per_pair
from repro.kernels.sgns_fused import _as_seed, fused_negative_ids
from repro.kernels.sgns_fused_pipe import (
    NUM_SLOTS,
    execute_schedule,
    make_row_dma_runner,
    plan_blocks,
    sgns_fused_pipe_step,
)

# Bulk hot-prefix DMAs per step beyond the cold pipeline's schedule: two
# prefix loads at step start (W, C) + two write-backs at step end — the
# ``4 * hot_rows`` row term in
# :func:`repro.kernels.sgns_fused_pipe.plan_row_traffic`.
HOT_PREFIX_DMA_OPS = 4


# ---------------------------------------------------------------------------
# Kernel body. Operand order:
#   lr (1,) f32 SMEM | n_w, n_c, hazard (nblocks,) i32 SMEM
#   uw | uc | w_pos | cp_pos | cn_pos | mask | cen | ctx | neg   [VMEM]
#   W, C (V, d) HBM (ANY), aliased to the first two outputs
# outputs: W', C' (ANY) | per-pair masked loss (nblocks, blk) VMEM
# scratch: bufW (S, R_W, d) | bufC (S, R_C, d) | hotW, hotC (kH+1, d —
#          the trailing spill row absorbs cold rows' write-off updates) |
#          gather + scatter DMA semaphore rings (S,) | hot DMA sems (2,)
# ---------------------------------------------------------------------------
def _tiered_kernel(nblocks, K, num_slots, kH,
                   lr_ref, n_w_ref, n_c_ref, hz_ref,
                   uw_ref, uc_ref, wpos_ref, cppos_ref, cnpos_ref, mask_ref,
                   cen_ref, ctx_ref, neg_ref, _w_in, _c_in,
                   w_hbm, c_hbm, loss_ref,
                   buf_w, buf_c, hot_w, hot_c, gsem, ssem, hsem):
    blk = wpos_ref.shape[1]
    d = buf_w.shape[2]
    lr = lr_ref[0]

    # Pin the hot tier: one bulk prefix DMA per table, VMEM-resident for
    # the whole step (the spill row at index kH stays uninitialized —
    # it only ever absorbs write-off updates). Disjoint from every cold
    # row (ids ≥ kH), so it needs no hazard ordering against the cold
    # pipeline.
    ld_w = pltpu.make_async_copy(w_hbm.at[pl.ds(0, kH)],
                                 hot_w.at[pl.ds(0, kH)], hsem.at[0])
    ld_c = pltpu.make_async_copy(c_hbm.at[pl.ds(0, kH)],
                                 hot_c.at[pl.ds(0, kH)], hsem.at[1])
    ld_w.start()
    ld_c.start()
    ld_w.wait()
    ld_c.wait()

    run_rows = make_row_dma_runner(uw_ref, uc_ref, n_w_ref, n_c_ref,
                                   w_hbm, c_hbm, buf_w, buf_c, gsem, ssem)

    def compute(b, s):
        W_blk = buf_w[s]                                    # (R_W, d)
        C_blk = buf_c[s]                                    # (R_C, d)
        cen = cen_ref[b]                                    # (blk,)
        ctx = ctx_ref[b]                                    # (blk,)
        neg = neg_ref[b]                                    # (blk·K,)
        hot_wm = cen < kH                                   # tier predicates
        hot_cpm = ctx < kH
        hot_cnm = neg < kH
        # hot ids are direct indices into the VMEM prefix; cold ids are
        # routed to the spill row at index kH, which absorbs their
        # (garbage) hot-side updates and is never written back
        i_w = jnp.where(hot_wm, cen, jnp.int32(kH))
        i_cp = jnp.where(hot_cpm, ctx, jnp.int32(kH))
        i_cn = jnp.where(hot_cnm, neg, jnp.int32(kH))
        # two-source gathers: per element, the hot VMEM copy or the
        # cold row buffer — bit-identical inputs either way (the
        # unselected side reads a spill/pad slot and is discarded)
        w = jnp.where(hot_wm[:, None], hot_w[i_w], W_blk[wpos_ref[b]])
        cp = jnp.where(hot_cpm[:, None], hot_c[i_cp], C_blk[cppos_ref[b]])
        cn = jnp.where(hot_cnm[:, None], hot_c[i_cn],
                       C_blk[cnpos_ref[b]]).reshape(blk, K, d)
        # the exact expressions of the sparse reference — what the
        # bit-equivalence contract stands on
        loss, d_w, d_cp, d_cn = sparse_row_grads_per_pair(w, cp, cn)
        m = mask_ref[b]                                     # (blk,)
        u_w = -lr * (d_w * m[:, None])
        u_cp = -lr * (d_cp * m[:, None])
        u_cn = (-lr * (d_cn * m[:, None, None])).reshape(blk * K, d)
        # dual unmasked scatters, same W → C-context → C-negative order
        # as the reference: each physical row receives exactly one
        # path's updates, because the other path's target is write-off
        # memory — a hot id's cold position is a pad slot ≥ n_w/n_c
        # (sentinel ids sort past every cold id, and only slots < n are
        # DMA'd back), and a cold id's hot index is the spill row kH
        # (the write-back copies only the [0, kH) prefix). Duplicates
        # accumulate in identical order either way.
        buf_w[s] = W_blk.at[wpos_ref[b]].add(u_w)
        buf_c[s] = (C_blk.at[cppos_ref[b]].add(u_cp)
                         .at[cnpos_ref[b]].add(u_cn))
        hot_w[...] = hot_w[...].at[i_w].add(u_w)
        hot_c[...] = (hot_c[...].at[i_cp].add(u_cp)
                                .at[i_cn].add(u_cn))
        loss_ref[b] = loss * m

    execute_schedule(nblocks, num_slots, hz_ref, run_rows, compute)

    # write the hot tier back: one bulk prefix DMA per table, after
    # every cold write-back has drained (the schedule's tail waits)
    st_w = pltpu.make_async_copy(hot_w.at[pl.ds(0, kH)],
                                 w_hbm.at[pl.ds(0, kH)], hsem.at[0])
    st_c = pltpu.make_async_copy(hot_c.at[pl.ds(0, kH)],
                                 c_hbm.at[pl.ds(0, kH)], hsem.at[1])
    st_w.start()
    st_c.start()
    st_w.wait()
    st_c.wait()


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=(
    "negatives", "block_pairs", "hot_rows", "ring_depth", "interpret"))
def sgns_fused_tiered_step(
    params: dict,
    centers: jax.Array,
    contexts: jax.Array,
    table: dict,
    key: jax.Array,
    lr: jax.Array,
    *,
    negatives: int = 5,
    block_pairs: int = 256,
    hot_rows: int = 256,
    ring_depth: int = NUM_SLOTS,
    interpret: bool = True,
) -> tuple[dict, jax.Array]:
    """One SGNS step through the frequency-tiered hot/cold engine.

    Same contract as
    :func:`repro.kernels.sgns_fused_pipe.sgns_fused_pipe_step` — and
    bit-identical to it (and to ``sgns_fused_hbm_step`` / the per-block
    sparse reference on the replayed negatives) at every ``hot_rows``
    setting. ``hot_rows`` is clamped to ``[0, V]``: 0 delegates to the
    pure pipeline, ``V`` is pure-VMEM-resident (zero per-block row
    DMAs). One ``pallas_call`` covers the whole batch.
    """
    V, d = params["W"].shape
    kH = max(0, min(int(hot_rows), V))
    if kH == 0:
        return sgns_fused_pipe_step(
            params, centers, contexts, table, key, lr, negatives=negatives,
            block_pairs=block_pairs, ring_depth=ring_depth,
            interpret=interpret)

    B = centers.shape[0]
    K = negatives
    seed = _as_seed(key)
    neg_ids = fused_negative_ids(seed, table["prob"], table["alias"], (B, K))
    plan = plan_blocks(centers, contexts, neg_ids, V, block_pairs,
                       hot_rows=kH, ring_depth=ring_depth)
    nblocks, blk = plan.nblocks, plan.block_pairs
    S = ring_depth

    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    vmem = functools.partial(pl.BlockSpec, memory_space=pltpu.VMEM)
    out = pl.pallas_call(
        functools.partial(_tiered_kernel, nblocks, K, S, kH),
        in_specs=[
            smem(),                                 # lr (1,)
            smem(), smem(), smem(),                 # n_w, n_c, hazard
            vmem(), vmem(),                         # uw, uc
            vmem(), vmem(), vmem(),                 # w_pos, cp_pos, cn_pos
            vmem(),                                 # mask
            vmem(), vmem(), vmem(),                 # cen, ctx, neg ids
            pl.BlockSpec(memory_space=pltpu.ANY),   # W (HBM)
            pl.BlockSpec(memory_space=pltpu.ANY),   # C (HBM)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            vmem(),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((V, d), params["W"].dtype),
            jax.ShapeDtypeStruct((V, d), params["C"].dtype),
            jax.ShapeDtypeStruct((nblocks, blk), jnp.float32),
        ],
        # in-place tables: HBM operands 13, 14 alias outputs 0, 1
        input_output_aliases={13: 0, 14: 1},
        scratch_shapes=[
            pltpu.VMEM((S, blk, d), jnp.float32),            # cold W rows
            pltpu.VMEM((S, blk * (K + 1), d), jnp.float32),  # cold C rows
            pltpu.VMEM((kH + 1, d), jnp.float32),            # hot W + spill
            pltpu.VMEM((kH + 1, d), jnp.float32),            # hot C + spill
            pltpu.SemaphoreType.DMA((S,)),                   # gathers
            pltpu.SemaphoreType.DMA((S,)),                   # scatters
            pltpu.SemaphoreType.DMA((2,)),                   # hot prefix
        ],
        interpret=interpret,
    )(jnp.reshape(lr, (1,)).astype(jnp.float32),
      plan.n_w, plan.n_c, plan.hazard,
      plan.uw, plan.uc, plan.w_pos, plan.cp_pos, plan.cn_pos, plan.mask,
      plan.cen, plan.ctx, plan.neg,
      params["W"], params["C"])
    # padded pairs were masked to exactly-zero loss, so the batch mean
    # divides by the true pair count
    return {"W": out[0], "C": out[1]}, jnp.sum(out[2]) / B
