"""Pallas TPU kernel: fused SGNS forward + backward on gathered rows.

This is the paper's compute hot-spot: billions of
``(center, context, k·negatives)`` micro-updates. On a CPU cluster these
are sparse scatter ops; on TPU the idiomatic shape is:

    gather rows (XLA) → **fused VMEM tile kernel** (this file) → scatter-add (XLA)

The kernel streams blocks of ``Bt`` training pairs through VMEM, holding
the center row, positive-context row and K negative rows of each pair,
and computes the stable ``log σ`` loss *and* all three row gradients in
one pass — logits, sigmoids and per-row grads never round-trip to HBM.
Arithmetic intensity is O(K) FLOPs/byte, so the kernel is VPU/bandwidth
bound by construction; the win over the unfused jnp path is the removal
of HBM traffic for the (B,K) logit/grad intermediates, not MXU math.

Tiling: grid over pair blocks; the full (lane-padded) embedding dim per
tile. ``Bt`` is chosen so the working set fits comfortably in ~16 MB
VMEM. D must be a multiple of 128 (the wrapper in ops.py pads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block_b(B: int, K: int, D: int, vmem_budget: int = 8 * 2**20) -> int:
    """Largest power-of-two pair-block that fits the VMEM working set
    *and divides B* (so the grid covers the batch exactly).

    Working set per pair (f32 in + out): 2·(2+2K+2)·D·4 bytes-ish; be
    conservative: (4 + 2K) rows of D floats, in+out → ×2. For a
    non-pow2 B the block halves until it divides B (down to 1) — the
    ops.py wrapper instead pads B up to a block multiple, which keeps
    the preferred ≥8 block size.
    """
    bytes_per_pair = (4 + 2 * K) * D * 4 * 2
    bt = vmem_budget // max(bytes_per_pair, 1)
    bt = 1 << max(int(bt).bit_length() - 1, 3)  # floor pow2, min 8
    bt = min(bt, 256)
    if bt > B:
        bt = 1 << max(B.bit_length() - 1, 0)    # floor pow2 ≤ B
    while B % bt:                               # clamp to a divisor of B
        bt >>= 1
    return int(bt)


def _sgns_kernel(w_ref, cp_ref, cn_ref, loss_ref, dw_ref, dcp_ref, dcn_ref):
    w = w_ref[...].astype(jnp.float32)        # (Bt, D)
    cp = cp_ref[...].astype(jnp.float32)      # (Bt, D)
    cn = cn_ref[...].astype(jnp.float32)      # (Bt, K, D)

    s_pos = jnp.sum(w * cp, axis=-1)                       # (Bt,)
    s_neg = jnp.sum(w[:, None, :] * cn, axis=-1)           # (Bt, K)

    # stable softplus/sigmoid
    def softplus(x):
        return jnp.maximum(x, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(x)))

    loss = softplus(-s_pos) + jnp.sum(softplus(s_neg), axis=-1)
    g_pos = jax.nn.sigmoid(s_pos) - 1.0                    # (Bt,)
    g_neg = jax.nn.sigmoid(s_neg)                          # (Bt, K)

    dw = g_pos[:, None] * cp + jnp.sum(g_neg[:, :, None] * cn, axis=1)
    dcp = g_pos[:, None] * w
    dcn = g_neg[:, :, None] * w[:, None, :]

    loss_ref[...] = loss[:, None]
    dw_ref[...] = dw.astype(dw_ref.dtype)
    dcp_ref[...] = dcp.astype(dcp_ref.dtype)
    dcn_ref[...] = dcn.astype(dcn_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def sgns_row_grads_kernel(
    w: jax.Array,
    c_pos: jax.Array,
    c_neg: jax.Array,
    *,
    block_b: int | None = None,
    interpret: bool = False,
):
    """Fused SGNS fwd+bwd. Shapes: w (B,D), c_pos (B,D), c_neg (B,K,D).

    Requires D % 128 == 0 and B % block_b == 0 (ops.py pads). Returns
    (per-pair loss (B,), dW (B,D), dC_pos (B,D), dC_neg (B,K,D)).
    """
    B, D = w.shape
    K = c_neg.shape[1]
    if D % 128 != 0:
        raise ValueError(f"embedding dim {D} must be lane-aligned (128)")
    bt = block_b or _pick_block_b(B, K, D)
    if B % bt != 0:
        raise ValueError(f"batch {B} not divisible by block {bt}")

    grid = (B // bt,)
    row = pl.BlockSpec((bt, D), lambda i: (i, 0))
    neg = pl.BlockSpec((bt, K, D), lambda i: (i, 0, 0))
    lss = pl.BlockSpec((bt, 1), lambda i: (i, 0))

    loss, dw, dcp, dcn = pl.pallas_call(
        _sgns_kernel,
        grid=grid,
        in_specs=[row, row, neg],
        out_specs=[lss, row, row, neg],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(c_pos.shape, c_pos.dtype),
            jax.ShapeDtypeStruct(c_neg.shape, c_neg.dtype),
        ],
        interpret=interpret,
    )(w, c_pos, c_neg)
    return loss[:, 0], dw, dcp, dcn
