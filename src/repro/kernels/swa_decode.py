"""Pallas TPU kernel: flash-style single-token sliding-window decode.

The hot op of the ``long_500k`` shape for dense archs: one query token
attends a ring-buffer KV cache of window W (8192 by default). Naive
jnp materializes the (B,H,W) score tensor in HBM; this kernel streams
W in VMEM-sized chunks with the online-softmax (running max / sum /
accumulator in VMEM scratch), so scores never touch HBM and the op runs
at HBM-bandwidth reading K/V once.

Assumes the steady state of long-context decode: the ring buffer is
full (every slot valid) — exactly the regime the shape exercises.
Grid: (batch, window-chunks); the output block revisits per chunk and
the accumulators live in scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, n_chunks: int, scale: float):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (H, D)
    k = k_ref[0].astype(jnp.float32)          # (Tw, H, D)
    v = v_ref[0].astype(jnp.float32)          # (Tw, H, D)

    s = jnp.sum(q[None, :, :] * k, axis=-1) * scale        # (Tw, H)
    m_prev = m_ref[...]                                     # (H,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=0))
    alpha = jnp.exp(m_prev - m_new)                         # (H,)
    p = jnp.exp(s - m_new[None, :])                         # (Tw, H)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=0)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.sum(
        p[:, :, None] * v, axis=0)                          # (H, D)
    m_ref[...] = m_new

    @pl.when(w == n_chunks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def swa_decode_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      chunk: int = 512, interpret: bool = False) -> jax.Array:
    """q (B,H,D), k/v (B,W,H,D), W % chunk == 0 → out (B,H,D)."""
    B, H, D = q.shape
    W = k.shape[1]
    if W % chunk != 0:
        raise ValueError(f"window {W} not divisible by chunk {chunk}")
    n_chunks = W // chunk
    scale = 1.0 / (D ** 0.5)

    kern = functools.partial(_swa_kernel, n_chunks=n_chunks, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, w: (b, 0, 0)),
            pl.BlockSpec((1, chunk, H, D), lambda b, w: (b, w, 0, 0)),
            pl.BlockSpec((1, chunk, H, D), lambda b, w: (b, w, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, w: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),     # running max m
            pltpu.VMEM((H,), jnp.float32),     # running sum l
            pltpu.VMEM((H, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
