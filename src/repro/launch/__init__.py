"""Launch layer: meshes, dry-run, roofline, training/serving drivers.

NOTE: import ``repro.launch.dryrun`` only as a script entry point — it
sets XLA_FLAGS for 512 placeholder devices at import time.
"""
