"""LLM batched greedy decode with KV/state caches — **seed scaffolding**
(see ``docs/SEED_SCAFFOLDING.md``). Kept because the transformer smoke
tests exercise it; it is NOT the paper system's serving tier — that is
``repro.launch.serve`` over the ``repro.serve`` package.

  PYTHONPATH=src python -m repro.launch.decode_llm --arch qwen1.5-0.5b \
      --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.models import transformer as tfm


def serve(arch: str, *, reduced: bool, batch: int, prompt_len: int,
          new_tokens: int, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len), dtype=np.int32))

    cache_len = prompt_len + new_tokens
    if cfg.attention_window is not None:
        cache_len = min(cache_len, cfg.attention_window)
    enc_len = prompt_len if cfg.encoder_layers else None
    cache = model.init_cache(batch, cache_len, enc_len=enc_len)
    if cfg.encoder_layers:
        frames = jnp.zeros((batch, prompt_len, cfg.d_model),
                           jnp.dtype(cfg.dtype))
        cache = jax.jit(lambda p, f, c: tfm.prefill_encoder(p, cfg, f, c, batch)
                        )(params, frames, cache)

    step = jax.jit(model.make_decode_step())

    # prefill by decoding the prompt (cache-building pass)
    t0 = time.perf_counter()
    logits = None
    for i in range(prompt_len):
        logits, cache = step(params, cache, prompts[:, i : i + 1],
                             jnp.int32(i))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    out = []
    tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1
                         ).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": batch * new_tokens / t_decode}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args(argv)
    gen, stats = serve(args.arch, reduced=args.reduced, batch=args.batch,
                       prompt_len=args.prompt_len, new_tokens=args.new_tokens)
    print(f"generated {gen.shape} tokens; "
          f"prefill {stats['prefill_s']:.2f}s decode {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    print("first sequence:", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
