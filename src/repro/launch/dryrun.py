import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count at
# first init. 512 placeholder host devices back the production meshes
# (16×16 single pod, 2×16×16 multi-pod). Never set this in conftest —
# smoke tests and benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) case.

For each case this builds the real step function (train_step with the
arch's production optimizer and microbatching, prefill forward, or
one-token decode against a full-length cache), binds ShapeDtypeStruct
inputs carrying NamedShardings from repro.sharding.rules, compiles for
the production mesh, and prints ``memory_analysis()`` (fits?) and
``cost_analysis()`` + collective-bytes (the §Roofline inputs).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] --json out.json
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCH_IDS, SHAPES, get_config, supports_shape, config_for_shape)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl
from repro.models import Model
from repro.models import transformer as tfm
from repro.optim import get_optimizer
from repro.sharding import (
    tree_param_specs, tree_data_specs, tree_cache_specs, with_sharding)
from repro.sharding import ctx as shctx
from jax.sharding import NamedSharding, PartitionSpec as P


def _sds_key():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)


def build_case(arch_id: str, shape_name: str, mesh, *,
               variant: str = "baseline"):
    """Returns (lowered, meta) for one (arch, shape, mesh) case."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch_id)
    ok, why = supports_shape(cfg, shape_name)
    if not ok:
        raise SkipCase(why)
    cfg = config_for_shape(cfg, shape_name).with_overrides(dtype="bfloat16")
    if variant != "baseline":
        cfg = apply_variant(cfg, variant, shape_name)
    model = Model(cfg)

    params_sds = jax.eval_shape(model.init, _sds_key())
    p_specs = tree_param_specs(params_sds, mesh, fsdp=cfg.fsdp)
    params_in = with_sharding(params_sds, p_specs, mesh)

    meta = {
        "arch": arch_id, "shape": shape_name, "variant": variant,
        "params": rl.count_params(params_sds),
        "active_params": rl.active_params(cfg, params_sds),
        "model_flops": rl.model_flops_for(cfg, params_sds, shape),
    }

    if shape.kind == "train":
        opt = get_optimizer(cfg.train_optimizer)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        o_specs = tree_param_specs(opt_sds, mesh, fsdp=cfg.fsdp)
        opt_in = with_sharding(opt_sds, o_specs, mesh)
        batch_sds = model.example_batch(shape, concrete=False)
        b_specs = tree_data_specs(batch_sds, mesh)
        batch_in = with_sharding(batch_sds, b_specs, mesh)
        step_in = jax.ShapeDtypeStruct((), jnp.int32)
        # per-microbatch batch must stay divisible by the batch shards
        # (pod×data), else GSPMD unshards the batch dim inside the scan
        import math
        n_shards = math.prod(
            s for a, s in zip(mesh.axis_names, mesh.devices.shape)
            if a in ("pod", "data"))
        mb = cfg.train_microbatches
        while mb > 1 and (shape.global_batch % mb or
                          (shape.global_batch // mb) % n_shards):
            mb //= 2
        train_step = model.make_train_step(opt, microbatches=max(mb, 1))
        fn = jax.jit(
            train_step,
            out_shardings=(p_specs_to_shardings(p_specs, mesh),
                           p_specs_to_shardings(o_specs, mesh),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1))
        with mesh, shctx.use_mesh_constraints(mesh):
            lowered = fn.lower(params_in, opt_in, batch_in, step_in)
        return lowered, meta

    if shape.kind == "prefill":
        batch_sds = model.example_batch(shape, concrete=False)
        b_specs = tree_data_specs(batch_sds, mesh)
        batch_in = with_sharding(batch_sds, b_specs, mesh)

        def prefill(params, batch):
            logits, _, _ = tfm.forward_logits(params, cfg, batch)
            return logits

        with mesh, shctx.use_mesh_constraints(mesh):
            lowered = jax.jit(prefill).lower(params_in, batch_in)
        return lowered, meta

    # decode
    B = shape.global_batch
    cache_len = model.decode_cache_len(shape)
    enc_len = shape.seq_len if cfg.encoder_layers else None
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, cache_len, enc_len=enc_len))
    c_specs = tree_cache_specs(cache_sds, mesh)
    cache_in = with_sharding(cache_sds, c_specs, mesh)
    tok_in = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=NamedSharding(mesh, tree_data_specs(
            jax.ShapeDtypeStruct((B, 1), jnp.int32), mesh)))
    pos_in = jax.ShapeDtypeStruct((), jnp.int32)
    decode = model.make_decode_step()
    fn = jax.jit(decode,
                 out_shardings=(NamedSharding(mesh, P()),
                                p_specs_to_shardings(c_specs, mesh)),
                 donate_argnums=(1,))
    with mesh, shctx.use_mesh_constraints(mesh):
        lowered = fn.lower(params_in, cache_in, tok_in, pos_in)
    meta["cache_len"] = cache_len
    return lowered, meta


def p_specs_to_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


class SkipCase(Exception):
    pass


# ---------------------------------------------------------------------------
# Variants for §Perf hillclimbing (beyond-paper optimizations).
# ---------------------------------------------------------------------------
def apply_variant(cfg, variant: str, shape_name: str):
    from dataclasses import replace
    if variant == "no_remat":
        return cfg.with_overrides(remat=False)
    if variant == "remat_per_layer":
        return cfg.with_overrides(remat_per_layer=True)
    if variant == "no_fsdp":          # pure TP × DP (no ZeRO-3 regather)
        return cfg.with_overrides(fsdp=False)
    if variant == "seq_mlstm":        # xlstm pre-optimization baseline
        return cfg.with_overrides(
            ssm=replace(cfg.ssm, mlstm_chunk=0, slstm_segment=0))
    if variant == "no_slstm_segment":
        return cfg.with_overrides(ssm=replace(cfg.ssm, slstm_segment=0))
    if variant.startswith("mlstm_chunk_"):
        return cfg.with_overrides(
            ssm=replace(cfg.ssm, mlstm_chunk=int(variant.rsplit("_", 1)[1])))
    if variant == "more_microbatch":
        return cfg.with_overrides(
            train_microbatches=cfg.train_microbatches * 2)
    if variant == "less_microbatch":
        return cfg.with_overrides(
            train_microbatches=max(1, cfg.train_microbatches // 2))
    if variant == "ungrouped_moe":   # pre-optimization MoE dispatch
        return cfg.with_overrides(moe=replace(cfg.moe, groups=1))
    if variant.startswith("capacity_"):
        f = float(variant.split("_", 1)[1])
        return cfg.with_overrides(moe=replace(cfg.moe, capacity_factor=f))
    raise ValueError(f"unknown variant {variant!r}")


# ---------------------------------------------------------------------------
def run_case(arch_id: str, shape_name: str, *, multi_pod: bool,
             variant: str = "baseline", verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    lowered, meta = build_case(arch_id, shape_name, mesh, variant=variant)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    # On the (pod=2,data=16,model=16) mesh, replica groups containing the
    # pod axis have sizes {2, 32, 512} — those cross DCN.
    dcn_sizes = frozenset({2, 32, 512}) if multi_pod else frozenset()
    r = rl.analyze(arch_id, shape_name, compiled, chips,
                   model_flops=meta["model_flops"],
                   dcn_group_sizes=dcn_sizes or None)
    row = r.row()
    row.update(variant=variant, multi_pod=multi_pod,
               params=meta["params"], active_params=meta["active_params"],
               lower_s=t1 - t0, compile_s=t2 - t1)
    if verbose:
        mem = compiled.memory_analysis()
        print(f"== {arch_id} × {shape_name} ({'2x16x16' if multi_pod else '16x16'}"
              f", variant={variant})")
        print(f"   params={meta['params']/1e9:.2f}B "
              f"active={meta['active_params']/1e9:.2f}B "
              f"lower={t1-t0:.1f}s compile={t2-t1:.1f}s")
        print(f"   memory_analysis: {mem}")
        print(f"   cost_analysis: flops/chip={r.flops_per_chip:.3e} "
              f"bytes/chip={r.bytes_per_chip:.3e}")
        print(f"   collectives: {r.collectives.count_by_op} "
              f"bytes/chip={r.collective_bytes_per_chip:.3e}")
        print(f"   roofline: compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s"
              f" collective={r.collective_s:.3e}s → {r.dominant}-bound; "
              f"MODEL/HLO flops={r.flops_utilization:.3f}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {tuple(SHAPES)} or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--json", default=None, help="append rows to this file")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else (args.arch,)
    shapes = tuple(SHAPES) if args.shape == "all" else (args.shape,)

    rows = []
    for a in archs:
        for s in shapes:
            try:
                rows.append(run_case(a, s, multi_pod=args.multi_pod,
                                     variant=args.variant))
            except SkipCase as e:
                print(f"== {a} × {s}: SKIP ({e})")
                rows.append({"arch": a, "shape": s, "skipped": str(e),
                             "variant": args.variant,
                             "multi_pod": args.multi_pod})
            except Exception:
                print(f"== {a} × {s}: FAILED")
                traceback.print_exc()
                rows.append({"arch": a, "shape": s, "failed": True,
                             "variant": args.variant,
                             "multi_pod": args.multi_pod})
    if args.json:
        existing = []
        if os.path.exists(args.json):
            existing = json.load(open(args.json))
        json.dump(existing + rows, open(args.json, "w"), indent=1)
    ok_rows = [r for r in rows if "compute_s" in r]
    if ok_rows:
        print()
        print(rl.format_table(ok_rows))
    failed = [r for r in rows if r.get("failed")]
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
