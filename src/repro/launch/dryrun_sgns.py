import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax import (see dryrun.py).

"""Dry-run + roofline for the paper's own workload: SGNS word-embedding
training at production scale (vocab 300k, dim 500) on a 256-chip pod.

Cases:
  async          — the paper: 256 sub-models, one per chip, shard_map
                   over the 'worker' axis, `sparse` engine with the
                   inverse-CDF draw. The compiled epoch is asserted to
                   contain ZERO collectives.
  async_alias    — `sparse:alias` engine: the O(1) alias draw replacing
                   the O(log V) CDF binary search. Compare this row's
                   HLO cost against `async` (ROADMAP item 4) — same
                   zero-collective property, less per-step HLO.
  async_fused    — `pallas_fused` engine: the alias draw moves *inside*
                   the step kernel; negative ids and (B,K) logit/grad
                   intermediates never appear as HBM arrays.
  async_fused_hbm— `pallas_fused_hbm` engine: the fused step with the
                   (V, d) tables *HBM-resident* — a grid of pair blocks
                   DMA-gathers/scatters only the touched rows, which is
                   what makes the 300k×500 sub-model shape of this very
                   dry-run feasible per worker. Same zero-collective
                   assertion as every async engine.
  async_fused_pipe— `pallas_fused_pipe` engine: the HBM-resident step
                   with the double-buffered DMA pipeline — deduped row
                   gathers/write-backs on a 2-slot VMEM ring, block
                   b+1's gathers in flight while block b computes,
                   hazard-ordered by the pure-JAX block planner. Same
                   zero-collective assertion (the planner is local
                   sort/searchsorted work, no communication).
  async_fused_tiered— `pallas_fused_tiered` engine: the pipelined step
                   with frequency-tiered placement — the hot_rows
                   hottest rows (the frequency-sorted id prefix) pinned
                   VMEM-resident for the whole step, cold rows behind
                   the same DMA ring. Per-worker tables are private, so
                   the hot tier needs no synchronization: the same
                   zero-collective assertion holds.
  sync           — the synchronized strawman (Hogwild/MLLib stand-in):
                   data-parallel minibatch SGNS, dense-gradient psum
                   every step (the 600 MB/step the paper eliminates).
  local_sgd_k    — beyond-paper: parameter averaging every k steps
                   (collective term ∝ 1/k; the paper is k→∞ + ALiR).
  merge          — the one-time ALiR merge phase, sharded over workers
                   (per-model Procrustes local, one all-reduce for Y).

Usage: python -m repro.launch.dryrun_sgns [--json out.json]
       [--cases async,async_alias,...] [--workers N --steps S --batch B]
       [--processes P] [--plan-only]

``--plan-only`` prints the per-host ingestion shard plans and exits
without lowering any case — the cheap multi-host smoke CI runs with
``--processes 4``.
"""

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.sgns_wiki import CONFIG as SGNS_CFG
from repro.core.async_trainer import (
    AsyncShardTrainer, make_sync_epoch, make_periodic_sync_epoch,
    assert_no_collectives)
from repro.core import merge as mg
from repro.launch.mesh import make_worker_mesh
from repro.launch import roofline as rl

WORKERS = 256
STEPS = 128          # steps per lowered epoch (collectives scale linearly)
BATCH = 1024         # pairs per worker per step

ASYNC_ENGINES = {
    "async": "sparse",            # inverse-CDF draw (the PR-1 baseline)
    "async_alias": "sparse:alias",
    "async_pallas": "pallas",
    "async_fused": "pallas_fused",
    "async_fused_hbm": "pallas_fused_hbm",
    "async_fused_pipe": "pallas_fused_pipe",
    "async_fused_tiered": "pallas_fused_tiered",
}


def sds(mesh, shape, dtype, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def lower_async(mesh, workers, steps, batch, engine="sparse"):
    trainer = AsyncShardTrainer(
        cfg=SGNS_CFG, num_workers=workers, total_steps=steps,
        backend="shard_map", mesh=mesh, engine=engine)
    return trainer.lower_epoch(steps, batch)


def lower_sync(mesh, workers, steps, batch):
    neg_cdf = jnp.linspace(0, 1, SGNS_CFG.vocab_size, dtype=jnp.float32)
    epoch = make_sync_epoch(SGNS_CFG, neg_cdf, steps, mesh=mesh,
                            data_axis="worker")
    V, d = SGNS_CFG.vocab_size, SGNS_CFG.dim
    params = {"W": sds(mesh, (V, d), jnp.float32, P()),
              "C": sds(mesh, (V, d), jnp.float32, P())}
    c = sds(mesh, (steps, workers * batch), jnp.int32, P(None, "worker"))
    key = sds(mesh, (2,), jnp.uint32, P())
    step0 = jax.ShapeDtypeStruct((), jnp.int32)
    return epoch.lower(params, c, c, key, step0)


def lower_local_sgd(mesh, workers, steps, batch, k: int):
    neg_cdf = jnp.linspace(0, 1, SGNS_CFG.vocab_size, dtype=jnp.float32)
    epoch = make_periodic_sync_epoch(SGNS_CFG, neg_cdf, steps, k, mesh,
                                     data_axis="worker")
    V, d = SGNS_CFG.vocab_size, SGNS_CFG.dim
    params = {"W": sds(mesh, (V, d), jnp.float32, P()),
              "C": sds(mesh, (V, d), jnp.float32, P())}
    c = sds(mesh, (steps // k, k, workers * batch), jnp.int32,
            P(None, None, "worker"))
    key = sds(mesh, (2,), jnp.uint32, P())
    step0 = jax.ShapeDtypeStruct((), jnp.int32)
    return epoch.lower(params, c, c, key, step0)


def lower_merge(mesh, workers, steps, batch):
    """One ALiR iteration over worker-sharded sub-models."""
    V, d = SGNS_CFG.vocab_size, SGNS_CFG.dim

    def one_iter(models, mask, Y):
        Y_new, disp, _ = mg._alir_iteration(Y, models, mask)
        return Y_new, disp

    models = sds(mesh, (workers, V, d), jnp.float32, P("worker"))
    mask = sds(mesh, (workers, V), jnp.bool_, P("worker"))
    Y = sds(mesh, (V, d), jnp.float32, P())
    return jax.jit(one_iter).lower(models, mask, Y)


def run(case: str, mesh, workers=WORKERS, steps=STEPS, batch=BATCH,
        vmem_budget_mb: float = 0.0) -> dict:
    if case.startswith("local_sgd_"):
        # the lowered program runs whole sync periods only — round the
        # step count so the roofline pairs/model_flops match it
        k = int(case.rsplit("_", 1)[1])
        steps = max(steps // k, 1) * k
    if case in ASYNC_ENGINES:
        # static VMEM footprint at this run's shape: report always,
        # enforce when a budget is given (async_fused is legitimately
        # over-budget at the 300k×500 shape — exactly why the
        # HBM-resident family exists — so the default is report-only)
        from repro.analysis.vmem import check_vmem_budget, estimate_vmem

        if vmem_budget_mb:
            est = check_vmem_budget(
                ASYNC_ENGINES[case], vocab_size=SGNS_CFG.vocab_size,
                dim=SGNS_CFG.dim, negatives=SGNS_CFG.negatives, batch=batch,
                budget_bytes=int(vmem_budget_mb * 2 ** 20))
        else:
            est = estimate_vmem(
                ASYNC_ENGINES[case], vocab_size=SGNS_CFG.vocab_size,
                dim=SGNS_CFG.dim, negatives=SGNS_CFG.negatives, batch=batch)
        print(f"   vmem: {est.summary()}")
        lowered = lower_async(mesh, workers, steps, batch,
                              engine=ASYNC_ENGINES[case])
        # every async engine keeps the paper's headline property —
        # certified by the structured op-walk, not the old HLO regex
        assert_no_collectives(lowered)
    else:
        lowered = {
            "sync": lower_sync,
            "local_sgd_8": lambda m, w, s, b: lower_local_sgd(m, w, s, b, 8),
            "local_sgd_64": lambda m, w, s, b: lower_local_sgd(m, w, s, b, 64),
            "merge_alir_iter": lower_merge,
        }[case](mesh, workers, steps, batch)
    compiled = lowered.compile()
    # model flops: per epoch, 2 tables × (K+1) dots fwd+bwd ≈ 6·B·(K+1)·d
    pairs = workers * batch * steps
    model_flops = 6.0 * pairs * (SGNS_CFG.negatives + 1) * SGNS_CFG.dim
    r = rl.analyze(f"sgns-{case}", f"epoch{steps}", compiled, workers,
                   model_flops=model_flops)
    row = r.row()
    row["collective_ops"] = dict(r.collectives.count_by_op)
    print(f"== sgns/{case}: compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s"
          f" collective={r.collective_s:.3e}s → {r.dominant}"
          f" | collectives={row['collective_ops']}")
    return row


def print_ingestion_plans(workers: int, processes: int, steps: int,
                          batch: int) -> list:
    """Per-host ingestion shard plans for the run's worker count: which
    workers each host extracts and the per-chunk block it contributes to
    `make_array_from_process_local_data`. Pure planning — works for any
    simulated `--processes` on a single-process dry-run."""
    from repro.data.pipeline import HostShardPlan

    plans = HostShardPlan.all_hosts(processes, workers)
    print(f"== ingestion plan: {workers} workers over {processes} host(s)")
    for plan in plans:
        block_mb = plan.num_local * steps * batch * 4 * 2 / 1e6  # c + x int32
        print(f"   {plan.describe()} — chunk block "
              f"({plan.num_local}, {steps}, {batch}) ×2 int32 "
              f"= {block_mb:.1f} MB/chunk")
    owned = sorted(w for p in plans for w in p.workers)
    assert owned == list(range(workers)), "plans must cover each worker once"
    return plans


def compare_sampler_paths(rows: list[dict]) -> None:
    """ROADMAP item 4: alias vs CDF negative-draw HLO cost, side by side.
    Both async rows are collective-free by assertion, so the comparison
    is purely the per-chip compute/memory roofline terms."""
    by_case = {r["arch"]: r for r in rows}
    base = by_case.get("sgns-async")
    for other in ("sgns-async_alias", "sgns-async_fused",
                  "sgns-async_fused_hbm", "sgns-async_fused_pipe",
                  "sgns-async_fused_tiered"):
        r = by_case.get(other)
        if not (base and r):
            continue
        dc = r["compute_s"] / max(base["compute_s"], 1e-30)
        dm = r["memory_s"] / max(base["memory_s"], 1e-30)
        print(f"-- {other[5:]} vs async (cdf draw): "
              f"compute ×{dc:.3f}, memory ×{dm:.3f} "
              f"(both zero-collective)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--cases",
                    default="async,async_alias,sync,local_sgd_8,"
                            "local_sgd_64,merge_alir_iter",
                    help="comma list; also available: async_pallas, "
                         "async_fused, async_fused_hbm, async_fused_pipe, "
                         "async_fused_tiered")
    ap.add_argument("--workers", type=int, default=WORKERS)
    ap.add_argument("--steps", type=int, default=STEPS)
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--processes", type=int, default=None,
                    help="ingestion hosts to plan for (default: "
                         "jax.process_count(); any count can be simulated)")
    ap.add_argument("--plan-only", action="store_true",
                    help="print the per-host ingestion plans and exit "
                         "(no case lowering — the CI multi-host smoke)")
    ap.add_argument("--vmem-budget-mb", type=float, default=0.0,
                    help="reject async cases whose static VMEM estimate "
                         "exceeds this budget (0 = report only; "
                         "async_fused at the 300k×500 dry-run shape is "
                         "over any realistic budget by design)")
    args = ap.parse_args(argv)
    processes = (args.processes if args.processes is not None
                 else jax.process_count())
    plans = print_ingestion_plans(args.workers, processes, args.steps,
                                  args.batch)
    if args.plan_only:
        assert plans, "ingestion planning produced no per-host plans"
        return
    mesh = make_worker_mesh(args.workers)
    rows = [run(c, mesh, args.workers, args.steps, args.batch,
                vmem_budget_mb=args.vmem_budget_mb)
            for c in args.cases.split(",")]
    compare_sampler_paths(rows)
    if args.json:
        existing = json.load(open(args.json)) if os.path.exists(args.json) else []
        json.dump(existing + rows, open(args.json, "w"), indent=1)


if __name__ == "__main__":
    main()
