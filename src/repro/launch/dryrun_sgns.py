import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax import (see dryrun.py).

"""Dry-run + roofline for the paper's own workload: SGNS word-embedding
training at production scale (vocab 300k, dim 500) on a 256-chip pod.

Cases:
  async        — the paper: 256 sub-models, one per chip, shard_map over
                 the 'worker' axis. The compiled epoch is asserted to
                 contain ZERO collectives.
  sync         — the synchronized strawman (Hogwild/MLLib stand-in):
                 data-parallel minibatch SGNS, dense-gradient psum every
                 step (the 600 MB/step the paper eliminates).
  local_sgd_k  — beyond-paper: parameter averaging every k steps
                 (collective term ∝ 1/k; the paper is k→∞ + ALiR merge).
  merge        — the one-time ALiR merge phase, sharded over workers
                 (per-model Procrustes local, one all-reduce for Y).

Usage: python -m repro.launch.dryrun_sgns [--json out.json]
"""

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.sgns_wiki import CONFIG as SGNS_CFG
from repro.core.async_trainer import (
    AsyncShardTrainer, make_sync_epoch, make_periodic_sync_epoch,
    assert_no_collectives, count_collective_ops)
from repro.core import merge as mg
from repro.launch.mesh import make_worker_mesh
from repro.launch import roofline as rl

WORKERS = 256
STEPS = 128          # steps per lowered epoch (collectives scale linearly)
BATCH = 1024         # pairs per worker per step


def sds(mesh, shape, dtype, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def lower_async(mesh):
    trainer = AsyncShardTrainer(
        cfg=SGNS_CFG, num_workers=WORKERS, total_steps=STEPS,
        backend="shard_map", mesh=mesh)
    return trainer.lower_epoch(STEPS, BATCH)


def lower_sync(mesh):
    neg_cdf = jnp.linspace(0, 1, SGNS_CFG.vocab_size, dtype=jnp.float32)
    epoch = make_sync_epoch(SGNS_CFG, neg_cdf, STEPS, mesh=mesh,
                            data_axis="worker")
    V, d = SGNS_CFG.vocab_size, SGNS_CFG.dim
    params = {"W": sds(mesh, (V, d), jnp.float32, P()),
              "C": sds(mesh, (V, d), jnp.float32, P())}
    c = sds(mesh, (STEPS, WORKERS * BATCH), jnp.int32, P(None, "worker"))
    key = sds(mesh, (2,), jnp.uint32, P())
    step0 = jax.ShapeDtypeStruct((), jnp.int32)
    return epoch.lower(params, c, c, key, step0)


def lower_local_sgd(mesh, k: int):
    neg_cdf = jnp.linspace(0, 1, SGNS_CFG.vocab_size, dtype=jnp.float32)
    epoch = make_periodic_sync_epoch(SGNS_CFG, neg_cdf, STEPS, k, mesh,
                                     data_axis="worker")
    V, d = SGNS_CFG.vocab_size, SGNS_CFG.dim
    params = {"W": sds(mesh, (V, d), jnp.float32, P()),
              "C": sds(mesh, (V, d), jnp.float32, P())}
    c = sds(mesh, (STEPS // k, k, WORKERS * BATCH), jnp.int32,
            P(None, None, "worker"))
    key = sds(mesh, (2,), jnp.uint32, P())
    step0 = jax.ShapeDtypeStruct((), jnp.int32)
    return epoch.lower(params, c, c, key, step0)


def lower_merge(mesh):
    """One ALiR iteration over worker-sharded sub-models."""
    V, d = SGNS_CFG.vocab_size, SGNS_CFG.dim

    def one_iter(models, mask, Y):
        Y_new, disp, _ = mg._alir_iteration(Y, models, mask)
        return Y_new, disp

    models = sds(mesh, (WORKERS, V, d), jnp.float32, P("worker"))
    mask = sds(mesh, (WORKERS, V), jnp.bool_, P("worker"))
    Y = sds(mesh, (V, d), jnp.float32, P())
    return jax.jit(one_iter).lower(models, mask, Y)


def run(case: str, mesh) -> dict:
    lowered = {
        "async": lower_async,
        "sync": lower_sync,
        "local_sgd_8": lambda m: lower_local_sgd(m, 8),
        "local_sgd_64": lambda m: lower_local_sgd(m, 64),
        "merge_alir_iter": lower_merge,
    }[case](mesh)
    if case == "async":
        assert_no_collectives(lowered)   # the paper's headline property
    compiled = lowered.compile()
    # model flops: per epoch, 2 tables × (K+1) dots fwd+bwd ≈ 6·B·(K+1)·d
    pairs = WORKERS * BATCH * STEPS
    model_flops = 6.0 * pairs * (SGNS_CFG.negatives + 1) * SGNS_CFG.dim
    r = rl.analyze(f"sgns-{case}", "epoch128", compiled, WORKERS,
                   model_flops=model_flops)
    row = r.row()
    row["collective_ops"] = dict(r.collectives.count_by_op)
    print(f"== sgns/{case}: compute={r.compute_s:.3e}s memory={r.memory_s:.3e}s"
          f" collective={r.collective_s:.3e}s → {r.dominant}"
          f" | collectives={row['collective_ops']}")
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--cases", default="async,sync,local_sgd_8,local_sgd_64,merge_alir_iter")
    args = ap.parse_args(argv)
    mesh = make_worker_mesh(WORKERS)
    rows = [run(c, mesh) for c in args.cases.split(",")]
    if args.json:
        existing = json.load(open(args.json)) if os.path.exists(args.json) else []
        json.dump(existing + rows, open(args.json, "w"), indent=1)


if __name__ == "__main__":
    main()
