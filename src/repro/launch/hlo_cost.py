"""Trip-count-aware static cost model over compiled HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scanned program (scan-over-layers, microbatch accumulation, recurrent
seq scans) is under-reported by its trip count. This walker parses the
post-optimization HLO, multiplies loop bodies by their
``known_trip_count`` (emitted by XLA for lax.scan loops), recurses into
fusions/calls, and produces:

  * flops          — 2·M·N·K for dots, |out| for elementwise/reductions
  * bytes          — HBM traffic model: operand+output bytes at fusion /
                     top-level op boundaries (reads inside a fusion stay
                     in registers/VMEM, which is the point of fusion)
  * collective_bytes / counts per op kind, with trip multipliers, and a
    ``dcn_bytes`` split for replica groups that span more than one pod
    (detected from the group-size annotation vs. pod size).

Everything is per-partition (the SPMD module is the per-device program),
matching the roofline formulas in repro.launch.roofline.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

_SHAPE_ATOM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")

ELEMENTWISE_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "reshape", "domain", "opt-barrier", "get-dimension-size",
}


def shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_ATOM.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str          # operand list + attributes (rest of line)

    def operand_names(self) -> list[str]:
        # operands are before the first "), " attr boundary; just scan the
        # call-paren region
        depth = 0
        end = len(self.rest)
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return _OPERAND_NAME.findall(self.rest[:end])


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        m = _COMP_HDR.match(s.strip()) if s.strip().endswith("{") else None
        if m and ("->" in s):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_LINE.match(s)
        if om:
            op = Op(om.group(1), om.group(2), om.group(3), om.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_counts: dict = field(default_factory=dict)
    dcn_bytes: float = 0.0
    warnings: list = field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.dcn_bytes += mult * other.dcn_bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + mult * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + mult * v
        self.warnings.extend(other.warnings)

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


class HloCostModel:
    def __init__(self, text: str, pod_chips: int | None = None,
                 dcn_group_sizes: frozenset | None = None):
        """``dcn_group_sizes``: replica-group sizes that must cross pods
        (on the (pod=2,data=16,model=16) mesh: axis subsets containing
        'pod' give sizes {2, 32, 512}); in-pod groups (16, 256) don't.
        Falls back to 'larger than a pod' when not provided."""
        self.comps = parse_module(text)
        self.pod_chips = pod_chips
        self.dcn_group_sizes = dcn_group_sizes
        self._memo: dict[tuple[str, bool], Cost] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
                if m:
                    entry = m.group(1)
                break
        # fall back: computation named like main
        self.entry = entry or next(
            (n for n in self.comps if n.startswith("main")), None)
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))

    # -------------------------------------------------------------- flops
    def _dot_flops(self, op: Op, comp: Computation) -> float:
        _, out_elems = shape_elems_bytes(op.shape)[0], None
        out_elems = shape_elems_bytes(op.shape)[0]
        m = _CONTRACT_RE.search(op.rest)
        contract = 1
        names = op.operand_names()
        if m and names:
            lhs_shape = comp.shapes.get(names[0], "")
            atoms = _SHAPE_ATOM.findall(lhs_shape)
            if atoms:
                dims = [int(d) for d in atoms[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def _operand_bytes(self, op: Op, comp: Computation) -> float:
        total = 0
        for n in op.operand_names():
            sh = comp.shapes.get(n)
            if sh:
                total += shape_elems_bytes(sh)[1]
        return float(total)

    def _fusion_io_bytes(self, op: Op, comp: Computation,
                         inner_name: str | None, out_bytes: int) -> float:
        """HBM traffic of a fusion's operands, modelling XLA aliasing:

        * a fusion whose root is a dynamic-update-slice writing into an
          operand of the SAME shape is an in-place scan-stacking write —
          only the updated slice moves, not the whole (S, …) buffer;
        * a fusion that dynamic-slices/gathers a big operand down to a
          much smaller output only reads the slice.
        Without this, scan forward/backward stacking is charged the full
        buffer per step — a ~S× overcount (observed 343 GB→8 GB case).
        """
        inner = self.comps.get(inner_name) if inner_name else None
        dus_update_bytes = None
        has_big_slice_read = False
        if inner is not None:
            for iop in inner.ops:
                if iop.opcode == "dynamic-update-slice":
                    names = iop.operand_names()
                    if len(names) >= 2:
                        upd = inner.shapes.get(names[1])
                        if upd:
                            b = shape_elems_bytes(upd)[1]
                            dus_update_bytes = (dus_update_bytes or 0) + b
                elif iop.opcode in ("dynamic-slice", "gather"):
                    has_big_slice_read = True
                elif iop.opcode == "pad":
                    # pad-to-buffer stacking (CPU lowering of scan
                    # stacking; DUS on TPU): treat like an in-place write
                    names = iop.operand_names()
                    if names:
                        src = inner.shapes.get(names[0])
                        if src:
                            b = shape_elems_bytes(src)[1]
                            ob = shape_elems_bytes(iop.shape)[1]
                            if ob > 8 * max(b, 1):
                                dus_update_bytes = (dus_update_bytes or 0) + b

        total = 0.0
        for n in op.operand_names():
            sh = comp.shapes.get(n)
            if not sh:
                continue
            b = shape_elems_bytes(sh)[1]
            if dus_update_bytes is not None and b == out_bytes and b > 0:
                # aliased in-place buffer: charge the slice write (R+W)
                total += 2.0 * dus_update_bytes
            elif has_big_slice_read and b > 8 * max(out_bytes, 1):
                total += float(out_bytes)     # slice read, not full buffer
            else:
                total += b
        return total

    # ------------------------------------------------------------- bodies
    def comp_cost(self, name: str, fused: bool = False) -> Cost:
        key = (name, fused)
        if key in self._memo:
            return self._memo[key]
        c = Cost()
        comp = self.comps.get(name)
        if comp is None:
            c.warnings.append(f"missing computation {name}")
            self._memo[key] = c
            return c
        for op in comp.ops:
            c.add(self.op_cost(op, comp, fused=fused))
        self._memo[key] = c
        return c

    def op_cost(self, op: Op, comp: Computation, fused: bool = False) -> Cost:
        c = Cost()
        code = op.opcode
        out_elems, out_bytes = shape_elems_bytes(op.shape)

        if code in ELEMENTWISE_FREE:
            return c

        if code == "while":
            body = _BODY_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            trips_m = _TRIP_RE.search(op.rest)
            trips = int(trips_m.group(1)) if trips_m else 1
            if not trips_m:
                c.warnings.append(f"while {op.name}: no known_trip_count")
            if body:
                c.add(self.comp_cost(body.group(1)), mult=trips)
            if cond:
                c.add(self.comp_cost(cond.group(1)), mult=trips)
            return c

        if code == "conditional":
            bm = _BRANCHES_RE.search(op.rest)
            if bm:
                branches = _OPERAND_NAME.findall(bm.group(1))
                if branches:  # assume worst-case branch
                    costs = [self.comp_cost(b) for b in branches]
                    c.add(max(costs, key=lambda x: x.flops))
            return c

        if code == "fusion":
            cm = _CALLS_RE.search(op.rest)
            inner_name = cm.group(1) if cm else None
            if inner_name:
                inner = self.comp_cost(inner_name, fused=True)
                c.add(inner)  # flops (+ any collectives inside)
            if not fused:
                c.bytes += out_bytes + self._fusion_io_bytes(
                    op, comp, inner_name, out_bytes)
            return c

        if code in ("call", "async-start", "async-done"):
            cm = _CALLS_RE.search(op.rest)
            if cm:
                c.add(self.comp_cost(cm.group(1), fused=fused))
            return c

        base = code.replace("-start", "")
        if base in COLLECTIVE_OPS:
            if code.endswith("-done"):
                return c
            moved = max(out_bytes, int(self._operand_bytes(op, comp)))
            c.coll_bytes[base] = c.coll_bytes.get(base, 0.0) + moved
            c.coll_counts[base] = c.coll_counts.get(base, 0.0) + 1
            g = _GROUPS_RE.search(op.rest)
            if g:
                group_size = int(g.group(2))
                if self.dcn_group_sizes is not None:
                    if group_size in self.dcn_group_sizes:
                        c.dcn_bytes += moved
                elif self.pod_chips and group_size > self.pod_chips:
                    c.dcn_bytes += moved
            if not fused:
                c.bytes += out_bytes + self._operand_bytes(op, comp)
            return c

        if code == "dot":
            c.flops += self._dot_flops(op, comp)
            if not fused:
                c.bytes += out_bytes + self._operand_bytes(op, comp)
            return c

        if code == "convolution":
            # depthwise/small convs only in this codebase; approximate
            c.flops += 2.0 * out_elems * 8
            if not fused:
                c.bytes += out_bytes + self._operand_bytes(op, comp)
            return c

        if code == "dynamic-update-slice":
            if not fused:
                names = op.operand_names()
                upd = comp.shapes.get(names[1]) if len(names) > 1 else None
                ub = shape_elems_bytes(upd)[1] if upd else out_bytes
                c.bytes += 2.0 * ub          # in-place: slice R+W only
            return c

        if code in ("dynamic-slice", "slice", "gather"):
            if not fused:
                c.bytes += 2.0 * out_bytes   # read the slice, write it
            return c

        if code in ("copy", "copy-start", "copy-done", "concatenate", "pad",
                    "scatter", "transpose", "reverse",
                    "broadcast", "select-and-scatter", "sort", "custom-call"):
            if not fused:
                c.bytes += out_bytes + self._operand_bytes(op, comp)
            if code == "scatter":
                c.flops += out_elems
            return c

        # elementwise / reduce / rng / compare / etc.
        c.flops += float(out_elems)
        if code == "reduce":
            c.flops += self._operand_bytes(op, comp) / 4.0  # ≈ input elems
        if not fused:
            c.bytes += out_bytes + self._operand_bytes(op, comp)
        return c

    # --------------------------------------------------------------- main
    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze_hlo(text: str, pod_chips: int | None = None,
                dcn_group_sizes: frozenset | None = None) -> Cost:
    return HloCostModel(text, pod_chips=pod_chips,
                        dcn_group_sizes=dcn_group_sizes).total()


def top_collectives(text: str, n: int = 12) -> list[tuple[float, float, str, str]]:
    """(bytes·trips, count·trips, opcode, jax op_name) — attribution of
    collective traffic to source ops, trip-count aware."""
    m = HloCostModel(text)
    acc: dict[tuple[str, str], list[float]] = {}
    opname_re = re.compile(r'op_name="([^"]+)"')

    def walk(comp_name, mult):
        comp = m.comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            if op.opcode == "while":
                b = _BODY_RE.search(op.rest)
                tr = _TRIP_RE.search(op.rest)
                trips = int(tr.group(1)) if tr else 1
                if b:
                    walk(b.group(1), mult * trips)
            elif op.opcode in ("fusion", "call"):
                c = _CALLS_RE.search(op.rest)
                if c:
                    walk(c.group(1), mult)
            else:
                base = op.opcode.replace("-start", "")
                if base in COLLECTIVE_OPS and not op.opcode.endswith("-done"):
                    nm = opname_re.search(op.rest)
                    key = (base, nm.group(1)[:100] if nm else "?")
                    b = shape_elems_bytes(op.shape)[1]
                    acc.setdefault(key, [0.0, 0.0])
                    acc[key][0] += mult * b
                    acc[key][1] += mult

    walk(m.entry, 1.0)
    rows = [(v[0], v[1], k[0], k[1]) for k, v in acc.items()]
    rows.sort(reverse=True)
    return rows[:n]
