"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init,
smoke tests keep seeing 1 device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e pod mesh: 16×16 = 256 chips per pod; 2 pods multi-pod.

    Axes: ``data`` (FSDP/batch), ``model`` (tensor/expert parallel),
    plus ``pod`` (pure DP over DCN) when multi_pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_worker_mesh(num_workers: int) -> Mesh:
    """Mesh for the paper's async SGNS training: one axis, one worker per
    slice, zero collectives inside the step."""
    return jax.make_mesh((num_workers,), ("worker",))


def make_smoke_mesh() -> Mesh:
    """1-device mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def multihost_train_kwargs(num_workers: int,
                           processes: int | None = None
                           ) -> tuple[int, dict]:
    """Resolve a CLI ``--processes`` value (None → the jax runtime's)
    and the extra ``train_submodels`` kwargs a multi-host run needs:
    per-host ingestion only makes sense under ``shard_map`` on a worker
    mesh, where the per-chunk input assembly is the sole inter-host
    exchange. Shared by ``train_sgns`` and ``train_w2v_100m``."""
    if processes is None:
        processes = jax.process_count()
    kwargs: dict = {}
    if processes > 1:
        kwargs = dict(backend="shard_map", mesh=make_worker_mesh(num_workers))
    return processes, kwargs


def assemble_worker_array(mesh: Mesh, plan, local: np.ndarray,
                          axis_name: str = "worker") -> jax.Array:
    """Global ``(num_workers, ...)`` device array from this host's
    ``(plan.num_local, ...)`` block of worker-leading data.

    ``plan`` is a :class:`repro.data.pipeline.HostShardPlan`. Each host
    hands in only the rows of the workers it extracted; the global array
    is sharded ``P(axis_name)`` over the mesh. Multi-host, this is
    :func:`jax.make_array_from_process_local_data` — no host ever
    materializes another host's chunk. Single-host (including every
    simulated-``process_count`` test, which concatenates the per-plan
    blocks itself before calling this) it is a plain sharded
    ``device_put`` of the full array.
    """
    local = np.asarray(local)
    if local.shape[0] != plan.num_local:
        raise ValueError(
            f"local block has {local.shape[0]} worker rows; "
            f"{plan.describe()} expects {plan.num_local}")
    sharding = NamedSharding(mesh, P(axis_name))
    if plan.process_count == 1:
        return jax.device_put(local, sharding)
    plan.validate_for_mesh(mesh)
    global_shape = (plan.num_workers,) + local.shape[1:]
    return jax.make_array_from_process_local_data(sharding, local,
                                                  global_shape)
