"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init,
smoke tests keep seeing 1 device.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e pod mesh: 16×16 = 256 chips per pod; 2 pods multi-pod.

    Axes: ``data`` (FSDP/batch), ``model`` (tensor/expert parallel),
    plus ``pod`` (pure DP over DCN) when multi_pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_worker_mesh(num_workers: int) -> Mesh:
    """Mesh for the paper's async SGNS training: one axis, one worker per
    slice, zero collectives inside the step."""
    return jax.make_mesh((num_workers,), ("worker",))


def make_smoke_mesh() -> Mesh:
    """1-device mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
