"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs / (chips × 197 TF/s bf16)
    memory     = HLO_bytes / (chips × 819 GB/s HBM)
    collective = collective_bytes / (chips × 50 GB/s/link ICI)

``compiled.cost_analysis()`` reports the *per-partition* (SPMD) module;
we scale by chip count to get global HLO_FLOPs/bytes, so the formulas
above reduce to per-chip seconds. collective_bytes is not in
cost_analysis — we parse the compiled HLO and sum output-shape bytes of
every collective op (per-partition, i.e. bytes moved per chip), counting
DCN-crossing collectives (replica-group spans > one pod) separately.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 per chip, TPU v5e
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 link used)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# one tensor shape like  bf16[16,4096,1024]{2,1,0}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        b = _shape_bytes(shape_str)
        st.bytes_by_op[op] = st.bytes_by_op.get(op, 0) + b
        st.count_by_op[op] = st.count_by_op.get(op, 0) + 1
    return st


@dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collectives: CollectiveStats
    peak_memory_per_chip: float = 0.0
    model_flops: float = 0.0           # 6·N_active·D global
    dcn_bytes_per_chip: float = 0.0    # collectives whose group spans pods
    xla_flops_per_chip: float = 0.0    # raw cost_analysis (loop bodies ×1)

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def flops_utilization(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — how much compiled compute is
        'useful'; catches remat/redundancy waste."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "flops_util": self.flops_utilization,
            "hbm_gb_per_chip": self.peak_memory_per_chip / 2**30,
            "collective_ops": dict(self.collectives.count_by_op),
            "collective_bytes_by_op": dict(self.collectives.bytes_by_op),
            "dcn_bytes_per_chip": self.dcn_bytes_per_chip,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "xla_flops_per_chip": self.xla_flops_per_chip,
        }


def analyze(arch: str, shape: str, compiled, chips: int,
            model_flops: float = 0.0, pod_chips: int = 256,
            dcn_group_sizes: frozenset | None = None) -> Roofline:
    """Roofline terms from the compiled module.

    Primary source is the trip-count-aware static model over the HLO
    (repro.launch.hlo_cost) — ``compiled.cost_analysis()`` counts while
    bodies once, so scanned programs (layers/microbatches/recurrences)
    would be under-reported by their trip counts. cost_analysis is kept
    as a cross-check lower bound.
    """
    from repro.launch import hlo_cost

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    hlo = compiled.as_text()
    cost = hlo_cost.analyze_hlo(hlo, pod_chips=pod_chips,
                                dcn_group_sizes=dcn_group_sizes)
    flops = max(cost.flops, float(ca.get("flops", 0.0)))
    byts = max(cost.bytes, 0.0)
    coll = CollectiveStats(
        bytes_by_op=dict(cost.coll_bytes),
        count_by_op={k: int(v) for k, v in cost.coll_counts.items()})
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = 0.0
    return Roofline(
        arch=arch, shape=shape, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        collective_bytes_per_chip=float(coll.total_bytes),
        collectives=coll, peak_memory_per_chip=peak,
        model_flops=model_flops, dcn_bytes_per_chip=cost.dcn_bytes,
        xla_flops_per_chip=float(ca.get("flops", 0.0)))


# ---------------------------------------------------------------------------
def count_params(tree) -> int:
    import jax
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def active_params(cfg, params_tree) -> float:
    """Active parameter count (MoE: only top_k of num_experts count)."""
    import jax
    total = 0.0
    def add(path, leaf):
        nonlocal total
        n = math.prod(leaf.shape)
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if cfg.moe is not None and len(leaf.shape) >= 3 and any(
                str(x) in ("gate", "up", "down") for x in names) and (
                leaf.shape[-3] == cfg.moe.num_experts or
                (len(leaf.shape) >= 4 and leaf.shape[-3] == cfg.moe.num_experts)):
            n = n * cfg.moe.top_k / cfg.moe.num_experts
        total += n
    jax.tree_util.tree_map_with_path(add, params_tree)
    return total


def model_flops_for(cfg, params_tree, shape) -> float:
    """6·N_active·D (training) or 2·N_active·D (inference fwd only)."""
    n_active = active_params(cfg, params_tree)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'chips':>5s} {'compute_s':>11s} "
           f"{'memory_s':>11s} {'collect_s':>11s} {'dominant':>10s} "
           f"{'MF/HLO':>7s} {'HBM GB':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['chips']:5d} "
            f"{r['compute_s']:11.3e} {r['memory_s']:11.3e} "
            f"{r['collective_s']:11.3e} {r['dominant']:>10s} "
            f"{r['flops_util']:7.3f} {r['hbm_gb_per_chip']:7.2f}")
    return "\n".join(lines)
