"""Embedding serving launcher — the read path of the train→publish→serve
loop.

Point it at an artifact directory that ``repro.launch.train_sgns
--publish`` (or :func:`repro.serve.publish_incremental`) wrote:

  # one-shot query from the CLI (raw word ids, comma-separated)
  PYTHONPATH=src python -m repro.launch.serve --artifact artifacts/ \
      --query 11,42,7

  # a worker's own space: present rows served, absent rows
  # reconstructed on the fly (Y @ W_i.T)
  PYTHONPATH=src python -m repro.launch.serve --artifact artifacts/ \
      --query 11,42,7 --submodel 2

  # long-running JSON-lines TCP server (requests: {"ids": [...]},
  # {"op": "stats"}, {"op": "refresh"} — see repro.serve.tcp)
  PYTHONPATH=src python -m repro.launch.serve --artifact artifacts/ \
      --port 8765

The server polls the artifact manifest every ``--refresh-s`` seconds
and hot-swaps to newer versions as the incremental merge publishes
them — a query never waits for training to finish.
"""

from __future__ import annotations

import argparse
import asyncio

import numpy as np

from repro.serve import EmbeddingServer, ServeConfig, start_tcp_server


def _config(args) -> ServeConfig:
    return ServeConfig(coalesce_ms=args.coalesce_ms, max_batch=args.max_batch,
                       max_concurrency=args.concurrency,
                       cache_rows=args.cache_rows)


async def query_once(server: EmbeddingServer, raw_ids: list[int],
                     submodel: int | None) -> None:
    res = await server.embed_ids(np.asarray(raw_ids), submodel=submodel)
    space = "merged" if submodel is None else f"submodel {submodel}"
    print(f"artifact v{res['version']}  space={space}  dim="
          f"{res['vectors'].shape[1]}")
    for rid, vec, ok in zip(raw_ids, res["vectors"], res["found"]):
        head = np.array2string(vec[:4], precision=3, suppress_small=True)
        status = "ok " if ok else "OOV"
        print(f"  id {rid:>8d} [{status}] ‖v‖={np.linalg.norm(vec):6.3f}  "
              f"{head}…")
    s = server.stats()
    print(f"stats: p50 {s['p50_ms']:.2f} ms  p99 {s['p99_ms']:.2f} ms  "
          f"mean batch {s['mean_batch']:.1f}  "
          f"cache hit rate {s['cache_hit_rate']:.2f}")


async def run_tcp(server: EmbeddingServer, host: str, port: int,
                  refresh_s: float) -> None:
    srv = await start_tcp_server(server, host, port)
    actual = srv.sockets[0].getsockname()[1]
    print(f"serving artifact v{server.store.version} on {host}:{actual} "
          f"(JSON lines; Ctrl-C to stop)")

    async def poll():
        while True:
            await asyncio.sleep(refresh_s)
            if server.refresh():
                print(f"hot-swapped to artifact v{server.store.version}")

    poller = asyncio.create_task(poll())
    try:
        async with srv:
            await srv.serve_forever()
    finally:
        poller.cancel()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--artifact", required=True,
                    help="artifact directory (publish_table output)")
    ap.add_argument("--query", default=None,
                    help="comma-separated raw word ids: answer once and exit")
    ap.add_argument("--submodel", type=int, default=None,
                    help="serve in this worker's sub-model space "
                         "(absent rows reconstructed on the fly)")
    ap.add_argument("--port", type=int, default=None,
                    help="run the JSON-lines TCP server on this port "
                         "(0 = ephemeral)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--version", type=int, default=None,
                    help="pin a table version (default: track latest)")
    ap.add_argument("--coalesce-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--concurrency", type=int, default=4)
    ap.add_argument("--cache-rows", type=int, default=4096)
    ap.add_argument("--refresh-s", type=float, default=2.0,
                    help="manifest poll interval for hot reloads")
    args = ap.parse_args(argv)

    from repro.serve import ArtifactStore
    store = ArtifactStore(args.artifact, version=args.version)
    server = EmbeddingServer(store, _config(args))

    if args.query is not None:
        ids = [int(x) for x in args.query.split(",") if x.strip()]
        asyncio.run(query_once(server, ids, args.submodel))
        return
    if args.port is not None:
        try:
            asyncio.run(run_tcp(server, args.host, args.port, args.refresh_s))
        except KeyboardInterrupt:
            pass
        return
    ap.error("one of --query or --port is required")


if __name__ == "__main__":
    main()
