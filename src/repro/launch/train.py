"""Transformer training launcher: ``--arch <id>`` from the registry.

On real TPU hardware this runs the production mesh; on CPU (tests,
examples) it runs the same code on a 1×1 mesh with reduced configs.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.optim import get_optimizer
from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step_path
from repro.data.corpus import SemanticCorpusModel
from repro.sharding import ctx as shctx
from repro.sharding import tree_param_specs, tree_data_specs, with_sharding


def synthetic_lm_batches(vocab: int, batch: int, seq: int, steps: int,
                         seed: int = 0):
    """LM token stream from the structured synthetic corpus model —
    real next-token signal, not uniform noise."""
    gen = SemanticCorpusModel.create(vocab_size=min(vocab, 4000), seed=seed)
    corpus = gen.generate(num_sentences=max(200, batch * steps // 2),
                          seed=seed + 1)
    toks = corpus.tokens
    need = batch * seq
    for i in range(steps):
        lo = (i * need) % max(len(toks) - need, 1)
        chunk = toks[lo : lo + need]
        if len(chunk) < need:
            chunk = np.tile(chunk, need // max(len(chunk), 1) + 1)[:need]
        yield jnp.asarray(chunk.reshape(batch, seq) % vocab, dtype=jnp.int32)


def train(arch: str, *, reduced: bool, steps: int, batch: int, seq: int,
          lr: float, ckpt_dir: str | None, ckpt_every: int, mesh=None,
          log_every: int = 10, resume: bool = False):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    opt = get_optimizer(cfg.train_optimizer,
                        **({"lr": lr} if cfg.train_optimizer != "sgd" else
                           {"lr": lr}))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step0 = 0
    if resume and ckpt_dir:
        path = latest_step_path(ckpt_dir)
        if path:
            tree, meta = load_checkpoint(path)
            params = jax.tree.map(
                lambda a, b: jnp.asarray(b, a.dtype), params, tree["params"])
            opt_state = jax.tree.map(
                lambda a, b: jnp.asarray(b, a.dtype), opt_state, tree["opt"])
            step0 = int(meta.get("step") or 0)
            print(f"resumed from {path} @ step {step0}")

    mb = 1 if reduced else cfg.train_microbatches
    step_fn = jax.jit(model.make_train_step(opt, microbatches=mb))

    if mesh is not None:
        shctx.enable(mesh)
    t0 = time.perf_counter()
    losses = []
    stream = synthetic_lm_batches(cfg.vocab_size, batch, seq, steps)
    for i, toks in enumerate(stream, start=step0):
        batch_dict = {"tokens": toks, "labels": toks}
        if cfg.frontend == "vision":
            batch_dict["patch_embeds"] = jnp.zeros(
                (toks.shape[0], cfg.frontend_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        if cfg.encoder_layers:
            batch_dict = {"frames": jnp.zeros(
                (toks.shape[0], seq, cfg.d_model), jnp.dtype(cfg.dtype)),
                "tokens": toks, "labels": toks}
        params, opt_state, loss = step_fn(params, opt_state, batch_dict,
                                          jnp.int32(i))
        losses.append(float(loss))
        if (i + 1) % log_every == 0:
            dt = time.perf_counter() - t0
            tok_s = (i + 1 - step0) * toks.size / dt
            print(f"step {i+1:5d} loss {np.mean(losses[-log_every:]):.4f} "
                  f"({tok_s:.0f} tok/s)")
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            save_checkpoint(f"{ckpt_dir}/step_{i+1}.npz",
                            {"params": params, "opt": opt_state}, step=i + 1)
    if ckpt_dir:
        save_checkpoint(f"{ckpt_dir}/step_{step0+steps}.npz",
                        {"params": params, "opt": opt_state},
                        step=step0 + steps)
    if mesh is not None:
        shctx.disable()
    return params, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)
    _, losses = train(args.arch, reduced=args.reduced, steps=args.steps,
                      batch=args.batch, seq=args.seq, lr=args.lr,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      resume=args.resume)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
