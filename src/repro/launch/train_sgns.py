"""The paper's training driver: divide → async train → merge → evaluate.

  PYTHONPATH=src python -m repro.launch.train_sgns \
      --strategy shuffle --workers 10 --epochs 6 --dim 64 \
      --sentences 30000 --merge alir_pca concat pca

Runs the full pipeline on the synthetic corpus (see DESIGN.md §4) and
prints paper-style scores + timings. ``--engine`` selects the per-step
update engine (``sparse``, ``dense``, ``pallas``, ``pallas_fused``,
``pallas_fused_hbm``, ``pallas_fused_pipe``, ``pallas_fused_tiered``,
optionally with a sampler suffix like ``sparse:alias``); Pallas engines
run in interpret mode on CPU, Mosaic on TPU. ``pallas_fused_hbm`` keeps
the parameter tables HBM-resident and DMA-streams only the touched rows
per pair block — the engine family for paper-scale (300k×500)
sub-models; ``pallas_fused_pipe`` is its double-buffered successor
(deduped row DMAs overlapped with compute behind a hazard-ordering
block planner), and ``pallas_fused_tiered`` adds frequency-tiered
placement on top (``--hot-rows`` hottest rows pinned VMEM-resident,
cold rows behind a ``--ring-depth``-slot DMA ring).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.driver import run_pipeline, train_sync_baseline
from repro.core.engine import get_engine
from repro.core.sgns import SGNSConfig
from repro.launch.mesh import multihost_train_kwargs
from repro.data.corpus import SemanticCorpusModel
from repro.eval.benchmarks import BenchmarkSuite, evaluate_all
from repro.checkpoint import save_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="shuffle",
                    choices=("equal", "random", "shuffle"))
    ap.add_argument("--workers", type=int, default=10)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--window", type=int, default=5)
    ap.add_argument("--negatives", type=int, default=5)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=2000)
    ap.add_argument("--sentences", type=int, default=30000)
    ap.add_argument("--merge", nargs="+",
                    default=("concat", "pca", "alir_pca"),
                    help="merge methods to apply (see "
                         "repro.core.merge.MERGE_METHODS; alir_tree is "
                         "the log-depth reduction-tree merge)")
    ap.add_argument("--merge-fan-in", type=int, default=2,
                    help="reduction-tree arity for the alir_tree merge "
                         "(>= 2; depth = ceil(log_fan_in(workers)))")
    ap.add_argument("--merge-shard", type=int, default=1,
                    help="ALiR Gram-accumulation row-block count — a "
                         "static dial: bits depend on the count, never "
                         "on which host computes which block")
    ap.add_argument("--baseline", action="store_true",
                    help="also train the synchronized baseline")
    ap.add_argument("--engine", default="sparse",
                    help="update engine: dense | sparse | pallas | "
                         "pallas_fused | pallas_fused_hbm | "
                         "pallas_fused_pipe | pallas_fused_tiered, "
                         "optionally ':cdf'/':alias' (e.g. sparse:alias)")
    ap.add_argument("--hot-rows", type=int, default=None,
                    help="pallas_fused_tiered: rows of the frequency-"
                         "sorted id prefix pinned VMEM-resident per "
                         "table (default 256; 0 = pure pipeline)")
    ap.add_argument("--ring-depth", type=int, default=None,
                    help="pallas_fused_pipe/_tiered: VMEM row-buffer "
                         "ring slots for the cold-row DMA pipeline "
                         "(default 2)")
    ap.add_argument("--processes", type=int, default=None,
                    help="ingestion host count (default: "
                         "jax.process_count()); each host extracts only "
                         "its HostShardPlan block of worker streams")
    ap.add_argument("--process-index", type=int, default=None,
                    help="this host's index (default: jax.process_index())")
    ap.add_argument("--vmem-budget-mb", type=float, default=16.0,
                    help="reject engine configs whose static VMEM "
                         "estimate (repro.analysis.vmem) exceeds this "
                         "budget before training starts (0 = report "
                         "only; default one TPU core's 16 MiB)")
    ap.add_argument("--elastic-state", default=None, metavar="DIR",
                    help="train preemption-tolerantly: each worker "
                         "checkpoints (tables + cursor) to DIR and a "
                         "re-run of the same command resumes every "
                         "worker from its last checkpoint, bit-identical "
                         "to the uninterrupted elastic run "
                         "(single-process; see docs/ARCHITECTURE.md "
                         "§Elasticity)")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="elastic checkpoint cadence in chunks, anchored "
                         "to stream position (default 1)")
    ap.add_argument("--no-resume", action="store_true",
                    help="with --elastic-state: ignore existing "
                         "checkpoints and train from scratch")
    ap.add_argument("--save", default=None, help="checkpoint path (.npz)")
    ap.add_argument("--publish", default=None, metavar="DIR",
                    help="incrementally ALiR-fold the sub-models and "
                         "publish versioned merged-table artifacts to "
                         "DIR (serve with `python -m repro.launch.serve "
                         "--artifact DIR`)")
    ap.add_argument("--publish-every", type=int, default=1,
                    help="publish a table version every k folded "
                         "sub-models (default 1: a version per worker)")
    args = ap.parse_args(argv)
    # engine-dial overrides only when set: passing hot_rows/ring_depth
    # to an engine without those fields is a clear TypeError
    overrides = {k: v for k, v in (("hot_rows", args.hot_rows),
                                   ("ring_depth", args.ring_depth))
                 if v is not None}
    args.engine = get_engine(args.engine, **overrides)
    # fail fast on a config that would blow the VMEM budget at this
    # run's shape, before any corpus generation or training happens
    from repro.analysis.vmem import check_vmem_budget, estimate_vmem
    if args.vmem_budget_mb:
        est = check_vmem_budget(
            args.engine, vocab_size=args.vocab, dim=args.dim,
            negatives=args.negatives, batch=args.batch,
            budget_bytes=int(args.vmem_budget_mb * 2 ** 20))
    else:
        est = estimate_vmem(args.engine, vocab_size=args.vocab,
                            dim=args.dim, negatives=args.negatives,
                            batch=args.batch)
    print(f"vmem: {est.summary()}")
    processes, train_kw = multihost_train_kwargs(args.workers, args.processes)

    gen = SemanticCorpusModel.create(vocab_size=args.vocab, seed=0)
    corpus = gen.generate(num_sentences=args.sentences, seed=1)
    suite = BenchmarkSuite.from_model(gen, top_words=int(args.vocab * 0.6))
    cfg = SGNSConfig(vocab_size=0, dim=args.dim, window=args.window,
                     negatives=args.negatives)

    if args.elastic_state:
        from repro.core.driver import apply_merges
        from repro.elastic import train_submodels_elastic

        res = train_submodels_elastic(
            corpus, args.vocab, args.strategy, args.workers, cfg,
            state_dir=args.elastic_state, resume=not args.no_resume,
            ckpt_every=args.ckpt_every, epochs=args.epochs,
            batch_size=args.batch, rate=args.rate, window=args.window,
            max_vocab=None, base_min_count=20, engine=args.engine)
        res = apply_merges(res, tuple(args.merge), out_dim=cfg.dim,
                           fan_in=args.merge_fan_in, shard=args.merge_shard)
    else:
        res = run_pipeline(
            corpus, args.vocab, strategy=args.strategy,
            num_workers=args.workers, cfg=cfg, epochs=args.epochs,
            batch_size=args.batch, rate=args.rate,
            window=args.window, max_vocab=None, base_min_count=20,
            merge_methods=tuple(args.merge),
            merge_fan_in=args.merge_fan_in, merge_shard=args.merge_shard,
            engine=args.engine,
            process_index=args.process_index, process_count=processes,
            **train_kw)
    print(f"strategy={args.strategy} workers={args.workers} "
          f"engine={args.engine.describe()} "
          f"train={res.timings['train_s']:.1f}s "
          f"steps/epoch={res.timings['steps_per_epoch']} "
          f"losses={['%.3f' % l for l in res.losses]}")
    for m, (emb, valid) in res.merged.items():
        scores = evaluate_all(emb, valid, res.union_vocab, suite)
        print(f"  {m:10s} sim={scores['similarity']:.3f}"
              f"({scores['similarity_oov']}) "
              f"ana={scores['analogy']:.3f}({scores['analogy_oov']}) "
              f"cat={scores['categorization']:.3f}"
              f"({scores['categorization_oov']}) "
              f"merge={res.timings.get('merge_%s_s' % m, 0):.2f}s")

    if args.baseline:
        params, vocab, info = train_sync_baseline(
            corpus, args.vocab, cfg, epochs=args.epochs,
            batch_size=args.batch, window=args.window, max_vocab=None)
        emb = np.asarray(params["W"])
        scores = evaluate_all(emb, np.ones(vocab.size, bool), vocab, suite)
        print(f"  sync-base  sim={scores['similarity']:.3f} "
              f"ana={scores['analogy']:.3f} "
              f"cat={scores['categorization']:.3f} "
              f"train={info['train_s']:.1f}s")

    if args.publish:
        from repro.serve import publish_incremental
        from repro.serve.publish import submodel_arrivals
        versions, final = publish_incremental(
            submodel_arrivals(res.stacked), args.publish,
            word_ids=res.union_vocab.word_ids,
            publish_every=args.publish_every,
            meta={"strategy": args.strategy})
        print(f"published {len(versions)} incremental table version(s) → "
              f"{args.publish} (latest v{versions[-1]}, "
              f"{int(np.asarray(final.valid).sum())} rows valid); serve: "
              f"python -m repro.launch.serve --artifact {args.publish} "
              f"--query <ids>")

    if args.save:
        best = args.merge[-1]
        emb, valid = res.merged[best]
        save_checkpoint(args.save, {"embedding": emb, "valid": valid,
                                    "word_ids": res.union_vocab.word_ids},
                        extra={"method": best, "strategy": args.strategy})
        print(f"saved merged embedding → {args.save}")


if __name__ == "__main__":
    main()
