"""Attention mixers: GQA (optionally sliding-window), MLA, cross-attention.

All functions are pure: ``init_*`` builds the param pytree, ``*_forward``
does full-sequence (train/prefill) attention, ``*_decode`` does one-token
decode against a cache. Caches:

* GQA full attention — k/v ``(B, S_max, Hkv, hd)`` + scalar length;
* GQA sliding window — ring buffer ``(B, W, Hkv, hd)`` (cache never
  exceeds the window: this is what makes dense archs eligible for the
  ``long_500k`` shape);
* MLA (DeepSeek-V2) — the *compressed* cache: ``c_kv (B, S, r_kv)`` +
  decoupled rope key ``k_rope (B, S, hd_rope)`` — the paper-faithful
  memory saving (arXiv:2405.04434).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, apply_rope, rope_angles

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_gqa(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             dtype, qkv_bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, S, n_kv, head_dim),
            v.reshape(B, S, n_kv, head_dim))


def _sdpa(q, k, v, mask):
    """q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D), GQA by head repetition. mask (Sq,Sk)
    or (B,1,Sq,Sk) additive."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qf = q.reshape(B, Sq, Hkv, rep, D).astype(jnp.float32)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(D))
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        else:  # (B,1,Sq,Sk) → (B,1,1,Sq,Sk)
            mask = mask[:, :, None]
        s = s + mask
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def causal_mask(Sq: int, Sk: int, window: int | None = None,
                offset: int = 0) -> jax.Array:
    """Additive (Sq, Sk) mask; query i attends keys j with
    j <= i+offset and (window is None or j > i+offset-window)."""
    qi = jnp.arange(Sq)[:, None] + offset
    kj = jnp.arange(Sk)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_forward(params, x, positions, *, n_heads, n_kv, head_dim,
                rope_theta=1e4, window=None, causal=True,
                rope_cos_sin=None) -> jax.Array:
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    if rope_cos_sin is None:
        cos, sin = rope_angles(positions, head_dim, rope_theta)
    else:
        cos, sin = rope_cos_sin
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    S = x.shape[1]
    mask = causal_mask(S, S, window) if causal else None
    o = _sdpa(q, k, v, mask)
    return o.reshape(x.shape[0], S, n_heads * head_dim) @ params["wo"]


def init_gqa_cache(batch, cache_len, n_kv, head_dim, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), dtype),
    }


def gqa_decode(params, cache, x, pos, *, n_heads, n_kv, head_dim,
               rope_theta=1e4, window=None, rope_cos_sin=None):
    """One-token decode. x (B,1,d); pos scalar int32 (tokens so far).

    Full attention: cache_len == S_max, slot = pos.
    Sliding window:  cache_len == window, slot = pos % window (ring).
    """
    B = x.shape[0]
    cache_len = cache["k"].shape[1]
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    if rope_cos_sin is None:
        p1 = jnp.full((B, 1), pos, dtype=jnp.int32)
        cos, sin = rope_angles(p1, head_dim, rope_theta)
    else:
        cos, sin = rope_cos_sin
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = pos % cache_len if window is not None else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    idx = jnp.arange(cache_len)
    if window is not None:
        valid = (idx <= slot) | (pos >= cache_len)  # ring: all valid once full
    else:
        valid = idx <= pos
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]  # (1,Sk)
    o = _sdpa(q, ck, cv, mask)
    out = o.reshape(B, 1, n_heads * head_dim) @ params["wo"]
    return {"k": ck, "v": cv}, out


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec)
# ---------------------------------------------------------------------------
def cross_forward(params, x, enc_kv, *, n_heads, n_kv, head_dim):
    """x (B,Sq,d) attends precomputed encoder k/v (B,Se,Hkv,hd)."""
    B, Sq, _ = x.shape
    q = (x @ params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(B, Sq, n_heads, head_dim)
    o = _sdpa(q, enc_kv["k"], enc_kv["v"], None)
    return o.reshape(B, Sq, n_heads * head_dim) @ params["wo"]


def encode_kv(params, enc_out, *, n_kv, head_dim):
    B, Se, _ = enc_out.shape
    k = enc_out @ params["wk"]
    v = enc_out @ params["wv"]
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    return {"k": k.reshape(B, Se, n_kv, head_dim),
            "v": v.reshape(B, Se, n_kv, head_dim)}


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------
def init_mla(key, d_model: int, n_heads: int, *, kv_lora_rank: int,
             head_dim: int, rope_head_dim: int, dtype) -> dict:
    ks = jax.random.split(key, 7)
    return {
        # queries (V2-Lite: no q compression)
        "wq_nope": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wq_rope": dense_init(ks[1], d_model, n_heads * rope_head_dim, dtype),
        # compressed KV path
        "w_dkv": dense_init(ks[2], d_model, kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], kv_lora_rank, n_heads * head_dim, dtype),
        "w_uv": dense_init(ks[4], kv_lora_rank, n_heads * head_dim, dtype),
        # decoupled shared rope key
        "w_krope": dense_init(ks[5], d_model, rope_head_dim, dtype),
        "wo": dense_init(ks[6], n_heads * head_dim, d_model, dtype),
    }


def _mla_qk(params, x, positions, n_heads, head_dim, rope_head_dim, rope_theta):
    B, S, _ = x.shape
    q_nope = (x @ params["wq_nope"]).reshape(B, S, n_heads, head_dim)
    q_rope = (x @ params["wq_rope"]).reshape(B, S, n_heads, rope_head_dim)
    cos, sin = rope_angles(positions, rope_head_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    c_kv = x @ params["w_dkv"]                                   # (B,S,r)
    k_rope = (x @ params["w_krope"]).reshape(B, S, 1, rope_head_dim)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0]               # (B,S,hr)
    return q_nope, q_rope, c_kv, k_rope


def mla_attend(q_nope, q_rope, c_kv, k_rope, params, n_heads, head_dim,
               mask, absorb: bool):
    """Score/combine either by expanding K/V (naive) or by absorbing
    W_UK/W_UV into the query/output path (decode-efficient variant —
    attends directly over the compressed cache)."""
    B, Sq = q_nope.shape[:2]
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim + q_rope.shape[-1]))
    if absorb:
        w_uk = params["w_uk"].reshape(-1, n_heads, head_dim)     # (r,H,hd)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))             # (B,Sq,H,r)
        s = jnp.einsum("bqhr,bkr->bhqk", q_lat, c_kv.astype(jnp.float32))
    else:
        k_nope = jnp.einsum(
            "bkr,rhd->bkhd", c_kv.astype(jnp.float32),
            params["w_uk"].reshape(-1, n_heads, head_dim).astype(jnp.float32))
        s = jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32), k_nope)
    s = s + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                       k_rope.astype(jnp.float32))
    s = s * scale
    if mask is not None:
        s = s + (mask[None, None] if mask.ndim == 2 else mask)
    p = jax.nn.softmax(s, axis=-1)
    if absorb:
        o_lat = jnp.einsum("bhqk,bkr->bqhr", p, c_kv.astype(jnp.float32))
        o = jnp.einsum(
            "bqhr,rhd->bqhd", o_lat,
            params["w_uv"].reshape(-1, n_heads, head_dim).astype(jnp.float32))
    else:
        v = jnp.einsum(
            "bkr,rhd->bkhd", c_kv.astype(jnp.float32),
            params["w_uv"].reshape(-1, n_heads, head_dim).astype(jnp.float32))
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    o = o.reshape(B, Sq, n_heads * head_dim).astype(q_nope.dtype)
    return o @ params["wo"]


def mla_forward(params, x, positions, *, n_heads, head_dim, rope_head_dim,
                rope_theta=1e4, window=None, absorb=False):
    q_nope, q_rope, c_kv, k_rope = _mla_qk(
        params, x, positions, n_heads, head_dim, rope_head_dim, rope_theta)
    S = x.shape[1]
    mask = causal_mask(S, S, window)
    return mla_attend(q_nope, q_rope, c_kv, k_rope, params, n_heads, head_dim,
                      mask, absorb)


def init_mla_cache(batch, cache_len, kv_lora_rank, rope_head_dim, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, cache_len, kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, rope_head_dim), dtype),
    }


def mla_decode(params, cache, x, pos, *, n_heads, head_dim, rope_head_dim,
               rope_theta=1e4, window=None, absorb=True):
    B = x.shape[0]
    cache_len = cache["c_kv"].shape[1]
    p1 = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qk(
        params, x, p1, n_heads, head_dim, rope_head_dim, rope_theta)
    slot = pos % cache_len if window is not None else pos
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, slot, 0))
    idx = jnp.arange(cache_len)
    valid = ((idx <= slot) | (pos >= cache_len)) if window is not None else (idx <= pos)
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]
    out = mla_attend(q_nope, q_rope, c_kv, k_rope, params, n_heads, head_dim,
                     mask, absorb)
    return {"c_kv": c_kv, "k_rope": k_rope}, out
