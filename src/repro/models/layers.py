"""Shared building blocks: norms, MLPs, RoPE / M-RoPE, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, fan_in: int, fan_out: int, dtype) -> jax.Array:
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    return (scale * jax.random.normal(key, (fan_in, fan_out))).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (0.02 * jax.random.normal(key, (vocab, dim))).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def init_rms(dim: int, dtype) -> jax.Array:
    return jnp.ones((dim,), dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP (llama-family FFN)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions (..., S) → cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (B, S, D/2) or (S, D/2)."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:  # (S, D/2)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:              # (B, S, D/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


def mrope_angles(positions_3d: jax.Array, head_dim: int, theta: float,
                 sections: tuple[int, int, int]) -> tuple:
    """Qwen2-VL multimodal RoPE (arXiv:2409.12191).

    ``positions_3d`` (3, B, S): temporal/height/width position ids.
    ``sections`` split the head_dim/2 frequency bands among (t, h, w);
    must sum to head_dim // 2.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_per_axis = positions_3d.astype(jnp.float32)[..., None] * freqs  # (3,B,S,half)
    # choose which axis drives each band
    band = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_per_axis, 0, -1),        # (B,S,half,3)
        band[None, None, :, None], axis=-1)[..., 0]  # (B,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def text_mrope_positions(batch: int, seq: int, start: jax.Array | int = 0) -> jax.Array:
    """For pure-text spans all three M-RoPE axes share the position id."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None] + jnp.asarray(start, jnp.int32)
    pos = jnp.broadcast_to(pos, (batch, seq))
    return jnp.broadcast_to(pos[None], (3, batch, seq))


# ---------------------------------------------------------------------------
# Cross-entropy LM loss
# ---------------------------------------------------------------------------
def lm_loss(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
            ) -> jax.Array:
    """Mean next-token cross-entropy. logits (B,S,V) already aligned with
    labels (B,S) (caller shifts).

    Uses the one-hot/where formulation instead of take_along_axis: a
    gather along a vocab-sharded axis would force GSPMD to all-gather the
    full (B,S,V) fp32 logits; the elementwise select keeps the vocab dim
    sharded and reduces locally (one tiny all-reduce of (B,S))."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, len(lg.shape) - 1)
    picked = jnp.sum(
        jnp.where(vocab_iota == labels[..., None].astype(jnp.int32), lg, 0.0),
        axis=-1)
    ll = picked - lse
    if mask is None:
        return -jnp.mean(ll)
    m = mask.astype(jnp.float32)
    return -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)
