"""Model facade: train/serve step builders + input specs per shape.

One class ties a ModelConfig to:
  * ``init(key)``                      — parameter pytree;
  * ``make_train_step(optimizer, microbatches)``
                                       — jit-able (state, batch, step) step
                                         with gradient accumulation;
  * ``make_prefill`` / ``make_decode_step``
                                       — serving entry points;
  * ``input_specs(shape)``             — ShapeDtypeStruct stand-ins for
                                         every input (the dry-run path).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer as tf
from repro.models.layers import lm_loss
from repro.optim import Optimizer


@dataclass
class Model:
    cfg: ModelConfig

    # ----------------------------------------------------------------- init
    def init(self, key) -> dict:
        return tf.init_model(key, self.cfg)

    # ----------------------------------------------------------------- loss
    def loss_fn(self, params, batch) -> jax.Array:
        cfg = self.cfg
        logits, aux, mask = tf.forward_logits(params, cfg, batch)
        labels = batch["labels"]
        S_lab = labels.shape[1]
        # Logits cover the full (possibly frontend-extended) sequence;
        # labels cover the text/decoder positions — take the tail.
        logits = logits[:, -S_lab:]
        mask = mask[:, -S_lab:]
        # next-token shift
        loss = lm_loss(logits[:, :-1], labels[:, 1:], mask[:, 1:])
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_weight * aux
        return loss

    # ----------------------------------------------------------- train step
    def make_train_step(self, optimizer: Optimizer, microbatches: int = 1):
        cfg = self.cfg

        def split_mb(x):
            B = x.shape[0]
            assert B % microbatches == 0, (B, microbatches)
            return x.reshape((microbatches, B // microbatches) + x.shape[1:])

        def train_step(params, opt_state, batch, step):
            if microbatches == 1:
                loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            else:
                mb = jax.tree.map(split_mb, batch)

                def body(acc, mb_i):
                    l, g = jax.value_and_grad(self.loss_fn)(params, mb_i)
                    return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

                zero = (jnp.float32(0.0),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                     params))
                (loss, grads), _ = jax.lax.scan(body, zero, mb)
                loss = loss / microbatches
                grads = jax.tree.map(lambda g: g / microbatches, grads)
            new_params, new_state = optimizer.update(grads, opt_state, params,
                                                     step)
            return new_params, new_state, loss

        return train_step

    # ------------------------------------------------------------- serving
    def make_decode_step(self):
        cfg = self.cfg

        def decode_step(params, cache, token, pos):
            return tf.decode_step(params, cfg, cache, token, pos)

        return decode_step

    def init_cache(self, batch: int, cache_len: int, enc_len: int | None = None):
        return tf.init_cache(self.cfg, batch, cache_len, enc_len)

    # ---------------------------------------------------------- input specs
    def example_batch(self, shape: InputShape, key=None, concrete: bool = True):
        """Concrete arrays (smoke tests) or ShapeDtypeStructs (dry-run)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        key = key if key is not None else jax.random.PRNGKey(0)

        def toks(shape_, hi):
            if concrete:
                return jax.random.randint(key, shape_, 0, hi, dtype=jnp.int32)
            return jax.ShapeDtypeStruct(shape_, jnp.int32)

        def dense(shape_):
            if concrete:
                return jnp.zeros(shape_, dt)
            return jax.ShapeDtypeStruct(shape_, dt)

        V = cfg.vocab_size
        if shape.kind == "train":
            if cfg.encoder_layers:
                S_dec = max(S // 4, 8)
                return {"frames": dense((B, S, cfg.d_model)),
                        "tokens": toks((B, S_dec), V),
                        "labels": toks((B, S_dec), V)}
            if cfg.frontend == "vision":
                P = cfg.frontend_tokens
                S_text = S - P
                return {"tokens": toks((B, S_text), V),
                        "patch_embeds": dense((B, P, cfg.d_model)),
                        "labels": toks((B, S_text), V)}
            return {"tokens": toks((B, S), V), "labels": toks((B, S), V)}
        if shape.kind == "prefill":
            if cfg.encoder_layers:
                S_dec = max(S // 4, 8)
                return {"frames": dense((B, S, cfg.d_model)),
                        "tokens": toks((B, S_dec), V)}
            if cfg.frontend == "vision":
                P = cfg.frontend_tokens
                return {"tokens": toks((B, S - P), V),
                        "patch_embeds": dense((B, P, cfg.d_model))}
            return {"tokens": toks((B, S), V)}
        # decode kinds
        return {"token": toks((B, 1), V),
                "pos": (jnp.int32(S - 1) if concrete
                        else jax.ShapeDtypeStruct((), jnp.int32))}

    def decode_cache_len(self, shape: InputShape) -> int:
        cfg = self.cfg
        if cfg.attention_window is not None:
            return min(shape.seq_len, cfg.attention_window)
        return shape.seq_len
