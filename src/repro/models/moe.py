"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

Used by qwen3-moe (128e top-8), deepseek-v2-lite (2 shared + 64 routed
top-6) and jamba (16e top-2). Fixed-shape dispatch: top-k routing →
position-in-expert by cumulative sum → scatter into per-expert capacity
buffers → vmapped expert FFN → gather/combine. Tokens overflowing an
expert's capacity are dropped (standard GShard behaviour); an auxiliary
load-balance loss keeps the router honest.

Sharding: expert-major params ``(E, ...)`` are expert-parallel over the
``model`` mesh axis; the capacity buffers inherit that sharding, so the
scatter/gather lower to the all-to-all-like collectives GSPMD picks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, d_model: int, d_ff_expert: int, num_experts: int,
             top_k: int, dtype, num_shared: int = 0,
             d_ff_shared: int | None = None) -> dict:
    ks = jax.random.split(key, 5)
    E = num_experts
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "gate": (0.02 * jax.random.normal(ks[1], (E, d_model, d_ff_expert))
                 ).astype(dtype),
        "up": (0.02 * jax.random.normal(ks[2], (E, d_model, d_ff_expert))
               ).astype(dtype),
        "down": (0.02 * jax.random.normal(ks[3], (E, d_ff_expert, d_model))
                 ).astype(dtype),
    }
    if num_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d_model,
                               (d_ff_shared or d_ff_expert) * num_shared, dtype)
    return p


def moe_forward(params: dict, x: jax.Array, *, num_experts: int, top_k: int,
                capacity_factor: float = 1.25, groups: int | None = None,
                ) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) → (out (B, S, d), aux_loss scalar).

    GROUPED GShard dispatch: tokens are split into G groups aligned with
    the data-parallel shards, capacity is enforced *per group*, and the
    dispatch buffers are (G, E, Cg, d) — sharded over BOTH mesh axes
    (G→data, E→model). The position cumsum is group-local (no cross-shard
    sequential dependency) and the expert FFN contraction is fully local;
    only the (G,E,Cg,d) dispatch/combine reshards cross the network
    (~N·k·d/G bytes per chip per layer), instead of the full-buffer
    all-reduce an ungrouped scatter forces (EXPERIMENTS §Perf qwen3-moe,
    ~16× collective-bytes reduction)."""
    from repro.sharding import ctx as shctx

    B, S, d = x.shape
    N = B * S
    E, k = num_experts, top_k
    if groups is None:
        # one group per batch shard (pod × data on the multi-pod mesh)
        groups = shctx.batch_shard_count() if shctx.enabled() else 1
    G = groups if N % groups == 0 and N >= groups else 1
    Ng = N // G
    xt = x.reshape(G, Ng, d)
    xt = shctx.shard_batch(xt)

    logits = (xt.astype(jnp.float32) @ params["router"])          # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)                   # (G, Ng, k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    # Load-balance auxiliary loss (Switch-style), over all tokens.
    me = jnp.mean(probs, axis=(0, 1))                             # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    capacity = int(max(1, round(capacity_factor * Ng * k / E)))

    # Per-group position of each assignment within its expert.
    flat_e = top_idx.reshape(G, Ng * k)                           # (G, Nk)
    one_hot_e = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (G, Nk, E)
    pos_in_e = jnp.cumsum(one_hot_e, axis=1) - 1
    pos = jnp.sum(pos_in_e * one_hot_e, axis=-1)                  # (G, Nk)
    keep = (pos < capacity).astype(x.dtype)
    tok_idx = jnp.tile(jnp.repeat(jnp.arange(Ng), k)[None], (G, 1))
    slot = jnp.clip(pos, 0, capacity - 1)

    # Dispatch into dual-sharded buffers (G→data, E→model), vmapped over
    # groups. (§Perf note: rewriting this with explicit 3-D indexing so
    # intermediates could carry constraints REGRESSED 24× — GSPMD lowers
    # batched advanced indexing far worse than the vmapped scatter/gather;
    # measured and reverted, see EXPERIMENTS §Perf qwen3-moe iteration 2.)
    def scatter_group(xg, fe, sl, kp, ti):
        buf = jnp.zeros((E, capacity, d), x.dtype)
        return buf.at[fe, sl].add(xg[ti] * kp[:, None])

    buf = jax.vmap(scatter_group)(xt, flat_e, slot, keep, tok_idx)
    buf = shctx.shard_group_experts(buf)                          # (G,E,Cg,d)

    # Expert FFN — local contraction on each (data=g, model=e) chip.
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, params["up"])
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["down"])
    out_buf = shctx.shard_group_experts(out_buf)

    # Combine: per-group gather of each assignment's output.
    def gather_group(ob, fe, sl, ti, w):
        vals = ob[fe, sl]                                         # (Nk,d)
        return jnp.zeros((Ng, d), x.dtype).at[ti].add(vals * w[:, None])

    w = (top_vals.reshape(G, Ng * k).astype(jnp.float32)
         * keep.astype(jnp.float32)).astype(x.dtype)
    combined = jax.vmap(gather_group)(out_buf, flat_e, slot, tok_idx, w)
    combined = shctx.shard_batch(combined)

    if "shared" in params:
        from repro.models.layers import mlp
        combined = combined + mlp(params["shared"], xt)

    return combined.reshape(B, S, d), aux
