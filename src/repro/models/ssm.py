"""State-space / recurrent mixers: Mamba (Jamba), sLSTM and mLSTM (xLSTM).

All three carry O(1)-per-token state, which is what makes the hybrid/SSM
architectures eligible for the ``long_500k`` decode shape: the "cache" is
a fixed-size recurrent state, independent of context length.

* Mamba — selective SSM (arXiv:2312.00752, as used in Jamba
  arXiv:2403.19887): depthwise causal conv + input-dependent (Δ, B, C),
  first-order diagonal recurrence evaluated with an associative scan
  (log-depth on TPU) for train/prefill and a single-step update for decode.
* sLSTM — scalar-memory LSTM with exponential gating and a normalizer/
  stabilizer state, block-diagonal per-head recurrence (arXiv:2405.04517).
  Strictly sequential (real recurrence) → ``lax.scan``.
* mLSTM — matrix-memory LSTM: C_t = f C_{t-1} + i v kᵀ, read h = C q.
  Implemented as a scan; per-step cost is O(H·dh²) — the TPU-friendly
  systolic formulation of the paper's "fully parallelizable" claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------
def init_mamba(key, d_model: int, *, d_inner: int, d_state: int = 16,
               d_conv: int = 4, dt_rank: int | None = None, dtype) -> dict:
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (d_conv, d_inner))).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.full((d_inner,), -4.0, dtype),   # softplus ≈ 0.018
        "A_log": jnp.log(A),                             # f32
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, d_model, dtype),
    }


def _mamba_conv_full(xs: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv over S. xs (B,S,di), w (d_conv, di)."""
    d_conv = w.shape[0]
    pad = jnp.pad(xs, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xs.shape[1]] * w[i] for i in range(d_conv))
    return out + b


def _mamba_dbc(params, xs, dt_rank, d_state):
    proj = xs @ params["x_proj"]
    dt_in, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] + params["dt_bias"])
    return dt.astype(jnp.float32), B_.astype(jnp.float32), C_.astype(jnp.float32)


def _selective_scan_combine(a, b):
    (a1, b1), (a2, b2) = a, b
    return a1 * a2, a2 * b1 + b2


def mamba_forward(params: dict, x: jax.Array, *, d_inner: int,
                  d_state: int = 16, dt_rank: int | None = None,
                  chunk: int = 512) -> jax.Array:
    """Selective SSM with a CHUNKED parallel scan: associative scan
    (log-depth, MXU/VPU-parallel) *within* chunks of length ``chunk``,
    first-order carry *across* chunks (lax.scan + remat). The monolithic
    associative scan materializes (B,S,d_inner,d_state) fp32 buffers at
    every level — for jamba train_4k that alone blows HBM (EXPERIMENTS
    §Perf jamba note); chunking caps the live set at
    (B,chunk,d_inner,d_state) while keeping the parallel math."""
    dt_rank = dt_rank or max(1, x.shape[-1] // 16)
    B, S, d = x.shape
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_mamba_conv_full(xs, params["conv_w"], params["conv_b"]))
    dt, B_, C_ = _mamba_dbc(params, xs, dt_rank, d_state)
    A = -jnp.exp(params["A_log"])                                   # (di, ds)

    def seg(xs_c, dt_c, B_c, C_c, h0):
        dA = jnp.exp(dt_c[..., None] * A)                           # (B,c,di,ds)
        dBx = (dt_c * xs_c.astype(jnp.float32))[..., None] * B_c[:, :, None, :]
        # fold the incoming state into the first element
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
        dAc, h = jax.lax.associative_scan(
            _selective_scan_combine, (dA, dBx), axis=1)
        y = jnp.sum(h * C_c[:, :, None, :], axis=-1)                # (B,c,di)
        return y, h[:, -1]

    if chunk and S % chunk == 0 and S > chunk:
        nc = S // chunk
        as_chunks = lambda a: jnp.moveaxis(
            a.reshape(B, nc, chunk, *a.shape[2:]), 1, 0)

        def body(h0, xs_i):
            y, h1 = seg(*xs_i, h0)
            return h1, y

        h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
        _, ys = jax.lax.scan(jax.checkpoint(body), h0,
                             (as_chunks(xs), as_chunks(dt),
                              as_chunks(B_), as_chunks(C_)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_inner)
    else:
        y, _ = seg(xs, dt, B_, C_,
                   jnp.zeros((B, d_inner, d_state), jnp.float32))
    y = y + params["D"] * xs.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"]


def init_mamba_cache(batch, d_inner, d_state, d_conv, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
    }


def mamba_decode(params: dict, cache: dict, x: jax.Array, *, d_inner: int,
                 d_state: int = 16, dt_rank: int | None = None):
    """x (B,1,d) → (cache', y (B,1,d))."""
    dt_rank = dt_rank or max(1, x.shape[-1] // 16)
    xz = x[:, 0] @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                               # (B,di)
    hist = jnp.concatenate([cache["conv"], xs[:, None]], axis=1)    # (B,dc,di)
    conv = jnp.sum(hist * params["conv_w"][None], axis=1) + params["conv_b"]
    xs_c = jax.nn.silu(conv)
    dt, B_, C_ = _mamba_dbc(params, xs_c[:, None], dt_rank, d_state)
    dt, B_, C_ = dt[:, 0], B_[:, 0], C_[:, 0]
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[..., None] * A)                                 # (B,di,ds)
    h = dA * cache["h"] + (dt * xs_c.astype(jnp.float32))[..., None] * B_[:, None, :]
    y = jnp.sum(h * C_[:, None, :], axis=-1) + params["D"] * xs_c.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    new_cache = {"conv": hist[:, 1:], "h": h}
    return new_cache, (y @ params["out_proj"])[:, None]


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, d_model: int, n_heads: int, dtype) -> dict:
    dh = d_model // n_heads
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype),   # z,i,f,o pre-acts
        "r": (0.02 * jax.random.normal(ks[1], (4, n_heads, dh, dh))).astype(dtype),
        "b": jnp.zeros((4 * d_model,), dtype),
        "out_proj": dense_init(ks[2], d_model, d_model, dtype),
        "norm": jnp.ones((d_model,), dtype),
    }


def _slstm_step(params, carry, pre, n_heads, dh):
    """carry: (h, c, n, m) each (B, H, dh) f32; pre (B, 4·d) input
    pre-activations (the x_t @ w_in matmul is hoisted OUT of the scan —
    one big (B,S,4d) matmul instead of S small sharded ones, which would
    otherwise emit a collective per step)."""
    h, c, n, m = carry
    B = pre.shape[0]
    pre = pre.reshape(B, 4, n_heads, dh).astype(jnp.float32)
    r = params["r"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->bghe", h, r)                         # (B,4,H,dh)
    z_t = jnp.tanh(pre[:, 0] + rec[:, 0])
    i_t = pre[:, 1] + rec[:, 1]
    f_t = pre[:, 2] + rec[:, 2]
    o_t = jax.nn.sigmoid(pre[:, 3] + rec[:, 3])
    m_new = jnp.maximum(f_t + m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + m - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = f_p * n + i_p
    h_new = o_t * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (h_new, c_new, n_new, m_new)


def init_slstm_state(batch, n_heads, dh):
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return (z, z, z, jnp.zeros((batch, n_heads, dh), jnp.float32))


def slstm_forward(params: dict, x: jax.Array, *, n_heads: int,
                  segment: int = 64) -> jax.Array:
    """sLSTM is a true recurrence (not parallelizable — the xLSTM paper's
    own point), so train/prefill scans the sequence. To keep backward
    memory O(S/segment) instead of O(S), the scan is segmented with remat:
    the outer scan checkpoints only segment-boundary states and the
    backward pass recomputes the per-step gates inside each segment
    (EXPERIMENTS §Perf xlstm iteration 3)."""
    from repro.models.layers import rms_norm
    B, S, d = x.shape
    dh = d // n_heads
    carry0 = init_slstm_state(B, n_heads, dh)
    pre = x @ params["w_in"] + params["b"]          # hoisted out of the scan

    def body(carry, pre_t):
        new = _slstm_step(params, carry, pre_t, n_heads, dh)
        return new, new[0]

    if segment and S % segment == 0 and S > segment:
        pre_seg = jnp.moveaxis(
            pre.reshape(B, S // segment, segment, 4 * d), 1, 0)  # (ns,B,c,4d)

        def seg_body(carry, pre_c):
            c2, hs_c = jax.lax.scan(body, carry, jnp.moveaxis(pre_c, 1, 0))
            return c2, hs_c

        _, hs = jax.lax.scan(jax.checkpoint(seg_body), carry0, pre_seg)
        hs = hs.reshape(S, B, n_heads, dh)
    else:
        _, hs = jax.lax.scan(body, carry0, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    y = rms_norm(y, params["norm"])
    return y @ params["out_proj"]


def slstm_decode(params: dict, state, x: jax.Array, *, n_heads: int):
    from repro.models.layers import rms_norm
    B, _, d = x.shape
    dh = d // n_heads
    pre = x[:, 0] @ params["w_in"] + params["b"]
    new = _slstm_step(params, state, pre, n_heads, dh)
    y = new[0].reshape(B, 1, d).astype(x.dtype)
    y = rms_norm(y, params["norm"])
    return new, y @ params["out_proj"]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, d_model: int, n_heads: int, *, expand: int = 2, dtype) -> dict:
    di = expand * d_model
    dh = di // n_heads
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d_model, 2 * di, dtype),           # x branch + gate
        "wq": dense_init(ks[1], di, di, dtype),
        "wk": dense_init(ks[2], di, di, dtype),
        "wv": dense_init(ks[3], di, di, dtype),
        "w_if": dense_init(ks[4], di, 2 * n_heads, dtype),         # i,f pre-acts
        "norm": jnp.ones((di,), dtype),
        "down": dense_init(ks[6], di, d_model, dtype),
    }


def init_mlstm_state(batch, n_heads, dh):
    return (
        jnp.zeros((batch, n_heads, dh, dh), jnp.float32),   # C
        jnp.zeros((batch, n_heads, dh), jnp.float32),       # n
        jnp.zeros((batch, n_heads), jnp.float32),           # m
    )


def _mlstm_step(carry, qkv_if, n_heads, dh):
    """One stabilized mLSTM step. Forget gate in log-sigmoid space
    (the xLSTM "chunkwise kernels" convention), running-max stabilizer m;
    denominator max(|n·q|, exp(−m)) per the stabilized read-out."""
    C, n, m = carry
    q, k, v, i_t, f_t = qkv_if
    lf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(lf + m, i_t)
    i_p = jnp.exp(i_t - m_new)[..., None]                   # (B,H,1)
    f_p = jnp.exp(lf + m - m_new)[..., None]
    kn = k / jnp.sqrt(jnp.float32(dh))
    C_new = f_p[..., None] * C + i_p[..., None] * (v[..., None] * kn[..., None, :])
    n_new = f_p * n + i_p * kn
    num = jnp.einsum("bhde,bhe->bhd", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.sum(n_new * q, axis=-1)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return (C_new, n_new, m_new), h


def _mlstm_qkv(params, xs, n_heads, dh):
    B, S, di = xs.shape
    q = (xs @ params["wq"]).reshape(B, S, n_heads, dh).astype(jnp.float32)
    k = (xs @ params["wk"]).reshape(B, S, n_heads, dh).astype(jnp.float32)
    v = (xs @ params["wv"]).reshape(B, S, n_heads, dh).astype(jnp.float32)
    if_pre = (xs @ params["w_if"]).reshape(B, S, 2, n_heads).astype(jnp.float32)
    return q, k, v, if_pre[:, :, 0], if_pre[:, :, 1]


def _mlstm_chunk_scan(q, k, v, i_pre, f_pre, chunk: int):
    """Chunkwise-parallel stabilized mLSTM (the TPU-idiomatic form).

    Exactly equivalent to scanning :func:`_mlstm_step` over S, but:
      * within a chunk of length c the output is a causal (c×c)
        attention-like matmul (MXU work, parallel over positions);
      * the (dh×dh) matrix state is carried only across S/c chunk
        boundaries — backward saves S/c states instead of S (the 343 GB →
        ~1 GB fix for xlstm train_4k, see EXPERIMENTS §Perf).

    Derivation (log-space, per head): with local cumulative log-forget
    b_t = Σ_{s≤t} lf_s and running stabilizer m_t = b_t + cummax(m_0,
    max_{s≤t}(li_s − b_s)), the step-t output splits into an inter-chunk
    term exp(m_0 + b_t − m_t)·C_0 q_t and an intra-chunk term
    Σ_{s≤t} exp(b_t − b_s + li_s − m_t)(q_t·k̄_s) v_s.
    """
    B, S, H, dh = q.shape
    assert S % chunk == 0
    nc = S // chunk
    shp = (B, nc, chunk, H)

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, chunk, *a.shape[2:]), 1, 0)

    kn = k / jnp.sqrt(jnp.float32(dh))
    qc, kc, vc = to_chunks(q), to_chunks(kn), to_chunks(v)    # (nc,B,c,H,dh)
    lf = jax.nn.log_sigmoid(f_pre)                             # (B,S,H)
    lic = to_chunks(i_pre)                                     # (nc,B,c,H)
    lfc = to_chunks(lf)

    def chunk_body(carry, xs):
        C0, n0, m0 = carry            # (B,H,dh,dh), (B,H,dh), (B,H)
        qb, kb, vb, lib, lfb = xs     # (B,c,H,dh) / (B,c,H)
        b = jnp.cumsum(lfb, axis=1)                            # (B,c,H)
        g = jax.lax.cummax(jnp.maximum(m0[:, None], lib - b), axis=1)
        m = b + g                                              # (B,c,H) = m_t
        # inter-chunk contribution
        inter_w = jnp.exp(m0[:, None] + b - m)                 # (B,c,H)
        inter_h = jnp.einsum("bhde,bche->bchd", C0, qb)
        inter_n = jnp.einsum("bhe,bche->bch", n0, qb)
        # intra-chunk causal attention with decay matrix D
        li_minus_b = lib - b
        logD = b[:, :, None] + (li_minus_b)[:, None, :] - m[:, :, None]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(causal[None, :, :, None], jnp.exp(logD), 0.0)
        scores = jnp.einsum("bchd,bshd->bcsh", qb, kb)         # (B,c,c,H)
        intra_h = jnp.einsum("bcsh,bshd->bchd", D * scores, vb)
        intra_n = jnp.einsum("bcsh,bcsh->bch", D, scores)
        num = inter_w[..., None] * inter_h + intra_h
        nq = inter_w * inter_n + intra_n
        den = jnp.maximum(jnp.abs(nq), jnp.exp(-m))[..., None]
        h = num / den                                          # (B,c,H,dh)
        # end-of-chunk state
        bc = b[:, -1]                                          # (B,H)
        mc = m[:, -1]
        w0 = jnp.exp(m0 + bc - mc)                             # (B,H)
        ws = jnp.exp(bc[:, None] - b + lib - mc[:, None])      # (B,c,H)
        C_new = w0[..., None, None] * C0 + jnp.einsum(
            "bch,bchd,bche->bhde", ws, vb, kb)
        n_new = w0[..., None] * n0 + jnp.einsum("bch,bchd->bhd", ws, kb)
        return (C_new, n_new, mc), h

    carry0 = init_mlstm_state(B, H, dh)
    body = jax.checkpoint(chunk_body)
    _, hs = jax.lax.scan(body, carry0, (qc, kc, vc, lic, lfc))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)


def mlstm_forward(params: dict, x: jax.Array, *, n_heads: int,
                  expand: int = 2, chunk: int = 256) -> jax.Array:
    from repro.models.layers import rms_norm
    B, S, d = x.shape
    di = expand * d
    dh = di // n_heads
    up = x @ params["up"]
    xs, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkv(params, xs, n_heads, dh)
    if chunk and S % chunk == 0 and S > chunk:
        hs = _mlstm_chunk_scan(q, k, v, i_pre, f_pre, chunk)
        y = hs.reshape(B, S, di).astype(x.dtype)
    else:
        carry0 = init_mlstm_state(B, n_heads, dh)

        def body(carry, t):
            return _mlstm_step(carry, t, n_heads, dh)

        xs_t = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
                jnp.moveaxis(v, 1, 0),
                jnp.moveaxis(i_pre, 1, 0), jnp.moveaxis(f_pre, 1, 0))
        _, hs = jax.lax.scan(body, carry0, xs_t)
        y = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y, params["norm"]) * jax.nn.silu(z)
    return y @ params["down"]


def mlstm_decode(params: dict, state, x: jax.Array, *, n_heads: int,
                 expand: int = 2):
    from repro.models.layers import rms_norm
    B, _, d = x.shape
    di = expand * d
    dh = di // n_heads
    up = x[:, 0] @ params["up"]
    xs, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_pre, f_pre = _mlstm_qkv(params, xs[:, None], n_heads, dh)
    new, h = _mlstm_step(state, (q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0],
                                 f_pre[:, 0]), n_heads, dh)
    y = h.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y, params["norm"]) * jax.nn.silu(z[:, None])
    return new, y @ params["down"]
