"""Architecture assembly: layer stacks, scan-over-cycles, caches.

The stack is ``prefix_codes`` (unrolled) + ``cycle_codes × num_cycles``
(lax.scan over stacked params — keeps HLO size independent of depth,
which is what makes 72-layer multi-pod dry-run compiles tractable).
See configs/base.py for the layer-code grammar.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    dense_init, embed_init, init_mlp, init_rms, mlp, rms_norm,
    rope_angles, mrope_angles,
)
from repro.sharding import ctx as shctx


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------
def init_layer(key, code: str, cfg: ModelConfig) -> dict:
    mixer, ffn = cfg.parse_code(code)
    keys = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p: dict = {"norm": init_rms(d, dt)}
    if mixer in ("A", "S", "C"):
        p["attn"] = attn.init_gqa(keys[0], d, cfg.num_heads, cfg.num_kv_heads,
                                  cfg.resolved_head_dim, dt,
                                  qkv_bias=cfg.qkv_bias)
        if mixer == "C":
            p["norm_x"] = init_rms(d, dt)
            p["cross"] = attn.init_gqa(keys[2], d, cfg.num_heads,
                                       cfg.num_kv_heads,
                                       cfg.resolved_head_dim, dt,
                                       qkv_bias=cfg.qkv_bias)
    elif mixer == "L":
        p["attn"] = attn.init_mla(keys[0], d, cfg.num_heads,
                                  kv_lora_rank=cfg.mla.kv_lora_rank,
                                  head_dim=cfg.resolved_head_dim,
                                  rope_head_dim=cfg.mla.rope_head_dim, dtype=dt)
    elif mixer == "M":
        p["mixer"] = ssm.init_mamba(keys[0], d,
                                    d_inner=cfg.ssm.expand * d,
                                    d_state=cfg.ssm.d_state,
                                    d_conv=cfg.ssm.d_conv,
                                    dt_rank=cfg.ssm.dt_rank, dtype=dt)
    elif mixer == "m":
        p["mixer"] = ssm.init_mlstm(keys[0], d, cfg.num_heads,
                                    expand=cfg.ssm.mlstm_expand, dtype=dt)
    elif mixer == "s":
        p["mixer"] = ssm.init_slstm(keys[0], d, cfg.num_heads, dt)
    else:
        raise ValueError(code)

    if ffn == "D":
        p["norm2"] = init_rms(d, dt)
        p["ffn"] = init_mlp(keys[1], d, cfg.d_ff, dt)
    elif ffn == "E":
        p["norm2"] = init_rms(d, dt)
        p["ffn"] = moe_mod.init_moe(
            keys[1], d, cfg.moe.d_ff_expert, cfg.moe.num_experts,
            cfg.moe.top_k, dt, num_shared=cfg.moe.num_shared,
            d_ff_shared=cfg.moe.d_ff_shared)
    return p


# ---------------------------------------------------------------------------
# Context threaded through layers
# ---------------------------------------------------------------------------
@dataclass
class Ctx:
    cfg: ModelConfig
    positions: jax.Array | None = None      # (B,S) or (3,B,S) for mrope
    rope_cos_sin: tuple | None = None
    enc_kv: dict | None = None               # decoder cross-attn K/V
    window: int | None = None                # effective SWA window


def _mixer_kwargs(cfg: ModelConfig):
    return dict(n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)


def apply_layer_forward(lp: dict, code: str, x: jax.Array, ctx: Ctx):
    cfg = ctx.cfg
    mixer, ffn = cfg.parse_code(code)
    aux = jnp.float32(0.0)
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    if mixer in ("A", "S", "C"):
        y = attn.gqa_forward(lp["attn"], h, ctx.positions,
                             window=ctx.window,
                             rope_cos_sin=ctx.rope_cos_sin,
                             **_mixer_kwargs(cfg))
    elif mixer == "L":
        y = attn.mla_forward(lp["attn"], h, ctx.positions,
                             n_heads=cfg.num_heads,
                             head_dim=cfg.resolved_head_dim,
                             rope_head_dim=cfg.mla.rope_head_dim,
                             rope_theta=cfg.rope_theta, window=ctx.window)
    elif mixer == "M":
        y = ssm.mamba_forward(lp["mixer"], h, d_inner=cfg.ssm.expand * cfg.d_model,
                              d_state=cfg.ssm.d_state, dt_rank=cfg.ssm.dt_rank)
    elif mixer == "m":
        y = ssm.mlstm_forward(lp["mixer"], h, n_heads=cfg.num_heads,
                              expand=cfg.ssm.mlstm_expand,
                              chunk=cfg.ssm.mlstm_chunk)
    elif mixer == "s":
        y = ssm.slstm_forward(lp["mixer"], h, n_heads=cfg.num_heads,
                              segment=cfg.ssm.slstm_segment)
    x = x + y
    if mixer == "C":
        hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        x = x + attn.cross_forward(lp["cross"], hx, ctx.enc_kv,
                                   n_heads=cfg.num_heads,
                                   n_kv=cfg.num_kv_heads,
                                   head_dim=cfg.resolved_head_dim)
    if ffn == "D":
        x = x + mlp(lp["ffn"], rms_norm(x, lp["norm2"], cfg.norm_eps))
    elif ffn == "E":
        y, a = moe_mod.moe_forward(
            lp["ffn"], rms_norm(x, lp["norm2"], cfg.norm_eps),
            num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor, groups=cfg.moe.groups)
        x = x + y
        aux = aux + a
    return x, aux


def apply_layer_decode(lp: dict, code: str, cache, x: jax.Array,
                       pos: jax.Array, ctx: Ctx):
    cfg = ctx.cfg
    mixer, ffn = cfg.parse_code(code)
    h = rms_norm(x, lp["norm"], cfg.norm_eps)
    if mixer in ("A", "S", "C"):
        self_cache = {"k": cache["k"], "v": cache["v"]}
        new_cache, y = attn.gqa_decode(lp["attn"], self_cache, h, pos,
                                       window=ctx.window,
                                       rope_cos_sin=ctx.rope_cos_sin,
                                       **_mixer_kwargs(cfg))
    elif mixer == "L":
        new_cache, y = attn.mla_decode(lp["attn"], cache, h, pos,
                                       n_heads=cfg.num_heads,
                                       head_dim=cfg.resolved_head_dim,
                                       rope_head_dim=cfg.mla.rope_head_dim,
                                       rope_theta=cfg.rope_theta,
                                       window=ctx.window, absorb=True)
    elif mixer == "M":
        new_cache, y = ssm.mamba_decode(lp["mixer"], cache, h,
                                        d_inner=cfg.ssm.expand * cfg.d_model,
                                        d_state=cfg.ssm.d_state,
                                        dt_rank=cfg.ssm.dt_rank)
    elif mixer == "m":
        st = (cache["C"], cache["n"], cache["m"])
        st, y = ssm.mlstm_decode(lp["mixer"], st, h, n_heads=cfg.num_heads,
                                 expand=cfg.ssm.mlstm_expand)
        new_cache = {"C": st[0], "n": st[1], "m": st[2]}
    elif mixer == "s":
        st = (cache["h"], cache["c"], cache["n"], cache["m"])
        st, y = ssm.slstm_decode(lp["mixer"], st, h, n_heads=cfg.num_heads)
        new_cache = {"h": st[0], "c": st[1], "n": st[2], "m": st[3]}
    x = x + y
    if mixer == "C":
        hx = rms_norm(x, lp["norm_x"], cfg.norm_eps)
        enc_kv = {"k": cache["cross_k"], "v": cache["cross_v"]}
        x = x + attn.cross_forward(lp["cross"], hx, enc_kv,
                                   n_heads=cfg.num_heads,
                                   n_kv=cfg.num_kv_heads,
                                   head_dim=cfg.resolved_head_dim)
        new_cache = dict(new_cache, cross_k=cache["cross_k"],
                         cross_v=cache["cross_v"])
    if ffn == "D":
        x = x + mlp(lp["ffn"], rms_norm(x, lp["norm2"], cfg.norm_eps))
    elif ffn == "E":
        y, _ = moe_mod.moe_forward(
            lp["ffn"], rms_norm(x, lp["norm2"], cfg.norm_eps),
            num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor, groups=cfg.moe.groups)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# Cache init per layer
# ---------------------------------------------------------------------------
def init_layer_cache(code: str, cfg: ModelConfig, batch: int, cache_len: int,
                     dtype, enc_len: int | None = None) -> dict:
    mixer, _ = cfg.parse_code(code)
    d = cfg.d_model
    if mixer in ("A", "S", "C"):
        c = attn.init_gqa_cache(batch, cache_len, cfg.num_kv_heads,
                                cfg.resolved_head_dim, dtype)
        if mixer == "C":
            c["cross_k"] = jnp.zeros(
                (batch, enc_len, cfg.num_kv_heads, cfg.resolved_head_dim), dtype)
            c["cross_v"] = jnp.zeros_like(c["cross_k"])
        return c
    if mixer == "L":
        return attn.init_mla_cache(batch, cache_len, cfg.mla.kv_lora_rank,
                                   cfg.mla.rope_head_dim, dtype)
    if mixer == "M":
        return ssm.init_mamba_cache(batch, cfg.ssm.expand * d, cfg.ssm.d_state,
                                    cfg.ssm.d_conv, dtype)
    if mixer == "m":
        di = cfg.ssm.mlstm_expand * d
        dh = di // cfg.num_heads
        C, n, m = ssm.init_mlstm_state(batch, cfg.num_heads, dh)
        return {"C": C, "n": n, "m": m}
    if mixer == "s":
        dh = d // cfg.num_heads
        h, c, n, m = ssm.init_slstm_state(batch, cfg.num_heads, dh)
        return {"h": h, "c": c, "n": n, "m": m}
    raise ValueError(code)


# ---------------------------------------------------------------------------
# Stack init
# ---------------------------------------------------------------------------
def _init_cycle(key, codes, cfg) -> dict:
    ks = jax.random.split(key, len(codes))
    return {str(j): init_layer(ks[j], c, cfg) for j, c in enumerate(codes)}


def init_stack(key, cfg: ModelConfig, codes_prefix, codes_cycle, n_cycles):
    kp, kc = jax.random.split(key)
    prefix = [init_layer(k, c, cfg)
              for k, c in zip(jax.random.split(kp, max(len(codes_prefix), 1)),
                              codes_prefix)]
    cycle = None
    if n_cycles:
        cycle = jax.vmap(lambda k: _init_cycle(k, codes_cycle, cfg))(
            jax.random.split(kc, n_cycles))
    return {"prefix": prefix, "cycle": cycle}


def init_model(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    params = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "final_norm": init_rms(cfg.d_model, dt),
        "stack": init_stack(ks[1], cfg, cfg.prefix_codes, cfg.cycle_codes,
                            cfg.resolved_num_cycles),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dt)
    if cfg.encoder_layers:
        # Encoder: plain bidirectional attention cycle ("A-D").
        enc_cycles = cfg.encoder_layers
        params["enc"] = {
            "stack": init_stack(ks[3], cfg, (), ("A-D",), enc_cycles),
            "final_norm": init_rms(cfg.d_model, dt),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def _make_ctx_forward(cfg: ModelConfig, B: int, S: int,
                      positions=None, enc_kv=None) -> Ctx:
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.rope_kind == "mrope":
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
        cos, sin = mrope_angles(positions, cfg.resolved_head_dim,
                                cfg.rope_theta, cfg.mrope_sections)
        rope = (cos, sin)
        pos2d = positions[0]
    else:
        cos, sin = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)
        rope = (cos, sin)
        pos2d = positions
    return Ctx(cfg=cfg, positions=pos2d, rope_cos_sin=rope, enc_kv=enc_kv,
               window=cfg.attention_window)


def run_stack_forward(stack: dict, cfg: ModelConfig, x: jax.Array, ctx: Ctx,
                      codes_prefix, codes_cycle):
    aux = jnp.float32(0.0)
    x = shctx.shard_batch(x)
    for lp, code in zip(stack["prefix"], codes_prefix):
        x, a = apply_layer_forward(lp, code, x, ctx)
        x = shctx.shard_batch(x)
        aux = aux + a
    if stack["cycle"] is not None:
        def one_layer(lp, code, xx):
            xx, a = apply_layer_forward(lp, code, xx, ctx)
            return shctx.shard_batch(xx), a

        if cfg.remat_per_layer:
            one_layer = jax.checkpoint(one_layer, static_argnums=(1,))

        def body(carry, lp_cycle):
            xx, au = carry
            for j, code in enumerate(codes_cycle):
                xx, a = one_layer(lp_cycle[str(j)], code, xx)
                au = au + a
            return (xx, au), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), stack["cycle"])
    return x, aux


def forward_logits(params: dict, cfg: ModelConfig, batch: dict):
    """Full-sequence forward. batch:
       dense/moe/ssm:  {tokens (B,S)}
       vlm:            {tokens (B,S_text), patch_embeds (B,P,d), [positions]}
       audio enc-dec:  {frames (B,S_enc,d), tokens (B,S_dec)}
    Returns (logits (B,S,Vp), aux_loss, loss_mask (B,S))."""
    if cfg.encoder_layers:
        enc_x = batch["frames"]
        B, Se, _ = enc_x.shape
        enc_ctx = _make_ctx_forward(cfg, B, Se)
        enc_ctx.window = None
        enc_out, _ = run_stack_forward(params["enc"]["stack"], cfg, enc_x,
                                       enc_ctx, (), ("A-D",))
        enc_out = rms_norm(enc_out, params["enc"]["final_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
        # per-layer cross K/V are computed inside 'C' layers from enc_out;
        # to keep the scan homogeneous we precompute shared K/V per cycle
        # position lazily via the layer's own projections (enc_kv below is
        # recomputed per layer from enc_out).
        ctx = _make_ctx_forward(cfg, B, S)
        ctx.enc_out = enc_out  # type: ignore[attr-defined]

        # Wrap apply to inject per-layer cross K/V.
        aux = jnp.float32(0.0)

        x = shctx.shard_batch(x)

        def body(carry, lp_cycle):
            xx, au = carry
            for j, code in enumerate(cfg.cycle_codes):
                lp = lp_cycle[str(j)]
                mixer, _ = cfg.parse_code(code)
                if mixer == "C":
                    ctx.enc_kv = attn.encode_kv(
                        lp["cross"], enc_out, n_kv=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim)
                xx, a = apply_layer_forward(lp, code, xx, ctx)
                xx = shctx.shard_batch(xx)
                au = au + a
            return (xx, au), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["stack"]["cycle"])
        mask = jnp.ones_like(tokens, dtype=jnp.float32)
    else:
        tokens = batch["tokens"]
        B, S_text = tokens.shape
        x = params["embed"][tokens]
        mask = jnp.ones((B, S_text), jnp.float32)
        positions = batch.get("positions")
        pe = batch.get("patch_embeds")
        if cfg.frontend == "vision" and pe is not None:
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((B, pe.shape[1]), jnp.float32), mask], axis=1)
        B, S, _ = x.shape
        ctx = _make_ctx_forward(cfg, B, S, positions=positions)
        x, aux = run_stack_forward(params["stack"], cfg, x, ctx,
                                   cfg.prefix_codes, cfg.cycle_codes)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shctx.shard_batch(x @ head, model_dim=-1)
    return logits, aux, mask


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               enc_len: int | None = None):
    """Stacked caches mirroring the param structure."""
    dt = jnp.dtype(cfg.dtype)
    # effective per-layer cache length: SWA caches only hold the window
    def cl(code):
        mixer, _ = cfg.parse_code(code)
        if mixer in ("A", "S", "C", "L") and cfg.attention_window is not None:
            return min(cache_len, cfg.attention_window)
        return cache_len

    prefix = [init_layer_cache(c, cfg, batch, cl(c), dt, enc_len)
              for c in cfg.prefix_codes]
    cycle = None
    if cfg.resolved_num_cycles:
        def one(_):
            return {str(j): init_layer_cache(c, cfg, batch, cl(c), dt, enc_len)
                    for j, c in enumerate(cfg.cycle_codes)}
        cycle = jax.vmap(one)(jnp.arange(cfg.resolved_num_cycles))
    return {"prefix": prefix, "cycle": cycle}


def decode_step(params: dict, cfg: ModelConfig, cache, token: jax.Array,
                pos: jax.Array):
    """token (B,1) int32; pos scalar int32. Returns (logits (B,1,Vp), cache)."""
    B = token.shape[0]
    x = shctx.shard_batch(params["embed"][token])
    p1 = jnp.full((B, 1), pos, dtype=jnp.int32)
    if cfg.rope_kind == "mrope":
        p3 = jnp.broadcast_to(p1[None], (3, B, 1))
        cos, sin = mrope_angles(p3, cfg.resolved_head_dim, cfg.rope_theta,
                                cfg.mrope_sections)
    else:
        cos, sin = rope_angles(p1, cfg.resolved_head_dim, cfg.rope_theta)
    ctx = Ctx(cfg=cfg, positions=p1, rope_cos_sin=(cos, sin),
              window=cfg.attention_window)

    new_prefix = []
    for lp, code, c in zip(params["stack"]["prefix"], cfg.prefix_codes,
                           cache["prefix"]):
        x, nc = apply_layer_decode(lp, code, c, x, pos, ctx)
        new_prefix.append(nc)

    new_cycle = None
    if params["stack"]["cycle"] is not None:
        def body(xx, inputs):
            lp_cycle, c_cycle = inputs
            ncs = {}
            for j, code in enumerate(cfg.cycle_codes):
                xx, nc = apply_layer_decode(lp_cycle[str(j)], code,
                                            c_cycle[str(j)], xx, pos, ctx)
                xx = shctx.shard_batch(xx)
                ncs[str(j)] = nc
            return xx, ncs

        x, new_cycle = jax.lax.scan(body, x,
                                    (params["stack"]["cycle"], cache["cycle"]))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, {"prefix": new_prefix, "cycle": new_cycle}


def prefill_encoder(params: dict, cfg: ModelConfig, frames: jax.Array,
                    cache, batch: int):
    """Run the encoder and fill decoder cross-attn K/V into the cache."""
    B, Se, _ = frames.shape
    ctx = _make_ctx_forward(cfg, B, Se)
    ctx.window = None
    enc_out, _ = run_stack_forward(params["enc"]["stack"], cfg, frames, ctx,
                                   (), ("A-D",))
    enc_out = rms_norm(enc_out, params["enc"]["final_norm"], cfg.norm_eps)

    def fill(lp_cycle, c_cycle):
        for j, code in enumerate(cfg.cycle_codes):
            mixer, _ = cfg.parse_code(code)
            if mixer == "C":
                kv = attn.encode_kv(lp_cycle[str(j)]["cross"], enc_out,
                                    n_kv=cfg.num_kv_heads,
                                    head_dim=cfg.resolved_head_dim)
                c_cycle[str(j)] = dict(c_cycle[str(j)],
                                       cross_k=kv["k"], cross_v=kv["v"])
        return c_cycle

    new_cycle = jax.vmap(fill)(params["stack"]["cycle"], cache["cycle"])
    return {"prefix": cache["prefix"], "cycle": new_cycle}
