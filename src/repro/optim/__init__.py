from repro.optim.optimizers import (
    Optimizer, sgd, adamw, adafactor, get_optimizer,
)

__all__ = ["Optimizer", "sgd", "adamw", "adafactor", "get_optimizer"]
