"""Optimizers (optax-free, minimal but real).

* ``sgd``       — plain SGD (+momentum); what word2vec/SGNS uses.
* ``adamw``     — fp32 moments + decoupled weight decay; default for the
                  transformer zoo.
* ``adafactor`` — factored second moment, no first moment; the only
                  optimizer whose state fits for the 398B jamba config at
                  train_4k on a single 256-chip pod (see DESIGN.md).

All are (init_fn, update_fn) pairs over arbitrary pytrees and are safe
under jit/scan/pjit.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable   # (grads, state, params, step) -> (new_params, new_state)


def sgd(lr: float = 0.01, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        del step
        if momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                               params, grads)
            return new, state
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                          state["mu"], grads)
        new = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype), params, mu)
        return new, {"mu": mu}

    return Optimizer("sgd", init, update)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        z32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z32, params), "v": jax.tree.map(z32, params)}

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_ = b1 * m + (1 - b1) * g32
            v_ = b2 * v + (1 - b2) * g32 * g32
            upd_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            p_ = p.astype(jnp.float32) - lr * (upd_ + weight_decay * p.astype(jnp.float32))
            return p_.astype(p.dtype), m_, v_

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tree, [o[0] for o in out])
        new_m = jax.tree.unflatten(tree, [o[1] for o in out])
        new_v = jax.tree.unflatten(tree, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update)


def adafactor(lr: float = 1e-2, eps: float = 1e-30,
              decay: float = 0.8, clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment only (Shazeer & Stern): state for an (n, m)
    matrix is n + m floats instead of 2·n·m."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(one, params,
                            is_leaf=lambda x: isinstance(x, jax.Array))

    def update(grads, state, params, step):
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def one(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                u = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                           + 1e-30)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 / (jnp.sqrt(v) + 1e-30)
                ns = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        # state is a pytree-of-dicts mirroring params
        flat_s = jax.tree.flatten(
            state, is_leaf=lambda x: isinstance(x, dict) and (
                "v" in x or "vr" in x))[0]
        out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = jax.tree.unflatten(tree, [o[0] for o in out])
        new_s = jax.tree.unflatten(tree, [o[1] for o in out])
        return new_p, new_s

    return Optimizer("adafactor", init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}[name](**kw)
