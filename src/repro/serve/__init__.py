"""The embedding serving tier — the read path of the system.

Training produces sub-models; the merge folds them into a consensus
table; this package serves that table to clients:

* :mod:`repro.serve.publish` — incremental merge → versioned artifact
  (one atomic :func:`repro.checkpoint.publish_table` per fold);
* :mod:`repro.serve.store`   — artifact directory → always-complete
  in-memory :class:`~repro.checkpoint.ServableTable`, hot-reloadable;
* :mod:`repro.serve.batcher` — asyncio request coalescing + semaphore-
  bounded batch dispatch;
* :mod:`repro.serve.cache`   — hot-row LRU;
* :mod:`repro.serve.server`  — :class:`EmbeddingServer`, tying the four
  together, including on-the-fly ``reconstruct_missing`` for words
  absent from some sub-models;
* :mod:`repro.serve.tcp`     — a JSON-lines TCP front end.

See ``docs/ARCHITECTURE.md`` ("Merge and serve") for the dataflow.
"""

from repro.serve.batcher import CoalescingBatcher, ServeConfig
from repro.serve.cache import LRUCache
from repro.serve.publish import publish_incremental
from repro.serve.server import MERGED, EmbeddingServer
from repro.serve.store import ArtifactStore
from repro.serve.tcp import request_once, start_tcp_server

__all__ = [
    "ArtifactStore",
    "CoalescingBatcher",
    "EmbeddingServer",
    "LRUCache",
    "MERGED",
    "ServeConfig",
    "publish_incremental",
    "request_once",
    "start_tcp_server",
]
