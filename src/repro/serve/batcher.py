"""Request coalescing + semaphore-bounded batch dispatch (asyncio).

The serving-side idiom: individual lookups arriving within a short
window are coalesced into one deduplicated batch, batches dispatch
under a concurrency semaphore, and every caller's future resolves with
its own row. One batched gather per window amortizes the per-call
overhead exactly the way one batched device step amortizes launch
overhead on the write path.

Timeline of one window (``coalesce_ms = 2``)::

    t=0.0  submit(a) ──┐ opens the window, starts the flush timer
    t=0.4  submit(b) ──┤ joins the pending batch
    t=0.9  submit(a) ──┤ dedup: shares a's future
    t=2.0  timer fires ─┴─► dispatch({a, b}) under the semaphore
                            → both a-waiters + the b-waiter resolve

A burst that reaches ``max_batch`` before the timer flushes
immediately — the window bounds latency, the batch cap bounds memory.
"""

from __future__ import annotations

import asyncio
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Hashable, Sequence

_STATS_WINDOW = 65_536   # most recent request latencies / batch sizes kept


@dataclass(frozen=True)
class ServeConfig:
    """Serving-tier knobs.

    ``coalesce_ms``      — how long the first request of a window waits
                           for company before its batch dispatches;
    ``max_batch``        — flush immediately at this many distinct keys;
    ``max_concurrency``  — concurrent in-flight batch dispatches;
    ``cache_rows``       — hot-row LRU capacity (0 disables);
    ``dispatch_in_thread`` — run the gather in a worker thread
                           (``asyncio.to_thread``) so a large gather
                           never blocks the event loop; leave off for
                           micro-batches where the hop costs more than
                           the gather.
    """

    coalesce_ms: float = 2.0
    max_batch: int = 256
    max_concurrency: int = 4
    cache_rows: int = 4096
    dispatch_in_thread: bool = False


class CoalescingBatcher:
    """Coalesces single-key lookups into deduplicated batch dispatches.

    Args:
        dispatch: ``(keys) -> {key: value}`` — the batched lookup. Runs
            on the event loop (or a worker thread, see
            ``ServeConfig.dispatch_in_thread``); must return a value
            for every requested key.
        cfg: the :class:`ServeConfig` window/batch/concurrency knobs.

    Invariants: a key has at most one pending future at a time
    (concurrent submits of the same key share it); every submitted key
    is dispatched exactly once per window it is pending in; dispatch
    failures reject all of that batch's futures with the same error.
    """

    def __init__(self, dispatch: Callable[[Sequence[Hashable]], dict],
                 cfg: ServeConfig = ServeConfig()):
        self._dispatch = dispatch
        self.cfg = cfg
        self._pending: dict[Hashable, tuple[asyncio.Future, float]] = {}
        self._timer: asyncio.Task | None = None
        self._sem = asyncio.Semaphore(cfg.max_concurrency)
        self._inflight: set[asyncio.Task] = set()
        # telemetry (bounded windows)
        self._latencies_s: deque[float] = deque(maxlen=_STATS_WINDOW)
        self._batch_sizes: deque[int] = deque(maxlen=_STATS_WINDOW)
        self.requests = 0
        self.dispatches = 0
        self._max_concurrent_seen = 0
        self._now_concurrent = 0

    async def submit(self, key: Hashable):
        """Look up one key; resolves when its coalesced batch does."""
        self.requests += 1
        entry = self._pending.get(key)
        if entry is None:
            fut = asyncio.get_running_loop().create_future()
            self._pending[key] = (fut, time.perf_counter())
            if len(self._pending) >= self.cfg.max_batch:
                self._flush()
            elif self._timer is None or self._timer.done():
                self._timer = asyncio.create_task(self._flush_after_window())
        else:
            fut = entry[0]
        return await fut

    async def _flush_after_window(self) -> None:
        await asyncio.sleep(self.cfg.coalesce_ms / 1000.0)
        self._flush()

    def _flush(self) -> None:
        if not self._pending:
            return
        batch, self._pending = self._pending, {}
        timer, cur = self._timer, asyncio.current_task()
        if timer is not None and timer is not cur and not timer.done():
            timer.cancel()
        self._timer = None
        task = asyncio.create_task(self._run_batch(batch))
        # keep a strong ref until done (create_task refs are weak)
        self._inflight.add(task)
        task.add_done_callback(self._inflight.discard)

    async def _run_batch(self, batch: dict) -> None:
        keys = list(batch)
        async with self._sem:
            self._now_concurrent += 1
            self._max_concurrent_seen = max(self._max_concurrent_seen,
                                            self._now_concurrent)
            try:
                if self.cfg.dispatch_in_thread:
                    results = await asyncio.to_thread(self._dispatch, keys)
                else:
                    results = self._dispatch(keys)
            except Exception as e:          # reject the whole batch
                for fut, _ in batch.values():
                    if not fut.done():
                        fut.set_exception(e)
                return
            finally:
                self._now_concurrent -= 1
        done = time.perf_counter()
        self.dispatches += 1
        self._batch_sizes.append(len(keys))
        for key, (fut, t0) in batch.items():
            self._latencies_s.append(done - t0)
            if not fut.done():
                fut.set_result(results[key])

    async def drain(self) -> None:
        """Flush anything pending and wait for in-flight dispatches."""
        self._flush()
        while self._inflight:
            await asyncio.gather(*tuple(self._inflight),
                                 return_exceptions=True)

    def stats(self) -> dict:
        """Latency percentiles (per request, submit→resolve), coalesced
        batch sizes, and dispatch counters — over the most recent
        telemetry window."""
        lat = sorted(self._latencies_s)
        sizes = self._batch_sizes

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        return {
            "requests": self.requests,
            "dispatches": self.dispatches,
            "p50_ms": pct(0.50) * 1e3,
            "p99_ms": pct(0.99) * 1e3,
            "mean_batch": (sum(sizes) / len(sizes)) if sizes else 0.0,
            "max_batch": max(sizes) if sizes else 0,
            "max_concurrent_dispatches": self._max_concurrent_seen,
        }
