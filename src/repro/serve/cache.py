"""Hot-row LRU cache.

Word frequencies are Zipfian, so a small set of rows absorbs most
lookups (the same skew the frequency-tiered engine exploits on the
write path). The cache sits *in front of* the coalescing batcher: a hit
never enqueues, a miss rides the next coalesced batch and is inserted
on completion.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Hashable


class LRUCache:
    """A plain ordered-dict LRU with hit/miss counters.

    Args:
        capacity: max entries; 0 disables caching (every ``get`` is a
            recorded miss, ``put`` is a no-op).

    Not thread-safe — it is only touched from the server's event loop.
    """

    def __init__(self, capacity: int):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._d: OrderedDict[Hashable, object] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._d

    def get(self, key: Hashable):
        """The cached value (refreshing its recency) or ``None``."""
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value) -> None:
        """Insert/refresh ``key``, evicting the least-recently-used
        entry past capacity."""
        if self.capacity == 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def clear(self) -> None:
        """Drop all entries (counters survive — they describe the
        process lifetime, not one table version)."""
        self._d.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
