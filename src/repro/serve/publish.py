"""Incremental merge → versioned artifact: the train→serve bridge.

The trainer's output is a stack of sub-models; this module folds them
through a :class:`~repro.core.merge.Merger` (any registry entry — the
flat ``"alir"`` solver or the ``"alir_tree"`` reduction tree) **as they
arrive** and atomically publishes one artifact version per fold. A
serving process pointed at the directory picks up each version via
``refresh()`` — the first workers' embeddings are live while the rest
are still training; the final fold (cold, canonical order) is
bit-identical to the batch merge.
"""

from __future__ import annotations

from typing import Iterable, Iterator

import numpy as np

from repro.checkpoint.io import publish_table
from repro.core.merge import MergeResult, Merger, alir_transforms, get_merger


def submodel_arrivals(stacked, order: Iterable[int] | None = None
                      ) -> Iterator[tuple[int, np.ndarray, np.ndarray]]:
    """Yield ``(worker_id, model, mask)`` from a trained
    :class:`~repro.core.merge.StackedModels` — in ``order`` if given
    (simulating an out-of-order finish), else worker order."""
    models = np.asarray(stacked.models)
    masks = np.asarray(stacked.mask)
    for w in (range(len(models)) if order is None else order):
        yield int(w), models[int(w)], masks[int(w)]


def publish_incremental(
    arrivals,
    artifact_dir: str,
    *,
    word_ids: np.ndarray | None = None,
    publish_every: int = 1,
    include_models: bool = True,
    final_cold_fold: bool = True,
    merger: Merger | str | None = None,
    meta: dict | None = None,
) -> tuple[list[int], MergeResult]:
    """Fold arriving sub-models and publish a table version per fold.

    Args:
        arrivals: iterable of ``(worker_id, model (V, d), mask (V,))``
            — a :func:`submodel_arrivals` generator over a trained
            stack, or a live queue drained as workers finish.
        artifact_dir: target directory (created if needed); versions
            are monotonic across runs into the same directory.
        word_ids: raw word id per union-vocab row
            (``union_vocab.word_ids``) — published so the server can
            answer raw-id queries.
        publish_every: publish after every k-th arrival (the last
            arrival always publishes).
        include_models: ship the folded sub-models as an artifact
            sidecar so sub-model-space queries can serve *present* rows
            too; turn off at production vocab where ``n·V·d`` dwarfs
            the table and only reconstruction (absent rows) is needed.
        final_cold_fold: finish with ``fold(warm=False)`` — the
            canonical solve that is bit-identical to the batch merge
            regardless of arrival order.
        merger: a :class:`~repro.core.merge.Merger` instance or registry
            name (default ``"alir"``; ``"alir_tree"`` scales the fold to
            large worker counts).
        meta: extra manifest fields for every published version.

    Returns:
        ``(published version numbers, final MergeResult)``.
    """
    merger = get_merger(merger if merger is not None else "alir")
    versions: list[int] = []
    fold = None
    arrivals = list(arrivals)
    if not arrivals:
        raise ValueError("no sub-model arrivals to publish")
    for k, (worker_id, model, mask) in enumerate(arrivals):
        last = k == len(arrivals) - 1
        result = merger.add(worker_id, model, mask)
        fold = result if result is not None else fold
        if last and final_cold_fold:
            fold = merger.fold(warm=False)
        if fold is None:
            continue  # late arrival before any fold — nothing servable yet
        if last or (k + 1) % publish_every == 0:
            versions.append(_publish_fold(
                merger, fold, artifact_dir, word_ids=word_ids,
                include_models=include_models,
                meta={**(meta or {}), "final": last}))
    return versions, fold


def _publish_fold(merger: Merger, fold: MergeResult,
                  artifact_dir: str, *, word_ids, include_models: bool,
                  meta: dict) -> int:
    stacked = merger.stacked()
    # ALiR mergers carry the worker→consensus maps in the result (the
    # tree merger's are composed down the tree); fall back to a direct
    # Procrustes solve for mergers that don't.
    Ws = (fold.transforms if fold.transforms is not None
          else alir_transforms(stacked, fold.Y))
    return publish_table(
        artifact_dir,
        np.asarray(fold.Y), np.asarray(fold.valid),
        word_ids=word_ids,
        worker_ids=np.asarray(fold.worker_ids, dtype=np.int32),
        mask=np.asarray(stacked.mask),
        transforms=np.asarray(Ws),
        models=np.asarray(stacked.models) if include_models else None,
        meta={"merge": f"{merger.name}_incremental",
              "n_folded": merger.n_folded, **meta})
