"""Incremental merge → versioned artifact: the train→serve bridge.

The trainer's output is a stack of sub-models; this module folds them
through :class:`~repro.core.merge.IncrementalAlirMerger` **as they
arrive** and atomically publishes one artifact version per fold. A
serving process pointed at the directory picks up each version via
``refresh()`` — the first workers' embeddings are live while the rest
are still training; the final fold (cold, canonical order) is
bit-identical to the batch merge.
"""

from __future__ import annotations

from typing import Iterable, Iterator

import numpy as np

from repro.checkpoint.io import publish_table
from repro.core.merge import FoldResult, IncrementalAlirMerger, alir_transforms


def submodel_arrivals(stacked, order: Iterable[int] | None = None
                      ) -> Iterator[tuple[int, np.ndarray, np.ndarray]]:
    """Yield ``(worker_id, model, mask)`` from a trained
    :class:`~repro.core.merge.StackedModels` — in ``order`` if given
    (simulating an out-of-order finish), else worker order."""
    models = np.asarray(stacked.models)
    masks = np.asarray(stacked.mask)
    for w in (range(len(models)) if order is None else order):
        yield int(w), models[int(w)], masks[int(w)]


def publish_incremental(
    arrivals,
    artifact_dir: str,
    *,
    word_ids: np.ndarray | None = None,
    publish_every: int = 1,
    include_models: bool = True,
    final_cold_fold: bool = True,
    merger: IncrementalAlirMerger | None = None,
    meta: dict | None = None,
) -> tuple[list[int], FoldResult]:
    """Fold arriving sub-models and publish a table version per fold.

    Args:
        arrivals: iterable of ``(worker_id, model (V, d), mask (V,))``
            — a :func:`submodel_arrivals` generator over a trained
            stack, or a live queue drained as workers finish.
        artifact_dir: target directory (created if needed); versions
            are monotonic across runs into the same directory.
        word_ids: raw word id per union-vocab row
            (``union_vocab.word_ids``) — published so the server can
            answer raw-id queries.
        publish_every: publish after every k-th arrival (the last
            arrival always publishes).
        include_models: ship the folded sub-models as an artifact
            sidecar so sub-model-space queries can serve *present* rows
            too; turn off at production vocab where ``n·V·d`` dwarfs
            the table and only reconstruction (absent rows) is needed.
        final_cold_fold: finish with ``fold(warm=False)`` — the
            canonical solve that is bit-identical to the batch
            ``merge_alir`` regardless of arrival order.
        merger: a pre-configured :class:`IncrementalAlirMerger`
            (defaults to one with the standard init/iters/tol).
        meta: extra manifest fields for every published version.

    Returns:
        ``(published version numbers, final FoldResult)``.
    """
    merger = merger or IncrementalAlirMerger()
    versions: list[int] = []
    fold = None
    arrivals = list(arrivals)
    if not arrivals:
        raise ValueError("no sub-model arrivals to publish")
    for k, (worker_id, model, mask) in enumerate(arrivals):
        last = k == len(arrivals) - 1
        fold = merger.add(worker_id, model, mask)
        if last and final_cold_fold:
            fold = merger.fold(warm=False)
        if last or (k + 1) % publish_every == 0:
            versions.append(_publish_fold(
                merger, fold, artifact_dir, word_ids=word_ids,
                include_models=include_models,
                meta={**(meta or {}), "final": last}))
    return versions, fold


def _publish_fold(merger: IncrementalAlirMerger, fold: FoldResult,
                  artifact_dir: str, *, word_ids, include_models: bool,
                  meta: dict) -> int:
    stacked = merger.stacked()
    Ws = alir_transforms(stacked, fold.Y)
    return publish_table(
        artifact_dir,
        np.asarray(fold.Y), np.asarray(fold.valid),
        word_ids=word_ids,
        worker_ids=np.asarray(fold.worker_ids, dtype=np.int32),
        mask=np.asarray(stacked.mask),
        transforms=np.asarray(Ws),
        models=np.asarray(stacked.models) if include_models else None,
        meta={"merge": "alir_incremental",
              "n_folded": merger.n_folded, **meta})
