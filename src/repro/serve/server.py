"""The embedding query server.

One :class:`EmbeddingServer` owns the read path end to end: external
word ids map to table rows (store), hot rows come from the LRU, misses
ride a coalesced batch dispatch, and sub-model-space queries
reconstruct absent rows on the fly — the paper's robustness claim
(``reconstruct_missing``, benchmarked in ``bench_oov.py``) as a per-
query serving feature.

Query spaces:

* **merged** (default) — rows of the ALiR consensus table ``Y``;
* **sub-model** (``submodel=worker_id``) — rows in that worker's own
  coordinate space: present rows are the worker's trained vectors
  (requires the artifact's ``models`` sidecar), absent rows are
  reconstructed as ``Y[row] @ W_i.T`` from the stored alignment maps —
  bit-identical to :func:`repro.core.merge.reconstruct_missing`.
"""

from __future__ import annotations

import asyncio

import numpy as np

from repro.data.vocab import UNK
from repro.serve.batcher import CoalescingBatcher, ServeConfig
from repro.serve.cache import LRUCache
from repro.serve.store import ArtifactStore

MERGED = -1   # the merged-consensus query space (sentinel "submodel")


class EmbeddingServer:
    """Batched asyncio lookups over a published artifact.

    Args:
        store: an :class:`ArtifactStore` (or a path, for convenience).
        cfg: coalescing window / batch cap / concurrency / cache size.

    All lookups for all spaces flow through one batcher and one cache,
    keyed by ``(space, row)`` — a reconstruction is cached exactly like
    a plain row. ``refresh()`` hot-swaps to a newer table version and
    drops the cache; row ids are stable across versions (the union
    vocabulary is fixed before training), so in-flight keys stay valid.
    """

    def __init__(self, store: ArtifactStore | str,
                 cfg: ServeConfig = ServeConfig()):
        self.store = ArtifactStore(store) if isinstance(store, str) else store
        self.cfg = cfg
        self.cache = LRUCache(cfg.cache_rows)
        self.batcher = CoalescingBatcher(self._gather, cfg)

    # ------------------------------------------------------------------ query
    async def embed_ids(self, raw_ids, submodel: int | None = None) -> dict:
        """Embed external (raw) word ids.

        Args:
            raw_ids: sequence of raw word ids (the corpus namespace —
                what ``Vocab.word_ids`` holds per table row).
            submodel: a worker id for sub-model-space vectors; ``None``
                for the merged consensus.

        Returns:
            ``{"vectors": (B, d) float32, "found": (B,) bool,
            "version": int}``. Ids unknown to the vocabulary or not yet
            covered by any folded sub-model come back zero with
            ``found=False`` — a serving miss, never an error.
        """
        rows = self.store.rows_of(np.asarray(raw_ids, dtype=np.int64))
        return await self.embed_rows(rows, submodel=submodel)

    async def embed_rows(self, rows, submodel: int | None = None) -> dict:
        """Embed table-row ids directly (see :meth:`embed_ids`)."""
        table = self.store.table
        rows = np.asarray(rows, dtype=np.int64)
        space = MERGED if submodel is None else self._axis_of(submodel)
        found = (rows != UNK) & (rows >= 0) & (rows < len(table.valid))
        found = found & table.valid[np.clip(rows, 0, len(table.valid) - 1)]
        out = np.zeros((len(rows), table.dim), dtype=np.float32)

        async def one(i: int, row: int):
            key = (space, row)
            vec = self.cache.get(key)
            if vec is None:
                vec = await self.batcher.submit(key)
                self.cache.put(key, vec)
            out[i] = vec

        await asyncio.gather(*(one(i, int(r)) for i, r in enumerate(rows)
                               if found[i]))
        return {"vectors": out, "found": found,
                "version": table.version}

    def _axis_of(self, worker_id: int) -> int:
        """Map a worker id to its sub-model axis index in the artifact."""
        table = self.store.table
        if table.mask is None:
            raise ValueError(
                "artifact has no per-sub-model mask — published without "
                "sub-model sidecars; sub-model-space queries unavailable")
        if table.worker_ids is None:
            axis = int(worker_id)
        else:
            hits = np.flatnonzero(np.asarray(table.worker_ids) == worker_id)
            if len(hits) == 0:
                raise KeyError(
                    f"worker {worker_id} not in this artifact's fold "
                    f"(has {np.asarray(table.worker_ids).tolist()})")
            axis = int(hits[0])
        if not 0 <= axis < table.mask.shape[0]:
            raise KeyError(f"sub-model axis {axis} out of range")
        return axis

    # --------------------------------------------------------------- dispatch
    def _gather(self, keys) -> dict:
        """The batched lookup behind the coalescer: group the deduped
        ``(space, row)`` keys by space, one vectorized gather (or
        reconstruct) per space."""
        table = self.store.table
        by_space: dict[int, list[int]] = {}
        for space, row in keys:
            by_space.setdefault(space, []).append(row)
        out = {}
        for space, rows in by_space.items():
            r = np.asarray(rows, dtype=np.int64)
            if space == MERGED:
                vecs = table.emb[r]
            else:
                vecs = self._reconstruct(table, space, r)
            for row, v in zip(rows, vecs):
                out[(space, row)] = np.asarray(v, dtype=np.float32)
        return out

    @staticmethod
    def _reconstruct(table, axis: int, rows: np.ndarray) -> np.ndarray:
        """Sub-model-space rows: the worker's own vector where present,
        ``Y[row] @ W_i.T`` where absent (reconstruct_missing, served)."""
        present = table.mask[axis, rows].astype(bool)
        if table.transforms is None:
            raise ValueError(
                "artifact has no alignment transforms — publish with "
                "transforms=alir_transforms(...) to serve reconstructions")
        rec = table.emb[rows] @ table.transforms[axis].T
        if present.any():
            if table.models is None:
                raise ValueError(
                    "rows present in this sub-model need the artifact's "
                    "`models` sidecar (publish_table(..., models=...)); "
                    "only absent rows are reconstructable from Y and W_i")
            rec = np.where(present[:, None], table.models[axis, rows], rec)
        return rec

    # ------------------------------------------------------------- lifecycle
    def refresh(self) -> bool:
        """Hot-swap to the newest published version (drops the cache).
        Returns True when a swap happened."""
        if self.store.refresh():
            self.cache.clear()
            return True
        return False

    async def drain(self) -> None:
        """Flush pending coalesced batches and wait for them."""
        await self.batcher.drain()

    def stats(self) -> dict:
        """Batcher latency/batch stats + cache hit rate + live version."""
        return {**self.batcher.stats(),
                "cache_hit_rate": self.cache.hit_rate,
                "cache_rows": len(self.cache),
                "version": self.store.version}
