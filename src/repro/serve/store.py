"""Artifact directory → always-complete in-memory table, hot-swappable.

The store is the reader half of the atomic-publish contract in
``repro.checkpoint.io``: it only ever opens table files the manifest
names, so it can never observe a partial write, and :meth:`refresh`
swaps to a newer version in one reference assignment — queries in
flight keep the table object they started with.
"""

from __future__ import annotations

import numpy as np

from repro.checkpoint.io import ServableTable, load_manifest, load_table
from repro.data.vocab import UNK


class ArtifactStore:
    """A live view over a versioned artifact directory.

    Args:
        artifact_dir: directory :func:`repro.checkpoint.publish_table`
            writes to.
        version: pin a specific version (``refresh`` then never moves);
            default tracks the manifest's latest.

    Attributes:
        table: the current :class:`~repro.checkpoint.ServableTable`.
    """

    def __init__(self, artifact_dir: str, version: int | None = None):
        self.artifact_dir = artifact_dir
        self._pinned = version
        self.table: ServableTable = load_table(artifact_dir, version)
        self._raw_to_row = self._build_lookup(self.table)

    @staticmethod
    def _build_lookup(table: ServableTable) -> np.ndarray | None:
        """raw word id → table row (or UNK), from the artifact's
        ``word_ids``; ``None`` when the artifact was published without
        one (queries are then already row ids)."""
        if table.word_ids is None:
            return None
        word_ids = np.asarray(table.word_ids)
        lookup = np.full(int(word_ids.max()) + 1, UNK, dtype=np.int32)
        lookup[word_ids] = np.arange(len(word_ids), dtype=np.int32)
        return lookup

    @property
    def version(self) -> int:
        """Version of the currently loaded table."""
        return self.table.version

    def latest_available(self) -> int | None:
        """The manifest's latest published version (cheap poll)."""
        manifest = load_manifest(self.artifact_dir)
        return manifest["latest"] if manifest else None

    def refresh(self) -> bool:
        """Reload if a newer version has been published (and the store
        is not pinned). Returns True when the table was swapped."""
        if self._pinned is not None:
            return False
        latest = self.latest_available()
        if latest is None or latest <= self.table.version:
            return False
        self.table = load_table(self.artifact_dir, latest)
        self._raw_to_row = self._build_lookup(self.table)
        return True

    def rows_of(self, raw_ids: np.ndarray) -> np.ndarray:
        """Map external (raw) word ids to table rows; unknown → UNK.

        With no ``word_ids`` in the artifact the query namespace *is*
        row space: out-of-range ids map to UNK."""
        raw_ids = np.asarray(raw_ids)
        if self._raw_to_row is None:
            rows = raw_ids.astype(np.int32, copy=True)
            rows[(rows < 0) | (rows >= len(self.table.emb))] = UNK
            return rows
        rows = np.full(raw_ids.shape, UNK, dtype=np.int32)
        ok = (raw_ids >= 0) & (raw_ids < len(self._raw_to_row))
        rows[ok] = self._raw_to_row[raw_ids[ok]]
        return rows
