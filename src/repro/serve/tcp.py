"""JSON-lines TCP front end for :class:`~repro.serve.server.EmbeddingServer`.

One request per line, one response line back — a protocol simple enough
that ``nc`` is a valid client. Requests::

    {"ids": [3, 17, 99]}                     merged-space lookup (raw ids)
    {"ids": [3], "submodel": 2}              worker 2's space (reconstructs)
    {"rows": [0, 1, 2]}                      table-row ids, skip vocab map
    {"op": "stats"}                          serving telemetry
    {"op": "refresh"}                        hot-swap to the newest version

Responses mirror :meth:`EmbeddingServer.embed_ids` with lists instead
of arrays, plus ``{"error": ...}`` on malformed input (the connection
stays open). Concurrent requests across connections coalesce into the
same batches — the whole point of fronting one server object.
"""

from __future__ import annotations

import asyncio
import json

import numpy as np

from repro.serve.server import EmbeddingServer


async def _handle_line(server: EmbeddingServer, line: bytes) -> dict:
    try:
        req = json.loads(line)
        if not isinstance(req, dict):
            raise ValueError("request must be a JSON object")
        op = req.get("op", "embed")
        if op == "stats":
            return {"stats": server.stats()}
        if op == "refresh":
            return {"refreshed": server.refresh(),
                    "version": server.store.version}
        if op != "embed":
            raise ValueError(f"unknown op {op!r}")
        submodel = req.get("submodel")
        if "rows" in req:
            res = await server.embed_rows(np.asarray(req["rows"]),
                                          submodel=submodel)
        else:
            res = await server.embed_ids(np.asarray(req["ids"]),
                                         submodel=submodel)
        return {"vectors": res["vectors"].tolist(),
                "found": res["found"].tolist(),
                "version": res["version"]}
    except Exception as e:               # malformed request ≠ dead server
        return {"error": f"{type(e).__name__}: {e}"}


async def _serve_connection(server: EmbeddingServer,
                            reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
    try:
        while line := await reader.readline():
            if not line.strip():
                continue
            resp = await _handle_line(server, line)
            writer.write(json.dumps(resp).encode() + b"\n")
            await writer.drain()
    finally:
        writer.close()


async def start_tcp_server(server: EmbeddingServer, host: str = "127.0.0.1",
                           port: int = 0) -> asyncio.base_events.Server:
    """Start serving; ``port=0`` picks a free port (read it back from
    ``srv.sockets[0].getsockname()[1]``). Caller owns the lifetime
    (``srv.close(); await srv.wait_closed()``)."""
    return await asyncio.start_server(
        lambda r, w: _serve_connection(server, r, w), host, port)


async def request_once(host: str, port: int, payload: dict) -> dict:
    """One request/response round trip — the reference client."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(json.dumps(payload).encode() + b"\n")
        await writer.drain()
        return json.loads(await reader.readline())
    finally:
        writer.close()
