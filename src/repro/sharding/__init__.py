from repro.sharding.rules import (
    param_spec, tree_param_specs, data_spec, cache_spec,
    tree_data_specs, tree_cache_specs, with_sharding, batch_axes,
)

__all__ = [
    "param_spec", "tree_param_specs", "data_spec", "cache_spec",
    "tree_data_specs", "tree_cache_specs", "with_sharding", "batch_axes",
]
