"""Activation sharding-constraint context.

GSPMD propagation alone does not keep the batch dim of activations
sharded through gather-heavy graphs (embedding lookups, remat'd scans):
without explicit constraints the compiler happily replicates the batch
and only splits the model dim — 16× the FLOPs/chip (observed on the
first dry-run of qwen1.5: attention dots of shape f32[256,4096,4096]
per chip). Production frameworks pin activations with
``with_sharding_constraint`` at layer boundaries; this module is that
hook, enabled by the launchers and a no-op in single-device tests.
"""

from __future__ import annotations

import math
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_STATE: dict = {"enabled": False, "batch_axes": ("data",), "sizes": {}}


def enable(mesh) -> None:
    names = mesh.axis_names
    _STATE["enabled"] = True
    _STATE["batch_axes"] = tuple(a for a in ("pod", "data") if a in names)
    _STATE["sizes"] = dict(zip(names, mesh.devices.shape))


def disable() -> None:
    _STATE["enabled"] = False


@contextmanager
def use_mesh_constraints(mesh):
    enable(mesh)
    try:
        yield
    finally:
        disable()


def _size(axes) -> int:
    return math.prod(_STATE["sizes"].get(a, 1) for a in axes)


def shard_batch(x: jax.Array, model_dim: int | None = None) -> jax.Array:
    """Constrain dim0 to the batch axes (when divisible); optionally
    constrain ``model_dim`` to the model axis."""
    if not _STATE["enabled"]:
        return x
    ba = _STATE["batch_axes"]
    spec = [None] * x.ndim
    if x.shape[0] % _size(ba) == 0 and x.shape[0] >= _size(ba):
        spec[0] = ba
    if model_dim is not None:
        md = model_dim % x.ndim
        if x.shape[md] % _size(("model",)) == 0 and spec[md] is None:
            spec[md] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_experts(x: jax.Array) -> jax.Array:
    """Constrain dim0 (experts) to the model axis (expert parallelism)."""
    if not _STATE["enabled"]:
        return x
    if x.shape[0] % _size(("model",)) == 0:
        return jax.lax.with_sharding_constraint(
            x, P("model", *([None] * (x.ndim - 1))))
    return x


def shard_seq(x: jax.Array, seq_dim: int = 1) -> jax.Array:
    """Constrain a sequence dim over 'data' (flash-decoding-style cache)."""
    if not _STATE["enabled"]:
        return x
    spec = [None] * x.ndim
    if x.shape[seq_dim] % _size(("data",)) == 0:
        spec[seq_dim] = "data"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_group_experts(x: jax.Array) -> jax.Array:
    """(G, E, C, d) MoE dispatch buffers: G→data, E→model (dual-sharded)."""
    if not _STATE["enabled"]:
        return x
    spec = [None] * x.ndim
    if x.shape[0] % _size(("data",)) == 0:
        spec[0] = "data"
    if x.ndim > 1 and x.shape[1] % _size(("model",)) == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def data_axis_size() -> int:
    return _size(("data",))


def batch_shard_count() -> int:
    """Total batch-dim shards (pod × data on the multi-pod mesh)."""
    return _size(_STATE["batch_axes"])


def enabled() -> bool:
    return bool(_STATE["enabled"])
