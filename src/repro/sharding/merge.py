"""Worker-mesh execution of the merge phase's sharded Gram reduction.

This is the **one intentional collective in the system**. Training is
zero-collective by design (the paper's headline property, certified by
``repro.analysis.contracts``); the merge phase is the single
synchronization point, and when its Gram accumulations
(:func:`repro.core.merge.sharded_gram`) run distributed over the
``worker`` mesh, the partial row-block Grams must be gathered before the
fixed-order reduction. That gather — one ``all_gather`` of ``(S, d, d)``
partials, tiny next to the ``(V, d)`` tables — is the only collective
the merge emits, and it is deliberately **outside** the RL004
zero-collective lint scope (see :mod:`repro.analysis.lint_rules`, which
covers the train path: ``kernels/``, ``data/``, ``core/engine.py``,
``core/sgns.py``).

Bit-identity contract (tested in ``tests/test_merge.py``): the mesh path
computes exactly the same per-block partials as the local path
(placement never changes a block's bits) and reduces them in the same
ascending block order (a sequential scan over the gathered stack — not a
``psum``, whose reduction order is implementation-defined), so
``mesh_sharded_gram(A, B, mesh, num_shards=S)`` equals
``sharded_gram(A, B, S)`` bit-for-bit on any device count dividing S.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.async_trainer import shard_map_compat
from repro.core.merge import gram_block_partials, reduce_gram_partials


def mesh_sharded_gram(A: jax.Array, B: jax.Array, mesh, *,
                      num_shards: int | None = None,
                      axis: str = "worker") -> jax.Array:
    """``AᵀB`` computed distributed over ``mesh``'s ``axis``: each
    device owns a contiguous row slice of ``A``/``B``, computes its
    ``num_shards / n_devices`` block partials locally, all-gathers the
    ``(num_shards, d, e)`` partial stack, and reduces it in ascending
    block order — bit-identical to the single-host
    :func:`~repro.core.merge.sharded_gram` at the same ``num_shards``.

    ``num_shards`` defaults to the mesh axis size and must be a
    multiple of it; row counts must divide evenly (the ALiR caller works
    on fixed ``(V, d)`` tables — pad upstream if V is ragged).
    """
    n_dev = mesh.shape[axis]
    S = int(num_shards) if num_shards is not None else n_dev
    if S % n_dev:
        raise ValueError(f"num_shards {S} must be a multiple of the mesh "
                         f"axis size {n_dev}")
    V = A.shape[0]
    if V % S:
        raise ValueError(f"rows {V} must divide evenly into {S} shards "
                         f"(pad upstream)")
    per_dev = S // n_dev

    def local(a, b):
        parts = gram_block_partials(a, b, per_dev)
        # The merge phase's one intentional collective: gather every
        # device's block partials so each replica can run the same
        # canonical fixed-order reduction.
        # repro-lint: ignore[RL004]
        allp = jax.lax.all_gather(parts, axis, tiled=True)
        return reduce_gram_partials(allp)

    f = shard_map_compat(local, mesh, in_specs=(P(axis), P(axis)),
                         out_specs=P())
    return f(jnp.asarray(A), jnp.asarray(B))


def lower_mesh_gram(V: int, d: int, mesh, *,
                    num_shards: int | None = None, axis: str = "worker"):
    """Lowered (StableHLO) mesh Gram for the analysis layer: the
    certifier counts exactly one ``all_gather`` here — the allow-listed
    merge collective — while the train path stays zero-collective."""
    spec = jax.ShapeDtypeStruct((V, d), jnp.float32)
    fn = jax.jit(lambda A, B: mesh_sharded_gram(
        A, B, mesh, num_shards=num_shards, axis=axis))
    return fn.lower(spec, spec)
