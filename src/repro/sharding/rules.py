"""Logical-axis sharding rules → PartitionSpec, with divisibility fallback.

Mesh axes: ``data`` (FSDP/batch), ``model`` (tensor/expert parallel),
optionally ``pod`` (pure data parallel across pods — only gradient
all-reduce crosses DCN).

Parameters are matched by the *name of their leaf path* (e.g. ``wq``,
``down``, ``embed``) — names are stable across the whole zoo because all
layers are built from the same building blocks. Any proposed axis whose
mesh size does not divide the corresponding dim is dropped (replicated),
which is what makes the same rule table work for 15-head smollm and
64-head jamba alike. Cycle-stacked params (leading ``num_cycles`` dim)
are detected by path and get a ``None`` prepended.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# name → proposed spec for the *unstacked* param
# ("data" on the fan-in/d_model-ish dim = FSDP; "model" on the
# head/ffn/vocab dim = tensor parallel; experts (3D) = expert parallel)
_RULES_2D = {
    "embed": ("model", "data"),       # (V, d): vocab-sharded
    "lm_head": ("data", "model"),     # (d, V)
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "wq_nope": ("data", "model"),
    "wq_rope": ("data", "model"),
    "w_dkv": ("data", None),
    "w_uk": (None, "model"),
    "w_uv": (None, "model"),
    "w_krope": ("data", None),
    "gate": ("data", "model"),
    "up": ("data", "model"),
    "down": ("model", "data"),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "w_if": ("model", None),
    "w_in": ("data", "model"),
    "router": ("data", None),
    "conv_w": (None, "model"),
    "A_log": ("model", None),
}

_RULES_3D_EXPERT = {  # (E, in, out)
    "gate": ("model", "data", None),
    "up": ("model", "data", None),
    "down": ("model", None, "data"),
}

_VEC_SHARD_MIN = 4096  # 1-D params smaller than this are replicated


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """Device-free mesh for spec computation, on any supported jax.

    jax ≥ 0.5 takes ``AbstractMesh(axis_sizes, axis_names)``; jax 0.4.x
    takes a single ``((name, size), ...)`` shape tuple.
    """
    AM = jax.sharding.AbstractMesh
    try:
        return AM(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # jax 0.4.x signature
        return AM(tuple(zip(axis_names, axis_sizes)))


def _axis_sizes(mesh) -> dict[str, int]:
    # works for both Mesh and AbstractMesh
    return {name: int(size) for name, size in mesh.shape.items()}


def _check(spec: tuple, shape: tuple, sizes: dict) -> P:
    out = []
    for ax, dim in zip(spec, shape):
        if ax is None:
            out.append(None)
            continue
        size = math.prod(sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,)))
        out.append(ax if dim % size == 0 and dim >= size else None)
    return P(*out)


def param_spec(path: tuple[str, ...], shape: tuple[int, ...], mesh: Mesh,
               fsdp: bool = True) -> P:
    sizes = _axis_sizes(mesh)
    stacked = "cycle" in path
    # the param's own name: last path element not an optimizer-state leaf
    leaf_names = [p for p in path if p not in ("m", "v", "vr", "vc", "mu")]
    name = leaf_names[-1] if leaf_names else ""
    core_shape = shape[1:] if stacked and len(shape) > 1 else shape
    nd = len(core_shape)

    if name in ("gate", "up", "down") and nd == 3:
        rule = _RULES_3D_EXPERT[name]
    elif name in _RULES_2D and nd == 2:
        rule = _RULES_2D[name]
    elif name == "r" and nd == 4:
        # sLSTM recurrent (4,H,dh,dh): REPLICATED — it is tiny (~17 MB)
        # and sharding it puts a collective inside every scan step
        # (EXPERIMENTS §Perf xlstm iteration 2).
        rule = (None, None, None, None)
    elif nd == 1:
        rule = ("model",) if core_shape[0] >= _VEC_SHARD_MIN else (None,)
    else:
        # fallback: shard the largest divisible dim over 'model'
        rule = [None] * nd
        order = sorted(range(nd), key=lambda i: -core_shape[i])
        for i in order:
            if core_shape[i] % sizes.get("model", 1) == 0 and core_shape[i] >= sizes.get("model", 1):
                rule[i] = "model"
                break
        rule = tuple(rule)

    if not fsdp:
        # pure tensor-parallel: drop the 'data' weight shard (no per-use
        # re-gather; weights replicated across the data axis)
        rule = tuple(None if ax == "data" else ax for ax in rule)
    spec = _check(rule, core_shape, sizes)
    if stacked and len(shape) > len(core_shape):
        spec = P(None, *spec)
    return spec


def _path_names(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def tree_param_specs(tree, mesh: Mesh, fsdp: bool = True):
    """PartitionSpec pytree for a params/opt-state pytree (of arrays or
    ShapeDtypeStructs)."""
    def one(path, leaf):
        return param_spec(_path_names(path), leaf.shape, mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(one, tree)


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------
def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """Input-batch arrays: dim0 = global batch over (pod, data)."""
    sizes = _axis_sizes(mesh)
    ba = batch_axes(mesh)
    n = math.prod(sizes[a] for a in ba)
    if not shape:
        return P()
    if shape[0] % n == 0 and shape[0] >= n:
        return P(ba, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """KV caches / recurrent state: batch over data axes when divisible,
    else the sequence dim over 'data' (flash-decoding style); the largest
    remaining divisible feature dim over 'model'."""
    sizes = _axis_sizes(mesh)
    ba = batch_axes(mesh)
    nb = math.prod(sizes[a] for a in ba)
    nd = len(shape)
    spec: list = [None] * nd
    if nd and shape[0] % nb == 0 and shape[0] >= nb:
        spec[0] = ba
    elif nd > 1 and shape[1] % sizes.get("data", 1) == 0 and shape[1] > sizes.get("data", 1):
        spec[1] = "data"
    m = sizes.get("model", 1)
    free = [i for i in range(nd) if spec[i] is None]
    for i in sorted(free, key=lambda i: -shape[i]):
        if shape[i] % m == 0 and shape[i] >= m and shape[i] > 1:
            spec[i] = "model"
            break
    return P(*spec)


def tree_data_specs(tree, mesh: Mesh):
    return jax.tree.map(lambda l: data_spec(l.shape, mesh), tree)


def tree_cache_specs(tree, mesh: Mesh):
    return jax.tree.map(lambda l: cache_spec(l.shape, mesh), tree)


def with_sharding(tree, specs, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)
