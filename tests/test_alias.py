"""Alias-method negative sampler: exactness, distribution agreement with
the inverse-CDF oracle, and trainer integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributions import build_alias_table, alias_implied_probs
from repro.data.pairs import (
    AliasSampler, NegativeSampler, build_noise_table, cdf_to_ids,
    negative_sampler_fn, sample_negatives_cdf, unigram_noise_probs)


def _zipf_counts(V, seed=0):
    return np.random.default_rng(seed).zipf(1.3, V).astype(np.float64)


# --------------------------------------------- CDF boundary regression
def test_cdf_boundaries_never_map_to_zero_probability_ids():
    """Regression: zero-count union rows duplicate CDF boundaries.
    u == 0.0 (with a leading zero-count row) or u exactly on a
    duplicated boundary used to return a zero-probability id — a row
    absent from the worker's vocabulary, which corrupted the merge
    presence mask. Adversarial u values must all land on positive-
    probability ids."""
    counts = np.array([0, 5, 0, 0, 3, 0, 0, 2, 1, 0], dtype=np.float64)
    p = unigram_noise_probs(counts)
    assert (p == 0).any()                      # the trap is actually set
    cdf = build_noise_table(counts, kind="cdf")
    u = jnp.concatenate([
        jnp.zeros(1, jnp.float32),             # the u == 0.0 case
        cdf[cdf < 1.0],                        # every exact boundary
        jnp.asarray([np.nextafter(np.float32(1.0), np.float32(0.0))]),
    ])                                         # (u ~ U[0,1) never hits 1.0)
    ids = np.asarray(cdf_to_ids(cdf, u))
    assert (p[ids] > 0).all(), ids


def test_sample_negatives_cdf_skips_interspersed_zero_count_rows():
    """Drawn ids always have positive probability, at draw counts where
    the old boundary handling reliably produced zero-prob hits."""
    rng = np.random.default_rng(4)
    counts = rng.zipf(1.3, 900).astype(np.float64)
    counts[::3] = 0.0                          # interspersed absent rows
    p = unigram_noise_probs(counts)
    cdf = build_noise_table(counts, kind="cdf")
    draws = np.asarray(
        sample_negatives_cdf(cdf, jax.random.PRNGKey(2), (300_000,)))
    assert (p[draws] > 0).all()
    # distribution still matches the target on the present rows
    assert _empirical_kl(draws, p) < 1e-2


# ------------------------------------------------------------- table build
def test_alias_table_exactly_reconstructs_distribution():
    """Vose tables are *exact*: the implied distribution equals the input
    up to float64 rounding — no sampling noise needed to verify."""
    p = unigram_noise_probs(_zipf_counts(5000))
    prob, alias = build_alias_table(p)
    assert prob.shape == (5000,) and alias.shape == (5000,)
    assert ((0.0 <= prob) & (prob <= 1.0)).all()
    assert ((0 <= alias) & (alias < 5000)).all()
    np.testing.assert_allclose(alias_implied_probs(prob, alias), p, atol=1e-12)


@pytest.mark.parametrize("p", [
    np.array([1.0]),                      # singleton
    np.full(7, 1 / 7),                    # uniform
    np.array([1.0, 0.0, 0.0]),            # one-hot
    np.array([0.5, 0.25, 0.125, 0.125]),  # dyadic
])
def test_alias_table_edge_distributions(p):
    prob, alias = build_alias_table(p)
    np.testing.assert_allclose(alias_implied_probs(prob, alias), p, atol=1e-12)


def test_alias_table_rejects_bad_input():
    with pytest.raises(ValueError):
        build_alias_table(np.array([]))
    with pytest.raises(ValueError):
        build_alias_table(np.array([0.5, -0.5]))
    with pytest.raises(ValueError):
        build_alias_table(np.zeros(4))


# ------------------------------------------------------ sampled agreement
def _empirical_kl(draws: np.ndarray, p: np.ndarray) -> float:
    emp = np.bincount(draws, minlength=len(p)) / len(draws)
    mask = emp > 0
    return float(np.sum(emp[mask] * np.log(emp[mask] / np.maximum(p[mask], 1e-300))))


def test_alias_matches_cdf_distribution_on_large_draws():
    """KL(empirical || true) < 1e-3 on 2e6 draws, for both samplers —
    the alias path agrees with the CDF oracle's target distribution."""
    V, N = 1000, 2_000_000
    counts = _zipf_counts(V)
    p = unigram_noise_probs(counts)
    for sampler in (NegativeSampler(counts), AliasSampler(counts)):
        draws = np.asarray(
            jax.jit(lambda k, s=sampler: s.sample(k, (N,)))(jax.random.PRNGKey(7)))
        kl = _empirical_kl(draws, p)
        assert kl < 1e-3, (type(sampler).__name__, kl)


def test_alias_and_cdf_empirical_distributions_agree():
    """The two samplers' empirical histograms match each other (not just
    the analytic target) within sampling noise."""
    V, N = 500, 1_000_000
    counts = _zipf_counts(V, seed=3)
    a = np.asarray(AliasSampler(counts).sample(jax.random.PRNGKey(0), (N,)))
    c = np.asarray(NegativeSampler(counts).sample(jax.random.PRNGKey(1), (N,)))
    ha = np.bincount(a, minlength=V) / N
    hc = np.bincount(c, minlength=V) / N
    assert np.abs(ha - hc).max() < 5e-3


def test_alias_sampler_deterministic_and_in_range():
    s = AliasSampler(_zipf_counts(300))
    k = jax.random.PRNGKey(11)
    d1, d2 = s.sample(k, (64, 5)), s.sample(k, (64, 5))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    assert d1.dtype == jnp.int32
    assert (np.asarray(d1) >= 0).all() and (np.asarray(d1) < 300).all()


def test_negative_sampler_fn_registry():
    assert negative_sampler_fn("cdf") is not None
    assert negative_sampler_fn("alias") is not None
    with pytest.raises(ValueError):
        negative_sampler_fn("nope")


# ----------------------------------------------------- trainer integration
def test_async_trainer_trains_with_alias_sampler():
    from repro.core.async_trainer import AsyncShardTrainer
    from repro.core.driver import _neg_tables
    from repro.core.sgns import SGNSConfig
    from repro.data.vocab import Vocab

    V, n, S, B = 128, 2, 6, 64
    counts = _zipf_counts(V, seed=5).astype(np.int64)
    vocab = Vocab(word_ids=np.arange(V), counts=counts,
                  lookup=np.arange(V, dtype=np.int32))
    cfg = SGNSConfig(vocab_size=V, dim=16, negatives=3)
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, V, (n, S, B)), jnp.int32)
    x = jnp.asarray(rng.integers(0, V, (n, S, B)), jnp.int32)

    results = {}
    for sampler in ("cdf", "alias"):
        tr = AsyncShardTrainer(cfg=cfg, num_workers=n, total_steps=S,
                               engine=f"sparse:{sampler}")
        params = tr.init(jax.random.PRNGKey(0))
        table = _neg_tables([vocab, vocab], kind=sampler)
        params, losses = tr.epoch(params, c, x, table, jax.random.PRNGKey(1))
        assert losses.shape == (n, S)
        assert np.isfinite(np.asarray(losses)).all()
        results[sampler] = float(jnp.mean(losses))
    # same data, same init: mean losses land in the same ballpark
    assert abs(results["cdf"] - results["alias"]) < 0.5


# (The "sparse:alias" zero-collective check lives in tests/test_engine.py's
# parametrized engine × sampler matrix.)
