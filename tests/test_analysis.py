"""Static-analysis layer: the shipping artifacts certify clean, and —
the part that makes the checkers trustworthy — every seeded mutation
(dropped DMA wait, slot collision, off-by-one hazard window, planted
collective, broken donation aliasing, over-budget config, lint-rule
violations, tampered bench baseline) is caught."""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import dma_model
from repro.analysis.contracts import (
    ContractViolation, certify_bench_traffic, certify_table_aliasing,
    certify_zero_collective, count_collective_ops, parse_op_counts)
from repro.analysis.lint_rules import run_lint
from repro.analysis.vmem import (
    VmemBudgetError, check_vmem_budget, estimate_vmem)
from repro.kernels.sgns_fused_pipe import kernel_schedule, plan_blocks


# ---------------------------------------------------------------- dma model
def test_shipping_schedule_certifies():
    rep = dma_model.check_schedule_space(ring_depths=(2, 3, 4),
                                         max_nblocks=4)
    assert rep.ok, rep.summary()
    assert rep.schedules_checked > 0


def test_shipping_planner_certifies():
    rep = dma_model.check_planner(ring_depths=(2, 3), max_nblocks=3)
    assert rep.ok, rep.summary()
    assert rep.plans_checked > 0


def test_dropped_dma_wait_is_caught():
    """Mutation: a schedule that never waits on the last block's
    write-back ships an unretired DMA — every resolution must flag it."""
    def mutant(nblocks, S):
        return [e for e in kernel_schedule(nblocks, S)
                if not (e[0] == "wait_scatter" and e[1] == nblocks - 1)]

    rep = dma_model.check_schedule_space(ring_depths=(2,), max_nblocks=3,
                                         schedule_fn=mutant)
    assert not rep.ok
    assert all(v.rule == "matched-dma" for v in rep.violations)


def test_slot_collision_is_caught():
    """Hand-built sequence: block 2's gather reuses slot 0 before block
    0's write-back even started — the ring-slot race."""
    events = [
        ("gather", 0, 0), ("wait_gather", 0, 0), ("compute", 0, 0),
        ("gather", 1, 1), ("wait_gather", 1, 1), ("compute", 1, 1),
        ("gather", 2, 0),                       # <-- rewrites live buf[0]
        ("scatter", 0, 0), ("wait_scatter", 0, 0),
        ("scatter", 1, 1), ("wait_scatter", 1, 1),
        ("wait_gather", 2, 0), ("compute", 2, 0),
        ("scatter", 2, 0), ("wait_scatter", 2, 0),
    ]
    out = dma_model.check_events(
        events, nblocks=3, ring_depth=2,
        may_overlap=lambda b0, b: False)
    assert any(v.rule == "slot-race" for v in out), [str(v) for v in out]


def test_off_by_one_hazard_window_is_caught():
    """S=3, hazard flags block 2 against its window {0, 1}; draining
    only block 1 before gather 2 (the off-by-one) leaves block 0's
    write-back racing the regather."""
    hazard = (0, 0, 1)
    events = [
        ("gather", 0, 0), ("wait_gather", 0, 0), ("compute", 0, 0),
        ("gather", 1, 1), ("wait_gather", 1, 1), ("compute", 1, 1),
        ("scatter", 0, 0), ("scatter", 1, 1),
        ("wait_scatter", 1, 1),                 # <-- block 0 left in flight
        ("gather", 2, 2), ("wait_gather", 2, 2), ("compute", 2, 2),
        ("wait_scatter", 0, 0),
        ("scatter", 2, 2), ("wait_scatter", 2, 2),
    ]
    out = dma_model.check_events(
        events, nblocks=3, ring_depth=3, hazard=hazard,
        may_overlap=dma_model.hazard_may_overlap(hazard, 3))
    assert any(v.rule == "war-hazard" and "block 0" in v.detail
               for v in out), [str(v) for v in out]
    # the correctly drained order certifies clean
    fixed = events[:8] + [("wait_scatter", 0, 0), ("wait_scatter", 1, 1)] \
        + [e for e in events[8:] if e != ("wait_scatter", 1, 1)
           and e != ("wait_scatter", 0, 0)]
    assert dma_model.check_events(
        fixed, nblocks=3, ring_depth=3, hazard=hazard,
        may_overlap=dma_model.hazard_may_overlap(hazard, 3)) == []


def test_planner_that_drops_hazards_is_caught():
    """Mutation: a planner that reports no hazards diverges from the
    independent windowed look-behind oracle."""
    def mutant(c, x, n, V, blk, *, hot_rows=0, ring_depth=2):
        plan = plan_blocks(c, x, n, V, blk, hot_rows=hot_rows,
                           ring_depth=ring_depth)
        return plan._replace(hazard=jnp.zeros_like(plan.hazard))

    rep = dma_model.check_planner(ring_depths=(2,), max_nblocks=2,
                                  plan_fn=mutant)
    assert not rep.ok
    assert any(v.rule == "war-hazard" for v in rep.violations)


# ---------------------------------------------------------------- contracts
def _psum_lowered():
    from jax.sharding import PartitionSpec as P

    from repro.core.async_trainer import shard_map_compat

    mesh = jax.make_mesh((1,), ("w",))
    f = shard_map_compat(lambda v: jax.lax.psum(v, "w"), mesh,
                         in_specs=P("w"), out_specs=P())
    return jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))


def test_planted_psum_is_caught_on_lowered_mlir():
    """The regression the certifier exists for: lowered text is
    StableHLO MLIR (underscore spellings) where the old hyphen-matching
    HLO regex found nothing — the structured op-walk must catch the
    planted psum in both the lowered and the compiled form."""
    lowered = _psum_lowered()
    txt = lowered.as_text()
    assert "all_reduce" in txt                      # it IS the MLIR form
    hits = count_collective_ops(txt)
    assert hits and all("all_reduce" in k for k in hits), hits
    with pytest.raises(ContractViolation, match="zero-collective"):
        certify_zero_collective(lowered, label="planted")
    compiled_txt = lowered.compile().as_text()
    assert count_collective_ops(compiled_txt), "compiled HLO form missed"


def test_collective_name_in_strings_is_not_a_false_positive():
    """Metadata/location strings mentioning collective names are not
    ops; only op-position identifiers count."""
    fp_text = '\n'.join([
        '  %0 = stablehlo.add %arg0, %arg1 : tensor<4xf32> '
        'loc("all_reduce_helper/add")',
        '  %1 = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b), '
        'metadata={op_name="jit(all-reduce-wrapper)/add"}',
        '  // the all-reduce that is not there',
    ])
    assert count_collective_ops(fp_text) == {}
    counts = parse_op_counts(fp_text)
    assert counts.get("stablehlo.add") == 1 and counts.get("add") == 1


def test_merge_gram_is_the_one_intentional_collective():
    """The zero-collective contract is scoped to the TRAIN path. The
    merge phase's sharded Gram reduction is the one intentional
    collective: its lowering must show exactly one all_gather — visible
    to the same certifier that keeps the train path clean — and the
    certifier must (correctly) reject it if pointed there."""
    import jax

    from repro.sharding.merge import lower_mesh_gram

    mesh = jax.make_mesh((1,), ("worker",))
    lowered = lower_mesh_gram(64, 8, mesh, num_shards=4)
    hits = count_collective_ops(lowered.as_text())
    assert hits == {"stablehlo.all_gather": 1}, hits
    with pytest.raises(ContractViolation, match="zero-collective"):
        certify_zero_collective(lowered, label="merge-gram")


def test_broken_table_donation_aliasing_is_caught():
    """Mutation: a step whose outputs cannot reuse the donated (V, d)
    buffers (transposed tables) must fail the aliasing certificate."""
    from repro.core.engine import SparseEngine

    class TransposingEngine(SparseEngine):
        def make_step(self, cfg, total_steps):
            inner = super().make_step(cfg, total_steps)

            def step(params, c, x, table, key, i):
                params, loss = inner(params, c, x, table, key, i)
                return jax.tree.map(jnp.transpose, params), loss

            return step

    with pytest.raises(ContractViolation, match="aliasing"):
        certify_table_aliasing(TransposingEngine(), vocab_size=96, dim=16,
                               negatives=2, batch=32)
    # the unmutated engine certifies
    rep = certify_table_aliasing("sparse", vocab_size=96, dim=16,
                                 negatives=2, batch=32)
    assert rep.aliased_table_args >= 2


def test_bench_traffic_certificate_and_tamper_detection(tmp_path):
    """The committed @zipf50k baseline matches the planner; a tampered
    row is caught."""
    reports = certify_bench_traffic("BENCH_wallclock.json")
    assert {r.engine for r in reports} == {
        "pallas_fused_pipe@zipf50k", "pallas_fused_tiered@zipf50k"}
    rows = [r for r in json.load(open("BENCH_wallclock.json"))]
    for r in rows:
        if r.get("engine") == "pallas_fused_tiered@zipf50k":
            r["hbm_rows_per_step"] += 2          # silent planner drift
    tampered = tmp_path / "BENCH_wallclock.json"
    tampered.write_text(json.dumps(rows))
    with pytest.raises(ContractViolation, match="traffic"):
        certify_bench_traffic(str(tampered))


# --------------------------------------------------------------------- vmem
def test_vmem_estimates_scale_with_dials():
    shape = dict(vocab_size=50_000, dim=128, negatives=5, batch=1024)
    for eng in ("dense", "sparse"):
        assert estimate_vmem(eng, **shape).total_bytes == 0
    from repro.core.engine import get_engine

    pipe2 = estimate_vmem(get_engine("pallas_fused_pipe"), **shape)
    pipe4 = estimate_vmem(get_engine("pallas_fused_pipe", ring_depth=4),
                          **shape)
    assert pipe4.total_bytes > pipe2.total_bytes
    t0 = estimate_vmem(get_engine("pallas_fused_tiered", hot_rows=0),
                       **shape)
    t1 = estimate_vmem(get_engine("pallas_fused_tiered", hot_rows=4096),
                       **shape)
    assert t1.total_bytes > t0.total_bytes
    assert t1.terms["hot_prefix"] > t0.terms["hot_prefix"]


def test_vmem_budget_rejects_resident_tables_at_paper_shape():
    paper = dict(vocab_size=300_000, dim=500, negatives=5, batch=1024)
    with pytest.raises(VmemBudgetError, match="HBM-resident"):
        check_vmem_budget("pallas_fused", **paper)
    # the HBM family exists exactly to fit this shape
    for eng in ("pallas_fused_hbm", "pallas_fused_pipe",
                "pallas_fused_tiered"):
        est = check_vmem_budget(eng, **paper)
        assert est.total_bytes <= 16 * 2 ** 20


def test_vmem_budget_rejects_oversized_dials():
    with pytest.raises(VmemBudgetError, match="hot_rows"):
        from repro.core.engine import get_engine
        check_vmem_budget(
            get_engine("pallas_fused_tiered", hot_rows=200_000),
            vocab_size=300_000, dim=500, negatives=5, batch=1024)


# --------------------------------------------------------------------- lint
def _write(tmp_path, rel, text):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def test_lint_flags_each_rule(tmp_path):
    _write(tmp_path, "core/seeds.py",
           "import jax\n"
           "def f(seed, worker):\n"
           "    return jax.random.PRNGKey(seed + worker)\n")
    _write(tmp_path, "data/draw.py",
           "import numpy as np\n"
           "def g(cdf, u):\n"
           "    return np.searchsorted(cdf, u)\n"
           "def h(cdf, u):\n"
           "    return np.searchsorted(cdf, u, side='left')\n")
    _write(tmp_path, "kernels/rng.py",
           "import numpy as np\n"
           "import random\n"
           "from numpy.random import default_rng\n"
           "def f():\n"
           "    np.random.seed(0)\n"
           "    rng = default_rng()\n"
           "    return random.random()\n")
    _write(tmp_path, "kernels/coll.py",
           "from jax import lax\n"
           "def f(x):\n"
           "    return lax.psum(x, 'w')\n")
    rules = {f.rule for f in run_lint(tmp_path)}
    assert rules == {"RL001", "RL002", "RL003", "RL004"}
    by_rule = {}
    for f in run_lint(tmp_path):
        by_rule.setdefault(f.rule, []).append(f)
    assert len(by_rule["RL002"]) == 2     # missing side + wrong side
    assert len(by_rule["RL003"]) == 3     # legacy, stdlib, unseeded


def test_lint_pragma_suppresses_and_scoping_limits(tmp_path):
    _write(tmp_path, "core/ok.py",
           "import numpy as np\n"
           "np.random.seed(0)  # repro-lint: ignore[RL003]\n")
    # same hazards OUTSIDE core//kernels/ are out of scope for RL003/4
    _write(tmp_path, "benchmarks_like/timing.py",
           "import numpy as np\n"
           "np.random.seed(0)\n"
           "from jax import lax\n"
           "def f(x):\n"
           "    return lax.psum(x, 'w')\n")
    assert run_lint(tmp_path) == []
    # async_trainer hosts the sync baselines: RL004 does not apply there
    _write(tmp_path, "core/async_trainer.py",
           "from jax import lax\n"
           "def f(x):\n"
           "    return lax.psum(x, 'w')\n")
    assert run_lint(tmp_path) == []


def test_lint_real_tree_is_clean():
    assert [str(f) for f in run_lint("src/repro")] == []


# ----------------------------------------------------------------- wiring
def test_trainer_collective_helpers_delegate_to_contracts():
    """core.assert_no_collectives must catch the MLIR spelling now (the
    old regex did not) — the dedupe is behavioral, not cosmetic."""
    from repro.core import assert_no_collectives
    from repro.core import count_collective_ops as core_counts

    lowered = _psum_lowered()
    with pytest.raises(AssertionError):
        assert_no_collectives(lowered)
    assert core_counts(lowered.as_text())
