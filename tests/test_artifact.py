"""Versioned merged-table artifacts: atomic publish, crash safety,
version monotonicity (repro.checkpoint.io publish_table/load_table)."""

import os

import numpy as np
import pytest

from repro.checkpoint import (MANIFEST_NAME, load_manifest, load_table,
                              next_version, publish_table)
from repro.checkpoint.io import _atomic_write_bytes, _savez_to, _table_path


def _payload(V=20, d=4, n=3, seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        emb=rng.normal(size=(V, d)).astype(np.float32),
        valid=np.ones(V, bool),
        word_ids=np.arange(V, dtype=np.int32) * 2,
        worker_ids=np.arange(n, dtype=np.int32),
        mask=rng.random((n, V)) > 0.3,
        transforms=rng.normal(size=(n, d, d)).astype(np.float32),
        models=rng.normal(size=(n, V, d)).astype(np.float32),
    )


def test_publish_load_roundtrip_with_sidecars(tmp_path):
    p = _payload()
    v = publish_table(str(tmp_path), meta={"merge": "test"}, **p)
    assert v == 1
    t = load_table(str(tmp_path))
    assert t.version == 1 and t.dim == p["emb"].shape[1]
    for k in p:
        np.testing.assert_array_equal(getattr(t, k), p[k])
    assert t.meta["merge"] == "test"
    assert t.meta["rows"] == p["emb"].shape[0]
    assert t.meta["n_models"] == p["mask"].shape[0]


def test_optional_sidecars_absent_load_as_none(tmp_path):
    p = _payload()
    publish_table(str(tmp_path), p["emb"], p["valid"])
    t = load_table(str(tmp_path))
    assert t.word_ids is None and t.worker_ids is None
    assert t.mask is None and t.transforms is None and t.models is None


def test_versions_monotonic_and_pinnable(tmp_path):
    for k in range(3):
        p = _payload(seed=k)
        assert publish_table(str(tmp_path), p["emb"], p["valid"]) == k + 1
    assert load_table(str(tmp_path)).version == 3
    t2 = load_table(str(tmp_path), version=2)
    np.testing.assert_array_equal(t2.emb, _payload(seed=1)["emb"])
    m = load_manifest(str(tmp_path))
    assert m["latest"] == 3
    assert [e["version"] for e in m["versions"]] == [1, 2, 3]


def test_load_before_first_publish_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        load_table(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        load_table(str(tmp_path / "never-created"))


def test_failed_write_leaves_no_temp_and_no_manifest(tmp_path):
    """A crash mid-table-write must leave the directory publishable and
    readers unaffected: the temp file is cleaned up (or at worst ignored
    — it never matches the table_v*/manifest names)."""
    target = str(tmp_path / "table_v000001.npz")

    def boom(tmp):
        with open(tmp, "wb") as f:
            f.write(b"partial")
        raise OSError("disk full")

    with pytest.raises(OSError):
        _atomic_write_bytes(target, boom)
    assert os.listdir(tmp_path) == []          # temp removed, no target
    assert load_manifest(str(tmp_path)) is None
    p = _payload()
    assert publish_table(str(tmp_path), p["emb"], p["valid"]) == 1


def test_stray_tmp_file_is_invisible_to_readers(tmp_path):
    p = _payload()
    publish_table(str(tmp_path), p["emb"], p["valid"])
    (tmp_path / ".tmp-table_v000002.npz.999").write_bytes(b"partial write")
    t = load_table(str(tmp_path))                    # still v1, complete
    assert t.version == 1
    np.testing.assert_array_equal(t.emb, p["emb"])
    assert next_version(str(tmp_path)) == 2          # tmp name not scanned


def test_orphan_table_version_never_reused(tmp_path):
    """Crash *between* the table rename and the manifest rename: the new
    file exists but the manifest still names the old version. Readers
    stay on the old version; the orphan's number is burned forever, so a
    version string uniquely names one byte-content."""
    p1 = _payload(seed=1)
    publish_table(str(tmp_path), p1["emb"], p1["valid"])
    # simulate the crash: v2's table lands, manifest never updated
    orphan = _payload(seed=2)
    _savez_to(_table_path(str(tmp_path), 2),
              {"emb": orphan["emb"], "valid": orphan["valid"]})

    t = load_table(str(tmp_path))
    assert t.version == 1                            # manifest is truth
    np.testing.assert_array_equal(t.emb, p1["emb"])
    with pytest.raises(FileNotFoundError):
        load_table(str(tmp_path), version=2)         # orphan unloadable

    p3 = _payload(seed=3)
    v = publish_table(str(tmp_path), p3["emb"], p3["valid"])
    assert v == 3                                    # 2 never reused
    np.testing.assert_array_equal(load_table(str(tmp_path)).emb, p3["emb"])


def test_manifest_written_after_table(tmp_path):
    """The manifest only ever names files that are fully on disk."""
    p = _payload()
    publish_table(str(tmp_path), p["emb"], p["valid"])
    m = load_manifest(str(tmp_path))
    for e in m["versions"]:
        path = tmp_path / e["file"]
        assert path.exists()
        with np.load(path) as data:                  # loadable = complete
            assert "emb" in data.files
    assert (tmp_path / MANIFEST_NAME).exists()
