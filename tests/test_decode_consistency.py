"""Decode-vs-forward consistency: token-by-token decoding with a cache
must reproduce the full-sequence forward logits at the last position.

This exercises every mixer's cache path (GQA full + ring-buffer SWA,
MLA compressed cache with absorbed matmuls, Mamba conv+SSM state,
mLSTM matrix memory, sLSTM state, enc-dec cross-attn cache)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models import transformer as tf

# archs that exercise distinct cache mechanics
ARCHS = [
    "llama3-8b",            # GQA full cache
    "h2o-danube-1.8b",      # native SWA ring buffer
    "deepseek-v2-lite-16b", # MLA compressed cache (absorb path)
    "jamba-1.5-large-398b", # hybrid: mamba state + attention cache + MoE
    "xlstm-1.3b",           # mLSTM + sLSTM states
    "qwen2-vl-7b",          # M-RoPE positions at decode
]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        # decode capacity: give headroom so no token drops in this test
        from dataclasses import replace
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=4.0))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))

    logits_full, _, _ = jax.jit(
        lambda p, t: tf.forward_logits(p, cfg, {"tokens": t}))(params, toks)

    cache = m.init_cache(B, S)
    step = jax.jit(m.make_decode_step())
    out = None
    for i in range(S):
        out, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))

    a = np.asarray(out[:, 0], np.float32)
    b = np.asarray(logits_full[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


def test_swa_ring_buffer_matches_windowed_forward():
    """Sequence longer than the window: ring-buffer decode must equal the
    windowed full forward."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.attention_window is not None
    W = cfg.attention_window
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, W + 13  # crosses the window boundary
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))

    logits_full, _, _ = jax.jit(
        lambda p, t: tf.forward_logits(p, cfg, {"tokens": t}))(params, toks)

    cache = m.init_cache(B, W)  # cache only holds the window
    step = jax.jit(m.make_decode_step())
    out = None
    for i in range(S):
        out, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               atol=2e-3, rtol=2e-3)


def test_encdec_decode_matches_forward():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    B, Se, Sd = 2, 10, 8
    rng = np.random.default_rng(2)
    frames = jnp.asarray(rng.normal(size=(B, Se, cfg.d_model)).astype(np.float32))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sd), dtype=np.int32))

    logits_full, _, _ = jax.jit(
        lambda p, f, t: tf.forward_logits(p, cfg, {"frames": f, "tokens": t})
    )(params, frames, toks)

    cache = m.init_cache(B, Sd, enc_len=Se)
    cache = jax.jit(
        lambda p, f, c: tf.prefill_encoder(p, cfg, f, c, B))(params, frames, cache)
    step = jax.jit(m.make_decode_step())
    out = None
    for i in range(Sd):
        out, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(out[:, 0], np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               atol=2e-3, rtol=2e-3)


def test_mla_absorb_equals_naive():
    """Beyond-paper MLA optimization: absorbed matmuls must be exact."""
    from repro.models import attention as attn
    d, H, hd, hr, r = 64, 4, 16, 8, 32
    key = jax.random.PRNGKey(0)
    p = attn.init_mla(key, d, H, kv_lora_rank=r, head_dim=hd, rope_head_dim=hr,
                      dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(6)[None], (2, 6))
    y_naive = attn.mla_forward(p, x, pos, n_heads=H, head_dim=hd,
                               rope_head_dim=hr, absorb=False)
    y_abs = attn.mla_forward(p, x, pos, n_heads=H, head_dim=hd,
                             rope_head_dim=hr, absorb=True)
    np.testing.assert_allclose(np.asarray(y_naive), np.asarray(y_abs),
                               atol=1e-4)
