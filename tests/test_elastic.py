"""Elastic preemption-tolerant training.

Four claims under test (docs/ARCHITECTURE.md §Elasticity):

1. **Cursor determinism** — a worker resumed from a
   :class:`WorkerCursor` at any chunk boundary replays its pair chunks
   and negative-draw keys bit-exactly (deterministic suffix tests here;
   arbitrary cut points under hypothesis in the property section).
2. **Crash safety** — a kill between the table rename and the manifest
   rename leaves readers on the previous version, never a torn one, and
   ``gc_orphans`` sweeps the debris without reopening version numbers.
3. **Quorum merge** — ``IncrementalAlirMerger.final()`` over whatever
   arrived is bit-identical to the batch ALiR merge over that subset.
4. **Fault equivalence** — seeded kill/restart/delay/steal schedules over
   the in-process multi-host simulation produce final tables
   bit-identical to the uninterrupted elastic run (quick fixed schedules
   in tier 1; the seeded matrix under ``-m chaos``).
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckio
from repro.core import merge as mg
from repro.core.driver import prepare_training, worker_chunk_key
from repro.core.schedule import plan_epoch
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.data.pipeline import PairChunkStream, make_worker_streams
from repro.data.vocab import build_vocab
from repro.elastic import (
    ElasticRunner, FaultEvent, FaultSchedule, WorkerCursor,
    WorkerStateStore, simulate_elastic)

N_WORKERS = 4
EPOCHS = 2


@pytest.fixture(scope="module")
def world():
    gen = SemanticCorpusModel.create(vocab_size=150, seed=0)
    return gen.generate(num_sentences=500, seed=1)


@pytest.fixture(scope="module")
def setup(world):
    cfg = SGNSConfig(vocab_size=0, dim=8, negatives=2)
    s = prepare_training(world, 150, "random", N_WORKERS, cfg,
                         epochs=EPOCHS, batch_size=16,
                         max_steps_per_epoch=8, steps_per_chunk=2,
                         seed=3, subsample_t=None,
                         process_index=0, process_count=1)
    assert s.sched.num_chunks >= 3, s.sched   # mid-epoch cuts must exist
    return s


@pytest.fixture(scope="module")
def baseline(setup, tmp_path_factory):
    """The uninterrupted elastic run — the bit-identity reference."""
    store = WorkerStateStore(str(tmp_path_factory.mktemp("baseline")))
    return ElasticRunner(setup, store, ckpt_every=1).run_all()


def assert_tables_equal(a: dict, b: dict, ctx=""):
    for k in ("W", "C"):
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=f"{ctx} key={k}")


# ======================================================================
# 1. Cursors
# ======================================================================
def test_cursor_progression_wraps_epochs():
    sched = plan_epoch(min_pairs=64, batch_size=4, epochs=2,
                       steps_per_chunk=4)          # 4 chunks/epoch
    cur = WorkerCursor.start(worker=2)
    seen = []
    while not cur.done(2):
        seen.append((cur.epoch, cur.chunk, cur.step0))
        cur.validate(sched)
        cur = cur.advanced(sched)
    assert seen == [(e, c, e * sched.steps_per_epoch + c * sched.chunk_steps)
                    for e in range(2) for c in range(sched.num_chunks)]
    assert cur.done(2) and cur.worker == 2


def test_cursor_meta_roundtrip_and_validation():
    sched = plan_epoch(64, 4, 2, 4)
    cur = WorkerCursor(worker=1, epoch=1, chunk=2,
                       step0=sched.step0(1, 2))
    assert WorkerCursor.from_meta(cur.to_meta()) == cur
    cur.validate(sched)
    with pytest.raises(ValueError, match="different schedule"):
        WorkerCursor(worker=1, epoch=1, chunk=2, step0=5).validate(sched)
    with pytest.raises(ValueError, match="out of range"):
        WorkerCursor(worker=1, epoch=0, chunk=99, step0=0).validate(sched)
    with pytest.raises(ValueError, match="non-negative"):
        WorkerCursor(worker=-1, epoch=0, chunk=0, step0=0)


# ======================================================================
# 1b. Stream fast-forward + key replay (deterministic suffix checks)
# ======================================================================
def test_start_chunk_suffix_bit_exact(setup):
    """chunks(epoch, N, start_chunk=c) must equal the suffix of the
    uninterrupted stream for every chunk boundary c — the stream half of
    mid-epoch resume."""
    sched = setup.sched
    for w in (0, N_WORKERS - 1):
        stream = PairChunkStream(
            [setup.streams[w]], batch_size=setup.batch_size,
            steps_per_chunk=sched.chunk_steps,
            sentences_per_block=setup.sentences_per_block)
        for epoch in range(EPOCHS):
            full = list(stream.chunks(epoch, sched.num_chunks))
            for cut in range(sched.num_chunks + 1):
                tail = list(stream.chunks(epoch, sched.num_chunks,
                                          start_chunk=cut))
                assert len(tail) == sched.num_chunks - cut
                for (fc, fx), (tc, tx) in zip(full[cut:], tail):
                    np.testing.assert_array_equal(fc, tc)
                    np.testing.assert_array_equal(fx, tx)


def test_chunk_keys_and_step0_are_position_pure(setup):
    """The per-chunk PRNG key and LR offset depend only on the cursor's
    coordinates — not on how training reached them — so the negative
    draws of a resumed worker are bit-identical by construction."""
    sched = setup.sched
    for epoch in range(EPOCHS):
        for chunk in range(sched.num_chunks):
            k1 = worker_chunk_key(setup.seed, epoch, chunk, N_WORKERS, 1)
            k2 = worker_chunk_key(setup.seed, epoch, chunk, N_WORKERS, 1)
            np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
            cur = WorkerCursor(worker=1, epoch=epoch, chunk=chunk,
                               step0=sched.step0(epoch, chunk))
            cur.validate(sched)
    # distinct coordinates → distinct keys (no stream aliasing)
    keys = {tuple(np.asarray(worker_chunk_key(
        setup.seed, e, c, N_WORKERS, w)).ravel().tolist())
        for e in range(EPOCHS) for c in range(sched.num_chunks)
        for w in range(N_WORKERS)}
    assert len(keys) == EPOCHS * sched.num_chunks * N_WORKERS


# ======================================================================
# 2. Mid-epoch kill → resume, same process (store round-trip)
# ======================================================================
def test_resume_from_any_checkpoint_is_bit_identical(setup, baseline,
                                                     tmp_path):
    """Train worker 0 for k chunks, throw the runner away (the "kill"),
    resume from the store with a fresh runner, finish — final tables
    must equal the uninterrupted run for several mid-epoch k."""
    sched = setup.sched
    total = sched.num_chunks * EPOCHS
    for k in (1, sched.num_chunks - 1, sched.num_chunks + 1, total - 1):
        store = WorkerStateStore(str(tmp_path / f"cut{k}"))
        r1 = ElasticRunner(setup, store, ckpt_every=1)
        params, cursor = r1.load_worker(0)
        it = None
        for _ in range(k):
            if it is None:
                it = r1.chunk_iter(0, cursor)
            params = r1.train_chunk(params, cursor, next(it))
            cursor = cursor.advanced(sched)
            if cursor.chunk == 0:
                it = None
            r1._maybe_save(params, cursor, done=cursor.done(EPOCHS))
        del r1, params, cursor, it                  # the kill
        r2 = ElasticRunner(setup, store, ckpt_every=1)
        final = r2.run_worker(0, resume=True)
        assert_tables_equal(final, baseline[0], ctx=f"cut after {k} chunks")


def test_sparse_checkpoint_cadence_still_bit_identical(setup, baseline,
                                                       tmp_path):
    """ckpt_every > 1: a kill loses the chunks since the last checkpoint
    but the replay regenerates them bit-exactly."""
    store = WorkerStateStore(str(tmp_path / "sparse"))
    r1 = ElasticRunner(setup, store, ckpt_every=3)
    sched = setup.sched
    params, cursor = r1.load_worker(1)
    it = None
    for _ in range(sched.num_chunks + 2):          # dies mid-epoch 1
        if it is None:
            it = r1.chunk_iter(1, cursor)
        params = r1.train_chunk(params, cursor, next(it))
        cursor = cursor.advanced(sched)
        if cursor.chunk == 0:
            it = None
        r1._maybe_save(params, cursor, done=cursor.done(EPOCHS))
    stored = store.cursor(1)
    assert stored is not None
    assert stored.global_chunk_index(sched) <= sched.num_chunks + 2
    final = ElasticRunner(setup, store, ckpt_every=3).run_worker(1)
    assert_tables_equal(final, baseline[1], ctx="sparse cadence")


def test_schedule_drift_rejected_on_resume(setup, tmp_path):
    store = WorkerStateStore(str(tmp_path))
    wrong = WorkerCursor(worker=0, epoch=0, chunk=1, step0=999)
    store.save(wrong, {"W": np.zeros((4, 2), np.float32)})
    with pytest.raises(ValueError, match="different schedule"):
        ElasticRunner(setup, store).load_worker(0)


# ======================================================================
# 3. Crash window in checkpoint/io
# ======================================================================
class _DieOnManifest:
    """os.replace stand-in that kills the process (raises) the moment
    the manifest rename is attempted — after the table npz landed."""

    def __init__(self, real):
        self.real = real

    def __call__(self, src, dst):
        if os.path.basename(dst) == ckio.MANIFEST_NAME:
            raise RuntimeError("killed between table and manifest rename")
        return self.real(src, dst)


def test_crash_between_table_and_manifest_is_invisible(tmp_path,
                                                       monkeypatch):
    d = str(tmp_path)
    v1 = ckio.publish_arrays(d, {"a": np.arange(3)}, meta={"tag": "one"})
    real = os.replace
    monkeypatch.setattr(os, "replace", _DieOnManifest(real))
    with pytest.raises(RuntimeError, match="killed between"):
        ckio.publish_arrays(d, {"a": np.arange(9)}, meta={"tag": "two"})
    monkeypatch.setattr(os, "replace", real)

    # The orphan npz exists on disk but no reader can ever see it.
    orphans = [f for f in os.listdir(d)
               if f.startswith("table_v") and f.endswith(".npz")]
    assert len(orphans) == 2                       # v1 + the orphan v2
    arrays, meta, version = ckio.load_arrays(d)
    assert version == v1 and meta["tag"] == "one"
    np.testing.assert_array_equal(arrays["a"], np.arange(3))

    # The orphan's number is burned: the next publish skips it.
    v3 = ckio.publish_arrays(d, {"a": np.arange(5)}, meta={"tag": "three"})
    assert v3 == v1 + 2
    arrays, meta, _ = ckio.load_arrays(d)
    assert meta["tag"] == "three"


def test_gc_orphans_sweeps_debris_without_reusing_versions(tmp_path,
                                                           monkeypatch):
    d = str(tmp_path)
    v1 = ckio.publish_arrays(d, {"a": np.arange(3)})
    real = os.replace
    monkeypatch.setattr(os, "replace", _DieOnManifest(real))
    with pytest.raises(RuntimeError):
        ckio.publish_arrays(d, {"a": np.arange(4)})
    monkeypatch.setattr(os, "replace", real)
    # a partial tmp write (crash mid-npz) is debris too
    open(os.path.join(d, ".tmp-deadbeef"), "wb").write(b"partial")

    removed = ckio.gc_orphans(d)
    assert sorted(removed) == sorted(
        [".tmp-deadbeef", os.path.basename(ckio._table_path(d, v1 + 1))])
    # reader still on v1; collected number still never reused
    _, _, version = ckio.load_arrays(d)
    assert version == v1
    assert ckio.next_version(d) == v1 + 2
    v3 = ckio.publish_arrays(d, {"a": np.arange(5)})
    assert v3 == v1 + 2
    assert ckio.gc_orphans(d) == []                # idempotent


def test_worker_store_crash_window(tmp_path, monkeypatch):
    """The same invisibility guarantee through the WorkerStateStore:
    a kill mid-checkpoint leaves the previous (params, cursor) pair
    loadable — never a torn one."""
    sched = plan_epoch(64, 4, 2, 4)
    store = WorkerStateStore(str(tmp_path))
    c0 = WorkerCursor(worker=0, epoch=0, chunk=1, step0=sched.step0(0, 1))
    store.save(c0, {"W": np.ones((4, 2), np.float32)})
    real = os.replace
    monkeypatch.setattr(os, "replace", _DieOnManifest(real))
    c1 = WorkerCursor(worker=0, epoch=0, chunk=2, step0=sched.step0(0, 2))
    with pytest.raises(RuntimeError):
        store.save(c1, {"W": np.full((4, 2), 2.0, np.float32)})
    monkeypatch.setattr(os, "replace", real)
    params, cursor, _ = store.load(0)
    assert cursor == c0
    np.testing.assert_array_equal(params["W"], np.ones((4, 2), np.float32))
    assert store.gc(num_workers=1)                 # debris existed


# ======================================================================
# 4. Quorum / deadline merge
# ======================================================================
def _rotated_world(V=90, d=8, n=4, seed=5, exclusive_block=0):
    """n rotated copies of one truth table; optionally a block of words
    seen ONLY by the last worker (the elastic dead-worker scenario)."""
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        mask = rng.random(V) >= 0.25
        mask[: d + 2] = True                       # shared anchor rows
        if exclusive_block:
            sl = slice(V - exclusive_block, V)
            mask[sl] = i == n - 1                  # only worker n-1 sees
        M = (Y @ q).astype(np.float32)
        M[~mask] = 9.9                             # garbage where absent
        models.append(M)
        masks.append(mask.copy())
    return Y, models, masks


@pytest.mark.parametrize("n_missing", [1, 2, 3])
def test_quorum_final_matches_batch_over_survivors(n_missing):
    _, models, masks = _rotated_world(n=4, seed=100 + n_missing)
    rng = np.random.default_rng(n_missing)
    survivors = sorted(rng.choice(4, size=4 - n_missing, replace=False))
    batch = mg.get_merger("alir").merge(mg.stack_models(
        [models[w] for w in survivors], [masks[w] for w in survivors]))
    m = mg.IncrementalAlirMerger(quorum=len(survivors))
    assert not m.quorum_met
    for w in rng.permutation(survivors):           # any arrival order
        m.add(int(w), models[w], masks[w])
    assert m.quorum_met
    final = m.final()
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(batch.Y))
    np.testing.assert_array_equal(np.asarray(final.valid),
                                  np.asarray(batch.valid))


def test_quorum_unmet_raises_but_can_be_overridden():
    _, models, masks = _rotated_world(n=4, seed=7)
    m = mg.IncrementalAlirMerger(quorum=3)
    m.add(0, models[0], masks[0])
    with pytest.raises(RuntimeError, match="quorum"):
        m.final()
    fold = m.final(require_quorum=False)           # explicit best-effort
    assert fold.worker_ids == (0,)


def test_deadline_excludes_late_arrivals():
    _, models, masks = _rotated_world(n=4, seed=9)
    now = [0.0]
    m = mg.IncrementalAlirMerger(quorum=2, deadline=10.0,
                                 clock=lambda: now[0])
    m.add(0, models[0], masks[0])
    now[0] = 5.0
    m.add(2, models[2], masks[2])
    now[0] = 11.0                                  # window closed
    assert m.deadline_passed
    assert m.add(3, models[3], masks[3]) is None
    assert m.late_workers == [3]
    final = m.final()
    assert final.worker_ids == (0, 2)              # pure on-time subset
    batch = mg.get_merger("alir").merge(
        mg.stack_models([models[0], models[2]], [masks[0], masks[2]]))
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(batch.Y))


def test_dead_worker_checkpoint_round_trips_its_exclusive_words():
    """Words only the dead worker ever saw: a quorum merge over the
    survivors cannot cover them (they are OOV there), but folding the
    dead worker's *last checkpoint* in lets reconstruct_missing
    round-trip those rows into every survivor's space — coverage is
    rescued by a partial checkpoint, the elastic serving story."""
    B = 10
    Y, models, masks = _rotated_world(V=90, d=8, n=4, seed=13,
                                      exclusive_block=B)
    sl = slice(90 - B, 90)
    survivors = [0, 1, 2]
    m = mg.IncrementalAlirMerger(quorum=3)
    for w in survivors:
        m.add(w, models[w], masks[w])
    fold = m.final()
    assert not np.asarray(fold.valid)[sl].any()    # exclusive words OOV

    # Fold the dead worker's checkpointed table in (it saw the block):
    stacked = mg.stack_models(models, masks)
    res_all = mg.get_merger("alir", max_iters=60, tol=1e-12).merge(stacked)
    Yall, valid_all = res_all.Y, res_all.valid
    assert np.asarray(valid_all)[sl].all()         # coverage rescued
    Ws = np.asarray(mg.alir_transforms(stacked, Yall))
    # At the ALiR fixed point, an exclusively-dead-worker consensus row
    # is exactly the dead checkpoint's row carried through its map:
    np.testing.assert_allclose(np.asarray(Yall)[sl],
                               models[3][sl] @ Ws[3], atol=1e-5)
    rec = np.asarray(mg.reconstruct_missing(stacked, Yall))
    for w in survivors:
        # round-trip: the survivor-space reconstruction maps back onto
        # the consensus bit-tightly (W_i orthogonal), so the exclusive
        # words' representations really did come from the dead worker.
        np.testing.assert_allclose(rec[w][sl] @ Ws[w],
                                   np.asarray(Yall)[sl], atol=1e-4)
        assert np.abs(rec[w][sl]).max() > 0.1      # not zero-filled OOV


# ======================================================================
# 5. Fault simulation — quick fixed schedules (tier 1)
# ======================================================================
def test_kill_restart_resume_bit_identical(setup, baseline, tmp_path):
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=1)
    faults = FaultSchedule((FaultEvent("kill", 1, 2),
                            FaultEvent("restart", 1, 4),
                            FaultEvent("delay", 0, 3, duration=2)))
    sim = simulate_elastic(r, 2, faults)
    assert sim.unfinished == []
    for w in range(N_WORKERS):
        assert_tables_equal(sim.params[w], baseline[w], ctx=f"worker {w}")


def test_kill_steal_bit_identical(setup, baseline, tmp_path):
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=1)
    sim = simulate_elastic(r, 2, FaultSchedule((FaultEvent("kill", 1, 1),)),
                           steal_after=2)
    assert sim.unfinished == []
    assert sim.stolen                              # work moved hosts
    assert all(dst == 0 for _, dst in sim.stolen.values())
    for w in range(N_WORKERS):
        assert_tables_equal(sim.params[w], baseline[w], ctx=f"worker {w}")


def test_unrecovered_kill_leaves_workers_unfinished(setup, tmp_path):
    """No restart, no stealing: the dead host's workers never finish —
    the input to the quorum merge path — and the sim terminates instead
    of spinning."""
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=1)
    sim = simulate_elastic(r, 2, FaultSchedule((FaultEvent("kill", 1, 1),)))
    dead_block = list(range(2, N_WORKERS))         # host 1's block
    assert sim.unfinished == dead_block
    assert sorted(sim.params) == [0, 1]
    assert sim.ticks < 100


def test_merge_finished_feeds_registry_merger(setup, tmp_path):
    """merge_finished: whatever the simulation finished goes through the
    unified registry — quorum enforced, arrival order erased, and any
    registered merger (flat or reduction tree) accepted."""
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=1)
    sim = simulate_elastic(r, 2, FaultSchedule((FaultEvent("kill", 1, 1),)))
    survivors = sim.finished
    assert survivors == [0, 1]
    from repro.elastic import merge_finished
    mask = np.asarray(setup.mask)
    with pytest.raises(RuntimeError, match="quorum"):
        merge_finished(sim, mask, quorum=N_WORKERS)
    final = merge_finished(sim, mask, quorum=len(survivors))
    assert final.worker_ids == tuple(survivors)
    batch = mg.get_merger("alir").merge(mg.stack_models(
        [sim.params[w]["W"] for w in survivors],
        [mask[w] for w in survivors]))
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(batch.Y))
    # the reduction tree drops in through the same seam
    tree = merge_finished(sim, mask, merger="alir_tree", fan_in=2,
                          quorum=len(survivors))
    assert tree.worker_ids == tuple(survivors)
    assert np.isfinite(np.asarray(tree.Y)).all()


# ======================================================================
# 6. The chaos matrix (CI job: pytest -m chaos)
# ======================================================================
CHAOS_SEEDS = range(4)


@pytest.mark.chaos
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_resume(setup, baseline, tmp_path, seed):
    """Seeded kill+restart (+straggler delay) schedules: every worker
    finishes and every table is bit-identical to the uninterrupted run."""
    faults = FaultSchedule.seeded(seed, hosts=3, horizon=6, kills=2,
                                  restarts=2, delays=1)
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=1)
    sim = simulate_elastic(r, 3, faults)
    assert sim.unfinished == []
    for w in range(N_WORKERS):
        assert_tables_equal(sim.params[w], baseline[w],
                            ctx=f"seed {seed} worker {w}")


@pytest.mark.chaos
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_steal(setup, baseline, tmp_path, seed):
    """Seeded unrecovered kills + work-stealing: survivors adopt the
    victims' workers mid-stream; results still bit-identical."""
    faults = FaultSchedule.seeded(seed + 1000, hosts=3, horizon=6,
                                  kills=2, restarts=0)
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=2)
    sim = simulate_elastic(r, 3, faults, steal_after=1)
    assert sim.unfinished == []
    for w in range(N_WORKERS):
        assert_tables_equal(sim.params[w], baseline[w],
                            ctx=f"seed {seed} worker {w}")


@pytest.mark.chaos
@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_quorum_merge(setup, baseline, tmp_path, seed):
    """Seeded unrecovered kills, no stealing: merge whatever finished.
    The quorum fold must be bit-identical to the batch ALiR merge over
    the surviving subset, and every survivor's table bit-identical to
    the uninterrupted run."""
    faults = FaultSchedule.seeded(seed + 2000, hosts=4, horizon=5,
                                  kills=2, restarts=0)
    r = ElasticRunner(setup, WorkerStateStore(str(tmp_path)), ckpt_every=1)
    sim = simulate_elastic(r, 4, faults)
    survivors = sim.finished
    assert survivors                                # ≥1 host survived
    for w in survivors:
        assert_tables_equal(sim.params[w], baseline[w],
                            ctx=f"seed {seed} worker {w}")
    if not sim.unfinished:
        return                                      # lucky seed: all done
    mask = np.asarray(setup.mask)
    models = [sim.params[w]["W"] for w in survivors]
    masks = [mask[w] for w in survivors]
    batch = mg.get_merger("alir").merge(mg.stack_models(models, masks))
    m = mg.IncrementalAlirMerger(quorum=len(survivors))
    order = np.random.default_rng(seed).permutation(survivors)
    for w in order:
        m.add(int(w), sim.params[int(w)]["W"], mask[int(w)])
    final = m.final()
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(batch.Y))
    np.testing.assert_array_equal(np.asarray(final.valid),
                                  np.asarray(batch.valid))


# ======================================================================
# 7. Hypothesis: arbitrary cut points (skips when hypothesis missing)
# ======================================================================
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 50), worker=st.integers(0, N_WORKERS - 1),
           epoch=st.integers(0, 3), cut=st.integers(0, 6))
    def test_stream_resumable_at_arbitrary_cut_points(seed, worker, epoch,
                                                      cut):
        """For arbitrary (seed, worker, epoch, chunk-boundary) cut
        points: the fast-forwarded chunk stream is the exact suffix of
        the uninterrupted stream, and the per-chunk negative-draw keys
        agree — the full resumability property."""
        gen = SemanticCorpusModel.create(vocab_size=80, seed=0)
        corpus = gen.generate(num_sentences=120, seed=2)
        vocab = build_vocab(corpus, 80, min_count=1, max_size=None)
        stream = make_worker_streams(
            corpus, vocab, num_workers=N_WORKERS, strategy="equal",
            rate=1.0 / N_WORKERS, window=3, subsample_t=None,
            seed=seed)[worker]
        cs = PairChunkStream([stream], batch_size=8, steps_per_chunk=2,
                             sentences_per_block=64)
        num_chunks = 6
        cut = min(cut, num_chunks)
        full = list(cs.chunks(epoch, num_chunks))
        tail = list(cs.chunks(epoch, num_chunks, start_chunk=cut))
        assert len(tail) == num_chunks - cut
        for (fc, fx), (tc, tx) in zip(full[cut:], tail):
            np.testing.assert_array_equal(fc, tc)
            np.testing.assert_array_equal(fx, tx)
        for chunk in range(cut, num_chunks):
            np.testing.assert_array_equal(
                np.asarray(worker_chunk_key(seed, epoch, chunk,
                                            N_WORKERS, worker)),
                np.asarray(worker_chunk_key(seed, epoch, chunk,
                                            N_WORKERS, worker)))
