"""Update-engine abstraction: registry, cross-engine equivalence, the
fused kernel's in-kernel negative draw (replay + chi-square), and the
zero-collective property of every async engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sgns
from repro.core.engine import (
    ENGINE_NAMES, FusedPallasEngine, UpdateEngine, get_engine)
from repro.core.sgns import SGNSConfig
from repro.data.pairs import build_noise_table, unigram_noise_probs
from repro.kernels.sgns_fused import (
    fused_negative_ids, sample_negatives_fused, sgns_fused_step)


def _zipf_counts(V, seed=0):
    return np.random.default_rng(seed).zipf(1.3, V).astype(np.float64)


@pytest.fixture(scope="module")
def cfg():
    return SGNSConfig(vocab_size=150, dim=32, negatives=4)


@pytest.fixture(scope="module")
def batch(cfg):
    rng = np.random.default_rng(2)
    B = 48
    c = jnp.asarray(rng.integers(0, cfg.vocab_size, B, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, cfg.vocab_size, B, dtype=np.int32))
    return c, x


@pytest.fixture(scope="module")
def tables(cfg):
    counts = _zipf_counts(cfg.vocab_size)
    return {kind: build_noise_table(counts, kind=kind)
            for kind in ("cdf", "alias")}, counts


def _params(cfg, seed=1):
    p = sgns.init_params(jax.random.PRNGKey(seed), cfg)
    return {"W": p["W"], "C": 0.02 * jax.random.normal(
        jax.random.PRNGKey(seed + 1), p["C"].shape)}


# ------------------------------------------------------------------ registry
def test_registry_resolves_all_names():
    for name in ENGINE_NAMES:
        eng = get_engine(name)
        assert isinstance(eng, UpdateEngine)
        assert eng.name == name
        assert eng.table_kind in ("cdf", "alias")


def test_registry_sampler_suffix_and_overrides():
    assert get_engine("sparse:alias").sampler == "alias"
    assert get_engine("pallas:cdf").table_kind == "cdf"
    assert get_engine("dense", sampler="alias").table_kind == "alias"
    eng = get_engine("sparse")
    assert get_engine(eng) is eng                      # instance passthrough
    assert get_engine(eng, sampler="alias").sampler == "alias"


def test_registry_rejects_unknown():
    with pytest.raises(ValueError, match="unknown update engine"):
        get_engine("hogwild")


def test_fused_engine_is_alias_only():
    assert FusedPallasEngine().table_kind == "alias"
    with pytest.raises(ValueError, match="alias"):
        get_engine("pallas_fused:cdf")


def test_engines_hashable_and_value_equal():
    assert get_engine("sparse:alias") == get_engine("sparse:alias")
    assert hash(get_engine("pallas")) == hash(get_engine("pallas"))
    assert get_engine("sparse") != get_engine("sparse:alias")


# ------------------------------------------------------- dial validation
def test_engine_rejects_bad_dials_at_construction():
    """Shape-free dial errors surface at get_engine time with a clear
    message, not as a cryptic kernel failure mid-epoch."""
    with pytest.raises(ValueError, match="ring_depth >= 2"):
        get_engine("pallas_fused_pipe", ring_depth=1)
    with pytest.raises(ValueError, match="ring_depth >= 2"):
        get_engine("pallas_fused_tiered", ring_depth=0)
    with pytest.raises(ValueError, match="hot_rows >= 0"):
        get_engine("pallas_fused_tiered", hot_rows=-1)
    with pytest.raises(ValueError, match="block_pairs >= 1"):
        get_engine("pallas_fused_hbm", block_pairs=0)
    with pytest.raises(ValueError, match="block_pairs >= 1"):
        get_engine("pallas_fused_pipe", block_pairs=-3)


def test_trainer_rejects_hot_tier_larger_than_vocab(cfg):
    """hot_rows > V is a misconfiguration the trainer rejects at
    construction (engine.validate); hot_rows == V (pure-resident) is
    legal."""
    from repro.core.async_trainer import AsyncShardTrainer

    with pytest.raises(ValueError, match="exceeds"):
        AsyncShardTrainer(
            cfg=cfg, num_workers=1, total_steps=4,
            engine=get_engine("pallas_fused_tiered",
                              hot_rows=cfg.vocab_size + 1))
    tr = AsyncShardTrainer(
        cfg=cfg, num_workers=1, total_steps=4,
        engine=get_engine("pallas_fused_tiered",
                          hot_rows=cfg.vocab_size))
    assert tr.engine.hot_rows == cfg.vocab_size


# -------------------------------------------------------------- equivalence
def test_dense_sparse_pallas_steps_identical(cfg, batch, tables):
    """Same key ⇒ same negatives ⇒ dense ≡ sparse ≡ pallas losses and
    params (autodiff vs manual row grads vs the Pallas tile kernel)."""
    tabs, _ = tables
    c, x = batch
    key = jax.random.PRNGKey(7)
    outs = {}
    for name in ("dense", "sparse", "pallas"):
        step = get_engine(name).make_step(cfg, total_steps=100)
        p, loss = step(_params(cfg), c, x, tabs["cdf"], key, jnp.int32(3))
        outs[name] = (p, float(loss))
    for name in ("sparse", "pallas"):
        np.testing.assert_allclose(outs[name][1], outs["dense"][1], rtol=1e-5)
        np.testing.assert_allclose(outs[name][0]["W"], outs["dense"][0]["W"],
                                   atol=1e-5)
        np.testing.assert_allclose(outs[name][0]["C"], outs["dense"][0]["C"],
                                   atol=1e-5)


def test_fused_step_matches_sparse_with_replayed_negatives(cfg, batch, tables):
    """pallas_fused ≡ sparse when the sparse step is fed the exact ids
    the kernel's counter PRNG drew (replayed via fused_negative_ids)."""
    tabs, _ = tables
    c, x = batch
    key = jax.random.PRNGKey(11)
    lr = jnp.float32(0.04)
    p0 = _params(cfg)
    pf, loss_f = sgns_fused_step(jax.tree.map(jnp.copy, p0), c, x,
                                 tabs["alias"], key, lr,
                                 negatives=cfg.negatives, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), tabs["alias"]["prob"],
                             tabs["alias"]["alias"],
                             (c.shape[0], cfg.negatives))
    ps, loss_s = sgns.train_step_sparse(jax.tree.map(jnp.copy, p0), c, x,
                                        ids, lr)
    np.testing.assert_allclose(float(loss_f), float(loss_s), rtol=1e-5)
    np.testing.assert_allclose(pf["W"], ps["W"], atol=1e-6)
    np.testing.assert_allclose(pf["C"], ps["C"], atol=1e-6)


def test_all_engines_converge_through_trainer(cfg, tables):
    """Whole-epoch equivalence up to sampling seed: every engine trains
    the same data to a loss below the (k+1)·log2 init plateau, and the
    deterministic trio agrees exactly."""
    from repro.core.async_trainer import AsyncShardTrainer

    tabs, counts = tables
    n, S, B = 2, 12, 64
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, 30, (n, S, B)), jnp.int32)
    x = jnp.asarray((np.asarray(c) + 1) % 30, jnp.int32)   # structured
    losses = {}
    for name in ENGINE_NAMES:
        # fit the tiered hot prefix inside the 150-word test vocab (the
        # trainer rejects hot_rows > V at construction)
        eng = get_engine(name, hot_rows=64) \
            if name == "pallas_fused_tiered" else name
        tr = AsyncShardTrainer(cfg=cfg, num_workers=n, total_steps=S,
                               engine=eng)
        table = jax.tree.map(lambda a: jnp.stack([a, a]),
                             tabs[tr.engine.table_kind])
        p = tr.init(jax.random.PRNGKey(0))
        p, ls = tr.epoch(p, c, x, table, jax.random.PRNGKey(4))
        assert np.isfinite(np.asarray(ls)).all(), name
        losses[name] = np.asarray(ls)
        # learning happened: final loss under the all-zero-C plateau
        assert float(ls[:, -1].mean()) < (cfg.negatives + 1) * np.log(2), name
    np.testing.assert_allclose(losses["sparse"], losses["dense"], rtol=1e-4)
    np.testing.assert_allclose(losses["pallas"], losses["dense"], rtol=1e-4)
    # fused draws its own negatives: same ballpark, not bitwise
    assert abs(losses["pallas_fused"].mean() - losses["dense"].mean()) < 0.5


# ------------------------------------------------- in-kernel negative draw
def test_fused_draw_chi_square_matches_unigram_075(tables):
    """Chi-square goodness-of-fit of the *in-kernel* draws (interpret
    mode, via the standalone sampler kernel) against unigram^0.75."""
    tabs, counts = tables
    p = unigram_noise_probs(counts)
    N = 400_000
    draws = np.asarray(sample_negatives_fused(
        tabs["alias"], jax.random.PRNGKey(123), (N,), interpret=True))
    assert draws.min() >= 0 and draws.max() < len(p)
    obs = np.bincount(draws, minlength=len(p)).astype(np.float64)
    exp = p * N
    keep = exp >= 5.0                       # classic chi-square validity rule
    chi2 = float(np.sum((obs[keep] - exp[keep]) ** 2 / exp[keep])
                 + (obs[~keep].sum() - exp[~keep].sum()) ** 2
                 / max(exp[~keep].sum(), 1.0))
    df = int(keep.sum())                    # (+1 pooled bin, -1 constraint)
    # ~p=0.001 normal-approx critical value; generous but catches a
    # broken mixer or a biased index draw immediately
    crit = df + 4.0 * np.sqrt(2.0 * df)
    assert chi2 < crit, (chi2, df, crit)


def test_fused_draw_deterministic_and_seed_sensitive(tables):
    tabs, _ = tables
    k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
    a = sample_negatives_fused(tabs["alias"], k1, (64, 5))
    b = sample_negatives_fused(tabs["alias"], k1, (64, 5))
    c = sample_negatives_fused(tabs["alias"], k2, (64, 5))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert a.dtype == jnp.int32


def test_fused_draw_replay_matches_kernel(tables):
    """The pure-jnp replay (fused_negative_ids) is bit-identical to the
    in-kernel draw — the property the equivalence tests stand on."""
    tabs, _ = tables
    key = jax.random.PRNGKey(21)
    in_kernel = sample_negatives_fused(tabs["alias"], key, (32, 7))
    replay = fused_negative_ids(key.astype(jnp.uint32), tabs["alias"]["prob"],
                                tabs["alias"]["alias"], (32, 7))
    np.testing.assert_array_equal(np.asarray(in_kernel), np.asarray(replay))


def test_fused_steps_draw_fresh_negatives_each_scan_step(cfg, tables):
    """Across an epoch scan the per-step key split must decorrelate the
    in-kernel draws (a stuck counter/seed would reuse one negative set)."""
    tabs, _ = tables
    ids = [np.asarray(fused_negative_ids(
        jax.random.split(jax.random.PRNGKey(5), 3)[i].astype(jnp.uint32),
        tabs["alias"]["prob"], tabs["alias"]["alias"], (16, 4)))
        for i in range(3)]
    assert not np.array_equal(ids[0], ids[1])
    assert not np.array_equal(ids[1], ids[2])


# --------------------------------------------------------- no collectives
# Every registered engine × every sampler layout it supports. The single
# source of truth for the paper's headline property — the per-engine
# ad-hoc checks that used to live in test_system / test_alias are gone.
ASYNC_ENGINE_SPECS = (
    "dense:cdf", "dense:alias",
    "sparse:cdf", "sparse:alias",
    "pallas:cdf", "pallas:alias",
    "pallas_fused:alias",            # fused engines sample in-kernel:
    "pallas_fused_hbm:alias",        # alias is their only layout
    "pallas_fused_pipe:alias",       # planner replays the same draw —
                                     # sort/searchsorted, no collectives
    "pallas_fused_tiered:alias",     # hot tier is per-worker-private:
                                     # no synchronization to add
)


def test_collective_spec_matrix_covers_registry():
    """A new engine registered without a row here must fail loudly."""
    assert {s.split(":")[0] for s in ASYNC_ENGINE_SPECS} == set(ENGINE_NAMES)


@pytest.mark.parametrize("spec", ASYNC_ENGINE_SPECS)
def test_async_engine_epoch_is_collective_free(cfg, spec):
    """The paper's headline property holds for each engine × sampler,
    certified through ``repro.analysis.contracts`` (the single checker:
    structured op-walk over the lowered epoch + table-donation aliasing
    of the step) — no duplicated regexes in tests."""
    from repro.analysis.contracts import certify_engine_contracts
    from repro.core.engine import get_engine

    eng = get_engine(spec, hot_rows=64) \
        if spec.startswith("pallas_fused_tiered") else get_engine(spec)
    rep = certify_engine_contracts(
        eng, vocab_size=cfg.vocab_size, dim=cfg.dim,
        negatives=cfg.negatives, steps=4, batch=64)
    assert rep.zero_collective
    assert rep.aliasing.aliased_table_args >= 2, spec


# ----------------------------------------------------- sync epochs speak it
def test_sync_epoch_takes_engine(cfg, tables):
    from repro.core.async_trainer import make_sync_epoch

    tabs, _ = tables
    epoch = make_sync_epoch(cfg, tabs["alias"], total_steps=8,
                            engine="sparse:alias")
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    p, losses = epoch(sgns.init_params(jax.random.PRNGKey(0), cfg), c, c,
                      jax.random.PRNGKey(1), jnp.int32(0))
    assert losses.shape == (4,)
    assert np.isfinite(np.asarray(losses)).all()


def test_periodic_sync_epoch_runs_engine_steps(cfg, tables):
    from repro.core.async_trainer import make_periodic_sync_epoch

    tabs, _ = tables
    mesh = jax.make_mesh((1,), ("worker",))
    epoch = make_periodic_sync_epoch(cfg, tabs["cdf"], total_steps=8,
                                     sync_every=2, mesh=mesh,
                                     engine="sparse")
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 2, 32)), jnp.int32)
    p, losses = epoch(sgns.init_params(jax.random.PRNGKey(0), cfg), c, c,
                      jax.random.PRNGKey(1), jnp.int32(0))
    assert losses.shape == (2, 2)
    assert np.isfinite(np.asarray(losses)).all()
