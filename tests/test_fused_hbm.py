"""HBM-blocked fused SGNS engine: bit-equivalence against the sparse
reference at table sizes beyond the VMEM-resident kernel's envelope,
block-draw replay, per-pair sequential semantics, and trainer wiring.

The bit-identity comparisons use ``jax.jit(train_step_sparse)`` — the
form every engine actually runs it in. (The eager op-by-op form can
differ in the last ulp because XLA only fuses multiply-adds into FMAs
inside a jitted graph.)

The kernel-equivalence tests run the HBM-blocked kernel in interpret
mode at a (V, d) past the VMEM envelope — seconds each, so they carry
``@pytest.mark.slow`` and run in the dedicated slow CI job
(``pytest -m slow``); the tier-1 gate deselects them via addopts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sgns
from repro.core.engine import FusedHBMPallasEngine, get_engine
from repro.core.sgns import SGNSConfig
from repro.data.pairs import build_noise_table
from repro.kernels.sgns_fused import fused_negative_ids
from repro.kernels.sgns_fused_hbm import (
    _block_negative_ids, _pick_block_pairs, sgns_fused_hbm_step)

# Deliberately past the VMEM-resident kernel's intended envelope:
# 2 tables × V × d × 4 B = 2 × 34_000 × 64 × 4 ≈ 17.4 MB > ~16 MB VMEM.
V_BIG, D_BIG = 34_000, 64
B, K = 64, 4


@pytest.fixture(scope="module")
def cfg():
    return SGNSConfig(vocab_size=V_BIG, dim=D_BIG, negatives=K)


@pytest.fixture(scope="module")
def world(cfg):
    rng = np.random.default_rng(0)
    params = {
        "W": jnp.asarray(0.01 * rng.normal(size=(V_BIG, D_BIG)), jnp.float32),
        "C": jnp.asarray(0.01 * rng.normal(size=(V_BIG, D_BIG)), jnp.float32),
    }
    c = jnp.asarray(rng.integers(0, V_BIG, B, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, V_BIG, B, dtype=np.int32))
    # force duplicate rows within one block: the RMW scatter must
    # accumulate exactly like the sparse reference's scatter-add
    c = c.at[1].set(c[0])
    x = x.at[3].set(x[2])
    counts = rng.zipf(1.3, V_BIG).astype(np.float64)
    table = build_noise_table(counts, kind="alias")
    return params, c, x, table


def _sparse_blocked(params, c, x, ids, lr, blk):
    """The reference: one jitted sparse step per pair block."""
    step = jax.jit(sgns.train_step_sparse)
    params = jax.tree.map(jnp.copy, params)
    losses = []
    for b0 in range(0, c.shape[0], blk):
        params, loss = step(params, c[b0:b0 + blk], x[b0:b0 + blk],
                            ids[b0:b0 + blk], lr)
        losses.append(float(loss))
    return params, losses


# ------------------------------------------------------------ block picker
def test_pick_block_pairs_clamps_to_batch():
    assert _pick_block_pairs(96, 256) == 96
    assert _pick_block_pairs(96, 32) == 32
    assert _pick_block_pairs(96, 50) == 50         # remainder → tail block
    assert _pick_block_pairs(97, 50) == 50         # prime batch: NOT 1
    assert _pick_block_pairs(8, 0) == 1


@pytest.mark.slow
def test_non_dividing_block_uses_tail_invocation(cfg, world):
    """B not a multiple of block_pairs: the shorter tail block must
    still be bit-identical to the per-block sparse reference (and not
    silently degrade to single-pair blocks)."""
    params, c, x, table = world
    key = jax.random.PRNGKey(31)
    lr = jnp.float32(0.025)
    blk = 40                                        # 64 = 40 + tail 24
    ph, _ = sgns_fused_hbm_step(
        jax.tree.map(jnp.copy, params), c, x, table, key, lr,
        negatives=K, block_pairs=blk, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), table["prob"],
                             table["alias"], (B, K))
    pr, _ = _sparse_blocked(params, c, x, ids, lr, blk)
    np.testing.assert_array_equal(np.asarray(ph["W"]), np.asarray(pr["W"]))
    np.testing.assert_array_equal(np.asarray(ph["C"]), np.asarray(pr["C"]))


# ------------------------------------------------------------- draw replay
def test_block_draws_equal_full_batch_replay(world):
    """Per-block counters are global row-major positions, so the blocks'
    draws concatenate to exactly fused_negative_ids((B, K))."""
    _, _, _, table = world
    seed = jax.random.PRNGKey(17).astype(jnp.uint32)
    full = fused_negative_ids(seed, table["prob"], table["alias"], (B, K))
    blk = 16
    parts = [_block_negative_ids(seed, table["prob"], table["alias"],
                                 jnp.int32(b0), blk, K)
             for b0 in range(0, B, blk)]
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(parts)), np.asarray(full))


# ------------------------------------------------------------- equivalence
@pytest.mark.slow
def test_single_block_bit_identical_to_sparse_step(cfg, world):
    """One block covering the batch ⇒ bit-identical to a single sparse
    step on the replayed negatives — at a (V, d) the VMEM-resident
    fused kernel is not sized for."""
    params, c, x, table = world
    key = jax.random.PRNGKey(5)
    lr = jnp.float32(0.03)
    ph, loss_h = sgns_fused_hbm_step(
        jax.tree.map(jnp.copy, params), c, x, table, key, lr,
        negatives=K, block_pairs=B, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), table["prob"],
                             table["alias"], (B, K))
    ps, loss_s = jax.jit(sgns.train_step_sparse)(
        jax.tree.map(jnp.copy, params), c, x, ids, lr)
    np.testing.assert_array_equal(np.asarray(ph["W"]), np.asarray(ps["W"]))
    np.testing.assert_array_equal(np.asarray(ph["C"]), np.asarray(ps["C"]))
    assert float(loss_h) == pytest.approx(float(loss_s), rel=1e-6)


@pytest.mark.slow
def test_blocked_step_bit_identical_to_per_block_sparse(cfg, world):
    """Multi-block: block b+1's gathers must see block b's applied
    updates ⇒ bit-identical to running the sparse step block by block."""
    params, c, x, table = world
    key = jax.random.PRNGKey(11)
    lr = jnp.float32(0.025)
    blk = 16
    ph, loss_h = sgns_fused_hbm_step(
        jax.tree.map(jnp.copy, params), c, x, table, key, lr,
        negatives=K, block_pairs=blk, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), table["prob"],
                             table["alias"], (B, K))
    pr, losses = _sparse_blocked(params, c, x, ids, lr, blk)
    np.testing.assert_array_equal(np.asarray(ph["W"]), np.asarray(pr["W"]))
    np.testing.assert_array_equal(np.asarray(ph["C"]), np.asarray(pr["C"]))
    assert float(loss_h) == pytest.approx(np.mean(losses), rel=1e-5)


@pytest.mark.slow
def test_sequential_matches_per_pair_sparse_to_ulp(cfg, world):
    """sequential=True is word2vec's true update order: a chain of
    batch-size-1 sparse steps. Ulp-level tolerance, not bitwise — XLA
    is free to contract a*b+c into FMA differently in the two
    compilations (values here are O(1e-2), so 1e-8 ≈ a couple ulps)."""
    params, c, x, table = world
    B2 = 24
    key = jax.random.PRNGKey(23)
    lr = jnp.float32(0.025)
    ph, _ = sgns_fused_hbm_step(
        jax.tree.map(jnp.copy, params), c[:B2], x[:B2], table, key, lr,
        negatives=K, block_pairs=8, sequential=True, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), table["prob"],
                             table["alias"], (B2, K))
    pr, _ = _sparse_blocked(params, c[:B2], x[:B2], ids, lr, blk=1)
    np.testing.assert_allclose(np.asarray(ph["W"]), np.asarray(pr["W"]),
                               atol=1e-8, rtol=0)
    np.testing.assert_allclose(np.asarray(ph["C"]), np.asarray(pr["C"]),
                               atol=1e-8, rtol=0)


@pytest.mark.slow
def test_sequential_differs_from_blocked(cfg, world):
    """The two semantics are genuinely different update orders (if they
    were equal the ``sequential`` field would be dead weight)."""
    params, c, x, table = world
    B2 = 24
    key = jax.random.PRNGKey(23)
    lr = jnp.float32(0.025)
    kw = dict(negatives=K, block_pairs=8, interpret=True)
    pa, _ = sgns_fused_hbm_step(jax.tree.map(jnp.copy, params), c[:B2],
                                x[:B2], table, key, lr, **kw)
    pb, _ = sgns_fused_hbm_step(jax.tree.map(jnp.copy, params), c[:B2],
                                x[:B2], table, key, lr, sequential=True, **kw)
    assert not np.array_equal(np.asarray(pa["C"]), np.asarray(pb["C"]))


# ------------------------------------------------------------ engine wiring
def test_engine_fields_and_registry():
    eng = get_engine("pallas_fused_hbm")
    assert isinstance(eng, FusedHBMPallasEngine)
    assert eng.table_kind == "alias"
    assert eng.block_pairs == 256 and eng.sequential is False
    assert get_engine("pallas_fused_hbm", block_pairs=64).block_pairs == 64
    assert get_engine(eng, sequential=True).sequential is True
    with pytest.raises(ValueError, match="alias"):
        get_engine("pallas_fused_hbm:cdf")


@pytest.mark.slow
def test_engine_step_equals_kernel_entrypoint(cfg, world):
    params, c, x, table = world
    eng = get_engine("pallas_fused_hbm", block_pairs=32, interpret=True)
    step = eng.make_step(cfg, total_steps=1000)
    p1, l1 = step(jax.tree.map(jnp.copy, params), c, x, table,
                  jax.random.PRNGKey(3), jnp.int32(0))
    lr = sgns.linear_lr(jnp.int32(0), 1000, cfg)
    p2, l2 = sgns_fused_hbm_step(jax.tree.map(jnp.copy, params), c, x, table,
                                 jax.random.PRNGKey(3), lr, negatives=K,
                                 block_pairs=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(p1["W"]), np.asarray(p2["W"]))
    assert float(l1) == float(l2)


@pytest.mark.slow
def test_trainer_epoch_trains_with_hbm_engine():
    """AsyncShardTrainer (vmap backend, scan over steps) runs the HBM
    engine and the loss drops below the init plateau — the trainer-level
    wiring the driver and CLIs sit on."""
    from repro.core.async_trainer import AsyncShardTrainer

    cfg = SGNSConfig(vocab_size=150, dim=32, negatives=4)
    rng = np.random.default_rng(0)
    n, S, Bt = 2, 12, 64
    c = jnp.asarray(rng.integers(0, 30, (n, S, Bt)), jnp.int32)
    x = jnp.asarray((np.asarray(c) + 1) % 30, jnp.int32)
    counts = rng.zipf(1.3, cfg.vocab_size).astype(np.float64)
    table = jax.tree.map(lambda a: jnp.stack([a, a]),
                         build_noise_table(counts, kind="alias"))
    tr = AsyncShardTrainer(cfg=cfg, num_workers=n, total_steps=S,
                           engine=get_engine("pallas_fused_hbm",
                                             block_pairs=16))
    p = tr.init(jax.random.PRNGKey(0))
    p, losses = tr.epoch(p, c, x, table, jax.random.PRNGKey(4))
    assert np.isfinite(np.asarray(losses)).all()
    assert float(losses[:, -1].mean()) < (cfg.negatives + 1) * np.log(2)
