"""Pipelined HBM-blocked fused SGNS engine: block-planner invariants
(hypothesis property tests on adversarial pair streams), the static
pipeline schedule's ordering guarantees, and interpret-mode
bit-equivalence of ``pallas_fused_pipe`` against the per-block sparse
reference at a shape past the VMEM envelope (``slow`` marker, like the
unpipelined engine's equivalence tests).

The planner/schedule tests run entirely without Pallas — they are pure
functions of the pair stream — so they live in the tier-1 gate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sgns
from repro.core.engine import (
    FusedHBMPallasEngine, FusedPipePallasEngine, get_engine)
from repro.core.sgns import SGNSConfig
from repro.data.pairs import build_noise_table
from repro.kernels.sgns_fused import fused_negative_ids
from repro.kernels.sgns_fused_pipe import (
    NUM_SLOTS, kernel_schedule, plan_blocks, resolve_schedule,
    sgns_fused_pipe_step)

# Past the VMEM-resident kernel's envelope, like tests/test_fused_hbm.py:
# 2 tables × 34_000 × 64 × 4 B ≈ 17.4 MB > ~16 MB VMEM.
V_BIG, D_BIG = 34_000, 64
B, K = 64, 4


def _plan(centers, contexts, negs, V, blk, **kw):
    return plan_blocks(jnp.asarray(centers, jnp.int32),
                       jnp.asarray(contexts, jnp.int32),
                       jnp.asarray(negs, jnp.int32), V, blk, **kw)


def _np_plan(plan):
    return jax.tree.map(np.asarray, plan)


# --------------------------------------------------------------- planner
def test_planner_shapes_and_padding():
    rng = np.random.default_rng(0)
    V, blk, Bq, Kq = 50, 8, 19, 3          # 19 = 2 full blocks + tail 3
    p = _np_plan(_plan(rng.integers(0, V, Bq), rng.integers(0, V, Bq),
                       rng.integers(0, V, (Bq, Kq)), V, blk))
    assert p.uw.shape == (3, blk)
    assert p.uc.shape == (3, blk * (Kq + 1))
    assert p.mask.sum() == Bq
    assert (p.mask[-1] == [1, 1, 1] + [0] * 5).all()
    # padded unique slots hold V, real slots hold sorted ids < V
    for b in range(3):
        assert (p.uw[b, p.n_w[b]:] == V).all()
        assert (np.diff(p.uw[b, :p.n_w[b]]) > 0).all()


def test_planner_positions_recover_ids():
    rng = np.random.default_rng(1)
    V, blk, Bq, Kq = 40, 16, 32, 4
    c = rng.integers(0, V, Bq)
    x = rng.integers(0, V, Bq)
    n = rng.integers(0, V, (Bq, Kq))
    p = _np_plan(_plan(c, x, n, V, blk))
    for b in range(2):
        sl = slice(b * blk, (b + 1) * blk)
        np.testing.assert_array_equal(p.uw[b][p.w_pos[b]], c[sl])
        np.testing.assert_array_equal(p.uc[b][p.cp_pos[b]], x[sl])
        np.testing.assert_array_equal(
            p.uc[b][p.cn_pos[b]].reshape(blk, Kq), n[sl])


def test_planner_hazard_flags():
    V, blk = 100, 2
    c = np.array([1, 2, 3, 4, 1, 9], np.int32)   # block 2 reuses row 1...
    x = np.array([11, 12, 13, 14, 15, 16], np.int32)
    n = np.full((6, 1), 77, np.int32)            # every block shares neg 77
    p = _np_plan(_plan(c, x, n, V, blk))
    # C-table: row 77 written by every block ⇒ hazard for blocks 1, 2
    np.testing.assert_array_equal(p.hazard, [0, 1, 1])
    # consecutive blocks disjoint in both tables ⇒ no hazards (block 2
    # reusing block 0's center row 1 is covered by slot recycling)
    n2 = np.arange(6, dtype=np.int32).reshape(6, 1) + 50
    p2 = _np_plan(_plan(c, x, n2, V, blk))
    np.testing.assert_array_equal(p2.hazard, [0, 0, 0])


def test_planner_hazard_is_lookbehind_one_only():
    """Sharing a row with block b-2 (but not b-1) must NOT set the flag:
    the 2-slot ring's recycling wait already serializes against b-2."""
    V, blk = 100, 2
    c = np.array([1, 2, 30, 40, 1, 9], np.int32)  # blocks 0 and 2 share row 1
    x = np.array([11, 12, 13, 14, 15, 16], np.int32)
    n = np.arange(6, dtype=np.int32).reshape(6, 1) + 50
    p = _np_plan(_plan(c, x, n, V, blk))
    np.testing.assert_array_equal(p.hazard, [0, 0, 0])


def test_planner_hazard_window_grows_with_ring_depth():
    """A deeper ring leaves block b-2's write-backs in flight when block
    b gathers, so at ring_depth=3 the same b-2 overlap that slot
    recycling covered at depth 2 becomes a hazard — the look-behind
    window is exactly ring_depth - 1 blocks."""
    V, blk = 100, 2
    c = np.array([1, 2, 30, 40, 1, 9], np.int32)  # blocks 0 and 2 share row 1
    x = np.array([11, 12, 13, 14, 15, 16], np.int32)
    n = np.arange(6, dtype=np.int32).reshape(6, 1) + 50
    p3 = _np_plan(_plan(c, x, n, V, blk, ring_depth=3))
    np.testing.assert_array_equal(p3.hazard, [0, 0, 1])
    # at depth 3 a b-3 overlap is still recycled away, not flagged
    c4 = np.array([1, 2, 30, 40, 50, 60, 1, 9], np.int32)
    x4 = np.array([11, 12, 13, 14, 15, 16, 17, 18], np.int32)
    n4 = np.arange(8, dtype=np.int32).reshape(8, 1) + 50
    p4 = _np_plan(_plan(c4, x4, n4, V, blk, ring_depth=3))
    np.testing.assert_array_equal(p4.hazard, [0, 0, 0, 0])


# -------------------------------------------------------------- schedule
def _check_schedule(events, nblocks, row_sets, hazard, num_slots=NUM_SLOTS):
    """The three pipeline-safety properties on a concrete event order."""
    S = num_slots
    pos = {}
    for i, ev in enumerate(events):
        pos[ev] = i
    for b in range(nblocks):
        s = b % S
        # basic dataflow per block
        assert pos[("gather", b, s)] < pos[("wait_gather", b, s)]
        assert pos[("wait_gather", b, s)] < pos[("compute", b, s)]
        assert pos[("compute", b, s)] < pos[("scatter", b, s)]
        assert pos[("scatter", b, s)] < pos[("wait_scatter", b, s)]
        # no slot reuse before its semaphore wait: block b's gathers
        # overwrite block b-S's buffers, whose scatters read from them
        if b >= S:
            prev = (b - S, (b - S) % S)
            assert pos[("wait_scatter", *prev)] < pos[("gather", b, s)], \
                f"slot of block {b} reused before block {b - S}'s " \
                f"scatters drained"
        # scatter-before-regather: any earlier block writing a row this
        # block touches must have fully drained before this gather
        for b0 in range(b):
            if row_sets[b0] & row_sets[b]:
                assert pos[("wait_scatter", b0, b0 % S)] < \
                    pos[("gather", b, s)], \
                    f"block {b} gathers rows block {b0} still scatters"
    # every op happens exactly once per block
    assert len(events) == len(pos)
    from collections import Counter
    counts = Counter(op for op, _, _ in events)
    assert counts == {op: nblocks for op in
                      ("gather", "wait_gather", "compute", "scatter",
                       "wait_scatter")}


def test_schedule_static_structure():
    """For each per-block event, the guards over its occurrence sites
    PARTITION the hazard-outcome space: under every hazard vector the
    event resolves exactly once, so every DMA is started and waited
    exactly once no matter how the flags come out."""
    import itertools

    for S in (2, 3, 4):
        for nblocks in (1, 2, 3, 5):
            sites = {}
            for op, b, s, g in kernel_schedule(nblocks, S):
                sites.setdefault((op, b, s), []).append(g)
            for bits in itertools.product((False, True), repeat=nblocks):
                for key, guards in sites.items():
                    hits = sum(
                        1 for g in guards
                        if g is None or all(bits[f] is w for f, w in g))
                    assert hits == 1, (S, nblocks, key, bits, guards)


def test_schedule_rejects_degenerate_ring():
    with pytest.raises(ValueError, match="2 slots"):
        kernel_schedule(4, 1)


def test_schedule_resolves_safely_for_all_hazard_vectors():
    """Exhaustive over hazard outcomes at small nblocks and ring depths:
    every resolved event order keeps the dataflow/slot/once-each
    properties (hazard row-set interactions are exercised by the
    hypothesis test below)."""
    import itertools

    for S in (2, 3):
        for nblocks in (1, 2, 4, 5):
            for bits in itertools.product((0, 1), repeat=nblocks - 1):
                hz = (0,) + bits
                ev = resolve_schedule(hz, S)
                # row sets consistent with the hazard vector: hazard[b]=1
                # means block b shares block b-1's own row, else block b
                # is disjoint from every block in its look-behind window
                row_sets = [{(b, 0)} for b in range(nblocks)]
                for b in range(1, nblocks):
                    if hz[b]:
                        row_sets[b].add((b - 1, 0))
                _check_schedule(ev, nblocks, row_sets, hz, S)


# ----------------------------------------- invariants on adversarial streams
def _assert_planner_invariants(c, x, n, V, blk, ring_depth=NUM_SLOTS):
    """The pipeline-safety contract for one pair stream: dedup (every
    touched row gathered exactly once per block), exact windowed
    look-behind hazard flags (ring_depth - 1 blocks), and a resolved
    schedule whose event order respects slot recycling and
    scatter-before-regather for the stream's actual row sets."""
    p = _np_plan(_plan(c, x, n, V, blk, ring_depth=ring_depth))
    blk_eff = p.w_pos.shape[1]
    nblocks = p.uw.shape[0]

    w_sets, c_sets = [], []
    for b in range(nblocks):
        valid = p.mask[b].astype(bool)
        nv = int(valid.sum())
        cen = c[b * blk_eff:b * blk_eff + nv]
        ctx = x[b * blk_eff:b * blk_eff + nv]
        neg = n[b * blk_eff:b * blk_eff + nv]
        touched_w = set(cen.tolist())
        touched_c = set(ctx.tolist()) | set(neg.reshape(-1).tolist())
        # every touched row gathered exactly once per block (gather list
        # = the valid unique slots: strictly sorted ⇒ no duplicates)
        gw = p.uw[b, :p.n_w[b]]
        gc = p.uc[b, :p.n_c[b]]
        assert (np.diff(gw) > 0).all() and (np.diff(gc) > 0).all()
        # padded pairs only ever reference already-touched rows, so the
        # gather sets must cover and not exceed touched ∪ pad-source
        if valid.all():
            assert set(gw.tolist()) == touched_w
            assert set(gc.tolist()) == touched_c
        else:
            assert touched_w <= set(gw.tolist()) <= touched_w | {int(c[0])}
            assert touched_c <= set(gc.tolist()) <= (
                touched_c | {int(x[0])} | set(n[0].tolist()))
        w_sets.append(set(gw.tolist()))
        c_sets.append(set(gc.tolist()))

    # hazard flags are exactly the windowed look-behind intersections
    for b in range(nblocks):
        expect = any((w_sets[b] & w_sets[b - m]) or (c_sets[b] & c_sets[b - m])
                     for m in range(1, min(ring_depth, b + 1)))
        assert bool(p.hazard[b]) == expect, (b, p.hazard)

    # the resolved schedule keeps slot/hazard/dataflow safety for the
    # actual row sets of this stream (W and C live in separate buffers,
    # so the combined per-block "row set" tags rows by table)
    row_sets = [{("w", r) for r in w_sets[b]} | {("c", r) for r in c_sets[b]}
                for b in range(nblocks)]
    _check_schedule(resolve_schedule(p.hazard, ring_depth), nblocks,
                    row_sets, p.hazard, ring_depth)


def test_planner_invariants_on_seeded_adversarial_streams():
    """Deterministic sweep of the same invariants hypothesis fuzzes:
    tiny vocabularies (maximal row collisions), single-pair blocks,
    non-dividing batches, K=1..4."""
    rng = np.random.default_rng(42)
    cases = [(5, 7, 1, 1, 2), (5, 17, 2, 3, 2), (7, 40, 3, 16, 2),
             (60, 33, 4, 8, 3), (11, 24, 2, 5, 3), (31, 1, 1, 4, 4),
             (5, 17, 2, 3, 3)]
    for V, Bq, Kq, blk, rd in cases:
        for _ in range(8):
            _assert_planner_invariants(
                rng.integers(0, V, Bq).astype(np.int32),
                rng.integers(0, V, Bq).astype(np.int32),
                rng.integers(0, V, (Bq, Kq)).astype(np.int32), V, blk,
                ring_depth=rd)


try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(data=st.data(), V=st.integers(5, 60), Bq=st.integers(1, 40),
           Kq=st.integers(1, 4), blk=st.integers(1, 16),
           rd=st.integers(2, 4))
    def test_planner_invariants_on_adversarial_streams(data, V, Bq, Kq, blk,
                                                       rd):
        ids = st.integers(0, V - 1)
        c = np.array(data.draw(st.lists(ids, min_size=Bq, max_size=Bq)),
                     np.int32)
        x = np.array(data.draw(st.lists(ids, min_size=Bq, max_size=Bq)),
                     np.int32)
        n = np.array(data.draw(st.lists(
            st.lists(ids, min_size=Kq, max_size=Kq),
            min_size=Bq, max_size=Bq)), np.int32)
        _assert_planner_invariants(c, x, n, V, blk, ring_depth=rd)


# ------------------------------------------------------------- equivalence
@pytest.fixture(scope="module")
def cfg():
    return SGNSConfig(vocab_size=V_BIG, dim=D_BIG, negatives=K)


@pytest.fixture(scope="module")
def world(cfg):
    rng = np.random.default_rng(0)
    params = {
        "W": jnp.asarray(0.01 * rng.normal(size=(V_BIG, D_BIG)), jnp.float32),
        "C": jnp.asarray(0.01 * rng.normal(size=(V_BIG, D_BIG)), jnp.float32),
    }
    c = jnp.asarray(rng.integers(0, V_BIG, B, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, V_BIG, B, dtype=np.int32))
    # duplicates within a block: dedup + in-VMEM accumulation must match
    # the reference's duplicate-accumulating scatter-add bit for bit
    c = c.at[1].set(c[0])
    x = x.at[3].set(x[2])
    counts = rng.zipf(1.3, V_BIG).astype(np.float64)
    table = build_noise_table(counts, kind="alias")
    return params, c, x, table


def _sparse_blocked(params, c, x, ids, lr, blk):
    step = jax.jit(sgns.train_step_sparse)
    params = jax.tree.map(jnp.copy, params)
    for b0 in range(0, c.shape[0], blk):
        params, _ = step(params, c[b0:b0 + blk], x[b0:b0 + blk],
                         ids[b0:b0 + blk], lr)
    return params


@pytest.mark.slow
@pytest.mark.parametrize("blk,ring", [(16, 2), (40, 2), (16, 3)])
def test_pipe_bit_identical_to_per_block_sparse(cfg, world, blk, ring):
    """Past the VMEM envelope: the pipelined step ≡ the per-block sparse
    reference on the replayed negatives, bit for bit — including when
    the batch pads to a partial final block and at a deepened ring."""
    params, c, x, table = world
    key = jax.random.PRNGKey(11)
    lr = jnp.float32(0.025)
    ph, _ = sgns_fused_pipe_step(
        jax.tree.map(jnp.copy, params), c, x, table, key, lr,
        negatives=K, block_pairs=blk, ring_depth=ring, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), table["prob"],
                             table["alias"], (B, K))
    pr = _sparse_blocked(params, c, x, ids, lr, blk)
    np.testing.assert_array_equal(np.asarray(ph["W"]), np.asarray(pr["W"]))
    np.testing.assert_array_equal(np.asarray(ph["C"]), np.asarray(pr["C"]))


@pytest.mark.slow
def test_pipe_bit_identical_to_unpipelined_hbm_engine(cfg, world):
    """pallas_fused_pipe ≡ pallas_fused_hbm at the engine level: the DMA
    pipeline must not move a single bit relative to the serial chain."""
    params, c, x, table = world
    key = jax.random.PRNGKey(5)
    kw = dict(block_pairs=16, interpret=True)
    sp = get_engine("pallas_fused_pipe", **kw).make_step(cfg, 1000)
    sh = get_engine("pallas_fused_hbm", **kw).make_step(cfg, 1000)
    pp, lp = sp(jax.tree.map(jnp.copy, params), c, x, table, key, jnp.int32(2))
    ph, lh = sh(jax.tree.map(jnp.copy, params), c, x, table, key, jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(pp["W"]), np.asarray(ph["W"]))
    np.testing.assert_array_equal(np.asarray(pp["C"]), np.asarray(ph["C"]))
    assert float(lp) == pytest.approx(float(lh), rel=1e-6)


@pytest.mark.slow
def test_pipe_sequential_falls_back_to_per_pair_oracle(cfg, world):
    """sequential=True on the pipe engine runs the unpipelined per-pair
    kernel — bit-identical to the hbm engine's sequential path."""
    params, c, x, table = world
    B2 = 16
    key = jax.random.PRNGKey(23)
    pe = get_engine("pallas_fused_pipe", block_pairs=8, sequential=True,
                    interpret=True)
    he = get_engine("pallas_fused_hbm", block_pairs=8, sequential=True,
                    interpret=True)
    pp, _ = pe.make_step(cfg, 1000)(jax.tree.map(jnp.copy, params),
                                    c[:B2], x[:B2], table, key, jnp.int32(0))
    ph, _ = he.make_step(cfg, 1000)(jax.tree.map(jnp.copy, params),
                                    c[:B2], x[:B2], table, key, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(pp["W"]), np.asarray(ph["W"]))
    np.testing.assert_array_equal(np.asarray(pp["C"]), np.asarray(ph["C"]))


# ------------------------------------------------------------ engine wiring
def test_engine_fields_and_registry():
    eng = get_engine("pallas_fused_pipe")
    assert isinstance(eng, FusedPipePallasEngine)
    assert isinstance(eng, FusedHBMPallasEngine)    # inherits hbm fields
    assert eng.table_kind == "alias"
    assert eng.block_pairs == 256 and eng.sequential is False
    assert eng.ring_depth == 2
    assert get_engine("pallas_fused_pipe", block_pairs=64).block_pairs == 64
    assert get_engine("pallas_fused_pipe", ring_depth=3).ring_depth == 3
    with pytest.raises(ValueError, match="alias"):
        get_engine("pallas_fused_pipe:cdf")
    with pytest.raises(ValueError, match="ring_depth"):
        get_engine("pallas_fused_pipe", ring_depth=1)


def test_trainer_epoch_trains_with_pipe_engine():
    """AsyncShardTrainer (vmap backend, scan over steps) runs the
    pipelined engine end to end and the loss drops below the init
    plateau — the wiring the driver and CLIs sit on."""
    from repro.core.async_trainer import AsyncShardTrainer

    cfg = SGNSConfig(vocab_size=150, dim=32, negatives=4)
    rng = np.random.default_rng(0)
    n, S, Bt = 2, 12, 64
    c = jnp.asarray(rng.integers(0, 30, (n, S, Bt)), jnp.int32)
    x = jnp.asarray((np.asarray(c) + 1) % 30, jnp.int32)
    counts = rng.zipf(1.3, cfg.vocab_size).astype(np.float64)
    table = jax.tree.map(lambda a: jnp.stack([a, a]),
                         build_noise_table(counts, kind="alias"))
    tr = AsyncShardTrainer(cfg=cfg, num_workers=n, total_steps=S,
                           engine=get_engine("pallas_fused_pipe",
                                             block_pairs=16))
    p = tr.init(jax.random.PRNGKey(0))
    p, losses = tr.epoch(p, c, x, table, jax.random.PRNGKey(4))
    assert np.isfinite(np.asarray(losses)).all()
    assert float(losses[:, -1].mean()) < (cfg.negatives + 1) * np.log(2)
