"""Frequency-tiered hot/cold fused SGNS engine: tier-routing planner
invariants (every touched row served by exactly one tier, hot rows never
in the DMA lists, cold-side dedup/hazard contract intact — unit +
hypothesis property tests), engine wiring, and interpret-mode
bit-equivalence of ``pallas_fused_tiered`` against the sparse reference
and ``pallas_fused_hbm`` at a shape past the VMEM envelope, swept over
hot fractions {0, small, all} (``slow`` marker).

The planner tests run entirely without Pallas — they are pure functions
of the pair stream — so they live in the tier-1 gate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sgns
from repro.core.engine import (
    FusedPipePallasEngine, FusedTieredPallasEngine, get_engine)
from repro.core.sgns import SGNSConfig
from repro.data.pairs import build_noise_table
from repro.kernels.sgns_fused import fused_negative_ids
from repro.kernels.sgns_fused_pipe import plan_blocks, resolve_schedule
from repro.kernels.sgns_fused_tiered import sgns_fused_tiered_step

# Past the VMEM-resident kernel's envelope, like tests/test_fused_pipe.py:
# 2 tables × 34_000 × 64 × 4 B ≈ 17.4 MB > ~16 MB VMEM.
V_BIG, D_BIG = 34_000, 64
B, K = 64, 4
# hot fractions for the slow sweep: pure-pipe, a small non-aligned hot
# set, and pure-resident (hot_rows covers the whole vocab)
HOT_SWEEP = (0, 257, V_BIG)


def _plan(centers, contexts, negs, V, blk, **kw):
    return jax.tree.map(np.asarray, plan_blocks(
        jnp.asarray(centers, jnp.int32), jnp.asarray(contexts, jnp.int32),
        jnp.asarray(negs, jnp.int32), V, blk, **kw))


# ------------------------------------------------------- tier routing
def _assert_tier_routing_invariants(c, x, n, V, blk, hot, ring_depth=2):
    """The tiered-planner contract for one pair stream:

    * every touched row is served by exactly one tier — cold rows appear
      exactly once in their block's gather list, hot rows never do;
    * the cold-side dedup / position-map / windowed-hazard invariants of
      the pure pipeline hold over the cold rows alone;
    * the blocked id arrays (``cen``/``ctx``/``neg``) recover the input
      stream, so the kernel's direct hot indices are the true ids.
    """
    p = _plan(c, x, n, V, blk, hot_rows=hot, ring_depth=ring_depth)
    blk_eff = p.w_pos.shape[1]
    nblocks = p.uw.shape[0]
    Kq = n.shape[1]

    # blocked ids recover the (padded) stream
    flat_c = p.cen.reshape(-1)[:len(c)]
    flat_x = p.ctx.reshape(-1)[:len(x)]
    flat_n = p.neg.reshape(nblocks, blk_eff, Kq).reshape(-1, Kq)[:len(n)]
    np.testing.assert_array_equal(flat_c, c)
    np.testing.assert_array_equal(flat_x, x)
    np.testing.assert_array_equal(flat_n, n)

    w_sets, c_sets = [], []
    for b in range(nblocks):
        valid = p.mask[b].astype(bool)
        nv = int(valid.sum())
        cen = c[b * blk_eff:b * blk_eff + nv]
        ctx = x[b * blk_eff:b * blk_eff + nv]
        neg = n[b * blk_eff:b * blk_eff + nv]
        touched_w = set(cen.tolist())
        touched_c = set(ctx.tolist()) | set(neg.reshape(-1).tolist())
        cold_w = {r for r in touched_w if r >= hot}
        cold_c = {r for r in touched_c if r >= hot}
        gw = p.uw[b, :p.n_w[b]]
        gc = p.uc[b, :p.n_c[b]]
        # dedup: strictly sorted ⇒ each cold row exactly once
        assert (np.diff(gw) > 0).all() and (np.diff(gc) > 0).all()
        # hot rows NEVER enter the gather/scatter lists
        assert (gw >= hot).all() and (gc >= hot).all()
        # padding slots hold the V sentinel
        assert (p.uw[b, p.n_w[b]:] == V).all()
        assert (p.uc[b, p.n_c[b]:] == V).all()
        # exactly-once coverage: the cold gather set covers the block's
        # cold touched rows (plus at most the pad-source pair's cold
        # rows when the tail block is padded); hot rows are covered by
        # the id arrays checked above — (hot ∪ cold) is a partition of
        # touched because tier membership is a pure id predicate
        if valid.all():
            assert set(gw.tolist()) == cold_w
            assert set(gc.tolist()) == cold_c
        else:
            pad_w = {int(c[0])} if int(c[0]) >= hot else set()
            pad_c = {r for r in ({int(x[0])} | set(n[0].tolist()))
                     if r >= hot}
            assert cold_w <= set(gw.tolist()) <= cold_w | pad_w
            assert cold_c <= set(gc.tolist()) <= cold_c | pad_c
        # position maps: every pair element resolves either hot (id <
        # hot, not positioned in the buffer's valid region) or to the
        # buffer slot holding exactly its row
        pc = p.cen[b]
        for j in range(blk_eff):
            if pc[j] >= hot:
                assert p.uw[b][p.w_pos[b][j]] == pc[j]
            else:
                assert p.w_pos[b][j] >= p.n_w[b]   # masked pad slot
        px = p.ctx[b]
        for j in range(blk_eff):
            if px[j] >= hot:
                assert p.uc[b][p.cp_pos[b][j]] == px[j]
            else:
                assert p.cp_pos[b][j] >= p.n_c[b]
        pn = p.neg[b]
        for j in range(blk_eff * Kq):
            if pn[j] >= hot:
                assert p.uc[b][p.cn_pos[b][j]] == pn[j]
            else:
                assert p.cn_pos[b][j] >= p.n_c[b]
        w_sets.append(set(gw.tolist()))
        c_sets.append(set(gc.tolist()))

    # hazards are exactly the windowed intersections of COLD rows — a
    # hot row shared between adjacent blocks must not flag (it never
    # moves over DMA)
    for b in range(nblocks):
        expect = any((w_sets[b] & w_sets[b - m]) or (c_sets[b] & c_sets[b - m])
                     for m in range(1, min(ring_depth, b + 1)))
        assert bool(p.hazard[b]) == expect, (b, p.hazard)

    # the resolved schedule stays safe for the actual cold row sets
    # (tests/ is on sys.path under pytest's prepend import mode)
    from test_fused_pipe import _check_schedule
    row_sets = [{("w", r) for r in w_sets[b]} | {("c", r) for r in c_sets[b]}
                for b in range(nblocks)]
    _check_schedule(resolve_schedule(p.hazard, ring_depth), nblocks,
                    row_sets, p.hazard, ring_depth)


def test_tier_routing_drops_hot_rows_from_dma_lists():
    V, blk, hot = 50, 4, 10
    rng = np.random.default_rng(0)
    c = rng.integers(0, V, 16).astype(np.int32)
    x = rng.integers(0, V, 16).astype(np.int32)
    n = rng.integers(0, V, (16, 3)).astype(np.int32)
    _assert_tier_routing_invariants(c, x, n, V, blk, hot)


def test_tier_routing_hot_overlap_is_not_a_hazard():
    """Adjacent blocks sharing only a HOT row must not set the hazard
    flag: the row lives in VMEM for the whole step, no DMA to order."""
    V, blk, hot = 100, 2, 5
    c = np.array([1, 2, 1, 9], np.int32)      # blocks share hot row 1
    x = np.array([50, 51, 52, 53], np.int32)
    n = np.arange(4, dtype=np.int32).reshape(4, 1) + 60
    p = _plan(c, x, n, V, blk, hot_rows=hot)
    np.testing.assert_array_equal(p.hazard, [0, 0])
    # the same stream with the shared row COLD does flag
    p0 = _plan(c, x, n, V, blk, hot_rows=0)
    np.testing.assert_array_equal(p0.hazard, [0, 1])


def test_tier_routing_extremes_match_pipe_and_empty():
    """hot_rows=0 reproduces the pure-pipe plan exactly; hot_rows=V
    empties every gather list and clears every hazard."""
    V, blk = 30, 4
    rng = np.random.default_rng(3)
    c = rng.integers(0, V, 21).astype(np.int32)
    x = rng.integers(0, V, 21).astype(np.int32)
    n = rng.integers(0, V, (21, 2)).astype(np.int32)
    p0 = _plan(c, x, n, V, blk, hot_rows=0)
    pp = _plan(c, x, n, V, blk)
    for a, b in zip(p0, pp):
        np.testing.assert_array_equal(a, b)
    pv = _plan(c, x, n, V, blk, hot_rows=V)
    assert (pv.n_w == 0).all() and (pv.n_c == 0).all()
    assert (pv.uw == V).all() and (pv.uc == V).all()
    assert (pv.hazard == 0).all()


try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAS_HYPOTHESIS = False

if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(data=st.data(), V=st.integers(5, 60), Bq=st.integers(1, 40),
           Kq=st.integers(1, 4), blk=st.integers(1, 16),
           rd=st.integers(2, 4))
    def test_tier_routing_invariants_on_adversarial_streams(
            data, V, Bq, Kq, blk, rd):
        """For ANY pair stream and ANY hot set size: (hot ∪ cold)
        routing covers every touched row exactly once, the cold-side
        dedup/hazard invariants hold, hot rows never appear in the
        gather/scatter lists."""
        hot = data.draw(st.integers(0, V))
        ids = st.integers(0, V - 1)
        c = np.array(data.draw(st.lists(ids, min_size=Bq, max_size=Bq)),
                     np.int32)
        x = np.array(data.draw(st.lists(ids, min_size=Bq, max_size=Bq)),
                     np.int32)
        n = np.array(data.draw(st.lists(
            st.lists(ids, min_size=Kq, max_size=Kq),
            min_size=Bq, max_size=Bq)), np.int32)
        _assert_tier_routing_invariants(c, x, n, V, blk, hot, ring_depth=rd)


# ------------------------------------------------------------- equivalence
@pytest.fixture(scope="module")
def cfg():
    return SGNSConfig(vocab_size=V_BIG, dim=D_BIG, negatives=K)


@pytest.fixture(scope="module")
def world(cfg):
    rng = np.random.default_rng(0)
    params = {
        "W": jnp.asarray(0.01 * rng.normal(size=(V_BIG, D_BIG)), jnp.float32),
        "C": jnp.asarray(0.01 * rng.normal(size=(V_BIG, D_BIG)), jnp.float32),
    }
    # Zipfian center/context stream: the hot prefix is genuinely hot,
    # and duplicates within and across blocks exercise both tiers'
    # accumulation order
    c = jnp.asarray(np.minimum(rng.zipf(1.2, B) - 1, V_BIG - 1)
                    .astype(np.int32))
    x = jnp.asarray(np.minimum(rng.zipf(1.2, B) - 1, V_BIG - 1)
                    .astype(np.int32))
    c = c.at[1].set(c[0])
    x = x.at[3].set(x[2])
    counts = rng.zipf(1.3, V_BIG).astype(np.float64)
    table = build_noise_table(counts, kind="alias")
    return params, c, x, table


def _sparse_blocked(params, c, x, ids, lr, blk):
    step = jax.jit(sgns.train_step_sparse)
    params = jax.tree.map(jnp.copy, params)
    for b0 in range(0, c.shape[0], blk):
        params, _ = step(params, c[b0:b0 + blk], x[b0:b0 + blk],
                         ids[b0:b0 + blk], lr)
    return params


@pytest.mark.slow
@pytest.mark.parametrize("hot", HOT_SWEEP)
def test_tiered_bit_identical_to_per_block_sparse(cfg, world, hot):
    """Past the VMEM envelope: the tiered step ≡ the per-block sparse
    reference on the replayed negatives, bit for bit, at every hot
    fraction from pure-pipe to pure-resident."""
    params, c, x, table = world
    key = jax.random.PRNGKey(11)
    lr = jnp.float32(0.025)
    blk = 40                                   # non-dividing: padded tail
    pt, _ = sgns_fused_tiered_step(
        jax.tree.map(jnp.copy, params), c, x, table, key, lr,
        negatives=K, block_pairs=blk, hot_rows=hot, interpret=True)
    ids = fused_negative_ids(key.astype(jnp.uint32), table["prob"],
                             table["alias"], (B, K))
    pr = _sparse_blocked(params, c, x, ids, lr, blk)
    np.testing.assert_array_equal(np.asarray(pt["W"]), np.asarray(pr["W"]))
    np.testing.assert_array_equal(np.asarray(pt["C"]), np.asarray(pr["C"]))


@pytest.mark.slow
@pytest.mark.parametrize("hot,ring", [(0, 2), (257, 2), (257, 3),
                                      (V_BIG, 2)])
def test_tiered_bit_identical_to_unpipelined_hbm_engine(cfg, world, hot,
                                                        ring):
    """pallas_fused_tiered ≡ pallas_fused_hbm at the engine level: tier
    routing and ring depth must not move a single bit relative to the
    serial chain, at every hot fraction."""
    params, c, x, table = world
    key = jax.random.PRNGKey(5)
    st_t = get_engine("pallas_fused_tiered", block_pairs=16, hot_rows=hot,
                      ring_depth=ring, interpret=True).make_step(cfg, 1000)
    st_h = get_engine("pallas_fused_hbm", block_pairs=16,
                      interpret=True).make_step(cfg, 1000)
    pt, lt = st_t(jax.tree.map(jnp.copy, params), c, x, table, key,
                  jnp.int32(2))
    ph, lh = st_h(jax.tree.map(jnp.copy, params), c, x, table, key,
                  jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(pt["W"]), np.asarray(ph["W"]))
    np.testing.assert_array_equal(np.asarray(pt["C"]), np.asarray(ph["C"]))
    assert float(lt) == pytest.approx(float(lh), rel=1e-6)


# ------------------------------------------------------------ engine wiring
def test_engine_fields_and_registry():
    eng = get_engine("pallas_fused_tiered")
    assert isinstance(eng, FusedTieredPallasEngine)
    assert isinstance(eng, FusedPipePallasEngine)   # inherits the pipeline
    assert eng.table_kind == "alias"
    assert eng.hot_rows == 256 and eng.ring_depth == 2
    assert get_engine("pallas_fused_tiered", hot_rows=1024).hot_rows == 1024
    assert get_engine("pallas_fused_tiered", ring_depth=4).ring_depth == 4
    with pytest.raises(ValueError, match="alias"):
        get_engine("pallas_fused_tiered:cdf")
    with pytest.raises(ValueError, match="hot_rows"):
        get_engine("pallas_fused_tiered", hot_rows=-1)
    with pytest.raises(ValueError, match="ring_depth"):
        get_engine("pallas_fused_tiered", ring_depth=1)


def test_tiered_sequential_falls_back_to_per_pair_oracle():
    """sequential=True on the tiered engine runs the unpipelined
    per-pair kernel — bit-identical to the hbm engine's sequential
    path (tiers don't apply: per-pair order is inherently serial)."""
    cfg = SGNSConfig(vocab_size=120, dim=16, negatives=3)
    rng = np.random.default_rng(2)
    params = {"W": jnp.asarray(0.01 * rng.normal(size=(120, 16)), jnp.float32),
              "C": jnp.asarray(0.01 * rng.normal(size=(120, 16)), jnp.float32)}
    c = jnp.asarray(rng.integers(0, 120, 16, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, 120, 16, dtype=np.int32))
    table = build_noise_table(rng.zipf(1.3, 120).astype(np.float64),
                              kind="alias")
    key = jax.random.PRNGKey(23)
    te = get_engine("pallas_fused_tiered", block_pairs=8, sequential=True,
                    interpret=True)
    he = get_engine("pallas_fused_hbm", block_pairs=8, sequential=True,
                    interpret=True)
    pt, _ = te.make_step(cfg, 1000)(jax.tree.map(jnp.copy, params),
                                    c, x, table, key, jnp.int32(0))
    ph, _ = he.make_step(cfg, 1000)(jax.tree.map(jnp.copy, params),
                                    c, x, table, key, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(pt["W"]), np.asarray(ph["W"]))
    np.testing.assert_array_equal(np.asarray(pt["C"]), np.asarray(ph["C"]))


def test_trainer_epoch_trains_with_tiered_engine():
    """AsyncShardTrainer (vmap backend, scan over steps) runs the tiered
    engine end to end and the loss drops below the init plateau — the
    wiring the driver and CLIs sit on."""
    from repro.core.async_trainer import AsyncShardTrainer

    cfg = SGNSConfig(vocab_size=150, dim=32, negatives=4)
    rng = np.random.default_rng(0)
    n, S, Bt = 2, 12, 64
    c = jnp.asarray(rng.integers(0, 30, (n, S, Bt)), jnp.int32)
    x = jnp.asarray((np.asarray(c) + 1) % 30, jnp.int32)
    counts = rng.zipf(1.3, cfg.vocab_size).astype(np.float64)
    table = jax.tree.map(lambda a: jnp.stack([a, a]),
                         build_noise_table(counts, kind="alias"))
    tr = AsyncShardTrainer(cfg=cfg, num_workers=n, total_steps=S,
                           engine=get_engine("pallas_fused_tiered",
                                             block_pairs=16, hot_rows=8))
    p = tr.init(jax.random.PRNGKey(0))
    p, losses = tr.epoch(p, c, x, table, jax.random.PRNGKey(4))
    assert np.isfinite(np.asarray(losses)).all()
    assert float(losses[:, -1].mean()) < (cfg.negatives + 1) * np.log(2)
