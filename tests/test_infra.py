"""Infrastructure layers: checkpoint, optimizers, sharding rules,
HLO cost model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step_path
from repro.optim import get_optimizer
from repro.sharding.rules import abstract_mesh, param_spec, data_spec, cache_spec
from repro.launch.hlo_cost import (
    parse_module, analyze_hlo, shape_elems_bytes, HloCostModel)
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"w": jnp.arange(6.0).reshape(2, 3)},
            "b": [jnp.ones((4,)), {"c": jnp.zeros((2, 2), jnp.int32)}],
            "none": None}
    p = str(tmp_path / "ck.npz")
    save_checkpoint(p, tree, step=7, extra={"note": "x"})
    out, meta = load_checkpoint(p)
    assert meta["step"] == 7 and meta["note"] == "x"
    np.testing.assert_array_equal(out["a"]["w"], np.arange(6.0).reshape(2, 3))
    np.testing.assert_array_equal(out["b"][0], np.ones(4))
    assert out["b"][1]["c"].dtype == np.int32
    assert out["none"] is None


def test_latest_step_path(tmp_path):
    for s in (10, 200, 30):
        save_checkpoint(str(tmp_path / f"step_{s}.npz"), {"x": jnp.ones(1)},
                        step=s)
    assert latest_step_path(str(tmp_path)).endswith("step_200.npz")


# --------------------------------------------------------------- optimizers
@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    opt = get_optimizer(name, lr=0.1 if name != "adamw" else 0.05)
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] + p["b"][None] - target) ** 2) / 8.0

    loss0 = float(loss_fn(params))
    for i in range(150):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params, jnp.int32(i))
    assert float(loss_fn(params)) < loss0 * 0.1, name


def test_adafactor_state_is_factored():
    opt = get_optimizer("adafactor")
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st["w"]["vr"].shape == (64,)
    assert st["w"]["vc"].shape == (32,)
    assert st["b"]["v"].shape == (32,)


# ------------------------------------------------------------ sharding rules
@pytest.fixture(scope="module")
def mesh16():
    # single real device is fine: specs are pure functions of axis sizes,
    # but Mesh wants real devices — use an abstract mesh instead.
    return abstract_mesh((16, 16), ("data", "model"))


def test_param_spec_rules(mesh16):
    assert param_spec(("embed",), (128256, 4096), mesh16) == P("model", "data")
    assert param_spec(("stack", "cycle", "0", "attn", "wq"),
                      (32, 4096, 4096), mesh16) == P(None, "data", "model")
    # non-divisible axes drop to replication: 15 heads → 960 still divides
    assert param_spec(("attn", "wq"), (960, 960), mesh16) == P("data", "model")
    # truly non-divisible: replicate that axis
    assert param_spec(("attn", "wk"), (960, 28 * 11), mesh16) == P("data", None)
    # expert params: expert-parallel
    assert param_spec(("ffn", "gate"), (128, 2048, 768), mesh16) == \
        P("model", "data", None)
    # tiny 1-D params replicate
    assert param_spec(("norm",), (1024,), mesh16) == P(None)
    # optimizer state mirrors its parameter
    assert param_spec(("m", "stack", "cycle", "0", "ffn", "down"),
                      (32, 14336, 4096), mesh16) == P(None, "model", "data")


def test_data_and_cache_specs(mesh16):
    assert data_spec((256, 4096), mesh16) == P(("data",), None)
    assert data_spec((1, 128), mesh16) == P(None, None)   # batch 1: replicate
    # KV cache: batch over data, heads over model when divisible
    assert cache_spec((128, 32768, 16, 128), mesh16)[0] in ("data", ("data",))
    # batch-1 long-context cache: shard the sequence dim
    spec = cache_spec((1, 524288, 8, 128), mesh16)
    assert spec[1] == "data"


def test_multipod_batch_axes():
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert data_spec((256, 4096), mesh) == P(("pod", "data"), None)


# ------------------------------------------------------------- hlo cost model
SYNTH_HLO = """
HloModule test

%body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %y = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[64,64]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[64,64])) -> pred[] {
  %p = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%zero, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_cost_counts_loop_trips():
    cost = analyze_hlo(SYNTH_HLO)
    # 7 iterations × 2·64³ dot flops
    assert cost.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)


def test_shape_bytes():
    assert shape_elems_bytes("bf16[4,8]{1,0}") == (32, 64)
    assert shape_elems_bytes("(f32[2], s32[3])") == (5, 20)


def test_parse_module_finds_computations():
    comps = parse_module(SYNTH_HLO)
    assert set(comps) >= {"body", "cond", "main"}
    assert any(o.opcode == "while" for o in comps["main"].ops)
