"""Pallas kernel ⇔ pure-jnp oracle allclose, swept over shapes/dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import sgns
from repro.kernels import ops, ref
from repro.kernels.sgns_update import _pick_block_b


def _rand(key, B, K, D, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (B, D), dtype) * 0.3
    cp = jax.random.normal(k2, (B, D), dtype) * 0.3
    cn = jax.random.normal(k3, (B, K, D), dtype) * 0.3
    return w, cp, cn


@pytest.mark.parametrize("B", [8, 64, 100])
@pytest.mark.parametrize("K", [1, 5])
@pytest.mark.parametrize("D", [128, 500])  # 500 = the paper's dim (padded inside)
def test_kernel_matches_ref_shapes(B, K, D):
    w, cp, cn = _rand(jax.random.PRNGKey(B * 1000 + K * 10 + D), B, K, D,
                      jnp.float32)
    loss_k, dw_k, dcp_k, dcn_k = ops.sgns_row_grads(w, cp, cn, interpret=True)
    loss_r, dw_r, dcp_r, dcn_r = ref.sgns_row_grads_ref(w, cp, cn)
    np.testing.assert_allclose(loss_k, jnp.mean(loss_r), rtol=1e-5)
    np.testing.assert_allclose(dw_k, dw_r, atol=1e-5)
    np.testing.assert_allclose(dcp_k, dcp_r, atol=1e-5)
    np.testing.assert_allclose(dcn_k, dcn_r, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    w, cp, cn = _rand(jax.random.PRNGKey(0), 32, 5, 128, dtype)
    loss_k, dw_k, dcp_k, dcn_k = ops.sgns_row_grads(w, cp, cn, interpret=True)
    loss_r, dw_r, dcp_r, dcn_r = ref.sgns_row_grads_ref(w, cp, cn)
    assert dw_k.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(loss_k, jnp.mean(loss_r), rtol=tol)
    np.testing.assert_allclose(np.asarray(dw_k, np.float32),
                               np.asarray(dw_r, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(dcn_k, np.float32),
                               np.asarray(dcn_r, np.float32), atol=tol)


def test_kernel_matches_autodiff():
    """Oracle itself must equal autodiff of the sum loss."""
    cfg = sgns.SGNSConfig(vocab_size=50, dim=128, negatives=3)
    p = sgns.init_params(jax.random.PRNGKey(0), cfg)
    p = {"W": p["W"], "C": 0.05 * jax.random.normal(jax.random.PRNGKey(1),
                                                    p["C"].shape)}
    B = 16
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, 50, B, dtype=np.int32))
    x = jnp.asarray(rng.integers(0, 50, B, dtype=np.int32))
    n = jnp.asarray(rng.integers(0, 50, (B, 3), dtype=np.int32))
    lr = jnp.float32(0.07)
    p_dense, _ = sgns.train_step_dense(jax.tree.map(jnp.copy, p), c, x, n, lr)
    p_kern, _ = ops.sgns_apply_step(jax.tree.map(jnp.copy, p), c, x, n, lr,
                                    interpret=True)
    np.testing.assert_allclose(p_dense["W"], p_kern["W"], atol=1e-5)
    np.testing.assert_allclose(p_dense["C"], p_kern["C"], atol=1e-5)


def test_kernel_plugs_into_trainer():
    """AsyncShardTrainer with the `pallas` engine trains identically to
    the `sparse` reference engine."""
    from repro.core.async_trainer import AsyncShardTrainer
    cfg = sgns.SGNSConfig(vocab_size=64, dim=128, negatives=2)
    tr_ref = AsyncShardTrainer(cfg=cfg, num_workers=2, total_steps=4)
    tr_k = AsyncShardTrainer(cfg=cfg, num_workers=2, total_steps=4,
                             engine="pallas")
    params = tr_ref.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.integers(0, 64, (2, 4, 16), dtype=np.int32))
    x = jnp.asarray(rng.integers(0, 64, (2, 4, 16), dtype=np.int32))
    cdf = jnp.tile(jnp.linspace(0, 1, 64, dtype=jnp.float32)[None], (2, 1))
    key = jax.random.PRNGKey(5)
    p1, l1 = tr_ref.epoch(jax.tree.map(jnp.copy, params), c, x, cdf, key)
    p2, l2 = tr_k.epoch(jax.tree.map(jnp.copy, params), c, x, cdf, key)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
    np.testing.assert_allclose(p1["W"], p2["W"], atol=1e-5)


def test_block_picker_fits_budget():
    for K in (1, 5, 20):
        for D in (128, 512, 1024):
            bt = _pick_block_b(4096, K, D)
            assert bt >= 8
            assert (4 + 2 * K) * D * 4 * 2 * bt <= 16 * 2**20


@pytest.mark.parametrize("B", [1, 6, 12, 100, 384, 1000, 4096])
def test_block_picker_divides_batch(B):
    """A non-pow2 B must yield a block that divides B (and stays pow2 for
    pow2-divisible batches), so the kernel's divisibility check can't
    fail on the picker's own choice."""
    for K in (1, 5):
        for D in (128, 512):
            bt = _pick_block_b(B, K, D)
            assert bt >= 1
            assert B % bt == 0, (B, bt)
            assert bt & (bt - 1) == 0 or bt == B  # pow2 unless B itself
            assert (4 + 2 * K) * D * 4 * 2 * bt <= 16 * 2**20


def test_kernel_direct_call_with_picked_block_non_pow2():
    """sgns_row_grads_kernel with the default (picked) block accepts a
    non-pow2 B — the regression the divisor clamp fixes."""
    from repro.kernels.sgns_update import sgns_row_grads_kernel
    B, K, D = 100, 2, 128
    w, cp, cn = _rand(jax.random.PRNGKey(0), B, K, D, jnp.float32)
    loss, dw, dcp, dcn = sgns_row_grads_kernel(w, cp, cn, interpret=True)
    _, dw_r, _, _ = ref.sgns_row_grads_ref(w, cp, cn)
    np.testing.assert_allclose(dw, dw_r, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 70),
    K=st.integers(1, 8),
    D=st.sampled_from([64, 128, 200, 384]),
    seed=st.integers(0, 2**30),
)
def test_kernel_matches_ref_property(B, K, D, seed):
    """Property: arbitrary (B, K, D) incl. non-aligned — wrapper pads."""
    w, cp, cn = _rand(jax.random.PRNGKey(seed), B, K, D, jnp.float32)
    loss_k, dw_k, dcp_k, dcn_k = ops.sgns_row_grads(w, cp, cn, interpret=True)
    loss_r, dw_r, dcp_r, dcn_r = ref.sgns_row_grads_ref(w, cp, cn)
    np.testing.assert_allclose(loss_k, jnp.mean(loss_r), rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(dw_k, dw_r, atol=2e-5)
    np.testing.assert_allclose(dcp_k, dcp_r, atol=2e-5)
    np.testing.assert_allclose(dcn_k, dcn_r, atol=2e-5)


# ---------------------------------------------------------------------------
# swa_decode: flash-style sliding-window decode kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,W,H,D,chunk", [
    (2, 256, 4, 64, 64),
    (1, 512, 8, 128, 128),
    (3, 128, 2, 32, 32),
    (2, 256, 4, 64, 256),   # single chunk = whole window
])
def test_swa_decode_matches_ref(B, W, H, D, chunk):
    from repro.kernels.swa_decode import swa_decode_kernel
    ks = jax.random.split(jax.random.PRNGKey(B * W + chunk), 3)
    q = jax.random.normal(ks[0], (B, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, W, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, W, H, D)) * 0.5
    out_k = swa_decode_kernel(q, k, v, chunk=chunk, interpret=True)
    out_r = ref.swa_decode_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_decode_dtypes(dtype):
    from repro.kernels.swa_decode import swa_decode_kernel
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = (jax.random.normal(ks[0], (2, 4, 64)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (2, 128, 4, 64)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (2, 128, 4, 64)) * 0.5).astype(dtype)
    out_k = swa_decode_kernel(q, k, v, chunk=64, interpret=True)
    out_r = ref.swa_decode_ref(q, k, v)
    assert out_k.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol)


def test_swa_decode_online_softmax_stability():
    """Large score magnitudes: the online max-shift must stay finite."""
    from repro.kernels.swa_decode import swa_decode_kernel
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 32)) * 20.0
    k = jax.random.normal(ks[1], (1, 128, 2, 32)) * 20.0
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    out = swa_decode_kernel(q, k, v, chunk=32, interpret=True)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.swa_decode_ref(q, k, v)),
                               atol=1e-4)
