"""Launcher integration: train.py / serve.py / train_sgns.py CLIs and
grouped-MoE semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_launcher_reduces_loss(tmp_path):
    from repro.launch.train import train
    params, losses = train("qwen1.5-0.5b", reduced=True, steps=25, batch=4,
                           seq=48, lr=3e-3, ckpt_dir=str(tmp_path),
                           ckpt_every=20)
    assert losses[-1] < losses[0]
    from repro.checkpoint import latest_step_path, load_checkpoint
    path = latest_step_path(str(tmp_path))
    assert path is not None
    tree, meta = load_checkpoint(path)
    assert meta["step"] == 25
    assert "params" in tree and "opt" in tree


def test_train_launcher_resume(tmp_path):
    from repro.launch.train import train
    train("smollm-360m", reduced=True, steps=10, batch=2, seq=32, lr=1e-3,
          ckpt_dir=str(tmp_path), ckpt_every=100)
    params, losses = train("smollm-360m", reduced=True, steps=5, batch=2,
                           seq=32, lr=1e-3, ckpt_dir=str(tmp_path),
                           ckpt_every=100, resume=True)
    assert len(losses) > 0 and np.isfinite(losses).all()


def test_decode_llm_launcher_generates():
    from repro.launch.decode_llm import serve
    gen, stats = serve("qwen1.5-0.5b", reduced=True, batch=2, prompt_len=6,
                       new_tokens=8)
    assert gen.shape == (2, 8)
    assert stats["tok_per_s"] > 0
    cfg_vocab = 512
    assert int(jnp.max(gen)) < cfg_vocab


def test_train_sgns_cli(capsys):
    from repro.launch.train_sgns import main
    main(["--strategy", "shuffle", "--workers", "3", "--epochs", "2",
          "--dim", "32", "--vocab", "600", "--sentences", "4000",
          "--merge", "alir_pca"])
    out = capsys.readouterr().out
    assert "alir_pca" in out and "sim=" in out


def test_train_sgns_publish_then_serve_cli(tmp_path, capsys):
    """The full production loop at CLI granularity: train with --publish,
    then answer queries (merged and sub-model space) with the serve
    launcher against the artifact directory."""
    from repro.launch.serve import main as serve_main
    from repro.launch.train_sgns import main as train_main
    art = str(tmp_path / "artifacts")
    train_main(["--strategy", "random", "--workers", "2", "--epochs", "1",
                "--dim", "16", "--vocab", "400", "--sentences", "3000",
                "--merge", "concat", "--publish", art])
    out = capsys.readouterr().out
    assert "published 2 incremental table version(s)" in out

    serve_main(["--artifact", art, "--query", "1,2,3,999999"])
    out = capsys.readouterr().out
    assert "artifact v2" in out and "space=merged" in out
    assert "[OOV]" in out                     # 999999 is out of vocab
    assert "stats:" in out

    serve_main(["--artifact", art, "--query", "1,2", "--submodel", "0",
                "--version", "1"])
    out = capsys.readouterr().out
    assert "artifact v1" in out and "space=submodel 0" in out


def test_grouped_moe_matches_ungrouped_with_ample_capacity():
    """With capacity high enough that nothing drops, grouping only
    changes dispatch order — outputs must match the ungrouped form."""
    from repro.models import moe as moe_mod
    key = jax.random.PRNGKey(0)
    E, k, d, f = 8, 2, 32, 64
    p = moe_mod.init_moe(key, d, f, E, k, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d)) * 0.5
    y1, aux1 = moe_mod.moe_forward(p, x, num_experts=E, top_k=k,
                                   capacity_factor=8.0, groups=1)
    y4, aux4 = moe_mod.moe_forward(p, x, num_experts=E, top_k=k,
                                   capacity_factor=8.0, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux4), rtol=1e-5)


def test_grouped_moe_capacity_is_per_group():
    """Capacity binds per group: with tiny capacity, each group drops its
    own overflow (outputs differ from ungrouped — by design, GShard)."""
    from repro.models import moe as moe_mod
    key = jax.random.PRNGKey(0)
    E, k, d, f = 4, 1, 16, 32
    p = moe_mod.init_moe(key, d, f, E, k, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, d))
    y, _ = moe_mod.moe_forward(p, x, num_experts=E, top_k=k,
                               capacity_factor=0.5, groups=4)
    assert np.isfinite(np.asarray(y)).all()
