"""Merge phase: Concat / PCA / ALiR — alignment, OOV reconstruction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import merge as mg


def make_rotated_models(V=120, d=12, n=4, miss_frac=0.0, noise=0.0, seed=0):
    """Sub-models = ground truth under random orthogonal maps (+noise),
    with randomly missing rows. This is exactly ALiR's data model."""
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        M = Y @ q.astype(np.float32) + noise * rng.normal(size=(V, d)).astype(np.float32)
        # model 0 keeps everything so the union always covers the vocab
        mask = np.ones(V, bool) if i == 0 else (rng.random(V) >= miss_frac)
        mask[: d + 2] = True  # keep enough shared rows to anchor alignment
        M[~mask] = 0.0
        models.append(M.astype(np.float32))
        masks.append(mask)
    return Y, mg.stack_models(models, masks)


def procrustes_distance(A, B):
    """Residual after optimally rotating A onto B, normalized."""
    W = np.asarray(mg.orthogonal_procrustes(jnp.asarray(A), jnp.asarray(B)))
    return float(np.linalg.norm(A @ W - B) / np.linalg.norm(B))


def test_procrustes_is_orthogonal_and_exact():
    rng = np.random.default_rng(1)
    A = rng.normal(size=(50, 8)).astype(np.float32)
    q, _ = np.linalg.qr(rng.normal(size=(8, 8)))
    B = A @ q.astype(np.float32)
    W = np.asarray(mg.orthogonal_procrustes(jnp.asarray(A), jnp.asarray(B)))
    np.testing.assert_allclose(W.T @ W, np.eye(8), atol=1e-4)
    np.testing.assert_allclose(A @ W, B, atol=1e-4)


def test_alir_recovers_consensus_full_vocab():
    Y, stacked = make_rotated_models(miss_frac=0.0, noise=0.01)
    out, valid, disps = mg.merge_alir(stacked, init="random", max_iters=12)
    assert bool(valid.all())
    assert procrustes_distance(np.asarray(out), Y) < 0.05
    # displacement decreases over iterations
    d = np.asarray(disps)
    assert d[-1] <= d[0]


def test_alir_reconstructs_missing_rows():
    Y, stacked = make_rotated_models(V=150, n=5, miss_frac=0.3, noise=0.005, seed=3)
    out, valid, _ = mg.merge_alir(stacked, init="pca", max_iters=15)
    assert bool(valid.all())  # union covers everything by construction
    # consensus close to truth up to rotation
    assert procrustes_distance(np.asarray(out), Y) < 0.08
    # per-model reconstruction of missing rows lands near truth-in-model-space
    completed = np.asarray(mg.reconstruct_missing(stacked, jnp.asarray(out)))
    mask = np.asarray(stacked.mask)
    for i in range(stacked.n):
        missing = ~mask[i]
        if missing.sum() == 0:
            continue
        # the completed missing rows, mapped to consensus space, match Y
        err = procrustes_distance(completed[i], np.asarray(out))
        assert err < 0.1, (i, err)


def test_alir_trace_frozen_after_convergence():
    """Regression: the scan kept recomputing (and mutating) the reported
    displacement after ``done``, so the trace misreported the converged
    error. Once the tol criterion fires, every later trace entry must be
    exactly the converged displacement."""
    # noise-free rotated models converge in a couple of iterations
    _, stacked = make_rotated_models(V=80, d=8, n=3, noise=0.0, seed=7)
    tol = 1e-4
    _, _, disps = mg.merge_alir(stacked, init="random", max_iters=20, tol=tol)
    d = np.asarray(disps)
    deltas = np.abs(np.diff(d, prepend=np.inf))
    conv = int(np.argmax(deltas < tol))         # first converged iteration
    assert deltas[conv] < tol                   # it did converge in budget
    np.testing.assert_array_equal(d[conv:], np.full(len(d) - conv, d[conv]))


def test_alir_converged_result_unchanged_by_extra_iterations():
    """Freezing must not change the merge result: Y after max_iters=6 and
    max_iters=20 is identical once converged before iteration 6."""
    _, stacked = make_rotated_models(V=80, d=8, n=3, noise=0.0, seed=7)
    key = jax.random.PRNGKey(1)
    y1, _, d1 = mg.merge_alir(stacked, init="random", max_iters=6, key=key)
    y2, _, d2 = mg.merge_alir(stacked, init="random", max_iters=20, key=key)
    assert np.abs(np.diff(np.asarray(d1))).min() < 1e-4  # converged in 6
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_reconstruct_missing_roundtrip_recovers_true_rows():
    """Paper §4.5 robustness claim: embed a known rotation per sub-model,
    mask rows out, and the per-model reconstruction from the consensus
    must recover the held-out rows (which were never seen by the merge)."""
    rng = np.random.default_rng(11)
    V, d, n = 140, 10, 4
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks, truth = [], [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        M_true = (Y @ q).astype(np.float32)
        mask = np.ones(V, bool) if i == 0 else rng.random(V) >= 0.35
        mask[: d + 2] = True
        M = M_true.copy()
        M[~mask] = 7.7          # garbage where absent: must not leak in
        models.append(M)
        masks.append(mask)
        truth.append(M_true)
    stacked = mg.stack_models(models, masks)
    rec = np.asarray(mg.reconstruct_missing(stacked, jnp.asarray(Y)))
    for i in range(n):
        missing = ~masks[i]
        if not missing.any():
            continue
        # held-out rows recovered in the sub-model's own rotated space
        err = np.abs(rec[i][missing] - truth[i][missing]).max()
        assert err < 1e-3, (i, err)
        # present rows pass through untouched
        np.testing.assert_array_equal(rec[i][~missing], models[i][~missing])


def test_average_fails_without_alignment_alir_does_not():
    """Paper §3.3.1 counter-example: sub-models differing by a rotation.

    Element-wise averaging destroys neighborhood structure; ALiR keeps it.
    """
    Y, stacked = make_rotated_models(V=100, n=3, noise=0.0, seed=5)

    def neighbor_overlap(emb):
        e = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)
        g = Y / np.linalg.norm(Y, axis=1, keepdims=True)
        sim_e, sim_g = e @ e.T, g @ g.T
        np.fill_diagonal(sim_e, -np.inf)
        np.fill_diagonal(sim_g, -np.inf)
        return float((sim_e.argmax(1) == sim_g.argmax(1)).mean())

    avg, _ = mg.merge_average(stacked)
    alir, _, _ = mg.merge_alir(stacked, init="random", max_iters=12)
    assert neighbor_overlap(np.asarray(alir)) > neighbor_overlap(np.asarray(avg)) + 0.2


def test_concat_dims_and_intersection():
    _, stacked = make_rotated_models(V=80, d=8, n=3, miss_frac=0.2, seed=7)
    emb, valid = mg.merge_concat(stacked)
    assert emb.shape == (80, 3 * 8)
    inter = np.asarray(stacked.mask).all(0)
    np.testing.assert_array_equal(np.asarray(valid), inter)
    assert np.all(np.asarray(emb)[~inter] == 0)


def test_pca_shape_and_variance_order():
    _, stacked = make_rotated_models(V=200, d=10, n=4, seed=9)
    emb, valid = mg.merge_pca(stacked, out_dim=10)
    assert emb.shape == (200, 10)
    e = np.asarray(emb)[np.asarray(valid)]
    var = e.var(axis=0)
    assert np.all(var[:-1] >= var[1:] - 1e-5)  # descending components


def test_incremental_add_validations():
    _, stacked = make_rotated_models(V=60, d=6, n=3)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    m = mg.IncrementalAlirMerger()
    m.add(0, models[0], masks[0])
    with pytest.raises(ValueError, match="already folded"):
        m.add(0, models[1], masks[1])
    with pytest.raises(ValueError, match="shape"):
        m.add(1, models[1][:, :3], masks[1])
    with pytest.raises(ValueError, match="mask"):
        m.add(1, models[1], masks[1][:10])
    assert m.worker_ids == (0,) and m.n_folded == 1


def test_incremental_cold_fold_bitwise_matches_batch():
    """fold(warm=False) after all arrivals must reproduce the batch
    merge_alir bit-for-bit, regardless of arrival order (the canonical
    worker-id restacking). Exhaustive permutations are property-tested
    in test_property.py; these are fixed representative orders."""
    _, stacked = make_rotated_models(V=80, d=8, n=4, miss_frac=0.2, seed=2)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    Yb, validb, _ = mg.merge_alir(stacked)
    for order in ((0, 1, 2, 3), (3, 1, 0, 2), (2, 3, 1, 0)):
        m = mg.IncrementalAlirMerger()
        for w in order:
            m.add(w, models[w], masks[w])
        final = m.fold(warm=False)
        assert final.worker_ids == (0, 1, 2, 3)
        np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(Yb))
        np.testing.assert_array_equal(np.asarray(final.valid),
                                      np.asarray(validb))


def test_incremental_warm_folds_match_batch_up_to_rotation():
    """Warm intermediate folds inherit their gauge from the arrival
    history: the documented tolerance vs the batch merge is a small
    residual after optimal rotation, not element-wise equality."""
    _, stacked = make_rotated_models(V=100, d=8, n=4, miss_frac=0.2,
                                     noise=0.005, seed=6)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    m = mg.IncrementalAlirMerger()
    folds = [m.add(w, models[w], masks[w]) for w in range(4)]
    # coverage grows monotonically with arrivals
    counts = [int(np.asarray(f.valid).sum()) for f in folds]
    assert counts == sorted(counts) and counts[-1] == 100
    Yb, validb, _ = mg.merge_alir(stacked)
    v = np.asarray(validb)
    warm = np.asarray(folds[-1].Y)
    assert procrustes_distance(warm[v], np.asarray(Yb)[v]) < 0.05


def test_incremental_early_fold_is_servable_for_its_coverage():
    _, stacked = make_rotated_models(V=80, d=8, n=3, miss_frac=0.4, seed=9)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    m = mg.IncrementalAlirMerger()
    first = m.add(1, models[1], masks[1])        # any worker can be first
    np.testing.assert_array_equal(np.asarray(first.valid), masks[1])
    Y = np.asarray(first.Y)
    assert np.isfinite(Y).all() and np.all(Y[~masks[1]] == 0)


def test_merge_dispatch_all_methods():
    _, stacked = make_rotated_models(V=60, d=6, n=3, miss_frac=0.1, seed=11)
    for m in mg.MERGE_METHODS:
        emb, valid = mg.merge(stacked, m, out_dim=6, key=jax.random.PRNGKey(0))
        assert emb.shape[0] == 60
        assert np.isfinite(np.asarray(emb)).all(), m
