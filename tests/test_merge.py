"""Merge phase: the Merger registry, Concat / PCA / ALiR — alignment,
OOV reconstruction, sharded Gram accumulation, deprecated shims."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import merge as mg


def make_rotated_models(V=120, d=12, n=4, miss_frac=0.0, noise=0.0, seed=0):
    """Sub-models = ground truth under random orthogonal maps (+noise),
    with randomly missing rows. This is exactly ALiR's data model."""
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        M = Y @ q.astype(np.float32) + noise * rng.normal(size=(V, d)).astype(np.float32)
        # model 0 keeps everything so the union always covers the vocab
        mask = np.ones(V, bool) if i == 0 else (rng.random(V) >= miss_frac)
        mask[: d + 2] = True  # keep enough shared rows to anchor alignment
        M[~mask] = 0.0
        models.append(M.astype(np.float32))
        masks.append(mask)
    return Y, mg.stack_models(models, masks)


def procrustes_distance(A, B):
    """Residual after optimally rotating A onto B, normalized."""
    W = np.asarray(mg.orthogonal_procrustes(jnp.asarray(A), jnp.asarray(B)))
    return float(np.linalg.norm(A @ W - B) / np.linalg.norm(B))


def alir_merge(stacked, *, init="pca", max_iters=10, tol=1e-4, key=None,
               shard=1):
    """Registry-path batch ALiR returning the legacy (Y, valid, disps)
    triple (what the deprecated merge_alir shim used to return)."""
    m = mg.AlirMerger(mg.MergeConfig(init=init, max_iters=max_iters,
                                     tol=tol, shard=shard), key=key)
    r = m.merge(stacked)
    return r.emb, r.valid, r.disps


def test_procrustes_is_orthogonal_and_exact():
    rng = np.random.default_rng(1)
    A = rng.normal(size=(50, 8)).astype(np.float32)
    q, _ = np.linalg.qr(rng.normal(size=(8, 8)))
    B = A @ q.astype(np.float32)
    W = np.asarray(mg.orthogonal_procrustes(jnp.asarray(A), jnp.asarray(B)))
    np.testing.assert_allclose(W.T @ W, np.eye(8), atol=1e-4)
    np.testing.assert_allclose(A @ W, B, atol=1e-4)


def test_alir_recovers_consensus_full_vocab():
    Y, stacked = make_rotated_models(miss_frac=0.0, noise=0.01)
    out, valid, disps = alir_merge(stacked, init="random", max_iters=12)
    assert bool(valid.all())
    assert procrustes_distance(np.asarray(out), Y) < 0.05
    # displacement decreases over iterations
    d = np.asarray(disps)
    assert d[-1] <= d[0]


def test_alir_reconstructs_missing_rows():
    Y, stacked = make_rotated_models(V=150, n=5, miss_frac=0.3, noise=0.005, seed=3)
    out, valid, _ = alir_merge(stacked, init="pca", max_iters=15)
    assert bool(valid.all())  # union covers everything by construction
    # consensus close to truth up to rotation
    assert procrustes_distance(np.asarray(out), Y) < 0.08
    # per-model reconstruction of missing rows lands near truth-in-model-space
    completed = np.asarray(mg.reconstruct_missing(stacked, jnp.asarray(out)))
    mask = np.asarray(stacked.mask)
    for i in range(stacked.n):
        missing = ~mask[i]
        if missing.sum() == 0:
            continue
        # the completed missing rows, mapped to consensus space, match Y
        err = procrustes_distance(completed[i], np.asarray(out))
        assert err < 0.1, (i, err)


def test_alir_trace_frozen_after_convergence():
    """Regression: the scan kept recomputing (and mutating) the reported
    displacement after ``done``, so the trace misreported the converged
    error. Once the tol criterion fires, every later trace entry must be
    exactly the converged displacement."""
    # noise-free rotated models converge in a couple of iterations
    _, stacked = make_rotated_models(V=80, d=8, n=3, noise=0.0, seed=7)
    tol = 1e-4
    _, _, disps = alir_merge(stacked, init="random", max_iters=20, tol=tol)
    d = np.asarray(disps)
    deltas = np.abs(np.diff(d, prepend=np.inf))
    conv = int(np.argmax(deltas < tol))         # first converged iteration
    assert deltas[conv] < tol                   # it did converge in budget
    np.testing.assert_array_equal(d[conv:], np.full(len(d) - conv, d[conv]))


def test_alir_converged_result_unchanged_by_extra_iterations():
    """Freezing must not change the merge result: Y after max_iters=6 and
    max_iters=20 is identical once converged before iteration 6."""
    _, stacked = make_rotated_models(V=80, d=8, n=3, noise=0.0, seed=7)
    key = jax.random.PRNGKey(1)
    y1, _, d1 = alir_merge(stacked, init="random", max_iters=6, key=key)
    y2, _, d2 = alir_merge(stacked, init="random", max_iters=20, key=key)
    assert np.abs(np.diff(np.asarray(d1))).min() < 1e-4  # converged in 6
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_reconstruct_missing_roundtrip_recovers_true_rows():
    """Paper §4.5 robustness claim: embed a known rotation per sub-model,
    mask rows out, and the per-model reconstruction from the consensus
    must recover the held-out rows (which were never seen by the merge)."""
    rng = np.random.default_rng(11)
    V, d, n = 140, 10, 4
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks, truth = [], [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        M_true = (Y @ q).astype(np.float32)
        mask = np.ones(V, bool) if i == 0 else rng.random(V) >= 0.35
        mask[: d + 2] = True
        M = M_true.copy()
        M[~mask] = 7.7          # garbage where absent: must not leak in
        models.append(M)
        masks.append(mask)
        truth.append(M_true)
    stacked = mg.stack_models(models, masks)
    rec = np.asarray(mg.reconstruct_missing(stacked, jnp.asarray(Y)))
    for i in range(n):
        missing = ~masks[i]
        if not missing.any():
            continue
        # held-out rows recovered in the sub-model's own rotated space
        err = np.abs(rec[i][missing] - truth[i][missing]).max()
        assert err < 1e-3, (i, err)
        # present rows pass through untouched
        np.testing.assert_array_equal(rec[i][~missing], models[i][~missing])


def test_average_fails_without_alignment_alir_does_not():
    """Paper §3.3.1 counter-example: sub-models differing by a rotation.

    Element-wise averaging destroys neighborhood structure; ALiR keeps it.
    """
    Y, stacked = make_rotated_models(V=100, n=3, noise=0.0, seed=5)

    def neighbor_overlap(emb):
        e = emb / (np.linalg.norm(emb, axis=1, keepdims=True) + 1e-9)
        g = Y / np.linalg.norm(Y, axis=1, keepdims=True)
        sim_e, sim_g = e @ e.T, g @ g.T
        np.fill_diagonal(sim_e, -np.inf)
        np.fill_diagonal(sim_g, -np.inf)
        return float((sim_e.argmax(1) == sim_g.argmax(1)).mean())

    avg = mg.get_merger("average").merge(stacked).emb
    alir, _, _ = alir_merge(stacked, init="random", max_iters=12)
    assert neighbor_overlap(np.asarray(alir)) > neighbor_overlap(np.asarray(avg)) + 0.2


def test_concat_dims_and_intersection():
    _, stacked = make_rotated_models(V=80, d=8, n=3, miss_frac=0.2, seed=7)
    res = mg.get_merger("concat").merge(stacked)
    emb, valid = res.emb, res.valid
    assert emb.shape == (80, 3 * 8)
    inter = np.asarray(stacked.mask).all(0)
    np.testing.assert_array_equal(np.asarray(valid), inter)
    assert np.all(np.asarray(emb)[~inter] == 0)


def test_pca_shape_and_variance_order():
    _, stacked = make_rotated_models(V=200, d=10, n=4, seed=9)
    res = mg.get_merger("pca", out_dim=10).merge(stacked)
    emb, valid = res.emb, res.valid
    assert emb.shape == (200, 10)
    e = np.asarray(emb)[np.asarray(valid)]
    var = e.var(axis=0)
    assert np.all(var[:-1] >= var[1:] - 1e-5)  # descending components


def test_incremental_add_validations():
    _, stacked = make_rotated_models(V=60, d=6, n=3)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    m = mg.IncrementalAlirMerger()
    m.add(0, models[0], masks[0])
    with pytest.raises(ValueError, match="already folded"):
        m.add(0, models[1], masks[1])
    with pytest.raises(ValueError, match="shape"):
        m.add(1, models[1][:, :3], masks[1])
    with pytest.raises(ValueError, match="mask"):
        m.add(1, models[1], masks[1][:10])
    assert m.worker_ids == (0,) and m.n_folded == 1


def test_incremental_cold_fold_bitwise_matches_batch():
    """fold(warm=False) after all arrivals must reproduce the batch
    merge bit-for-bit, regardless of arrival order (the canonical
    worker-id restacking). Exhaustive permutations are property-tested
    in test_property.py; these are fixed representative orders."""
    _, stacked = make_rotated_models(V=80, d=8, n=4, miss_frac=0.2, seed=2)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    Yb, validb, _ = alir_merge(stacked)
    for order in ((0, 1, 2, 3), (3, 1, 0, 2), (2, 3, 1, 0)):
        m = mg.IncrementalAlirMerger()
        for w in order:
            m.add(w, models[w], masks[w])
        final = m.fold(warm=False)
        assert final.worker_ids == (0, 1, 2, 3)
        np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(Yb))
        np.testing.assert_array_equal(np.asarray(final.valid),
                                      np.asarray(validb))


def test_incremental_warm_folds_match_batch_up_to_rotation():
    """Warm intermediate folds inherit their gauge from the arrival
    history: the documented tolerance vs the batch merge is a small
    residual after optimal rotation, not element-wise equality."""
    _, stacked = make_rotated_models(V=100, d=8, n=4, miss_frac=0.2,
                                     noise=0.005, seed=6)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    m = mg.IncrementalAlirMerger()
    folds = [m.add(w, models[w], masks[w]) for w in range(4)]
    # coverage grows monotonically with arrivals
    counts = [int(np.asarray(f.valid).sum()) for f in folds]
    assert counts == sorted(counts) and counts[-1] == 100
    Yb, validb, _ = alir_merge(stacked)
    v = np.asarray(validb)
    warm = np.asarray(folds[-1].Y)
    assert procrustes_distance(warm[v], np.asarray(Yb)[v]) < 0.05


def test_incremental_early_fold_is_servable_for_its_coverage():
    _, stacked = make_rotated_models(V=80, d=8, n=3, miss_frac=0.4, seed=9)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    m = mg.IncrementalAlirMerger()
    first = m.add(1, models[1], masks[1])        # any worker can be first
    np.testing.assert_array_equal(np.asarray(first.valid), masks[1])
    Y = np.asarray(first.Y)
    assert np.isfinite(Y).all() and np.all(Y[~masks[1]] == 0)


def test_merge_dispatch_all_methods():
    _, stacked = make_rotated_models(V=60, d=6, n=3, miss_frac=0.1, seed=11)
    for m in mg.MERGE_METHODS:
        emb, valid = mg.merge(stacked, m, out_dim=6, key=jax.random.PRNGKey(0))
        assert emb.shape[0] == 60
        assert np.isfinite(np.asarray(emb)).all(), m


# ---------------------------------------------------------------------------
# The Merger registry (the unified API surface).
# ---------------------------------------------------------------------------
def test_get_merger_registry_names_and_overrides():
    for name in mg.MERGER_NAMES:
        m = mg.get_merger(name)
        assert m.name == name, name
    m = mg.get_merger("alir", max_iters=3, quorum=2, deadline=5.0)
    assert (m.config.max_iters, m.config.quorum, m.config.deadline) == (3, 2, 5.0)
    # config + overrides compose via dataclasses.replace
    m = mg.get_merger("alir_tree", mg.MergeConfig(max_iters=7), fan_in=4)
    assert (m.config.max_iters, m.config.fan_in) == (7, 4)
    # instances pass through untouched; mixing with overrides is an error
    inst = mg.get_merger("average")
    assert mg.get_merger(inst) is inst
    with pytest.raises(ValueError, match="instance"):
        mg.get_merger(inst, quorum=2)
    with pytest.raises(ValueError, match="unknown merger"):
        mg.get_merger("nope")


def test_merge_config_validation():
    with pytest.raises(ValueError, match="quorum"):
        mg.get_merger("alir", quorum=0)
    with pytest.raises(ValueError, match="deadline"):
        mg.get_merger("alir", deadline=-1.0)
    with pytest.raises(ValueError, match="fan_in"):
        mg.get_merger("alir_tree", fan_in=1)
    with pytest.raises(ValueError, match="shard"):
        mg.get_merger("alir", shard=0)


def test_every_merger_supports_batch_and_incremental():
    """Batch and incremental are two methods on the same object: for
    every registered merger, add()-ing all workers then final() equals
    the one-shot batch merge bit-for-bit."""
    _, stacked = make_rotated_models(V=64, d=8, n=4, miss_frac=0.2, seed=13)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    for name in mg.MERGER_NAMES:
        batch = mg.get_merger(name, max_iters=6).merge(stacked)
        inc = mg.get_merger(name, max_iters=6)
        for w in (2, 0, 3, 1):
            inc.add(w, models[w], masks[w], fold=False)
        final = inc.final()
        assert final.worker_ids == (0, 1, 2, 3), name
        np.testing.assert_array_equal(np.asarray(final.emb),
                                      np.asarray(batch.emb), err_msg=name)


def test_alir_result_carries_transforms_for_reconstruction():
    """MergeResult.transforms must be the same maps alir_transforms
    solves — the serving tier reconstructs from the result directly."""
    _, stacked = make_rotated_models(V=70, d=8, n=3, miss_frac=0.3, seed=4)
    res = mg.get_merger("alir").merge(stacked)
    Ws = mg.alir_transforms(stacked, res.emb)
    np.testing.assert_array_equal(np.asarray(res.transforms), np.asarray(Ws))
    np.testing.assert_array_equal(np.asarray(res.mask),
                                  np.asarray(stacked.mask))


def test_deprecated_shims_warn_and_delegate():
    """The legacy free functions must emit DeprecationWarning and return
    exactly what the registry path computes."""
    _, stacked = make_rotated_models(V=50, d=6, n=3, miss_frac=0.1, seed=8)
    with pytest.warns(DeprecationWarning, match="merge_alir"):
        Y, valid, disps = mg.merge_alir(stacked, max_iters=6)
    reg = mg.get_merger("alir", max_iters=6).merge(stacked)
    np.testing.assert_array_equal(np.asarray(Y), np.asarray(reg.emb))
    with pytest.warns(DeprecationWarning, match="merge_concat"):
        emb, _ = mg.merge_concat(stacked)
    np.testing.assert_array_equal(
        np.asarray(emb), np.asarray(mg.get_merger("concat").merge(stacked).emb))
    with pytest.warns(DeprecationWarning, match="merge_average"):
        emb, _ = mg.merge_average(stacked)
    np.testing.assert_array_equal(
        np.asarray(emb), np.asarray(mg.get_merger("average").merge(stacked).emb))
    with pytest.warns(DeprecationWarning, match="merge_pca"):
        emb, _ = mg.merge_pca(stacked, out_dim=6)
    np.testing.assert_array_equal(
        np.asarray(emb),
        np.asarray(mg.get_merger("pca", out_dim=6).merge(stacked).emb))


def test_registry_paths_emit_no_deprecation_warnings():
    """Internal call paths must not route through the shims."""
    _, stacked = make_rotated_models(V=50, d=6, n=3, seed=8)
    models, masks = np.asarray(stacked.models), np.asarray(stacked.mask)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for name in mg.MERGER_NAMES:
            m = mg.get_merger(name, max_iters=4)
            m.merge(stacked)
            m.add(0, models[0], masks[0])
        mg.merge(stacked, "alir_pca", out_dim=6)
        mg.merge(stacked, "pca", out_dim=6)


def test_pca_merger_equivalent_to_legacy_function():
    """merge_pca folded into the registry: the PcaMerger output is the
    legacy function's output, bit for bit, at every out_dim."""
    _, stacked = make_rotated_models(V=90, d=8, n=3, miss_frac=0.15, seed=21)
    for out_dim in (4, 8, 16):
        reg = mg.get_merger("pca", out_dim=out_dim).merge(stacked)
        with pytest.warns(DeprecationWarning):
            legacy_emb, legacy_valid = mg.merge_pca(stacked, out_dim=out_dim)
        np.testing.assert_array_equal(np.asarray(reg.emb),
                                      np.asarray(legacy_emb))
        np.testing.assert_array_equal(np.asarray(reg.valid),
                                      np.asarray(legacy_valid))


# ---------------------------------------------------------------------------
# Sharded Gram accumulation — the distributable core of the ALiR solve.
# ---------------------------------------------------------------------------
def test_gram_partials_bit_identical_across_host_partitions():
    """The exact invariant that makes the solve distributable: each row
    block's partial Gram is bit-identical whether computed in the
    single-host batched call or separately by the host owning the
    slice. (The *reduction* is then the canonical fixed order.)"""
    rng = np.random.default_rng(0)
    V, d, S = 128, 16, 8
    A = rng.normal(size=(V, d)).astype(np.float32)
    B = rng.normal(size=(V, d)).astype(np.float32)
    full = np.asarray(mg.gram_block_partials(jnp.asarray(A), jnp.asarray(B), S))
    blk = V // S
    for hosts in (2, 4, 8):                   # simulated host partitions
        per_host = S // hosts
        got = []
        for h in range(hosts):                # each host: its own slice only
            sl = slice(h * per_host * blk, (h + 1) * per_host * blk)
            got.append(np.asarray(mg.gram_block_partials(
                jnp.asarray(A[sl]), jnp.asarray(B[sl]), per_host)))
        np.testing.assert_array_equal(np.concatenate(got), full)


def test_sharded_gram_fixed_order_reduction_is_canonical():
    """sharded_gram at a given shard count is deterministic, equals the
    explicit ascending-order partial sum, and matches the dense matmul
    to float tolerance (not bit-exactly — fp addition reassociated)."""
    rng = np.random.default_rng(3)
    A = rng.normal(size=(200, 12)).astype(np.float32)
    B = rng.normal(size=(200, 12)).astype(np.float32)
    for S in (2, 5, 8):
        g = np.asarray(mg.sharded_gram(jnp.asarray(A), jnp.asarray(B), S))
        g2 = np.asarray(mg.sharded_gram(jnp.asarray(A), jnp.asarray(B), S))
        np.testing.assert_array_equal(g, g2)
        parts = np.asarray(mg.gram_block_partials(jnp.asarray(A),
                                                  jnp.asarray(B), S))
        acc = np.zeros_like(parts[0])
        for p in parts:                        # ascending block order
            acc = acc + p
        np.testing.assert_allclose(g, acc, atol=1e-5)
        np.testing.assert_allclose(g, A.T @ B, atol=1e-3)
    # shard=1 is the plain matmul, bit for bit
    np.testing.assert_array_equal(
        np.asarray(mg.sharded_gram(jnp.asarray(A), jnp.asarray(B), 1)),
        np.asarray(jnp.asarray(A).T @ jnp.asarray(B)))


def test_mesh_sharded_gram_bit_identical_to_local_path():
    """The worker-mesh execution (shard_map + all_gather + ordered scan)
    must be bit-identical to the local fixed-order reduction — bits
    depend on the shard dial, never on the partition."""
    from repro.sharding.merge import mesh_sharded_gram

    mesh = jax.make_mesh((1,), ("worker",))
    rng = np.random.default_rng(5)
    A = rng.normal(size=(128, 16)).astype(np.float32)
    B = rng.normal(size=(128, 16)).astype(np.float32)
    for S in (1, 4, 8):
        got = np.asarray(mesh_sharded_gram(A, B, mesh, num_shards=S))
        ref = np.asarray(mg.sharded_gram(jnp.asarray(A), jnp.asarray(B), S))
        np.testing.assert_array_equal(got, ref, err_msg=f"shards={S}")


def test_sharded_solve_deterministic_and_quality_preserved():
    """shard>1 changes the Gram bits (documented) but not the solve
    quality: the sharded consensus matches the dense one up to a tiny
    rotation residual, and is itself exactly reproducible."""
    Y, stacked = make_rotated_models(V=128, d=8, n=4, miss_frac=0.2, seed=17)
    dense, _, _ = alir_merge(stacked, max_iters=12)
    for S in (4, 8):
        s1, _, _ = alir_merge(stacked, max_iters=12, shard=S)
        s2, _, _ = alir_merge(stacked, max_iters=12, shard=S)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        assert procrustes_distance(np.asarray(s1), np.asarray(dense)) < 1e-3
        assert procrustes_distance(np.asarray(s1), Y) < 0.08
