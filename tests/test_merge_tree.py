"""The log-depth reduction-tree merge (repro.core.merge_tree).

Acceptance properties under test:

1. **Topology determinism** — build_tree is a pure function of the
   canonical (sorted) worker-id set and fan_in.
2. **Permutation invariance** — 32+ sub-models folded in shuffled
   arrival orders produce a bit-identical root consensus (fixed-seed
   here; exhaustive permutations under hypothesis in test_property.py).
3. **Gauge-equivalence with the flat solve** — the tree consensus
   matches the flat batch ALiR merge up to a small rotation residual.
4. **Any-level serving** — composed transforms let reconstruct_worker
   rebuild a worker's table from ANY solved node, not just the root.
5. **Elastic node semantics** — deadline closes the window (late leaves
   never join), partially-arrived interior nodes solve over present
   children, quorum applies at the root.
6. **Restartability** — persisted leaves/nodes reload and are reused
   (zero re-solves) with a bit-identical result.
"""

import jax
import numpy as np
import pytest

from repro.core import merge as mg
from repro.core import merge_tree as mt


def rotated_world(V=96, d=8, n=8, miss_frac=0.25, seed=0):
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        mask = np.ones(V, bool) if i == 0 else rng.random(V) >= miss_frac
        mask[: d + 2] = True
        M = (Y @ q).astype(np.float32)
        M[~mask] = 0.0
        models.append(M)
        masks.append(mask)
    return Y, models, masks


def procrustes_distance(A, B):
    import jax.numpy as jnp
    W = np.asarray(mg.orthogonal_procrustes(jnp.asarray(A), jnp.asarray(B)))
    return float(np.linalg.norm(A @ W - B) / np.linalg.norm(B))


# ------------------------------------------------------------------ topology
def test_build_tree_topology_is_canonical():
    # unsorted, duplicated ids → same tree as the sorted unique set
    a = mt.build_tree([5, 1, 3, 1, 9], fan_in=2)
    b = mt.build_tree([1, 3, 5, 9], fan_in=2)
    assert a == b
    assert a.worker_ids == (1, 3, 5, 9)
    assert mt.tree_depth(a) == 2
    levels = mt.tree_levels(a)
    assert [len(lv) for lv in levels] == [4, 2, 1]
    # consecutive grouping in id order at every level
    assert levels[1][0].worker_ids == (1, 3)
    assert levels[1][1].worker_ids == (5, 9)


@pytest.mark.parametrize("n,fan_in,depth", [
    (2, 2, 1), (8, 2, 3), (9, 2, 4), (32, 2, 5), (32, 4, 3),
    (128, 2, 7), (128, 8, 3), (5, 4, 2),
])
def test_tree_depth_is_log_fan_in(n, fan_in, depth):
    root = mt.build_tree(range(n), fan_in=fan_in)
    assert mt.tree_depth(root) == depth
    assert root.worker_ids == tuple(range(n))
    # every worker appears exactly once among the leaves
    leaves = mt.tree_levels(root)[0]
    assert [lf.worker_ids for lf in leaves] == [(w,) for w in range(n)]


def test_build_tree_rejects_bad_inputs():
    with pytest.raises(ValueError, match="zero workers"):
        mt.build_tree([])
    with pytest.raises(ValueError, match="fan_in"):
        mt.build_tree([0, 1], fan_in=1)


# ------------------------------------------------- determinism & invariance
def test_tree_32_models_deterministic_and_arrival_invariant():
    """The tentpole acceptance test: 32 sub-models, shuffled arrival
    orders, bit-identical root consensus every time — and identical to
    the one-shot batch tree merge."""
    _, models, masks = rotated_world(V=64, d=6, n=32, seed=3)
    stacked = mg.stack_models(models, masks)
    batch = mg.get_merger("alir_tree", max_iters=6).merge(stacked)
    assert batch.worker_ids == tuple(range(32))
    for order_seed in (0, 1, 2):
        order = np.random.default_rng(order_seed).permutation(32)
        m = mg.get_merger("alir_tree", max_iters=6)
        for w in order:
            m.add(int(w), models[w], masks[w], fold=False)
        final = m.fold()
        np.testing.assert_array_equal(np.asarray(final.Y),
                                      np.asarray(batch.Y))
        np.testing.assert_array_equal(np.asarray(final.valid),
                                      np.asarray(batch.valid))
        np.testing.assert_array_equal(np.asarray(final.transforms),
                                      np.asarray(batch.transforms))


def test_tree_fan_in_changes_bits_not_quality():
    Y, models, masks = rotated_world(V=96, d=8, n=16, seed=5)
    stacked = mg.stack_models(models, masks)
    flat = mg.get_merger("alir", max_iters=12).merge(stacked)
    for fan_in in (2, 4, 16):
        res = mg.get_merger("alir_tree", fan_in=fan_in,
                            max_iters=12).merge(stacked)
        assert bool(np.asarray(res.valid).all())
        # same consensus up to gauge, for every arity (fan_in=16 on 16
        # workers degenerates to the flat solve's shape: depth 1)
        assert procrustes_distance(np.asarray(res.Y),
                                   np.asarray(flat.Y)) < 5e-3, fan_in
        assert procrustes_distance(np.asarray(res.Y), Y) < 0.08, fan_in


def test_tree_merge_via_dispatch():
    """MERGE_METHODS exposes alir_tree through the classic merge()
    entrypoint (what the training driver calls)."""
    _, models, masks = rotated_world(n=8, seed=7)
    stacked = mg.stack_models(models, masks)
    emb, valid = mg.merge(stacked, "alir_tree", out_dim=8,
                          key=jax.random.PRNGKey(0), fan_in=4)
    assert emb.shape == (96, 8) and bool(np.asarray(valid).all())


# ------------------------------------------------------- any-level serving
def test_reconstruct_worker_from_every_level():
    """Composed transforms: a worker's own-space table reconstructed
    from its leaf, from every interior ancestor, and from the root all
    agree with the ground-truth rotated table on present rows."""
    Y, models, masks = rotated_world(V=96, d=8, n=8, miss_frac=0.3, seed=9)
    m = mg.get_merger("alir_tree", max_iters=15)
    for w in range(8):
        m.add(w, models[w], masks[w], fold=False)
    root = m.fold()
    w = 5
    present = masks[w]
    for level, index in [(1, 2), (2, 1), (3, 0)]:      # ancestors of leaf 5
        node = m.node(level, index)
        assert node is not None and w in node.worker_ids
        rec = np.asarray(mt.reconstruct_worker(node, w))
        err = np.abs(rec[present] - models[w][present]).max()
        assert err < 0.05, (level, index, err)
    # the root MergeResult works through the same function
    rec = np.asarray(mt.reconstruct_worker(root, w))
    assert np.abs(rec[present] - models[w][present]).max() < 0.05
    with pytest.raises(KeyError, match="not covered"):
        mt.reconstruct_worker(m.node(1, 0), 5)         # leaf-01 subtree


# ------------------------------------------------- elastic node semantics
def test_deadline_late_leaf_never_joins_interior_nodes():
    """A worker arriving after the deadline is excluded from the WHOLE
    tree: its leaf stays empty, every ancestor solves over the present
    children only, and the root covers the on-time subset."""
    _, models, masks = rotated_world(n=8, seed=11)
    now = [0.0]
    m = mt.TreeAlirMerger(mg.MergeConfig(deadline=10.0, fan_in=2,
                                         max_iters=6),
                          workers=range(8), clock=lambda: now[0])
    for w in (0, 1, 2, 4, 5, 6, 7):
        assert m.add(w, models[w], masks[w], fold=False) is None
    now[0] = 11.0
    assert m.deadline_passed
    assert m.add(3, models[3], masks[3]) is None       # late → rejected
    assert m.late_workers == [3]
    final = m.fold()
    assert final.worker_ids == (0, 1, 2, 4, 5, 6, 7)
    # node (1,1) = workers {2,3}: single present child → passthrough
    node = m.node(1, 1)
    assert node.worker_ids == (2,)
    np.testing.assert_array_equal(
        np.asarray(node.Y),
        np.asarray(models[2]) * masks[2][:, None])
    assert m.stats["passthrough"] >= 1


def test_quorum_applies_at_root():
    _, models, masks = rotated_world(n=8, seed=13)
    m = mg.get_merger("alir_tree", quorum=4, max_iters=4)
    m.add(0, models[0], masks[0], fold=False)
    m.add(6, models[6], masks[6], fold=False)
    assert not m.quorum_met
    with pytest.raises(RuntimeError, match="quorum"):
        m.final()
    fold = m.final(require_quorum=False)               # explicit best-effort
    assert fold.worker_ids == (0, 6)
    for w in (1, 2):
        m.add(w, models[w], masks[w], fold=False)
    assert m.quorum_met
    assert m.final().worker_ids == (0, 1, 2, 6)


def test_incremental_arrival_resolves_only_root_path():
    """Node-cache reuse: after a full fold, one more arrival re-solves
    only the nodes on its leaf-to-root path (≤ depth), not the tree."""
    _, models, masks = rotated_world(n=8, seed=15)
    m = mg.get_merger("alir_tree", max_iters=4)
    for w in range(7):
        m.add(w, models[w], masks[w], fold=False)
    m.fold()
    before = m.stats["solved"] + m.stats["passthrough"]
    m.add(7, models[7], masks[7])                      # fold=True re-folds
    path_cost = (m.stats["solved"] + m.stats["passthrough"]) - before
    root = mt.build_tree(range(8), fan_in=2)
    assert path_cost <= mt.tree_depth(root)            # ≤ 3 node solves
    # and a fold with nothing new re-solves nothing
    before = m.stats["solved"] + m.stats["passthrough"]
    m.fold()
    assert m.stats["solved"] + m.stats["passthrough"] == before


def test_critical_path_below_serial_solve_time():
    _, models, masks = rotated_world(n=8, seed=17)
    m = mg.get_merger("alir_tree", max_iters=6)
    m.merge(mg.stack_models(models, masks))
    serial = sum(m.stats["node_s"].values())
    assert 0 < m.critical_path_s() <= serial + 1e-9
    # 7 interior solves serially vs a depth-3 critical path
    assert len(m.stats["node_s"]) == 7


# ----------------------------------------------------------- restartability
def test_persisted_tree_resumes_without_resolving(tmp_path):
    _, models, masks = rotated_world(n=8, seed=19)
    d1 = str(tmp_path / "tree")
    m1 = mt.TreeAlirMerger(mg.MergeConfig(max_iters=6), workers=range(8),
                           state_dir=d1)
    for w in range(8):
        m1.add(w, models[w], masks[w], fold=False)
    ref = m1.fold()
    assert m1.stats["solved"] == 7

    m2 = mt.TreeAlirMerger(mg.MergeConfig(max_iters=6), workers=range(8),
                           state_dir=d1)
    assert m2.stats["loaded"] == 15                    # 8 leaves + 7 nodes
    resumed = m2.fold()
    assert m2.stats["solved"] == 0                     # pure cache reuse
    np.testing.assert_array_equal(np.asarray(resumed.Y), np.asarray(ref.Y))
    np.testing.assert_array_equal(np.asarray(resumed.transforms),
                                  np.asarray(ref.transforms))
    final = m2.final()
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(ref.Y))


def test_resume_after_partial_arrivals_then_continue(tmp_path):
    """Kill the merge mid-arrival: a new merger reloads the persisted
    leaves, accepts the remaining workers, and the finished fold is
    bit-identical to the uninterrupted one."""
    _, models, masks = rotated_world(n=8, seed=21)
    uninterrupted = mg.get_merger("alir_tree", max_iters=6).merge(
        mg.stack_models(models, masks))

    d1 = str(tmp_path / "tree")
    m1 = mt.TreeAlirMerger(mg.MergeConfig(max_iters=6), workers=range(8),
                           state_dir=d1)
    for w in (3, 0, 6, 1):
        m1.add(w, models[w], masks[w], fold=False)
    del m1                                             # "preempted"

    m2 = mt.TreeAlirMerger(mg.MergeConfig(max_iters=6), workers=range(8),
                           state_dir=d1)
    assert m2.worker_ids == (0, 1, 3, 6)               # leaves reloaded
    for w in (7, 2, 5, 4):
        m2.add(w, models[w], masks[w], fold=False)
    final = m2.fold()
    np.testing.assert_array_equal(np.asarray(final.Y),
                                  np.asarray(uninterrupted.Y))
