"""Per-architecture smoke tests (assignment requirement f).

Each assigned arch instantiates its REDUCED variant (≤2 cycles,
d_model ≤ 128, ≤4 experts) and runs one forward/train step on CPU,
asserting output shapes and absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_shape
from repro.models import Model
from repro.optim import get_optimizer


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            m = Model(cfg)
            cache[arch] = (m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    m, params = built(arch)
    cfg = m.cfg
    batch = m.example_batch(smoke_shape("train"))
    from repro.models import transformer as tf
    logits, aux, mask = jax.jit(
        lambda p, b: tf.forward_logits(p, cfg, b))(params, batch)
    B = batch["labels"].shape[0]
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch
    assert mask.shape == logits.shape[:2]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, built):
    m, params = built(arch)
    opt = get_optimizer(m.cfg.train_optimizer)
    state = opt.init(params)
    step_fn = jax.jit(m.make_train_step(opt, microbatches=1))
    batch = m.example_batch(smoke_shape("train"))
    new_params, new_state, loss = step_fn(params, state, batch, jnp.int32(0))
    assert bool(jnp.isfinite(loss)), arch
    # at least the embedding moved
    assert not np.allclose(np.asarray(new_params["embed"], np.float32),
                           np.asarray(params["embed"], np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_decode_step(arch, built):
    m, params = built(arch)
    cfg = m.cfg
    B, cache_len = 2, 64
    cache = m.init_cache(B, cache_len,
                         enc_len=16 if cfg.encoder_layers else None)
    step = jax.jit(m.make_decode_step())
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache = step(params, cache, tok, jnp.int32(5))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch


def test_training_reduces_loss_dense():
    cfg = get_config("smollm-360m").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = get_optimizer("adamw", lr=3e-3)
    state = opt.init(params)
    step_fn = jax.jit(m.make_train_step(opt))
    rng = np.random.default_rng(0)
    # fixed tiny batch → should memorize quickly
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32), dtype=np.int32))
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for i in range(30):
        params, state, loss = step_fn(params, state, batch, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_training_reduces_loss_moe():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = get_optimizer("adamw", lr=3e-3)
    state = opt.init(params)
    step_fn = jax.jit(m.make_train_step(opt))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32), dtype=np.int32))
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for i in range(30):
        params, state, loss = step_fn(params, state, batch, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_training_reduces_loss_ssm():
    cfg = get_config("xlstm-1.3b").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = get_optimizer("adamw", lr=3e-3)
    state = opt.init(params)
    step_fn = jax.jit(m.make_train_step(opt))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32), dtype=np.int32))
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for i in range(30):
        params, state, loss = step_fn(params, state, batch, jnp.int32(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("qwen1.5-0.5b").reduced()
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = get_optimizer("sgd", lr=0.1)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16), dtype=np.int32))
    batch = {"tokens": toks, "labels": toks}
    s1 = jax.jit(m.make_train_step(opt, microbatches=1))
    s2 = jax.jit(m.make_train_step(opt, microbatches=2))
    p1, _, l1 = s1(params, opt.init(params), batch, jnp.int32(0))
    p2, _, l2 = s2(params, opt.init(params), batch, jnp.int32(0))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_vocab_padding():
    cfg = get_config("seamless-m4t-large-v2")
    assert cfg.vocab_size == 256206
    assert cfg.padded_vocab == 256256
    assert cfg.padded_vocab % 256 == 0
