"""Simulated multi-host ingestion: every per-host path is a pure
function of (process_index, process_count), so 1–8 hosts are simulated
inside one process and checked bit-for-bit against the single-host
stream — the contract that makes pod-scale ingestion testable in CI.
"""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.data.corpus import SemanticCorpusModel
from repro.data.pipeline import (
    HostShardPlan, PairChunkStream, _extract_seed, make_worker_streams)
from repro.data.vocab import build_vocab

W = 6                       # global worker count under test
PROCESS_COUNTS = (1, 2, 3, 8)   # 8 > W: some hosts legitimately own none
STRATEGIES = ("equal", "random", "shuffle")
CHUNK_KW = dict(batch_size=32, steps_per_chunk=4, sentences_per_block=128)


@pytest.fixture(scope="module")
def world():
    gen = SemanticCorpusModel.create(vocab_size=300, seed=0)
    corpus = gen.generate(num_sentences=1200, seed=1)
    vocab = build_vocab(corpus, 300, min_count=1, max_size=None)
    return corpus, vocab


@pytest.fixture(scope="module")
def streams_by_strategy(world):
    corpus, vocab = world
    return {s: make_worker_streams(corpus, vocab, num_workers=W, strategy=s,
                                   window=3, seed=7)
            for s in STRATEGIES}


# ------------------------------------------------------------------ planner
@pytest.mark.parametrize("process_count", PROCESS_COUNTS)
def test_hosts_cover_each_worker_exactly_once(process_count):
    plans = HostShardPlan.all_hosts(process_count, W)
    owned = [w for p in plans for w in p.workers]
    assert sorted(owned) == list(range(W))          # cover, exactly once
    assert len(owned) == len(set(owned)) == W
    # contiguous blocks in host order (the device-order property the
    # per-process shard of make_array_from_process_local_data rests on)
    assert [p.start for p in plans] == sorted(p.start for p in plans)
    for p in plans:
        assert p.stop - p.start == p.num_local


def test_plan_validation():
    with pytest.raises(ValueError, match="process_count"):
        HostShardPlan(0, 0, 4)
    with pytest.raises(ValueError, match="process_index"):
        HostShardPlan(3, 2, 4)
    with pytest.raises(ValueError, match="num_workers"):
        HostShardPlan(0, 1, 0)
    plan = HostShardPlan(0, 2, 4)
    with pytest.raises(ValueError, match="streams"):
        plan.local_streams([None] * 3)


def test_for_runtime_defaults_to_jax_process_env():
    plan = HostShardPlan.for_runtime(5)
    assert plan == HostShardPlan(jax.process_index(), jax.process_count(), 5)
    assert HostShardPlan.for_runtime(5, process_index=1, process_count=3) == \
        HostShardPlan(1, 3, 5)


def test_validate_for_mesh_rejects_uneven_blocks():
    mesh = jax.make_mesh((1,), ("worker",))
    HostShardPlan(0, 1, 4).validate_for_mesh(mesh)          # even: fine
    with pytest.raises(ValueError, match="divide evenly"):
        HostShardPlan(0, 3, 8).validate_for_mesh(mesh)
    bad_axis = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="worker"):
        HostShardPlan(0, 1, 4).validate_for_mesh(bad_axis)


# ------------------------------------------------------- stream bit-identity
@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("process_count", PROCESS_COUNTS)
def test_host_streams_concat_bit_identical_to_single_host(
        streams_by_strategy, process_count, strategy):
    """The acceptance criterion: concatenating all simulated hosts'
    extracted chunks (in host order) is bit-identical to today's
    single-host PairChunkStream, for every strategy and host count."""
    streams = streams_by_strategy[strategy]
    base = list(PairChunkStream(streams, **CHUNK_KW).chunks(
        epoch=0, num_chunks=3))
    per_host = [
        list(plan.chunk_stream(streams, **CHUNK_KW).chunks(
            epoch=0, num_chunks=3))
        for plan in HostShardPlan.all_hosts(process_count, W)
    ]
    for k in range(3):
        c = np.concatenate([hc[k][0] for hc in per_host], axis=0)
        x = np.concatenate([hc[k][1] for hc in per_host], axis=0)
        np.testing.assert_array_equal(c, base[k][0])
        np.testing.assert_array_equal(x, base[k][1])


@pytest.mark.parametrize("strategy", ("random", "shuffle"))
def test_host_extraction_only_touches_owned_workers(streams_by_strategy,
                                                    strategy):
    """A host's local chunk stream is built from exactly its plan's
    worker streams — worker ids and per-worker pair rows line up."""
    streams = streams_by_strategy[strategy]
    plan = HostShardPlan(1, 3, W)                       # workers [2, 4)
    local = plan.local_streams(streams)
    assert [s.worker for s in local] == list(plan.workers)
    base_c, _ = next(PairChunkStream(streams, **CHUNK_KW).chunks(0, 1))
    host_c, _ = next(plan.chunk_stream(streams, **CHUNK_KW).chunks(0, 1))
    np.testing.assert_array_equal(host_c, base_c[plan.start:plan.stop])


@pytest.mark.parametrize("process_count", (2, 3, 8))
def test_prng_streams_disjoint_across_hosts(process_count):
    """Each (host, local worker) extraction stream is globally unique:
    worker ids never repeat across hosts, so the domain-tagged
    SeedSequences (and their first draws) are pairwise distinct."""
    draws = []
    for plan in HostShardPlan.all_hosts(process_count, W):
        for w in plan.workers:
            for epoch in (0, 1):
                rng = np.random.default_rng(_extract_seed(7, w, epoch))
                draws.append(tuple(rng.integers(0, 2**63, 4)))
    assert len(draws) == len(set(draws)) == 2 * W


def test_sentence_samples_disjoint_across_hosts(streams_by_strategy):
    """Random/shuffle sentence draws differ per worker, hence per host —
    no two hosts ingest the same sample stream."""
    for strategy in ("random", "shuffle"):
        streams = streams_by_strategy[strategy]
        idx = [tuple(s.sentence_indices(epoch=0)) for s in streams]
        assert len(set(idx)) == W


# ------------------------------------------------------------- assembly
def test_assemble_worker_array_roundtrip_and_sharding():
    from repro.launch.mesh import assemble_worker_array

    mesh = jax.make_mesh((1,), ("worker",))
    plan = HostShardPlan(0, 1, 4)
    local = np.arange(4 * 3 * 2, dtype=np.int32).reshape(4, 3, 2)
    arr = assemble_worker_array(mesh, plan, local)
    assert isinstance(arr, jax.Array)
    np.testing.assert_array_equal(np.asarray(arr), local)
    assert arr.sharding.spec == P("worker")
    with pytest.raises(ValueError, match="worker rows"):
        assemble_worker_array(mesh, plan, local[:3])


def test_trainer_device_chunk_and_table_assemble_globals():
    """AsyncShardTrainer under a single-host plan: device_chunk /
    device_table produce worker-sharded global arrays identical to the
    host blocks (the path the multi-host driver loop runs per chunk)."""
    from repro.core.async_trainer import AsyncShardTrainer
    from repro.core.sgns import SGNSConfig

    mesh = jax.make_mesh((1,), ("worker",))
    plan = HostShardPlan(0, 1, 2)
    tr = AsyncShardTrainer(
        cfg=SGNSConfig(vocab_size=64, dim=8, negatives=2), num_workers=2,
        total_steps=4, backend="shard_map", mesh=mesh, plan=plan)
    c = np.arange(2 * 4 * 8, dtype=np.int32).reshape(2, 4, 8)
    gc, gx = tr.device_chunk(c, c + 1)
    np.testing.assert_array_equal(np.asarray(gc), c)
    np.testing.assert_array_equal(np.asarray(gx), c + 1)
    assert gc.sharding.spec == P("worker")
    table = {"prob": np.ones((2, 64), np.float32),
             "alias": np.zeros((2, 64), np.int32)}
    gt = tr.device_table(table)
    assert gt["prob"].sharding.spec == P("worker")
    np.testing.assert_array_equal(np.asarray(gt["alias"]), table["alias"])


def test_trainer_rejects_mismatched_plan():
    from repro.core.async_trainer import AsyncShardTrainer
    from repro.core.sgns import SGNSConfig

    with pytest.raises(ValueError, match="plan covers"):
        AsyncShardTrainer(cfg=SGNSConfig(vocab_size=64, dim=8), num_workers=3,
                          total_steps=4, plan=HostShardPlan(0, 1, 2))
    with pytest.raises(ValueError, match="shard_map"):
        AsyncShardTrainer(cfg=SGNSConfig(vocab_size=64, dim=8), num_workers=4,
                          total_steps=4, plan=HostShardPlan(0, 2, 4))


# ------------------------------------------------------------- driver
def test_driver_process_args_are_bit_identical_single_host(world):
    """Threading (process_index, process_count) through train_submodels
    must not perturb the single-host path at all."""
    from repro.core.driver import train_submodels
    from repro.core.sgns import SGNSConfig

    corpus, _ = world
    kw = dict(strategy="shuffle", num_workers=2,
              cfg=SGNSConfig(vocab_size=0, dim=16, window=3, negatives=2),
              epochs=1, batch_size=128, window=3, max_vocab=None,
              max_steps_per_epoch=8, steps_per_chunk=4)
    a = train_submodels(corpus, 300, **kw)
    b = train_submodels(corpus, 300, process_index=0, process_count=1, **kw)
    np.testing.assert_array_equal(np.asarray(a.stacked.models),
                                  np.asarray(b.stacked.models))
    assert a.losses == b.losses


def test_driver_rejects_multihost_without_mesh(world):
    from repro.core.driver import train_submodels
    from repro.core.sgns import SGNSConfig

    corpus, _ = world
    with pytest.raises(ValueError, match="shard_map"):
        train_submodels(
            corpus, 300, strategy="shuffle", num_workers=2,
            cfg=SGNSConfig(vocab_size=0, dim=8, window=3, negatives=2),
            epochs=1, batch_size=64, window=3, max_vocab=None,
            max_steps_per_epoch=4, process_index=0, process_count=2)
