"""Hypothesis property tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import merge as mg
from repro.core.sampling import sample_sentence_indices
from repro.core.distributions import theorem2_threshold
from repro.data.pairs import NegativeSampler
from repro.data.vocab import build_vocab, union_vocab
from repro.data.corpus import Corpus


# ---------------------------------------------------------------- sampling
@settings(max_examples=30, deadline=None)
@given(n=st.integers(100, 5000), workers=st.integers(2, 20),
       worker=st.integers(0, 19), epoch=st.integers(0, 5),
       seed=st.integers(0, 2**20))
def test_sampling_deterministic_and_in_range(n, workers, worker, epoch, seed):
    worker = worker % workers
    for strategy in ("equal", "random", "shuffle"):
        idx = sample_sentence_indices(n, strategy, 1 / workers, worker,
                                      workers, epoch=epoch, seed=seed)
        idx2 = sample_sentence_indices(n, strategy, 1 / workers, worker,
                                       workers, epoch=epoch, seed=seed)
        np.testing.assert_array_equal(idx, idx2)
        assert (idx >= 0).all() and (idx < n).all()


@settings(max_examples=20, deadline=None)
@given(rate=st.floats(0.01, 0.9), length=st.floats(2.0, 500.0))
def test_theorem2_threshold_is_probability(rate, length):
    thr = theorem2_threshold(rate, length)
    assert 0.0 < thr < 1.0
    # monotone: higher sampling rate → lower miss threshold
    assert theorem2_threshold(min(rate * 1.5, 0.95), length) <= thr + 1e-12


# ---------------------------------------------------------------- merging
@settings(max_examples=15, deadline=None)
@given(v=st.integers(20, 80), d=st.integers(3, 10), seed=st.integers(0, 999))
def test_procrustes_orthogonality_property(v, d, seed):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(v, d)).astype(np.float32)
    B = rng.normal(size=(v, d)).astype(np.float32)
    W = np.asarray(mg.orthogonal_procrustes(jnp.asarray(A), jnp.asarray(B)))
    np.testing.assert_allclose(W.T @ W, np.eye(d), atol=1e-3)
    # optimality: residual no worse than identity map
    assert np.linalg.norm(A @ W - B) <= np.linalg.norm(A - B) + 1e-3


def _random_stacked(rng, n, v, d, full=False):
    models = rng.normal(size=(n, v, d)).astype(np.float32)
    if full:
        mask = np.ones((n, v), bool)
    else:
        mask = rng.random((n, v)) > 0.3
        mask[0] = True                      # keep the union total
    return mg.StackedModels(models=jnp.asarray(models),
                            mask=jnp.asarray(mask))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 5), v=st.integers(10, 60), d=st.integers(2, 8),
       seed=st.integers(0, 999))
def test_merge_average_concat_permutation_equivariant(n, v, d, seed):
    """Sub-model order is an artifact of worker numbering, so merges must
    be equivariant under it: `average` is permutation-invariant, `concat`
    permutes its column blocks, and both validity masks are invariant."""
    rng = np.random.default_rng(seed)
    stacked = _random_stacked(rng, n, v, d)
    perm = rng.permutation(n)
    permuted = mg.StackedModels(models=stacked.models[perm],
                                mask=stacked.mask[perm])

    res, res_p = (mg.get_merger("average").merge(s)
                  for s in (stacked, permuted))
    avg, valid, avg_p, valid_p = res.emb, res.valid, res_p.emb, res_p.valid
    # invariant up to float summation order over the n axis
    np.testing.assert_allclose(np.asarray(avg_p), np.asarray(avg),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(valid_p), np.asarray(valid))

    cres, cres_p = (mg.get_merger("concat").merge(s)
                    for s in (stacked, permuted))
    emb, cvalid, emb_p, cvalid_p = (cres.emb, cres.valid,
                                    cres_p.emb, cres_p.valid)
    expect = np.asarray(emb).reshape(v, n, d)[:, perm].reshape(v, n * d)
    np.testing.assert_array_equal(np.asarray(emb_p), expect)
    np.testing.assert_array_equal(np.asarray(cvalid_p), np.asarray(cvalid))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 5), v=st.integers(10, 60), d=st.integers(2, 8),
       seed=st.integers(0, 999))
def test_reconstruct_missing_is_exact_when_nothing_is_missing(n, v, d, seed):
    """With full presence masks there is nothing to reconstruct:
    reconstruct_missing must return every sub-model bit-unchanged
    (the `where` keeps original rows wherever the mask is set)."""
    rng = np.random.default_rng(seed)
    stacked = _random_stacked(rng, n, v, d, full=True)
    Y = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    out = mg.reconstruct_missing(stacked, Y)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(stacked.models))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 5), seed=st.integers(0, 999))
def test_alir_displacement_never_explodes(n, seed):
    rng = np.random.default_rng(seed)
    V, d = 40, 6
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        mask = np.ones(V, bool) if i == 0 else rng.random(V) > 0.2
        M = (Y @ q).astype(np.float32)
        M[~mask] = 0
        models.append(M)
        masks.append(mask)
    stacked = mg.stack_models(models, masks)
    res = mg.get_merger("alir", init="random", max_iters=10).merge(stacked)
    out, disps = res.emb, res.disps
    d_arr = np.asarray(disps)
    assert np.isfinite(np.asarray(out)).all()
    assert d_arr[-1] <= d_arr[0] + 1e-5     # displacement non-increasing-ish


@settings(max_examples=10, deadline=None)
@given(perm=st.permutations(tuple(range(4))), seed=st.integers(0, 999))
def test_incremental_cold_fold_is_arrival_order_invariant(perm, seed):
    """The acceptance property of the incremental merger: fold sub-models
    in ANY arrival order, finish with the canonical cold fold, and the
    result is bit-identical to the batch merge_alir — the canonical
    worker-id restacking erases the arrival history entirely."""
    rng = np.random.default_rng(seed)
    V, d = 40, 5
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(4):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        mask = np.ones(V, bool) if i == 0 else rng.random(V) > 0.25
        mask[: d + 2] = True
        M = (Y @ q).astype(np.float32)
        M[~mask] = 0
        models.append(M)
        masks.append(mask)
    stacked = mg.stack_models(models, masks)
    batch = mg.get_merger("alir").merge(stacked)

    merger = mg.IncrementalAlirMerger()
    for w in perm:
        merger.add(w, models[w], masks[w], fold=False)  # arrival only
    final = merger.fold(warm=False)
    assert final.worker_ids == (0, 1, 2, 3)
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(batch.Y))
    np.testing.assert_array_equal(np.asarray(final.valid),
                                  np.asarray(batch.valid))


@settings(max_examples=8, deadline=None)
@given(perm=st.permutations(tuple(range(6))), seed=st.integers(0, 999),
       fan_in=st.integers(2, 4))
def test_tree_fold_is_arrival_order_invariant(perm, seed, fan_in):
    """The reduction tree's acceptance property: its topology and every
    node key are pure functions of the canonical (sorted) worker-id set
    and fan-in, and interior nodes always cold-solve — so the root
    consensus is bit-identical under ANY arrival permutation, and equals
    the one-shot tree merge over the same stack."""
    rng = np.random.default_rng(seed)
    V, d = 40, 5
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(6):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        mask = np.ones(V, bool) if i == 0 else rng.random(V) > 0.25
        mask[: d + 2] = True
        M = (Y @ q).astype(np.float32)
        M[~mask] = 0
        models.append(M)
        masks.append(mask)
    stacked = mg.stack_models(models, masks)
    batch = mg.get_merger("alir_tree", fan_in=fan_in).merge(stacked)

    merger = mg.get_merger("alir_tree", fan_in=fan_in)
    for w in perm:
        merger.add(w, models[w], masks[w], fold=False)
    final = merger.fold()
    assert final.worker_ids == tuple(range(6))
    np.testing.assert_array_equal(np.asarray(final.Y), np.asarray(batch.Y))
    np.testing.assert_array_equal(np.asarray(final.valid),
                                  np.asarray(batch.valid))


# ------------------------------------------------------------ data substrate
@settings(max_examples=15, deadline=None)
@given(v=st.integers(10, 200), seed=st.integers(0, 999))
def test_negative_sampler_in_vocab(v, seed):
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, 1000, size=v)
    s = NegativeSampler(counts)
    out = np.asarray(s.sample(jax.random.PRNGKey(seed), (64, 3)))
    assert (out >= 0).all() and (out < v).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 999), min_count=st.integers(1, 5))
def test_vocab_frequency_sorted_and_union_superset(seed, min_count):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 50, size=2000).astype(np.int32)
    offs = np.arange(0, 2001, 20, dtype=np.int64)
    c = Corpus(tokens=toks, offsets=offs)
    vocab = build_vocab(c, 50, min_count=min_count)
    assert (np.diff(vocab.counts) <= 0).all()          # sorted desc
    assert (vocab.counts >= min_count).all()
    sub = Corpus(tokens=toks[:500], offsets=offs[offs <= 500])
    v2 = build_vocab(sub, 50, min_count=min_count)
    u = union_vocab([vocab, v2], 50)
    assert set(vocab.word_ids) <= set(u.word_ids)
