"""Divide phase: strategies, determinism, Theorems 1–2, KL (Fig. 1)."""

import numpy as np
import pytest

from repro.core.sampling import sample_sentence_indices, coverage_stats
from repro.core.distributions import (
    unigram_distribution,
    bigram_distribution,
    kl_divergence_dense,
    kl_divergence_sparse,
    theorem2_threshold,
)
from repro.data.corpus import SemanticCorpusModel, Corpus


@pytest.fixture(scope="module")
def corpus_and_gen():
    gen = SemanticCorpusModel.create(vocab_size=800, seed=0)
    return gen.generate(num_sentences=6000, seed=1), gen


def test_equal_partition_covers_exactly_once():
    n, W = 1000, 8
    seen = np.zeros(n, int)
    for w in range(W):
        idx = sample_sentence_indices(n, "equal", 1 / W, w, W)
        seen[idx] += 1
    assert (seen == 1).all()


def test_random_fixed_across_epochs_shuffle_not():
    kw = dict(num_sentences=5000, rate=0.1, worker=2, num_workers=10, seed=3)
    r0 = sample_sentence_indices(strategy="random", epoch=0, **kw)
    r1 = sample_sentence_indices(strategy="random", epoch=1, **kw)
    np.testing.assert_array_equal(r0, r1)
    s0 = sample_sentence_indices(strategy="shuffle", epoch=0, **kw)
    s1 = sample_sentence_indices(strategy="shuffle", epoch=1, **kw)
    assert not np.array_equal(s0, s1)
    # deterministic given (worker, epoch, seed)
    np.testing.assert_array_equal(
        s0, sample_sentence_indices(strategy="shuffle", epoch=0, **kw))


def test_workers_draw_distinct_samples():
    kw = dict(num_sentences=5000, rate=0.1, num_workers=10, seed=3, epoch=0)
    a = sample_sentence_indices(strategy="random", worker=0, **kw)
    b = sample_sentence_indices(strategy="random", worker=1, **kw)
    assert not np.array_equal(a, b)


def test_sample_sizes_match_rate():
    idx = sample_sentence_indices(10_000, "random", 0.07, 0, 14, seed=0)
    assert len(idx) == 700


def test_theorem1_unigram_preserved_in_expectation(corpus_and_gen):
    """E[unigram of sample] == corpus unigram (Theorem 1) — check the
    average over sub-corpora is far closer than any single partition."""
    corpus, gen = corpus_and_gen
    V = gen.vocab_size
    ref = unigram_distribution(corpus, V)
    samples = []
    for w in range(10):
        idx = sample_sentence_indices(corpus.num_sentences, "random", 0.1, w, 10,
                                      seed=5)
        samples.append(unigram_distribution(corpus.select(idx), V))
    avg = np.mean(samples, axis=0)
    assert kl_divergence_dense(avg, ref) < 0.01
    mean_single = np.mean([kl_divergence_dense(s, ref) for s in samples])
    assert kl_divergence_dense(avg, ref) < mean_single


def test_fig1_random_sampling_beats_equal_partitioning_on_kl(corpus_and_gen):
    """Paper Fig. 1 comparative claim, on a corpus with topical drift."""
    gen = SemanticCorpusModel.create(vocab_size=600, num_topics=8, seed=2)
    corpus = gen.generate(num_sentences=4000, seed=3)
    # Introduce drift: sort sentences by topic (equal partitioning then
    # slices topic-correlated chunks — its worst case, per the paper).
    V = gen.vocab_size
    ref_u = unigram_distribution(corpus, V)
    ref_b = bigram_distribution(corpus, V)

    def mean_kl(strategy):
        kls_u, kls_b = [], []
        for w in range(8):
            idx = sample_sentence_indices(corpus.num_sentences, strategy, 1 / 8,
                                          w, 8, seed=5)
            sub = corpus.select(idx)
            kls_u.append(kl_divergence_dense(unigram_distribution(sub, V), ref_u))
            kls_b.append(kl_divergence_sparse(bigram_distribution(sub, V), ref_b))
        return np.mean(kls_u), np.mean(kls_b)

    # sort by topic to create drift
    order = np.argsort([corpus.sentence(i)[0] % 8 for i in range(corpus.num_sentences)])
    corpus = corpus.select(np.asarray(order))
    ku_r, kb_r = mean_kl("random")
    ku_e, kb_e = mean_kl("equal")
    assert ku_r < ku_e, (ku_r, ku_e)
    assert kb_r < kb_e, (kb_r, kb_e)


def test_theorem2_threshold_example_from_paper():
    # u = 0.1, ℓ = 100 → ≈ 0.0095 (paper §3.1)
    assert theorem2_threshold(0.1, 100) == pytest.approx(0.0095, rel=0.05)


def test_theorem2_frequent_words_always_covered(corpus_and_gen):
    corpus, gen = corpus_and_gen
    V = gen.vocab_size
    ref = unigram_distribution(corpus, V)
    mean_len = corpus.num_tokens / corpus.num_sentences
    thr = theorem2_threshold(0.1, mean_len)
    frequent = np.where(ref > thr)[0]
    assert len(frequent) > 0
    for w in range(6):
        idx = sample_sentence_indices(corpus.num_sentences, "random", 0.1, w, 10,
                                      seed=9)
        sub_counts = np.bincount(corpus.select(idx).tokens, minlength=V)
        assert (sub_counts[frequent] > 0).all()


def test_coverage_stats(corpus_and_gen):
    corpus, _ = corpus_and_gen
    idxs = [sample_sentence_indices(corpus.num_sentences, "random", 0.2, w, 5,
                                    seed=1) for w in range(5)]
    st = coverage_stats(idxs, corpus.num_sentences)
    assert 0.5 < st["union_coverage"] <= 1.0
