"""Epoch/chunk/step schedule derivation (core.schedule) — the single
source the driver, LR decay and chunk loop all read."""

import numpy as np
import pytest

from repro.core.schedule import EpochSchedule, plan_epoch


def _legacy(min_pairs, batch_size, epochs, steps_per_chunk, cap):
    """The inline derivation plan_epoch replaced (regression oracle)."""
    steps = max(1, min_pairs // batch_size)
    if cap is not None:
        steps = min(steps, cap)
    num_chunks = -(-steps // min(steps_per_chunk, steps))
    chunk_steps = steps // num_chunks
    steps = num_chunks * chunk_steps
    return steps, num_chunks, chunk_steps, steps * epochs


@pytest.mark.parametrize("min_pairs,batch,epochs,spc,cap", [
    (10_000, 512, 3, 128, None),
    (10_000, 512, 3, 128, 10),
    (1_537, 128, 1, 4, 10),        # the driver test's shapes
    (100, 512, 2, 128, None),      # fewer pairs than one batch → 1 step
    (65_536, 64, 5, 7, 999),       # awkward chunk size
    (12_345, 97, 4, 13, 17),
])
def test_matches_legacy_inline_derivation(min_pairs, batch, epochs, spc, cap):
    s = plan_epoch(min_pairs, batch, epochs, spc, max_steps_per_epoch=cap)
    steps, num_chunks, chunk_steps, total = _legacy(
        min_pairs, batch, epochs, spc, cap)
    assert (s.steps_per_epoch, s.num_chunks, s.chunk_steps, s.total_steps) \
        == (steps, num_chunks, chunk_steps, total)


def test_invariants_hold_over_a_sweep():
    rng = np.random.default_rng(0)
    for _ in range(300):
        min_pairs = int(rng.integers(1, 1_000_000))
        batch = int(rng.integers(1, 4096))
        epochs = int(rng.integers(1, 8))
        spc = int(rng.integers(1, 512))
        cap = None if rng.random() < 0.3 else int(rng.integers(1, 2000))
        s = plan_epoch(min_pairs, batch, epochs, spc, max_steps_per_epoch=cap)
        assert s.steps_per_epoch == s.num_chunks * s.chunk_steps
        assert s.chunk_steps <= spc
        assert s.steps_per_epoch >= 1
        assert s.total_steps == s.steps_per_epoch * epochs
        if cap is not None:
            assert s.steps_per_epoch <= cap      # cap is a hard budget


def test_step0_indexing_is_gapless():
    """step0(e, k) walks 0, chunk_steps, 2·chunk_steps, … with no gaps —
    the LR schedule sees every step index exactly once."""
    s = plan_epoch(10_000, 64, 3, 16)
    seen = [s.step0(e, k) + i
            for e in range(s.epochs)
            for k in range(s.num_chunks)
            for i in range(s.chunk_steps)]
    assert seen == list(range(s.total_steps))


def test_total_steps_is_lr_horizon():
    s = EpochSchedule(steps_per_epoch=40, chunk_steps=10, num_chunks=4,
                      epochs=3)
    assert s.total_steps == 120
    assert s.step0(2, 3) == 110


def test_rejects_degenerate_inputs():
    with pytest.raises(ValueError):
        plan_epoch(0, 64, 1, 16)
    with pytest.raises(ValueError):
        plan_epoch(100, 0, 1, 16)
    with pytest.raises(ValueError):
        plan_epoch(100, 64, 0, 16)
    with pytest.raises(ValueError):
        plan_epoch(100, 64, 1, 0)
