"""Serving tier: LRU cache, request coalescing, the embedding server's
equivalence to the merge-phase math, hot reload, and the TCP front end."""

import asyncio
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import merge as mg
from repro.checkpoint import publish_table
from repro.serve import (ArtifactStore, CoalescingBatcher, EmbeddingServer,
                         LRUCache, ServeConfig)
from repro.serve.tcp import request_once, start_tcp_server

V, D, N = 60, 6, 3


def _stacked(V=V, d=D, n=N, seed=0, full=False):
    """Rotated copies of one table with per-model holes (ALiR's model)."""
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(V, d)).astype(np.float32)
    models, masks = [], []
    for i in range(n):
        q, _ = np.linalg.qr(rng.normal(size=(d, d)))
        M = (Y @ q).astype(np.float32)
        mask = np.ones(V, bool) if (i == 0 or full) else rng.random(V) >= 0.3
        mask[: d + 2] = True
        M[~mask] = 0.0
        models.append(M)
        masks.append(mask)
    return mg.stack_models(models, masks)


def _publish(artifact_dir, stacked, word_ids=None, scale=1.0):
    """Batch-merge and publish with every serving sidecar."""
    res = mg.get_merger("alir").merge(stacked)
    Y, valid = res.Y, res.valid
    Y = jnp.asarray(np.asarray(Y) * scale)
    Ws = mg.alir_transforms(stacked, Y)
    publish_table(str(artifact_dir), np.asarray(Y), np.asarray(valid),
                  word_ids=word_ids,
                  worker_ids=np.arange(stacked.n, dtype=np.int32),
                  mask=np.asarray(stacked.mask),
                  transforms=np.asarray(Ws),
                  models=np.asarray(stacked.models))
    return np.asarray(Y), np.asarray(valid)


# --------------------------------------------------------------------- cache
def test_lru_evicts_least_recently_used():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # refresh a → b is now LRU
    c.put("c", 3)
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3
    assert len(c) == 2


def test_lru_hit_rate_and_zero_capacity():
    c = LRUCache(4)
    c.put("k", 7)
    assert c.get("k") == 7 and c.get("x") is None
    assert c.hit_rate == pytest.approx(0.5)
    c.clear()
    assert len(c) == 0 and c.get("k") is None

    off = LRUCache(0)
    off.put("k", 7)
    assert off.get("k") is None and len(off) == 0


# ------------------------------------------------------------------- batcher
def test_batcher_coalesces_and_dedups_one_window():
    calls = []

    def dispatch(keys):
        calls.append(sorted(keys))
        return {k: k * 10 for k in keys}

    async def go():
        b = CoalescingBatcher(dispatch, ServeConfig(coalesce_ms=5.0,
                                                    max_batch=100))
        res = await asyncio.gather(*(b.submit(i % 3) for i in range(9)))
        assert res == [0, 10, 20] * 3
        assert b.requests == 9 and b.dispatches == 1
        s = b.stats()
        assert s["mean_batch"] == 3 and s["max_batch"] == 3

    asyncio.run(go())
    assert calls == [[0, 1, 2]]      # 9 submits → 1 deduped dispatch


def test_batcher_flushes_immediately_at_max_batch():
    def dispatch(keys):
        return {k: k for k in keys}

    async def go():
        b = CoalescingBatcher(dispatch, ServeConfig(coalesce_ms=1000.0,
                                                    max_batch=4))
        # a 1 s window would stall the test — only the cap can flush
        await asyncio.wait_for(
            asyncio.gather(*(b.submit(i) for i in range(8))), timeout=5)
        assert b.dispatches == 2 and b.stats()["max_batch"] == 4

    asyncio.run(go())


def test_batcher_respects_concurrency_semaphore():
    def dispatch(keys):
        time.sleep(0.02)
        return {k: k for k in keys}

    async def go():
        b = CoalescingBatcher(dispatch, ServeConfig(
            coalesce_ms=0.1, max_batch=1, max_concurrency=2,
            dispatch_in_thread=True))
        await asyncio.gather(*(b.submit(i) for i in range(6)))
        s = b.stats()
        assert s["dispatches"] == 6
        assert 1 <= s["max_concurrent_dispatches"] <= 2

    asyncio.run(go())


def test_batcher_rejects_whole_batch_on_dispatch_error():
    def dispatch(keys):
        raise RuntimeError("backend down")

    async def go():
        b = CoalescingBatcher(dispatch, ServeConfig(coalesce_ms=1.0))
        res = await asyncio.gather(b.submit("a"), b.submit("b"),
                                   return_exceptions=True)
        assert all(isinstance(r, RuntimeError) for r in res)
        # the batcher survives the failure: next window works if the
        # backend recovers
        b._dispatch = lambda keys: {k: 1 for k in keys}
        assert await b.submit("a") == 1

    asyncio.run(go())


# -------------------------------------------------------------------- server
def test_server_merged_rows_match_published_table(tmp_path):
    stacked = _stacked()
    Y, valid = _publish(tmp_path, stacked)

    async def go():
        srv = EmbeddingServer(str(tmp_path), ServeConfig(coalesce_ms=0.5))
        out = await srv.embed_rows(np.arange(V))
        np.testing.assert_array_equal(out["found"], valid)
        np.testing.assert_array_equal(out["vectors"][valid],
                                      Y.astype(np.float32)[valid])
        assert out["version"] == 1

    asyncio.run(go())


def test_server_submodel_space_equals_reconstruct_missing(tmp_path):
    """The served sub-model path must reproduce the merge-phase
    ``reconstruct_missing`` — present rows from the sidecar, absent
    rows ``Y @ W_i.T``."""
    stacked = _stacked()
    Y, _ = _publish(tmp_path, stacked)
    rec = np.asarray(mg.reconstruct_missing(stacked, jnp.asarray(Y)))

    async def go():
        srv = EmbeddingServer(str(tmp_path), ServeConfig(coalesce_ms=0.5))
        for w in range(N):
            out = await srv.embed_rows(np.arange(V), submodel=w)
            np.testing.assert_allclose(out["vectors"],
                                       rec[w].astype(np.float32),
                                       rtol=1e-5, atol=1e-5)
        with pytest.raises(KeyError):
            await srv.embed_rows([0], submodel=99)

    asyncio.run(go())


def test_server_serves_bench_oov_knockout_masks(tmp_path):
    """The bench_oov knock-out scenario end to end through the server:
    words masked out of random model subsets are still answerable in
    every sub-model's space."""
    from benchmarks.bench_oov import knock_out
    from repro.data.vocab import Vocab

    base = _stacked(full=True, seed=4)
    vocab = Vocab(word_ids=np.arange(V, dtype=np.int32),
                  counts=np.ones(V, np.int64),
                  lookup=np.arange(V, dtype=np.int32))
    stacked = knock_out(base, vocab, np.arange(V), frac=0.5, seed=1)
    mask = np.asarray(stacked.mask)
    assert not mask.all() and mask.any(axis=0).all()   # holes, full union
    Y, _ = _publish(tmp_path, stacked)
    rec = np.asarray(mg.reconstruct_missing(stacked, jnp.asarray(Y)))

    async def go():
        srv = EmbeddingServer(str(tmp_path), ServeConfig(coalesce_ms=0.5))
        w = int(np.argmax((~mask).sum(axis=1)))        # loss-heaviest model
        out = await srv.embed_rows(np.arange(V), submodel=w)
        assert out["found"].all()                      # nothing unanswerable
        np.testing.assert_allclose(out["vectors"], rec[w].astype(np.float32),
                                   rtol=1e-5, atol=1e-5)

    asyncio.run(go())


def test_server_raw_id_namespace_and_unknown_ids(tmp_path):
    stacked = _stacked()
    word_ids = np.arange(V, dtype=np.int32) * 2        # raw ids: evens
    Y, valid = _publish(tmp_path, stacked, word_ids=word_ids)

    async def go():
        srv = EmbeddingServer(str(tmp_path), ServeConfig(coalesce_ms=0.5))
        out = await srv.embed_ids([0, 4, 3, 10_000, -1])
        np.testing.assert_array_equal(out["found"],
                                      [valid[0], valid[2], False, False,
                                       False])
        np.testing.assert_array_equal(out["vectors"][1], Y[2])
        assert (out["vectors"][2:] == 0).all()         # misses are zeros

    asyncio.run(go())


def test_server_cache_hits_and_hot_reload(tmp_path):
    stacked = _stacked()
    Y1, _ = _publish(tmp_path, stacked)

    async def go():
        srv = EmbeddingServer(str(tmp_path), ServeConfig(coalesce_ms=0.5,
                                                         cache_rows=V))
        await srv.embed_rows(np.arange(V))
        out = await srv.embed_rows(np.arange(V))       # all cached now
        assert srv.stats()["cache_hit_rate"] >= 0.5
        assert srv.refresh() is False                  # nothing newer

        Y2, _ = _publish(tmp_path, stacked, scale=2.0)  # version 2
        assert srv.refresh() is True
        assert srv.store.version == 2 and len(srv.cache) == 0  # cache drop
        out2 = await srv.embed_rows(np.arange(V))
        np.testing.assert_array_equal(out2["vectors"][out2["found"]],
                                      Y2.astype(np.float32)[out2["found"]])
        assert not np.array_equal(out2["vectors"], out["vectors"])

        pinned = EmbeddingServer(ArtifactStore(str(tmp_path), version=1))
        assert pinned.refresh() is False and pinned.store.version == 1

    asyncio.run(go())


# ----------------------------------------------------------------------- tcp
def test_tcp_round_trip_stats_and_errors(tmp_path):
    stacked = _stacked()
    Y, valid = _publish(tmp_path, stacked)

    async def go():
        server = EmbeddingServer(str(tmp_path), ServeConfig(coalesce_ms=0.5))
        srv = await start_tcp_server(server)
        port = srv.sockets[0].getsockname()[1]
        try:
            r = await request_once("127.0.0.1", port, {"rows": [0, 1]})
            assert r["version"] == 1 and len(r["vectors"]) == 2
            np.testing.assert_allclose(r["vectors"][0], Y[0], rtol=1e-6)

            r = await request_once("127.0.0.1", port,
                                   {"rows": [5], "submodel": 0})
            assert r["found"] == [bool(valid[5])]

            s = await request_once("127.0.0.1", port, {"op": "stats"})
            assert s["stats"]["requests"] >= 3

            bad = await request_once("127.0.0.1", port, {"op": "nope"})
            assert "error" in bad
            # a malformed request didn't kill the server
            r = await request_once("127.0.0.1", port, {"op": "refresh"})
            assert r == {"refreshed": False, "version": 1}
        finally:
            srv.close()
            await srv.wait_closed()

    asyncio.run(go())
