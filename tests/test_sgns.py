"""SGNS objective + step functions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sgns
from repro.core.sgns import SGNSConfig


@pytest.fixture(scope="module")
def cfg():
    return SGNSConfig(vocab_size=97, dim=16, negatives=4)


@pytest.fixture(scope="module")
def batch(cfg):
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    B = 32
    centers = jax.random.randint(k1, (B,), 0, cfg.vocab_size)
    contexts = jax.random.randint(k2, (B,), 0, cfg.vocab_size)
    negatives = jax.random.randint(k3, (B, cfg.negatives), 0, cfg.vocab_size)
    return centers, contexts, negatives


def test_init_matches_word2vec(cfg):
    p = sgns.init_params(jax.random.PRNGKey(0), cfg)
    assert p["W"].shape == (cfg.vocab_size, cfg.dim)
    assert float(jnp.abs(p["W"]).max()) <= 0.5 / cfg.dim + 1e-6
    assert float(jnp.abs(p["C"]).max()) == 0.0


def test_loss_at_init_is_log2_times_k_plus_1(cfg, batch):
    # C = 0 ⇒ all logits 0 ⇒ loss = (k+1)·log 2.
    p = sgns.init_params(jax.random.PRNGKey(0), cfg)
    loss = sgns.loss_fn(p, *batch)
    np.testing.assert_allclose(loss, (cfg.negatives + 1) * np.log(2), rtol=1e-5)


def test_sparse_step_matches_dense_step(cfg, batch):
    p0 = sgns.init_params(jax.random.PRNGKey(1), cfg)
    # Make C nonzero so both tables receive real gradients.
    p0 = {"W": p0["W"], "C": 0.01 * jax.random.normal(
        jax.random.PRNGKey(2), p0["C"].shape)}
    lr = jnp.float32(0.05)
    pd, loss_d = sgns.train_step_dense(jax.tree.map(jnp.copy, p0), *batch, lr)
    ps, loss_s = sgns.train_step_sparse(p0, *batch, lr)
    np.testing.assert_allclose(loss_d, loss_s, rtol=1e-5)
    np.testing.assert_allclose(pd["W"], ps["W"], atol=1e-6)
    np.testing.assert_allclose(pd["C"], ps["C"], atol=1e-6)


def test_duplicate_indices_accumulate(cfg):
    """Same center repeated in a batch must accumulate updates (scatter-add)."""
    p = sgns.init_params(jax.random.PRNGKey(1), cfg)
    p = {"W": p["W"], "C": 0.01 * jnp.ones_like(p["C"])}
    centers = jnp.array([3, 3, 3, 3])
    contexts = jnp.array([5, 5, 5, 5])
    negs = jnp.full((4, cfg.negatives), 7)
    ps, _ = sgns.train_step_sparse(jax.tree.map(jnp.copy, p), centers, contexts,
                                   negs, jnp.float32(0.1))
    pd, _ = sgns.train_step_dense(jax.tree.map(jnp.copy, p), centers, contexts,
                                  negs, jnp.float32(0.1))
    np.testing.assert_allclose(ps["W"], pd["W"], atol=1e-6)
    np.testing.assert_allclose(ps["C"], pd["C"], atol=1e-6)
    # Rows other than 3 unchanged in W.
    mask = jnp.ones(cfg.vocab_size, bool).at[3].set(False)
    np.testing.assert_allclose(ps["W"][mask], p["W"][mask])


def test_training_reduces_loss(cfg):
    """A few hundred steps on a tiny structured problem reduce the loss."""
    rng = np.random.default_rng(0)
    p = sgns.init_params(jax.random.PRNGKey(3), cfg)
    B = 64
    lr = jnp.float32(0.05)
    first = last = None
    for i in range(200):
        c = rng.integers(0, 20, size=B).astype(np.int32)
        x = ((c + rng.integers(1, 3, size=B)) % 20).astype(np.int32)  # structured
        n = rng.integers(40, 97, size=(B, cfg.negatives)).astype(np.int32)
        p, loss = sgns.train_step_sparse(p, jnp.asarray(c), jnp.asarray(x),
                                         jnp.asarray(n), lr)
        if i == 0:
            first = float(loss)
        last = float(loss)
    assert last < first * 0.8, (first, last)


def test_linear_lr_decay(cfg):
    assert float(sgns.linear_lr(jnp.int32(0), 100, cfg)) == pytest.approx(cfg.lr)
    mid = float(sgns.linear_lr(jnp.int32(50), 100, cfg))
    assert mid == pytest.approx(cfg.lr * 0.5, rel=1e-3)
    assert float(sgns.linear_lr(jnp.int32(1000), 100, cfg)) == pytest.approx(cfg.lr_min)
