"""Streaming pair pipeline: chunk determinism, equivalence with the
materialized path, wrap-around, and prefetch behaviour."""

import time

import numpy as np
import pytest

from repro.data.corpus import SemanticCorpusModel
from repro.data.pipeline import (
    PairChunkStream, make_worker_streams, prefetch_chunks,
    stacked_pair_batches)
from repro.data.vocab import build_vocab


@pytest.fixture(scope="module")
def streams():
    gen = SemanticCorpusModel.create(vocab_size=400, seed=0)
    corpus = gen.generate(num_sentences=1500, seed=1)
    vocab = build_vocab(corpus, 400, min_count=1, max_size=None)
    return make_worker_streams(corpus, vocab, num_workers=3, strategy="shuffle",
                               window=4, seed=9)


def test_chunks_have_fixed_shape(streams):
    st = PairChunkStream(streams, batch_size=32, steps_per_chunk=4,
                         sentences_per_block=128)
    for c, x in st.chunks(epoch=0, num_chunks=3):
        assert c.shape == x.shape == (3, 4, 32)
        assert c.dtype == x.dtype == np.int32


def test_stream_is_deterministic(streams):
    st = PairChunkStream(streams, batch_size=32, steps_per_chunk=4,
                         sentences_per_block=128)
    a = list(st.chunks(epoch=1, num_chunks=4))
    b = list(st.chunks(epoch=1, num_chunks=4))
    for (c1, x1), (c2, x2) in zip(a, b):
        np.testing.assert_array_equal(c1, c2)
        np.testing.assert_array_equal(x1, x2)
    # a different epoch draws a different (shuffle) sample
    c3, _ = next(st.chunks(epoch=2, num_chunks=1))
    assert not np.array_equal(a[0][0], c3)


def test_stream_matches_materialized_path(streams):
    """Same seed ⇒ the streamed chunks concatenate to exactly the batches
    the materialized path produces (it is a one-chunk view of the same
    stream), including the wrap-around region."""
    B, S, K = 64, 8, 6
    st = PairChunkStream(streams, batch_size=B, steps_per_chunk=S,
                         sentences_per_block=256)
    cs, xs = zip(*st.chunks(epoch=0, num_chunks=K))
    streamed_c = np.concatenate(cs, axis=1)
    streamed_x = np.concatenate(xs, axis=1)
    # one chunk covering the whole request == K chunks, concatenated
    mat = PairChunkStream(streams, batch_size=B, steps_per_chunk=S * K,
                          sentences_per_block=256)
    mat_c, mat_x = next(mat.chunks(epoch=0, num_chunks=1))
    np.testing.assert_array_equal(streamed_c, mat_c)
    np.testing.assert_array_equal(streamed_x, mat_x)
    # and stacked_pair_batches is exactly that one-chunk view (at its
    # default block size)
    spb_c, spb_x = stacked_pair_batches(streams, epoch=0, batch_size=B,
                                        num_batches=S * K)
    dflt = PairChunkStream(streams, batch_size=B, steps_per_chunk=S * K)
    dflt_c, dflt_x = next(dflt.chunks(epoch=0, num_chunks=1))
    np.testing.assert_array_equal(spb_c, dflt_c)
    np.testing.assert_array_equal(spb_x, dflt_x)


def test_wraparound_replays_epoch(streams):
    """Requesting more pairs than an epoch holds wraps deterministically —
    the old np.tile semantics, without materializing anything."""
    n_pairs = min(s.count_pairs(0, sentences_per_block=256) for s in streams)
    B = 64
    S = (n_pairs // B) + 4     # guaranteed past the wrap point
    st = PairChunkStream(streams, batch_size=B, steps_per_chunk=S,
                         sentences_per_block=256)
    c, _ = next(st.chunks(epoch=0, num_chunks=1))
    flat = c.reshape(3, -1)
    per_epoch = [s.count_pairs(0, sentences_per_block=256) for s in streams]
    for w in range(3):
        wrap = per_epoch[w]
        if wrap < flat.shape[1]:
            tail = min(flat.shape[1] - wrap, wrap)
            np.testing.assert_array_equal(flat[w, wrap:wrap + tail],
                                          flat[w, :tail])


def test_empty_sample_raises():
    gen = SemanticCorpusModel.create(vocab_size=50, seed=0)
    corpus = gen.generate(num_sentences=40, seed=1)
    vocab = build_vocab(corpus, 50, min_count=1, max_size=None)
    streams = make_worker_streams(corpus, vocab, num_workers=2,
                                  strategy="random", window=2, seed=0,
                                  subsample_t=1e-12)  # drop ~everything
    st = PairChunkStream(streams, batch_size=64, steps_per_chunk=4)
    with pytest.raises(ValueError, match="empty sample"):
        next(st.chunks(epoch=0, num_chunks=1))


def test_count_pairs_matches_block_stream(streams):
    s = streams[0]
    total = sum(len(c) for c, _ in s.pair_blocks(0, sentences_per_block=200))
    assert s.count_pairs(0, sentences_per_block=200) == total
    assert total > 0


# ----------------------------------------------------------------- prefetch
def test_prefetch_preserves_order_and_values(streams):
    st = PairChunkStream(streams, batch_size=32, steps_per_chunk=4,
                         sentences_per_block=128)
    direct = list(st.chunks(epoch=0, num_chunks=5))
    fetched = list(prefetch_chunks(st.chunks(epoch=0, num_chunks=5), depth=2))
    assert len(fetched) == 5
    for (dc, dx), (fc, fx) in zip(direct, fetched):
        np.testing.assert_array_equal(dc, np.asarray(fc))
        np.testing.assert_array_equal(dx, np.asarray(fx))


def test_prefetch_propagates_errors():
    def boom():
        yield (np.zeros((1, 1, 1), np.int32),) * 2
        raise ValueError("exploded mid-stream")

    it = prefetch_chunks(boom(), depth=2, to_device=False)
    next(it)
    with pytest.raises(ValueError, match="exploded mid-stream"):
        next(it)


def test_prefetch_producer_exits_when_consumer_abandons():
    """Closing the generator mid-stream must release the producer thread
    (it would otherwise block forever on the bounded queue)."""
    import threading

    def source():
        for i in range(1000):
            yield (np.zeros((1, 1, 1), np.int32),) * 2

    it = prefetch_chunks(source(), depth=2, to_device=False)
    next(it)
    it.close()
    deadline = time.time() + 5.0
    while (any(t.name == "prefetch_chunks" and t.is_alive()
               for t in threading.enumerate())
           and time.time() < deadline):
        time.sleep(0.02)
    assert not any(t.name == "prefetch_chunks" and t.is_alive()
                   for t in threading.enumerate())


def test_prefetch_rejects_bad_depth():
    with pytest.raises(ValueError):
        next(prefetch_chunks(iter([]), depth=0))


def test_prefetch_rejects_bad_depth_eagerly():
    """Depth validation happens at call time, not first-next() time —
    a misconfigured pipeline fails where it was built, and no producer
    thread is ever spawned for it."""
    with pytest.raises(ValueError, match="depth"):
        prefetch_chunks(iter([]), depth=0)


def test_prefetch_error_delivered_even_when_queue_full():
    """Producer raises while the bounded queue is full and the consumer
    is slow: the exception must still arrive after the buffered chunks
    (the old failure mode was a producer blocked on put() forever)."""

    def source():
        for i in range(3):
            yield (np.full((1, 1, 1), i, np.int32),) * 2
        raise ValueError("died with a full queue")

    it = prefetch_chunks(source(), depth=1, to_device=False)
    got = []
    with pytest.raises(ValueError, match="died with a full queue"):
        for c, _ in it:
            time.sleep(0.1)            # let the producer hit the bound
            got.append(int(np.asarray(c).ravel()[0]))
    assert got == [0, 1, 2]            # no buffered chunk lost


def test_prefetch_consumer_exception_joins_producer():
    """A consumer that raises out of the loop (not just close()) must
    also reap the producer thread."""
    import threading

    def source():
        while True:
            yield (np.zeros((1, 1, 1), np.int32),) * 2

    def consume():
        for _ in prefetch_chunks(source(), depth=2, to_device=False):
            raise RuntimeError("consumer bug")

    with pytest.raises(RuntimeError, match="consumer bug"):
        consume()
    deadline = time.time() + 5.0
    while (any(t.name == "prefetch_chunks" and t.is_alive()
               for t in threading.enumerate())
           and time.time() < deadline):
        time.sleep(0.02)
    assert not any(t.name == "prefetch_chunks" and t.is_alive()
                   for t in threading.enumerate())


def test_prefetch_overlaps_producer_with_consumer():
    """Smoke test for the double buffering: while the consumer sits on the
    first chunk, the producer runs ahead and fills the queue."""
    produced = []

    def source():
        for i in range(4):
            produced.append(i)
            yield (np.full((1, 1, 1), i, np.int32),) * 2

    it = prefetch_chunks(source(), depth=2, to_device=False)
    first = next(it)
    deadline = time.time() + 5.0
    # depth-2 queue + the producer's in-flight item ⇒ ≥ 3 produced while
    # the consumer holds chunk 0
    while len(produced) < 3 and time.time() < deadline:
        time.sleep(0.01)
    assert len(produced) >= 3, produced
    rest = list(it)
    assert int(np.asarray(first[0]).ravel()[0]) == 0
    assert [int(np.asarray(c).ravel()[0]) for c, _ in rest] == [1, 2, 3]


# ------------------------------------------------------------ driver smoke
def test_driver_streams_without_epoch_materialization(monkeypatch):
    """train_submodels goes through PairChunkStream (WorkerStream.pairs —
    the materializing path — is never called) and trains to finite loss."""
    import repro.data.pipeline as pl
    from repro.core.driver import train_submodels
    from repro.core.sgns import SGNSConfig

    def forbidden(self, epoch, max_pairs=None):
        raise AssertionError("materializing WorkerStream.pairs was called")

    monkeypatch.setattr(pl.WorkerStream, "pairs", forbidden)
    gen = SemanticCorpusModel.create(vocab_size=300, seed=0)
    corpus = gen.generate(num_sentences=1200, seed=1)
    res = train_submodels(
        corpus, 300, strategy="shuffle", num_workers=2,
        cfg=SGNSConfig(vocab_size=0, dim=16, window=3, negatives=2),
        epochs=2, batch_size=128, window=3, max_vocab=None,
        max_steps_per_epoch=12, steps_per_chunk=4, engine="sparse:alias")
    assert len(res.losses) == 2
    assert np.isfinite(res.losses).all()
    assert res.timings["steps_per_epoch"] % 4 == 0


def test_driver_never_exceeds_max_steps_per_epoch():
    """Chunk rounding shrinks the chunk rather than overshooting the cap."""
    from repro.core.driver import train_submodels
    from repro.core.sgns import SGNSConfig

    gen = SemanticCorpusModel.create(vocab_size=300, seed=0)
    corpus = gen.generate(num_sentences=1200, seed=1)
    res = train_submodels(
        corpus, 300, strategy="shuffle", num_workers=2,
        cfg=SGNSConfig(vocab_size=0, dim=8, window=3, negatives=2),
        epochs=1, batch_size=128, window=3, max_vocab=None,
        max_steps_per_epoch=10, steps_per_chunk=4)
    # cap 10, chunk 4 → 3 chunks of 3 steps = 9 ≤ 10, never 12
    assert res.timings["steps_per_epoch"] <= 10
