"""End-to-end behaviour tests for the paper's system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.driver import run_pipeline, train_sync_baseline
from repro.core.sgns import SGNSConfig
from repro.core.async_trainer import (
    AsyncShardTrainer, assert_no_collectives, count_collective_ops)
from repro.data.corpus import SemanticCorpusModel
from repro.eval.benchmarks import BenchmarkSuite, evaluate_all


@pytest.fixture(scope="module")
def world():
    gen = SemanticCorpusModel.create(vocab_size=1000, seed=0)
    corpus = gen.generate(num_sentences=10_000, seed=1)
    suite = BenchmarkSuite.from_model(gen, top_words=700)
    return gen, corpus, suite


def test_full_pipeline_learns_semantics(world):
    """Divide→train→merge beats chance on all three task families and
    ALiR beats naive averaging (the paper's central claims, small)."""
    gen, corpus, suite = world
    cfg = SGNSConfig(vocab_size=0, dim=48, window=5, negatives=5)
    res = run_pipeline(corpus, 1000, strategy="shuffle", num_workers=4,
                       cfg=cfg, epochs=5, batch_size=512, window=5,
                       max_vocab=None,
                       merge_methods=("alir_pca", "average"))
    emb, valid = res.merged["alir_pca"]
    s = evaluate_all(emb, valid, res.union_vocab, suite)
    assert s["similarity"] > 0.05, s
    assert s["categorization"] > 0.15, s     # 16 topics → chance ≈ 0.10
    # training actually converged
    assert res.losses[-1] < res.losses[0] * 0.8
    emb_a, valid_a = res.merged["average"]
    s_avg = evaluate_all(emb_a, valid_a, res.union_vocab, suite)
    assert s["similarity"] >= s_avg["similarity"] - 0.02


def test_async_epoch_has_zero_collectives():
    """The paper's headline property, asserted on lowered HLO: the async
    train phase contains no cross-device collective at all."""
    mesh = jax.make_mesh((1,), ("worker",))
    cfg = SGNSConfig(vocab_size=256, dim=32, negatives=2)
    tr = AsyncShardTrainer(cfg=cfg, num_workers=1, total_steps=4,
                           backend="shard_map", mesh=mesh)
    lowered = tr.lower_epoch(steps=4, batch=64)
    txt = assert_no_collectives(lowered)          # raises on any collective
    assert count_collective_ops(txt) == {}


def test_sync_baseline_trains(world):
    gen, corpus, _ = world
    cfg = SGNSConfig(vocab_size=0, dim=32, window=5, negatives=5)
    params, vocab, info = train_sync_baseline(
        corpus, 1000, cfg, epochs=2, batch_size=512, window=5,
        max_vocab=None, max_steps_per_epoch=200)
    assert info["losses"][-1] < info["losses"][0]
    assert np.isfinite(np.asarray(params["W"])).all()


def test_pipeline_merge_union_covers_benchmarks(world):
    """Random sampling w/ per-worker vocab: union vocab recovers nearly
    all frequent words even when single sub-models miss them."""
    gen, corpus, suite = world
    cfg = SGNSConfig(vocab_size=0, dim=32, window=5, negatives=3)
    res = run_pipeline(corpus, 1000, strategy="random", num_workers=5,
                       cfg=cfg, epochs=2, batch_size=512, window=5,
                       max_vocab=None, base_min_count=25,
                       merge_methods=("alir_pca",),
                       max_steps_per_epoch=60)
    mask = np.asarray(res.stacked.mask)
    union = mask.any(0).sum()
    single = mask.sum(1).mean()
    assert union >= single  # union ≥ any single model
    emb, valid = res.merged["alir_pca"]
    assert int(np.asarray(valid).sum()) == union
