"""End-to-end behaviour tests for the paper's system."""

import numpy as np
import pytest

from repro.core.driver import run_pipeline, train_sync_baseline
from repro.core.sgns import SGNSConfig
from repro.data.corpus import SemanticCorpusModel
from repro.eval.benchmarks import BenchmarkSuite, evaluate_all


@pytest.fixture(scope="module")
def world():
    gen = SemanticCorpusModel.create(vocab_size=1000, seed=0)
    corpus = gen.generate(num_sentences=10_000, seed=1)
    suite = BenchmarkSuite.from_model(gen, top_words=700)
    return gen, corpus, suite


def test_full_pipeline_learns_semantics(world):
    """Divide→train→merge beats chance on all three task families and
    ALiR beats naive averaging (the paper's central claims, small)."""
    gen, corpus, suite = world
    cfg = SGNSConfig(vocab_size=0, dim=48, window=5, negatives=5)
    res = run_pipeline(corpus, 1000, strategy="shuffle", num_workers=4,
                       cfg=cfg, epochs=5, batch_size=512, window=5,
                       max_vocab=None,
                       merge_methods=("alir_pca", "average"))
    emb, valid = res.merged["alir_pca"]
    s = evaluate_all(emb, valid, res.union_vocab, suite)
    assert s["similarity"] > 0.05, s
    assert s["categorization"] > 0.15, s     # 16 topics → chance ≈ 0.10
    # training actually converged
    assert res.losses[-1] < res.losses[0] * 0.8
    emb_a, valid_a = res.merged["average"]
    s_avg = evaluate_all(emb_a, valid_a, res.union_vocab, suite)
    assert s["similarity"] >= s_avg["similarity"] - 0.02


# (The zero-collective assertions live in tests/test_engine.py as one
# parametrized matrix over every engine × sampler.)
def test_sync_baseline_trains(world):
    gen, corpus, _ = world
    cfg = SGNSConfig(vocab_size=0, dim=32, window=5, negatives=5)
    params, vocab, info = train_sync_baseline(
        corpus, 1000, cfg, epochs=2, batch_size=512, window=5,
        max_vocab=None, max_steps_per_epoch=200)
    assert info["losses"][-1] < info["losses"][0]
    assert np.isfinite(np.asarray(params["W"])).all()


def test_epoch_keys_distinct_across_seed_epoch_pairs():
    """Regression: the old arithmetic seeds (seed*1000+epoch etc.)
    collide — e.g. (seed=1, epoch=1000) and (seed=2, epoch=0) shared a
    PRNG stream. fold_in chains must give pairwise-distinct keys over a
    (seed, stream, epoch) grid, including the old collision pairs."""
    from repro.core.driver import _epoch_key, _epoch_rng

    # the documented collisions of the old scheme
    a = _epoch_key(1, 0, 1000)
    b = _epoch_key(2, 0, 0)
    assert not np.array_equal(np.asarray(a), np.asarray(b))

    seen = set()
    for seed in (0, 1, 2, 31, 77):
        for stream in (0, 1, 2):
            for epoch in (0, 1, 2, 77, 1000):
                seen.add(tuple(np.asarray(_epoch_key(seed, stream, epoch))))
    assert len(seen) == 5 * 3 * 5
    # numpy side: distinct first draws for the old-collision pairs
    r1 = _epoch_rng(1, 2, 77).integers(0, 2**63, 8)
    r2 = _epoch_rng(2, 2, 0).integers(0, 2**63, 8)
    assert not np.array_equal(r1, r2)


def test_numpy_seed_namespaces_are_disjoint():
    """The driver's epoch streams, the pipeline's whole-epoch extraction
    streams and its per-block streams must never alias — including the
    two traps SeedSequence sets: a stream tag equal to a worker index,
    and trailing-zero absorption making (…, e) == (…, e, 0)."""
    from repro.core.driver import _epoch_rng
    from repro.data.pipeline import _extract_seed

    def first(ss):
        return tuple(np.random.default_rng(ss).integers(0, 2**63, 4))

    seen = {tuple(_epoch_rng(0, stream, 1).integers(0, 2**63, 4))
            for stream in (0, 1, 2)}
    # driver stream 2 vs pipeline worker 2, same (seed, epoch)
    seen.add(first(_extract_seed(0, 2, 1)))
    # whole-epoch vs block-0 of the same (seed, worker, epoch)
    seen.add(first(_extract_seed(0, 1, 2)))
    seen.add(first(_extract_seed(0, 1, 2, block=0)))
    seen.add(first(_extract_seed(0, 1, 2, block=1)))
    assert len(seen) == 7


def test_tiled_permutation_reshuffles_each_tile():
    """Regression: a corpus smaller than one batch used to tile the SAME
    permutation verbatim — every pass replayed pairs in identical order."""
    from repro.core.driver import _tiled_permutation

    rng = np.random.default_rng(0)
    n, need = 40, 200
    perm = _tiled_permutation(rng, n, need)
    assert perm.shape == (need,)
    tiles = perm.reshape(need // n, n)
    for t in tiles:                       # each tile is a full epoch pass
        np.testing.assert_array_equal(np.sort(t), np.arange(n))
    assert any(not np.array_equal(tiles[0], t) for t in tiles[1:])
    # the no-tiling fast path still subsamples a single permutation
    short = _tiled_permutation(np.random.default_rng(1), 100, 60)
    assert short.shape == (60,) and len(set(short)) == 60


def test_sync_baseline_tiny_corpus_trains():
    """Corpus far smaller than one batch: the baseline must still train
    (tiles reshuffled, losses finite and improving on average)."""
    gen = SemanticCorpusModel.create(vocab_size=120, seed=4)
    tiny = gen.generate(num_sentences=40, seed=5)
    cfg = SGNSConfig(vocab_size=0, dim=16, window=3, negatives=3)
    params, vocab, info = train_sync_baseline(
        tiny, 120, cfg, epochs=3, batch_size=256, window=3, max_vocab=None)
    assert np.isfinite(np.asarray(params["W"])).all()
    assert np.isfinite(info["losses"]).all()
    assert info["losses"][-1] < info["losses"][0]


def test_pipeline_merge_union_covers_benchmarks(world):
    """Random sampling w/ per-worker vocab: union vocab recovers nearly
    all frequent words even when single sub-models miss them."""
    gen, corpus, suite = world
    cfg = SGNSConfig(vocab_size=0, dim=32, window=5, negatives=3)
    res = run_pipeline(corpus, 1000, strategy="random", num_workers=5,
                       cfg=cfg, epochs=2, batch_size=512, window=5,
                       max_vocab=None, base_min_count=25,
                       merge_methods=("alir_pca",),
                       max_steps_per_epoch=60)
    mask = np.asarray(res.stacked.mask)
    union = mask.any(0).sum()
    single = mask.sum(1).mean()
    assert union >= single  # union ≥ any single model
    emb, valid = res.merged["alir_pca"]
    assert int(np.asarray(valid).sum()) == union
